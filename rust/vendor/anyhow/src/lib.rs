//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! Vendored (like the JSON / RNG / CLI substrates under `util/`) so the
//! tier-1 build runs with zero registry access. API-compatible with the
//! subset this repo uses:
//!
//! * [`Error`] — a context chain of messages; `Display` prints the
//!   outermost message, `{:#}` the full `outer: ...: root` chain, and
//!   `Debug` (what `fn main() -> Result<()>` prints on exit) the
//!   message plus a `Caused by:` list.
//! * [`Result<T>`] with the error type defaulted.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (any std error, or an existing [`Error`]) and on `Option`.
//! * [`anyhow!`] / [`bail!`] macros.
//!
//! Source chains are flattened to strings eagerly, which keeps `Error`
//! trivially `Send + Sync` (the serving path moves errors across
//! threads) at the cost of downcasting — nothing in-tree downcasts.

use std::fmt;

/// Error: a non-empty chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a single message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket impls below coherent (same design as the
// real crate).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Anything convertible into [`Error`]: std errors and `Error` itself.
pub trait ToError {
    fn to_error(self) -> Error;
}

impl ToError for Error {
    fn to_error(self) -> Error {
        self
    }
}

impl<E> ToError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn to_error(self) -> Error {
        Error::from(self)
    }
}

/// Context attachment for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T>;
}

impl<T, E: ToError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Ok(t) => Ok(t),
            Err(e) => Err(e.to_error().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        match self {
            Ok(t) => Ok(t),
            Err(e) => Err(e.to_error().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
                                                       -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest x — run `make artifacts`")
            .unwrap_err();
        assert_eq!(format!("{e}"),
                   "reading manifest x — run `make artifacts`");
        let full = format!("{e:#}");
        assert!(full.contains("make artifacts") && full.contains("gone"),
                "{full}");
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::msg("root").context("outer");
        let d = format!("{e:?}");
        assert!(d.starts_with("outer") && d.contains("Caused by")
                && d.contains("root"), "{d}");
    }

    #[test]
    fn option_context_and_macros() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.context("missing")?;
            if v == 0 {
                bail!("zero: {v}");
            }
            Ok(v)
        }
        assert_eq!(f(Some(3)).unwrap(), 3);
        assert_eq!(format!("{}", f(None).unwrap_err()), "missing");
        assert_eq!(format!("{}", f(Some(0)).unwrap_err()), "zero: 0");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(format!("{from_string}"), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
