//! Telemetry end-to-end over the serving path: the trace invariant
//! (every landed plan-swap span is preceded by a drift check that
//! came up due) and the live-stats contract (a `ServerMsg::Stats`
//! snapshot agrees with the shutdown `ServeStats` and with the
//! resident session's own counters).
//!
//! One test on purpose: the event tracer is process-global, and
//! keeping a single server in this binary means every
//! `serve.plan_swap` span in the collected trace belongs to it.

use std::path::PathBuf;
use std::time::Instant;

use repro::coordinator::{self, BatchPolicy, Resident, ScoreResponse,
                         SwapPolicy};
use repro::datasets::{self, Dataset};
use repro::incremental::{DriftPolicy, GraphDelta};
use repro::obs::trace::{self, KIND_INSTANT, KIND_SPAN};
use repro::obs::StatsSnapshot;
use repro::session::{LowerSpec, Session};
use repro::util::Rng;

/// Artifacts dir that does not exist: forces the reference executor.
fn no_artifacts() -> PathBuf {
    std::env::temp_dir().join("repro-obs-telemetry-no-artifacts")
}

fn send_score(server: &coordinator::InferenceServer, node: u32,
              features: Vec<f32>) -> ScoreResponse {
    let (otx, orx) = coordinator::server::oneshot();
    server.client()
        .send(coordinator::ServerMsg::Score(coordinator::ScoreRequest {
            node,
            features,
            reply: otx,
            submitted: Instant::now(),
            pin_epoch: None,
        }))
        .expect("queue open");
    orx.recv().expect("batcher alive")
}

/// Blocking update: the reply is sent at flush time, so when this
/// returns the delta has been applied AND the post-flush drift/swap
/// check has run — no pending work is left to move the counters
/// between the final snapshot and shutdown.
fn send_update(server: &coordinator::InferenceServer, delta: GraphDelta) {
    let (otx, orx) = coordinator::server::update_oneshot();
    server.client()
        .send(coordinator::ServerMsg::Update(
            coordinator::UpdateRequest {
                delta,
                reply: Some(otx),
                submitted: Instant::now(),
            }))
        .expect("queue open");
    orx.recv().expect("batcher alive");
}

fn stats_snapshot(server: &coordinator::InferenceServer)
                  -> StatsSnapshot {
    let (stx, srx) = coordinator::server::stats_oneshot();
    server.client()
        .send(coordinator::ServerMsg::Stats(
            coordinator::StatsRequest { reply: stx }))
        .expect("queue open");
    srx.recv().expect("batcher alive")
}

#[test]
fn plan_swaps_trace_due_drift_checks_and_stats_agree() {
    trace::set_enabled(true);
    let ds: Dataset = datasets::load("BZR", 0.02, 7);
    // Negative threshold: every flush is due, so swaps land whenever
    // the re-plan produces a genuinely new plan.
    let spec = LowerSpec::default().with_shards(4).with_drift(
        DriftPolicy::default().with_threshold(-1.0));
    // Localize updates to shard 0 (deterministic partition seed =>
    // an identically specced probe session has the same shard map).
    let probe = Session::new(&ds, spec.clone());
    let members: Vec<u32> = (0..ds.n() as u32)
        .filter(|&v| probe.shard_of(v) == 0)
        .collect();
    assert!(members.len() >= 2, "shard 0 too small to localize");
    let mut session = Session::new(&ds, spec);
    let lowered = session.lower().unwrap();
    let resident = Some(Resident::new(
        session, &ds.graph, &lowered.hag,
        SwapPolicy { swap_plans: true, max_pending: 4 }));
    let server = coordinator::InferenceServer::for_lowered(
        no_artifacts(), "gcn", &ds, &lowered, BatchPolicy::default(),
        7, resident).unwrap();

    let mut rng = Rng::seed_from_u64(23);
    let mut scored = 0usize;
    for i in 0..48usize {
        let a = members[rng.range_usize(0, members.len())];
        let b = members[rng.range_usize(0, members.len())];
        if a == b {
            continue;
        }
        send_update(&server, GraphDelta::EdgeInsert { src: a, dst: b });
        if i % 6 == 0 {
            let node = rng.range_u32(0, ds.n() as u32);
            send_score(&server, node, vec![0.5; ds.f_in])
                .into_result().expect("scored");
            scored += 1;
        }
    }

    // Live snapshot over the same queue the traffic uses. Taken while
    // the server is up; nothing scores or flushes afterwards, so it
    // must agree exactly with the shutdown stats.
    let snap = stats_snapshot(&server);
    let out = server.shutdown_outcome();
    let stats = &out.stats;
    assert!(stats.plan_swaps >= 1, "drift must swap: {stats:?}");

    // Snapshot vs shutdown ServeStats: counts and percentiles come
    // from the same registry, through two different views.
    assert_eq!(snap.counter("serve.requests") as usize, stats.requests);
    assert_eq!(stats.requests, scored);
    assert_eq!(snap.counter("serve.plan_swaps") as usize,
               stats.plan_swaps);
    assert_eq!(snap.counter("serve.updates") as usize, stats.updates);
    let h = snap.hist("serve.latency").expect("latency histogram");
    assert_eq!(h.count as usize, stats.requests);
    assert!((h.p50_ns / 1.0e6 - stats.p50_ms).abs() < 1e-6,
            "snapshot p50 {} ns vs ServeStats {} ms",
            h.p50_ns, stats.p50_ms);
    assert!((h.p99_ns / 1.0e6 - stats.p99_ms).abs() < 1e-6,
            "snapshot p99 {} ns vs ServeStats {} ms",
            h.p99_ns, stats.p99_ms);

    // Snapshot vs the session's own counters (published as gauges by
    // the Stats handler from the resident pair).
    let res = out.resident.expect("resident handed back");
    assert_eq!(snap.gauge("session.shard_cache_hits"),
               res.session.stats().shard_cache_hits as i64);
    assert_eq!(snap.gauge("session.shard_searches"),
               res.session.stats().shard_searches as i64);
    assert_eq!(snap.gauge("incr.applied"),
               res.engine.stats().applied as i64);

    // Trace invariant: a `serve.plan_swap` span only exists for a
    // swap that actually landed, and every one is preceded on its
    // thread by a drift check that came up due (a == 1).
    let events = trace::collect();
    let swaps: Vec<_> = events.iter()
        .filter(|e| e.name == "serve.plan_swap" && e.kind == KIND_SPAN)
        .collect();
    assert!(!swaps.is_empty(), "landed swaps must leave spans");
    assert!(swaps.len() <= stats.plan_swaps,
            "{} plan_swap spans but only {} landed swaps",
            swaps.len(), stats.plan_swaps);
    for sw in &swaps {
        let preceded = events.iter().any(|e| {
            e.name == "serve.drift_check"
                && e.kind == KIND_INSTANT
                && e.tid == sw.tid
                && e.a == 1
                && e.ts_us <= sw.ts_us
        });
        assert!(preceded,
                "plan_swap span at {} us on tid {} lacks a preceding \
                 due drift check",
                sw.ts_us, sw.tid);
    }
}
