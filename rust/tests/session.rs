//! Session-subsystem tests: plan-cache correctness under randomized
//! delta sequences (ISSUE-3 satellite — cached dirty-shard re-planning
//! must be *identical* to a from-scratch `build_plan` on the
//! maintained HAG), the capacity end-to-end round-trip through
//! `buckets.json`, the golden byte-identity of default-spec buckets
//! against the primitive search→plan→bucket pipeline (the aot.py
//! contract), and cache-hit observability under localized streams.
//!
//! Same convention as `properties.rs` / `incremental.rs`: cases are
//! seeded and deterministic; failures print the case they came from.

use repro::coordinator::{bucket_for, write_buckets_json, Repr};
use repro::datasets::{self, community_graph, CommunityCfg};
use repro::graph::Graph;
use repro::hag::{build_plan, check_equivalence, hag_search,
                 AggregateKind, ExecutionPlan, Hag, PlanConfig,
                 SearchConfig};
use repro::incremental::{random_delta, GraphDelta, OverlayGraph};
use repro::runtime::BucketSpec;
use repro::session::{emit_buckets, LowerSpec, Session};
use repro::util::Rng;

fn community(n: usize, e: usize, seed: u64) -> Graph {
    let cfg = CommunityCfg {
        n,
        e,
        communities: (n / 125).max(4),
        intra_frac: 0.9,
        zipf_exp: 0.9,
        clone_frac: 0.5,
    };
    community_graph(&cfg, seed).0
}

/// `cliques` directed K_`size` blocks joined into a ring — clean shard
/// structure for cache-hit assertions.
fn clique_ring(cliques: usize, size: usize) -> Graph {
    let n = cliques * size;
    let mut edges = Vec::new();
    for c in 0..cliques {
        let b = (c * size) as u32;
        for i in 0..size as u32 {
            for j in 0..size as u32 {
                if i != j {
                    edges.push((b + i, b + j));
                }
            }
        }
        let nxt = (((c + 1) % cliques) * size) as u32;
        edges.push((b, nxt));
    }
    Graph::from_edges(n, &edges)
}

fn assert_plans_identical(case: &str, hag_c: &Hag, plan_c: &ExecutionPlan,
                          hag_f: &Hag, plan_f: &ExecutionPlan) {
    assert!(hag_c == hag_f,
            "{case}: cached HAG != from-scratch HAG \
             (cost {} vs {}, |V_A| {} vs {})",
            hag_c.cost_core(), hag_f.cost_core(),
            hag_c.agg_nodes.len(), hag_f.agg_nodes.len());
    assert!(plan_c == plan_f,
            "{case}: cached plan != from-scratch plan \
             (levels {} vs {}, l_pad {} vs {}, bands {:?} vs {:?})",
            plan_c.levels, plan_f.levels, plan_c.l_pad, plan_f.l_pad,
            plan_c.bands, plan_f.bands);
}

/// ISSUE-3 satellite: after a randomized delta sequence with periodic
/// re-planning, dirty-shard-only re-planning produces a plan identical
/// (level/band structure and index tensors) to a from-scratch search
/// of every shard on the maintained graph.
#[test]
fn prop_dirty_shard_replan_identical_to_from_scratch() {
    for case_seed in [3u64, 11, 29] {
        let g = community(800, 12_000, case_seed);
        let spec = LowerSpec::default().with_shards(4);
        let mut session = Session::from_graph(&g, spec);
        let mut mirror = OverlayGraph::new(g.clone());
        let mut rng = Rng::seed_from_u64(case_seed ^ 0xbeef);
        for i in 0..1_500 {
            let d = random_delta(&mut rng, &mirror, 0.5, 0.02);
            let a = mirror.apply(d);
            let b = session.apply(d);
            assert_eq!(a, b, "seed {case_seed}: delta {i} \
                              no-op disagreement on {d:?}");
            if (i + 1) % 250 == 0 {
                session.plan(); // interleaved cached re-plans
            }
        }
        let (hag_c, plan_c) = session.plan();
        let (hag_f, plan_f) = session.plan_fresh();
        assert_plans_identical(&format!("seed {case_seed}"),
                               &hag_c, &plan_c, &hag_f, &plan_f);
        // the maintained HAG is Theorem-1 equivalent to the live graph
        let g_now = session.graph();
        assert_eq!(g_now.n(), mirror.n());
        assert_eq!(g_now.e(), mirror.e());
        hag_c.validate().unwrap();
        check_equivalence(&g_now, &hag_c).unwrap();
        // re-plan work stayed far below one search per update
        let st = session.stats();
        assert!(st.shard_searches <= 4 * (st.plans + 1),
                "seed {case_seed}: {} searches for {} plans",
                st.shard_searches, st.plans);
    }
}

/// Node-add-heavy streams grow the partition and stay identical to
/// from-scratch (new nodes go to the deterministic lightest shard).
#[test]
fn prop_node_add_heavy_stream_stays_identical() {
    let g = community(400, 6_000, 17);
    let spec = LowerSpec::default().with_shards(3);
    let mut session = Session::from_graph(&g, spec);
    let mut mirror = OverlayGraph::new(g.clone());
    let mut rng = Rng::seed_from_u64(171);
    for i in 0..600 {
        let d = random_delta(&mut rng, &mirror, 0.6, 0.2);
        mirror.apply(d);
        session.apply(d);
        if (i + 1) % 150 == 0 {
            session.plan();
        }
    }
    assert!(session.n() > g.n(), "stream must have added nodes");
    let (hag_c, plan_c) = session.plan();
    let (hag_f, plan_f) = session.plan_fresh();
    assert_plans_identical("node-add stream", &hag_c, &plan_c,
                           &hag_f, &plan_f);
    assert_eq!(hag_c.n, session.n());
    check_equivalence(&session.graph(), &hag_c).unwrap();
}

/// Localized delta streams leave the untouched shards' searches
/// cached — the observable cache-hit win.
#[test]
fn localized_deltas_hit_the_cache() {
    let g = clique_ring(8, 6);
    let spec = LowerSpec::default().with_shards(4);
    let mut session = Session::from_graph(&g, spec);
    session.plan();
    assert_eq!(session.stats().shard_searches, 4);

    // one intra-shard edge, toggled: only its shard ever re-searches
    let (mut eu, mut ev) = (u32::MAX, 0u32);
    'find: for (v, ns) in g.iter() {
        for &u in ns {
            if session.shard_of(u) == session.shard_of(v) {
                eu = u;
                ev = v;
                break 'find;
            }
        }
    }
    assert_ne!(eu, u32::MAX, "clique ring has intra-shard edges");
    for round in 0..3 {
        let del = GraphDelta::EdgeDelete { src: eu, dst: ev };
        let ins = GraphDelta::EdgeInsert { src: eu, dst: ev };
        assert!(session.apply(del));
        assert_eq!(session.dirty_shards(), 1, "round {round}");
        session.plan();
        assert!(session.apply(ins));
        session.plan();
    }
    let st = session.stats();
    assert_eq!(st.shard_searches, 4 + 6,
               "one dirty shard per re-plan: {st:?}");
    assert_eq!(st.shard_cache_hits, 3 * 6,
               "three clean shards spliced per re-plan: {st:?}");
    let (hag_c, plan_c) = session.plan();
    let (hag_f, plan_f) = session.plan_fresh();
    assert_plans_identical("localized", &hag_c, &plan_c, &hag_f,
                           &plan_f);
}

/// Satellite: a capacity-bearing spec round-trips through buckets.json
/// and `BucketSpec::fits` — the emitted bucket and the train/infer
/// plan from the same spec can never disagree.
#[test]
fn capacity_spec_round_trips_through_buckets_json() {
    let dir = std::env::temp_dir().join("repro_session_capacity_rt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("buckets.json");
    let ds = datasets::load("BZR", 0.02, 3);
    let spec = LowerSpec::default().with_capacity(40);

    let written = emit_buckets(&[ds.clone()], &spec, &path).unwrap();
    assert_eq!(written.len(), 2);

    // aot.py-side parse
    let text = std::fs::read_to_string(&path).unwrap();
    let doc = repro::util::json::parse(&text).unwrap();
    let parsed: Vec<BucketSpec> = doc.req_arr("buckets").unwrap()
        .iter()
        .map(|b| BucketSpec::from_json(b).unwrap())
        .collect();

    // a later lowering with the *same spec* must fit the parsed bucket
    for repr in [Repr::GnnGraph, Repr::Hag] {
        let lowered =
            Session::new(&ds, spec.clone().with_repr(repr))
                .lower().unwrap();
        let bucket = parsed.iter()
            .find(|b| b.name == lowered.bucket.name)
            .expect("bucket present in json");
        assert!(bucket.fits(&lowered.plan),
                "{}: parsed bucket does not fit the re-lowered plan",
                bucket.name);
        if repr == Repr::Hag {
            assert!(lowered.hag.agg_nodes.len() <= 40,
                    "capacity not honored: {}",
                    lowered.hag.agg_nodes.len());
        }
    }

    // ... and a *different* capacity must not silently fit: the old
    // foot-gun emitted one capacity's buckets whatever the caller
    // later trained with. Capacity 0 forbids every merge, so its plan
    // has no levels — while the capacity-40 bucket must have some
    // (the BZR stand-in's cloned neighborhood templates guarantee
    // mergeable redundancy).
    let hag_bucket = parsed.iter()
        .find(|b| b.name == "bzr_hag").unwrap();
    assert!(hag_bucket.levels >= 1,
            "premise: capacity-40 search found no merges");
    let other = Session::new(
        &ds, LowerSpec::default().with_capacity(0)).lower().unwrap();
    assert_eq!(other.plan.levels, 0);
    assert!(!hag_bucket.fits(&other.plan),
            "capacity-0 plan must not fit the capacity-40 bucket");
}

/// Golden stability: the default-spec `buckets.json` for BZR is
/// byte-identical to the primitive search → plan → bucket pipeline the
/// pre-session entry points ran — protects the aot.py contract across
/// the migration.
#[test]
fn golden_default_buckets_byte_identical() {
    let dir = std::env::temp_dir().join("repro_session_golden");
    std::fs::create_dir_all(&dir).unwrap();
    let ds = datasets::load("BZR", 0.02, 3);

    // primitive pipeline (what the seed's lower_dataset did)
    let old_path = dir.join("buckets_old.json");
    let mut old_buckets = Vec::new();
    for repr in [Repr::GnnGraph, Repr::Hag] {
        let hag = match repr {
            Repr::GnnGraph =>
                Hag::from_graph(&ds.graph, AggregateKind::Set),
            Repr::Hag => {
                let cfg = SearchConfig::paper_default(ds.graph.n());
                hag_search(&ds.graph, &cfg).0
            }
        };
        let plan = build_plan(&ds.graph, &hag, &PlanConfig::default());
        old_buckets.push(bucket_for(&ds, &plan, repr));
    }
    write_buckets_json(&old_buckets, &old_path).unwrap();

    // session pipeline, default spec
    let new_path = dir.join("buckets_new.json");
    emit_buckets(&[ds], &LowerSpec::default(), &new_path).unwrap();

    let old = std::fs::read(&old_path).unwrap();
    let new = std::fs::read(&new_path).unwrap();
    assert!(old == new,
            "default-spec buckets.json changed across the Session \
             migration ({} vs {} bytes)", old.len(), new.len());
}

/// Cross-spec isolation: sessions with different specs never share
/// cache entries (fingerprints differ), and the same spec on the same
/// graph reproduces the same fingerprint.
#[test]
fn fingerprints_isolate_specs_and_graphs() {
    let g = clique_ring(4, 5);
    let a = Session::from_graph(&g, LowerSpec::default());
    let b = Session::from_graph(&g, LowerSpec::default());
    assert_eq!(a.fingerprint(), b.fingerprint());
    let c = Session::from_graph(
        &g, LowerSpec::default().with_capacity(3));
    assert_ne!(a.fingerprint(), c.fingerprint());
    let g2 = clique_ring(4, 6);
    let d = Session::from_graph(&g2, LowerSpec::default());
    assert_ne!(a.fingerprint(), d.fingerprint());
}
