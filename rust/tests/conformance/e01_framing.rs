//! e01 — binary framing: every request kind gets a reply frame
//! correlated by request id, with the serving epoch stamped in the
//! header.

use std::collections::HashMap;

use repro::net::frame::{Frame, FrameKind};
use repro::net::{NetConfig, Outcome};
use repro::util::json;

use crate::common::{auto_responder, connect, scripted, serial};

#[test]
fn every_request_kind_roundtrips_with_id_correlation() {
    let _guard = serial();
    let s = scripted(NetConfig::default());
    let responder = auto_responder(s.rx, s.epoch.clone());
    let mut c = connect(&s.net);

    // Ping: liveness + epoch probe.
    assert_eq!(c.ping().expect("ping"), 1);

    // Score: logits echo the node id (scripted backend).
    match c.score(7, &[0.5, 0.5]).expect("score") {
        Outcome::Ok(score) => {
            assert_eq!(score.epoch, 1);
            assert_eq!(score.logits, vec![7.0, 0.25]);
        }
        Outcome::Rejected(r) => panic!("unexpected rejection: {r}"),
    }

    // Update: acked with a sequence number.
    match c.edge_insert(0, 1).expect("update") {
        Outcome::Ok(ack) => {
            assert_eq!(ack.seq, 1);
            assert_eq!(ack.outcome, "NoOp");
            assert_eq!(ack.epoch, 1);
        }
        Outcome::Rejected(r) => panic!("unexpected rejection: {r}"),
    }

    // Stats: a benchkit-v1 document over the wire.
    match c.stats().expect("stats") {
        Outcome::Ok(doc) => {
            assert_eq!(doc.get("schema").and_then(|v| v.as_str()),
                       Some("benchkit-v1"));
        }
        Outcome::Rejected(r) => panic!("unexpected rejection: {r}"),
    }

    drop(c);
    drop(s.net);
    responder.join().expect("responder exits when queue closes");
}

#[test]
fn pipelined_requests_answer_each_id_exactly_once() {
    let _guard = serial();
    let s = scripted(NetConfig::default());
    let responder = auto_responder(s.rx, s.epoch.clone());
    let mut c = connect(&s.net);

    // Fire 8 scores without reading, then collect all replies.
    // Completion order is not guaranteed — correlation is by id.
    for id in 1..=8u64 {
        c.send(&Frame::new(
            FrameKind::ScoreReq, id, 0,
            json::obj(vec![("node", json::num(id as f64))])))
            .expect("send");
    }
    let mut got: HashMap<u64, Frame> = HashMap::new();
    for _ in 0..8 {
        let f = c.recv().expect("reply");
        assert!(got.insert(f.request_id, f).is_none(),
                "duplicate reply id");
    }
    for id in 1..=8u64 {
        let f = &got[&id];
        assert_eq!(f.kind, FrameKind::ScoreOk);
        assert_eq!(f.epoch, 1);
        // the scripted backend echoes the node into the logits, so a
        // cross-wired reply would be caught here
        assert_eq!(f.payload.req_arr("logits").unwrap()[0].as_f64(),
                   Some(id as f64));
    }

    drop(c);
    drop(s.net);
    responder.join().expect("responder exits");
}
