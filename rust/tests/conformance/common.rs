//! Shared harness for the conformance suite.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use repro::coordinator::{self, BatchPolicy, Resident, ScoreError,
                         ScoreOk, ScoreReject, ScoreRequest,
                         ScoreResponse, ServerMsg, SwapPolicy,
                         UpdateResponse};
use repro::datasets;
use repro::durability::DurabilityState;
use repro::incremental::{ApplyOutcome, DriftPolicy, RebuildEvent};
use repro::net::{Client, NetConfig, NetServer};
use repro::obs::metrics::MetricsRegistry;
use repro::session::{LowerSpec, Session};

/// Serialize the suite. Armed fault points (e11–e20) are
/// process-global: a `net.write=nth:1` armed by one test would fire
/// on whichever connection writes first across all concurrently
/// running tests. Every conformance test takes this guard first, so
/// the chaos tests see only their own traffic and the non-chaos
/// tests never absorb someone else's fault.
pub fn serial() -> std::sync::MutexGuard<'static, ()> {
    repro::fault::exclusive()
}

/// A front end over a test-owned batcher channel: the test *is* the
/// batcher, so admission, sheds, drains and epoch flips are
/// deterministic.
pub struct Scripted {
    pub net: NetServer,
    pub rx: Receiver<ServerMsg>,
    pub epoch: Arc<AtomicU64>,
    pub registry: Arc<MetricsRegistry>,
}

/// Spawn a scripted front end with an explicit batcher-queue bound
/// (the production queue is 4096; small bounds make the queue-full
/// shed testable).
pub fn scripted_with(cfg: NetConfig, queue_cap: usize) -> Scripted {
    let (tx, rx) = sync_channel::<ServerMsg>(queue_cap);
    // Epoch 1 = "serving the spawn-time plan"; 0 in a request header
    // means unpinned, so 0 is never a serving epoch.
    let epoch = Arc::new(AtomicU64::new(1));
    let registry = Arc::new(MetricsRegistry::new());
    let net = NetServer::spawn("127.0.0.1:0", tx, epoch.clone(),
                               registry.clone(), cfg)
        .expect("bind loopback");
    Scripted { net, rx, epoch, registry }
}

pub fn scripted(cfg: NetConfig) -> Scripted {
    scripted_with(cfg, 64)
}

/// Connect a client with a bounded read timeout: every "the server
/// must answer, not hang" assertion rides on this deadline.
pub fn connect(net: &NetServer) -> Client {
    let mut c = Client::connect(net.local_addr()).expect("connect");
    c.set_read_timeout(Duration::from_secs(5)).expect("timeout");
    c
}

/// Scripted batcher thread that answers every message immediately:
/// scores echo `[node, 0.25]` logits (honoring epoch pins against
/// the shared cell), updates ack as NoOp with a running seq, stats
/// answer an empty snapshot. Exits when the queue closes (i.e. when
/// the `NetServer` is dropped).
pub fn auto_responder(rx: Receiver<ServerMsg>,
                      epoch: Arc<AtomicU64>) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let reg = MetricsRegistry::new();
        let mut seq = 0u64;
        for msg in rx {
            match msg {
                ServerMsg::Score(req) => reply_score(req, &epoch),
                ServerMsg::Update(req) => {
                    seq += 1;
                    if let Some(reply) = req.reply {
                        let _ = reply.send(UpdateResponse {
                            seq,
                            outcome: ApplyOutcome::NoOp,
                            rebuild: RebuildEvent::None,
                            cost_core: 0,
                            latency: Duration::from_micros(5),
                        });
                    }
                }
                ServerMsg::Stats(req) => {
                    let _ = req.reply.send(reg.snapshot());
                }
            }
        }
    })
}

/// Answer one scoring request the way the real worker would: epoch
/// pins are validated against the live cell, everything else echoes.
pub fn reply_score(req: ScoreRequest, epoch: &AtomicU64) {
    let e = epoch.load(Ordering::Acquire);
    let resp = match req.pin_epoch {
        Some(p) if p != e => ScoreResponse::Err(ScoreError {
            node: req.node,
            reject: ScoreReject::EpochMismatch { pinned: p,
                                                 current: e },
            latency: Duration::from_micros(5),
            epoch: e,
        }),
        _ => ScoreResponse::Ok(ScoreOk {
            node: req.node,
            logits: vec![req.node as f32, 0.25],
            latency: Duration::from_micros(5),
            epoch: e,
        }),
    };
    let _ = req.reply.send(resp);
}

/// Unwrap a queue message as a scoring request.
pub fn expect_score(msg: ServerMsg) -> ScoreRequest {
    match msg {
        ServerMsg::Score(r) => r,
        ServerMsg::Update(_) => panic!("expected Score, got Update"),
        ServerMsg::Stats(_) => panic!("expected Score, got Stats"),
    }
}

/// Poll `ping` until the served epoch exceeds `floor` (hot swaps
/// land on the worker thread; bounded at ~5 s). Returns the last
/// observed epoch — callers assert on it.
pub fn wait_epoch_above(c: &mut Client, floor: u64) -> u64 {
    let mut e = 0;
    for _ in 0..250 {
        e = c.ping().expect("ping");
        if e > floor {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    e
}

/// Artifacts dir that does not exist: forces the host reference
/// executor regardless of what the checkout has compiled.
pub fn no_artifacts() -> PathBuf {
    std::env::temp_dir().join("repro-conformance-no-artifacts")
}

/// A live serving stack behind the wire: resident session with a
/// forced drift threshold (every coalesced flush attempts a hot
/// swap), so topology updates land real plan swaps and real epoch
/// bumps.
pub struct Live {
    pub net: NetServer,
    pub server: coordinator::InferenceServer,
    pub f_in: usize,
    pub n: u32,
    pub classes: usize,
}

pub fn live_swapping() -> Live {
    live_build(|r| r)
}

/// Fresh per-test WAL directory under the OS temp dir (removed if a
/// previous run left one behind — recovery must see only this run's
/// segments).
pub fn wal_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "repro-conf-wal-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// [`live_swapping`] plus crash-safe durability: every acked update
/// batch is journaled (fsync) into a WAL under `dir` before apply,
/// and a snapshot is cut every `snapshot_every` landed swap epochs
/// (0 = WAL only, never snapshot).
pub fn live_durable(dir: &Path, snapshot_every: u64) -> Live {
    let dur = DurabilityState::open(dir, 0, snapshot_every)
        .expect("open WAL");
    live_build(move |r| r.with_durability(dur))
}

/// Resume a durable serving stack from `dir`: recover (snapshot +
/// WAL suffix), replay into a fresh resident pair, reopen the WAL
/// after the recovered tail, and force the recovered plan live on
/// the first batch. Returns the replay report alongside the stack.
pub fn live_recovered(dir: &Path)
                      -> (Live, repro::durability::ReplayReport) {
    let rec = repro::durability::recover(dir).expect("recover");
    let mut report = None;
    let live = live_build(|mut r| {
        report = Some(r.resume(&rec).expect("resume"));
        let dur = DurabilityState::open(dir, rec.tail_seq, 0)
            .expect("reopen WAL");
        r.with_durability(dur).with_initial_swap()
    });
    (live, report.expect("resume ran"))
}

fn live_build(prep: impl FnOnce(Resident) -> Resident) -> Live {
    let ds = datasets::load("BZR", 0.02, 7);
    let spec = LowerSpec::default().with_shards(2).with_drift(
        DriftPolicy::default().with_threshold(-1.0));
    let mut session = Session::new(&ds, spec);
    let lowered = session.lower().expect("lower");
    let resident = prep(Resident::new(
        session, &ds.graph, &lowered.hag,
        SwapPolicy { swap_plans: true, max_pending: 1 }));
    let server = coordinator::InferenceServer::for_lowered(
        no_artifacts(), "gcn", &ds, &lowered, BatchPolicy::default(),
        7, Some(resident))
        .expect("spawn server");
    let reg = Arc::new(MetricsRegistry::new());
    let net = NetServer::spawn("127.0.0.1:0", server.client(),
                               server.epoch_cell(), reg,
                               NetConfig::default())
        .expect("bind loopback");
    Live {
        net,
        server,
        f_in: ds.f_in,
        n: ds.n() as u32,
        classes: ds.classes,
    }
}
