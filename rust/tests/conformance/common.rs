//! Shared harness for the conformance suite.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use repro::coordinator::{self, BatchPolicy, Resident, ScoreError,
                         ScoreOk, ScoreReject, ScoreRequest,
                         ScoreResponse, ServerMsg, SwapPolicy,
                         UpdateResponse};
use repro::datasets;
use repro::incremental::{ApplyOutcome, DriftPolicy, RebuildEvent};
use repro::net::{Client, NetConfig, NetServer};
use repro::obs::metrics::MetricsRegistry;
use repro::session::{LowerSpec, Session};

/// A front end over a test-owned batcher channel: the test *is* the
/// batcher, so admission, sheds, drains and epoch flips are
/// deterministic.
pub struct Scripted {
    pub net: NetServer,
    pub rx: Receiver<ServerMsg>,
    pub epoch: Arc<AtomicU64>,
    pub registry: Arc<MetricsRegistry>,
}

/// Spawn a scripted front end with an explicit batcher-queue bound
/// (the production queue is 4096; small bounds make the queue-full
/// shed testable).
pub fn scripted_with(cfg: NetConfig, queue_cap: usize) -> Scripted {
    let (tx, rx) = sync_channel::<ServerMsg>(queue_cap);
    // Epoch 1 = "serving the spawn-time plan"; 0 in a request header
    // means unpinned, so 0 is never a serving epoch.
    let epoch = Arc::new(AtomicU64::new(1));
    let registry = Arc::new(MetricsRegistry::new());
    let net = NetServer::spawn("127.0.0.1:0", tx, epoch.clone(),
                               registry.clone(), cfg)
        .expect("bind loopback");
    Scripted { net, rx, epoch, registry }
}

pub fn scripted(cfg: NetConfig) -> Scripted {
    scripted_with(cfg, 64)
}

/// Connect a client with a bounded read timeout: every "the server
/// must answer, not hang" assertion rides on this deadline.
pub fn connect(net: &NetServer) -> Client {
    let mut c = Client::connect(net.local_addr()).expect("connect");
    c.set_read_timeout(Duration::from_secs(5)).expect("timeout");
    c
}

/// Scripted batcher thread that answers every message immediately:
/// scores echo `[node, 0.25]` logits (honoring epoch pins against
/// the shared cell), updates ack as NoOp with a running seq, stats
/// answer an empty snapshot. Exits when the queue closes (i.e. when
/// the `NetServer` is dropped).
pub fn auto_responder(rx: Receiver<ServerMsg>,
                      epoch: Arc<AtomicU64>) -> JoinHandle<()> {
    std::thread::spawn(move || {
        let reg = MetricsRegistry::new();
        let mut seq = 0u64;
        for msg in rx {
            match msg {
                ServerMsg::Score(req) => reply_score(req, &epoch),
                ServerMsg::Update(req) => {
                    seq += 1;
                    if let Some(reply) = req.reply {
                        let _ = reply.send(UpdateResponse {
                            seq,
                            outcome: ApplyOutcome::NoOp,
                            rebuild: RebuildEvent::None,
                            cost_core: 0,
                            latency: Duration::from_micros(5),
                        });
                    }
                }
                ServerMsg::Stats(req) => {
                    let _ = req.reply.send(reg.snapshot());
                }
            }
        }
    })
}

/// Answer one scoring request the way the real worker would: epoch
/// pins are validated against the live cell, everything else echoes.
pub fn reply_score(req: ScoreRequest, epoch: &AtomicU64) {
    let e = epoch.load(Ordering::Acquire);
    let resp = match req.pin_epoch {
        Some(p) if p != e => ScoreResponse::Err(ScoreError {
            node: req.node,
            reject: ScoreReject::EpochMismatch { pinned: p,
                                                 current: e },
            latency: Duration::from_micros(5),
            epoch: e,
        }),
        _ => ScoreResponse::Ok(ScoreOk {
            node: req.node,
            logits: vec![req.node as f32, 0.25],
            latency: Duration::from_micros(5),
            epoch: e,
        }),
    };
    let _ = req.reply.send(resp);
}

/// Unwrap a queue message as a scoring request.
pub fn expect_score(msg: ServerMsg) -> ScoreRequest {
    match msg {
        ServerMsg::Score(r) => r,
        ServerMsg::Update(_) => panic!("expected Score, got Update"),
        ServerMsg::Stats(_) => panic!("expected Score, got Stats"),
    }
}

/// Artifacts dir that does not exist: forces the host reference
/// executor regardless of what the checkout has compiled.
pub fn no_artifacts() -> PathBuf {
    std::env::temp_dir().join("repro-conformance-no-artifacts")
}

/// A live serving stack behind the wire: resident session with a
/// forced drift threshold (every coalesced flush attempts a hot
/// swap), so topology updates land real plan swaps and real epoch
/// bumps.
pub struct Live {
    pub net: NetServer,
    pub server: coordinator::InferenceServer,
    pub f_in: usize,
    pub n: u32,
    pub classes: usize,
}

pub fn live_swapping() -> Live {
    let ds = datasets::load("BZR", 0.02, 7);
    let spec = LowerSpec::default().with_shards(2).with_drift(
        DriftPolicy::default().with_threshold(-1.0));
    let mut session = Session::new(&ds, spec);
    let lowered = session.lower().expect("lower");
    let resident = Resident::new(
        session, &ds.graph, &lowered.hag,
        SwapPolicy { swap_plans: true, max_pending: 1 });
    let server = coordinator::InferenceServer::for_lowered(
        no_artifacts(), "gcn", &ds, &lowered, BatchPolicy::default(),
        7, Some(resident))
        .expect("spawn server");
    let reg = Arc::new(MetricsRegistry::new());
    let net = NetServer::spawn("127.0.0.1:0", server.client(),
                               server.epoch_cell(), reg,
                               NetConfig::default())
        .expect("bind loopback");
    Live {
        net,
        server,
        f_in: ds.f_in,
        n: ds.n() as u32,
        classes: ds.classes,
    }
}
