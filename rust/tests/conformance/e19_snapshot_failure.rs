//! e19 — snapshots are best effort, the WAL is the truth: when every
//! snapshot write fails, acks and hot swaps proceed unimpeded, the
//! failures are counted, and recovery from the WAL alone still sees
//! every acked delta.

use std::time::Duration;

use repro::durability::{recover, snapshot};
use repro::fault::{self, FaultAction, Trigger};

use crate::common::{connect, live_durable, serial, wait_epoch_above,
                    wal_dir};

#[test]
fn snapshot_write_failures_never_block_serving_or_acks() {
    let _guard = serial();
    fault::reset();
    let dir = wal_dir("e19");
    let live = live_durable(&dir, 1); // tries on every landed epoch
    fault::arm("snapshot.write", Trigger::Always, FaultAction::Error,
               0);
    let mut c = connect(&live.net);

    c.node_add().expect("node_add").into_result().expect("acked");
    c.edge_insert(0, live.n).expect("edge_insert").into_result()
        .expect("acked");
    let e = wait_epoch_above(&mut c, 1);
    assert!(e > 1, "swaps land despite failing snapshots");

    // Serving is live on the new plan.
    let feats = vec![0.5f32; live.f_in];
    let s = c.score(live.n, &feats).expect("score").into_result()
        .expect("added node served");
    assert_eq!(s.logits.len(), live.classes);

    drop(c);
    live.net.drain(Duration::from_secs(5));
    let stats = live.server.shutdown();
    assert_eq!(stats.snapshots_written, 0);
    assert!(fault::fired("snapshot.write") >= 1,
            "the cadence did attempt snapshots");
    fault::reset();

    // WAL-only recovery is complete: no snapshot on disk, every
    // acked delta replayable.
    assert!(snapshot::list(&dir).expect("list").is_empty());
    let rec = recover(&dir).expect("recover");
    assert!(rec.snapshot.is_none());
    assert_eq!(rec.deltas.len(), 2);
    assert_eq!(rec.tail_seq, 2);

    std::fs::remove_dir_all(&dir).ok();
}
