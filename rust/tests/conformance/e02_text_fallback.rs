//! e02 — JSON text fallback: a line starting with `{` is a complete
//! frame, and the server answers each request in the encoding it
//! arrived in (text gets text, binary gets binary, mixed per-frame
//! on one connection).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use repro::net::frame::{self, Frame, FrameKind};
use repro::net::NetConfig;
use repro::util::json;

use crate::common::{auto_responder, scripted, serial};

/// Read one `\n`-terminated line from a raw stream.
fn read_line(s: &mut TcpStream) -> String {
    let mut out = Vec::new();
    let mut b = [0u8; 1];
    loop {
        match s.read(&mut b) {
            Ok(0) => panic!("eof before newline"),
            Ok(_) if b[0] == b'\n' => break,
            Ok(_) => out.push(b[0]),
            Err(e) => panic!("read failed: {e}"),
        }
    }
    String::from_utf8(out).expect("utf-8 line")
}

fn read_exact(s: &mut TcpStream, n: usize) -> Vec<u8> {
    let mut buf = vec![0u8; n];
    s.read_exact(&mut buf).expect("read_exact");
    buf
}

#[test]
fn text_and_binary_frames_mix_on_one_connection() {
    let _guard = serial();
    let s = scripted(NetConfig::default());
    let responder = auto_responder(s.rx, s.epoch.clone());
    let mut raw = TcpStream::connect(s.net.local_addr())
        .expect("connect");
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();

    // 1. Text ping → text pong (the reply's first byte is `{`).
    raw.write_all(b"{\"type\":\"ping\",\"id\":3}\n").unwrap();
    let line = read_line(&mut raw);
    assert!(line.starts_with('{'), "text request got {line:?}");
    let v = json::parse(&line).expect("reply is JSON");
    assert_eq!(v.req_str("type").unwrap(), "pong");
    assert_eq!(v.req_f64("id").unwrap(), 3.0);
    assert_eq!(v.req_f64("epoch").unwrap(), 1.0);

    // 2. Text score with a payload → text score_ok carrying logits.
    raw.write_all(b"{\"type\":\"score_req\",\"id\":4,\
                    \"payload\":{\"node\":9}}\n").unwrap();
    let v = json::parse(&read_line(&mut raw)).unwrap();
    assert_eq!(v.req_str("type").unwrap(), "score_ok");
    assert_eq!(v.req_f64("id").unwrap(), 4.0);
    let logits = v.req("payload").unwrap().req_arr("logits").unwrap();
    assert_eq!(logits[0].as_f64(), Some(9.0));

    // 3. Binary ping on the same connection → binary pong (the
    //    reply starts with the magic, not `{`).
    let ping = Frame::new(FrameKind::Ping, 5, 0,
                          repro::util::json::Value::Null);
    raw.write_all(&frame::encode_binary(&ping)).unwrap();
    let hdr = read_exact(&mut raw, frame::HEADER_LEN);
    assert_eq!(u16::from_le_bytes([hdr[0], hdr[1]]), frame::MAGIC);
    assert_eq!(hdr[3], FrameKind::Pong.as_u8());
    assert_eq!(u64::from_le_bytes(hdr[4..12].try_into().unwrap()), 5);

    // 4. …and text again: the mode is per-frame, not per-connection.
    raw.write_all(b"{\"type\":\"ping\",\"id\":6}\n").unwrap();
    let v = json::parse(&read_line(&mut raw)).unwrap();
    assert_eq!(v.req_str("type").unwrap(), "pong");
    assert_eq!(v.req_f64("id").unwrap(), 6.0);

    drop(raw);
    drop(s.net);
    responder.join().expect("responder exits");
}
