//! e14 — bounded-restart supervision: a batch worker that panics
//! mid-execute drops that round's in-flight replies (clients see an
//! explicit `Internal` error frame, not a hang), and the supervisor
//! restarts the round loop — the very next request is served.

use std::time::Duration;

use repro::fault::{self, FaultAction, Trigger};
use repro::net::frame::ErrorCode;

use crate::common::{connect, live_swapping, serial};

#[test]
fn a_panicking_batch_is_absorbed_and_the_worker_restarts() {
    let _guard = serial();
    fault::reset();
    let live = live_swapping();
    let mut c = connect(&live.net);
    let feats = vec![0.5f32; live.f_in];

    // The first executed batch panics (worker dies mid-batch).
    fault::arm("batcher.exec", Trigger::Nth(1), FaultAction::Panic, 0);
    let rej = c.score(0, &feats).expect("wire stays up")
        .into_result().expect_err("in-flight reply dropped");
    assert_eq!(rej.code, ErrorCode::Internal,
               "dropped reply surfaces as an explicit failure");
    assert_eq!(fault::fired("batcher.exec"), 1);

    // Supervision restarted the loop from the last good serving
    // plan: the same connection's next request is answered.
    let s = c.score(0, &feats).expect("score").into_result()
        .expect("served after restart");
    assert_eq!(s.logits.len(), live.classes);

    fault::reset();
    drop(c);
    live.net.drain(Duration::from_secs(5));
    let stats = live.server.shutdown();
    assert_eq!(stats.worker_restarts, 1);
    assert!(stats.requests >= 1, "post-restart request counted");
}
