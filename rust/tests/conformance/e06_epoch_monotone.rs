//! e06 — live epoch monotonicity: against a real `InferenceServer`
//! with a forced-drift resident session, every response carries the
//! serving plan epoch, hot swaps bump it, and the values a single
//! connection observes are non-decreasing.

use std::time::Duration;

use repro::net::frame::ErrorCode;
use repro::net::Outcome;

use crate::common::{connect, live_swapping, serial};

#[test]
fn live_swaps_stamp_strictly_newer_epochs() {
    let _guard = serial();
    let live = live_swapping();
    let mut c = connect(&live.net);
    let feats = vec![0.5f32; live.f_in];
    let mut seen: Vec<u64> = Vec::new();

    // Setup plan serves as epoch 1 (0 is reserved for "unpinned").
    let e0 = c.ping().expect("ping");
    assert_eq!(e0, 1);
    seen.push(e0);

    let s1 = c.score(0, &feats).expect("score").into_result()
        .expect("fresh plan answers");
    assert_eq!(s1.epoch, 1);
    assert_eq!(s1.logits.len(), live.classes);
    seen.push(s1.epoch);

    // Land a guaranteed-real plan change over the wire: grow the
    // graph, then wire the new node in (a bare edge insert could
    // coalesce into a tensor-identical plan, which must NOT bump).
    c.node_add().expect("node_add").into_result().expect("acked");
    c.edge_insert(0, live.n).expect("edge_insert").into_result()
        .expect("acked");

    // The swap lands on the worker thread; give it a bounded window.
    let mut e2 = 0;
    for _ in 0..250 {
        e2 = c.ping().expect("ping");
        if e2 > 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(e2 > 1, "hot swap must bump the epoch (still {e2})");
    seen.push(e2);

    let s2 = c.score(0, &feats).expect("score").into_result()
        .expect("post-swap answers");
    assert!(s2.epoch >= e2);
    seen.push(s2.epoch);

    // A pin at the retired epoch is refused with both values.
    match c.score_pinned(0, &feats, Some(1)).expect("stale pin") {
        Outcome::Ok(_) => panic!("stale pin served after a swap"),
        Outcome::Rejected(rej) => {
            assert_eq!(rej.code, ErrorCode::EpochMismatch);
            assert_eq!(rej.pinned, Some(1));
            assert_eq!(rej.current, Some(s2.epoch));
            seen.push(rej.epoch);
        }
    }

    // Re-pinning at the serving epoch works.
    let s3 = c.score_pinned(0, &feats, Some(s2.epoch))
        .expect("fresh pin").into_result()
        .expect("current pin answers");
    assert_eq!(s3.epoch, s2.epoch);
    seen.push(s3.epoch);

    for w in seen.windows(2) {
        assert!(w[0] <= w[1],
                "epochs went backwards: {seen:?}");
    }

    drop(c);
    let net_stats = live.net.drain(Duration::from_secs(5));
    assert!(net_stats.accepted >= 1);
    let stats = live.server.shutdown();
    assert!(stats.plan_swaps >= 1,
            "the epoch bump must come from a real swap");
}
