//! e20 — kill-at-any-point capstone: a durable serving run is shut
//! down, recovered from snapshot + WAL into a brand-new process-like
//! stack, and the recovered server (a) replays every acked delta,
//! (b) serves the recovered topology from its first batch, (c)
//! passes the paper-level identity check — the recovered incremental
//! plan equals a from-scratch plan — and (d) resumes durable
//! journaling after the recovered tail.

use std::time::Duration;

use repro::durability::recover;
use repro::incremental::GraphDelta;

use crate::common::{connect, live_durable, live_recovered, serial,
                    wait_epoch_above, wal_dir};

#[test]
fn recovery_resumes_identical_serving_after_shutdown() {
    let _guard = serial();
    repro::fault::reset();
    let dir = wal_dir("e20");

    // Phase 1: a durable run with a mid-stream snapshot cadence and
    // a mixed delta history (insert, wire, re-wire, delete).
    let added;
    {
        let live = live_durable(&dir, 2);
        added = live.n;
        let mut c = connect(&live.net);
        c.node_add().expect("node_add").into_result().expect("acked");
        c.edge_insert(0, added).expect("edge_insert").into_result()
            .expect("acked");
        c.edge_insert(1, added).expect("edge_insert").into_result()
            .expect("acked");
        c.edge_delete(1, added).expect("edge_delete").into_result()
            .expect("acked");
        let e = wait_epoch_above(&mut c, 1);
        assert!(e > 1, "history landed live before the shutdown");
        drop(c);
        live.net.drain(Duration::from_secs(5));
        let stats = live.server.shutdown();
        assert_eq!(stats.updates, 4);
        assert_eq!(stats.plan_matches_fresh, Some(true));
    }

    // Phase 2: recover into a fresh stack. The session replays the
    // full acked history; the engine resumes from the snapshot (if
    // one landed) plus the WAL suffix.
    let (live2, report) = live_recovered(&dir);
    assert_eq!(report.session_replayed, 4,
               "every acked delta replayed, none lost");
    assert_eq!(report.resume_seq, 5);

    let mut c = connect(&live2.net);
    // (b) The forced initial swap publishes the recovered plan
    // before the first batch: the node added pre-crash is served
    // immediately, under a bumped epoch.
    let feats = vec![0.5f32; live2.f_in];
    let s = c.score(added, &feats).expect("score").into_result()
        .expect("recovered plan serves the pre-crash node");
    assert_eq!(s.logits.len(), live2.classes);
    assert!(s.epoch >= 2, "recovered plan is live (epoch {})",
            s.epoch);

    // (d) Durable writes continue past the recovered tail.
    c.edge_insert(2, added).expect("edge_insert").into_result()
        .expect("acked post-recovery");

    drop(c);
    live2.net.drain(Duration::from_secs(5));
    let stats = live2.server.shutdown();
    // (c) The identity guarantee across the crash boundary:
    // recovered-and-continued incremental state plans exactly like a
    // from-scratch build of the same graph.
    assert_eq!(stats.plan_matches_fresh, Some(true),
               "recovered session == from-scratch plan");

    let rec = recover(&dir).expect("re-recover");
    assert_eq!(rec.tail_seq, 5,
               "sequence numbering resumed after the old tail");
    assert_eq!(rec.deltas.last().map(|&(_, d)| d),
               Some(GraphDelta::EdgeInsert { src: 2, dst: added }));

    std::fs::remove_dir_all(&dir).ok();
}
