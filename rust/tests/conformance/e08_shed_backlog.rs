//! e08 — server-wide admission: load past `--shed-after` (or past
//! the bounded batcher queue) yields `retry_after` error frames —
//! never an unbounded queue, never a hang — across *all*
//! connections, and recovers once the backlog drains.

use std::collections::HashMap;
use std::sync::mpsc::TryRecvError;

use repro::net::frame::{ErrorCode, Frame, FrameKind};
use repro::net::{NetConfig, Outcome};
use repro::util::json;

use crate::common::{connect, expect_score, reply_score,
                    scripted_with, serial};

fn send_scores(c: &mut repro::net::Client, ids: std::ops::RangeInclusive<u64>) {
    for id in ids {
        c.send(&Frame::new(
            FrameKind::ScoreReq, id, 0,
            json::obj(vec![("node", json::num(id as f64))])))
            .expect("send");
    }
}

#[test]
fn backlog_cap_sheds_across_connections() {
    let _guard = serial();
    let cfg = NetConfig {
        max_inflight: 100,
        shed_after: 4,
        ..NetConfig::default()
    };
    let s = scripted_with(cfg, 64);
    let mut c1 = connect(&s.net);

    // 6 pipelined requests against a shed_after of 4: exactly 4 are
    // admitted, 2 come back as retry_after ("backlog").
    send_scores(&mut c1, 1..=6);
    let mut shed_ids = Vec::new();
    for _ in 0..2 {
        let f = c1.recv().expect("shed answer");
        assert_eq!(f.kind, FrameKind::Error);
        assert_eq!(f.error_code(), Some(ErrorCode::RetryAfter));
        let msg = f.message().unwrap_or("").to_string();
        assert!(msg.contains("backlog"), "wrong reason: {msg:?}");
        shed_ids.push(f.request_id);
    }
    shed_ids.sort_unstable();
    assert_eq!(shed_ids, vec![5, 6]);

    // The gate is server-wide: a *different* connection is also shed
    // while the backlog stands.
    let mut c2 = connect(&s.net);
    match c2.score(9, &[]).expect("answered, not hung") {
        Outcome::Ok(_) => panic!("admitted past the backlog cap"),
        Outcome::Rejected(rej) => {
            assert_eq!(rej.code, ErrorCode::RetryAfter);
            assert!(rej.retry_after_ms.is_some());
        }
    }

    // Drain the backlog; the four admitted requests all answer.
    for i in 0..4 {
        reply_score(expect_score(
            s.rx.recv().unwrap_or_else(|_| panic!("req {i}"))),
            &s.epoch);
    }
    let mut got: HashMap<u64, Frame> = HashMap::new();
    for _ in 0..4 {
        let f = c1.recv().expect("admitted reply");
        assert_eq!(f.kind, FrameKind::ScoreOk);
        assert!(got.insert(f.request_id, f).is_none());
    }
    for id in 1..=4u64 {
        assert!(got.contains_key(&id), "request {id} lost");
    }

    // Nothing beyond the admitted four ever reached the queue, and
    // the inflight gauge is back to zero.
    assert!(matches!(s.rx.try_recv(), Err(TryRecvError::Empty)));
    assert_eq!(s.net.inflight(), 0);
    assert_eq!(s.net.stats().shed, 3);

    // Recovery: with the backlog gone, c2 is admitted again.
    let epoch = s.epoch.clone();
    let rx = s.rx;
    let t = std::thread::spawn(move || {
        reply_score(expect_score(rx.recv().expect("req")), &epoch);
    });
    match c2.score(9, &[]).expect("score") {
        Outcome::Ok(score) => assert_eq!(score.logits[0], 9.0),
        Outcome::Rejected(r) => panic!("recovery failed: {r}"),
    }
    t.join().expect("responder");
}

#[test]
fn bounded_batcher_queue_sheds_instead_of_buffering() {
    let _guard = serial();
    // A tiny scripted queue (cap 2) stands in for "the batcher is
    // slower than the wire": overflow sheds at enqueue time.
    let s = scripted_with(NetConfig::default(), 2);
    let mut c = connect(&s.net);

    send_scores(&mut c, 1..=5);
    let mut shed = 0;
    for _ in 0..3 {
        let f = c.recv().expect("shed answer");
        assert_eq!(f.kind, FrameKind::Error);
        assert_eq!(f.error_code(), Some(ErrorCode::RetryAfter));
        let msg = f.message().unwrap_or("").to_string();
        assert!(msg.contains("queue"), "wrong reason: {msg:?}");
        shed += 1;
    }
    assert_eq!(shed, 3);

    // Exactly the queue bound made it through.
    for _ in 0..2 {
        reply_score(expect_score(s.rx.recv().expect("queued req")),
                    &s.epoch);
    }
    assert!(matches!(s.rx.try_recv(), Err(TryRecvError::Empty)));
    for _ in 0..2 {
        let f = c.recv().expect("queued reply");
        assert_eq!(f.kind, FrameKind::ScoreOk);
    }
    assert_eq!(s.net.inflight(), 0);
}
