//! e16 — a failed reply write tears down only its own connection:
//! the client observes a transport error (never a silent hang, never
//! a corrupt half-frame), the server survives, and a fresh
//! connection is served normally.

use std::time::Duration;

use repro::fault::{self, FaultAction, Trigger};
use repro::net::NetConfig;

use crate::common::{auto_responder, connect, scripted, serial};

#[test]
fn a_failed_reply_write_tears_only_that_connection() {
    let _guard = serial();
    fault::reset();
    let s = scripted(NetConfig::default());
    let responder = auto_responder(s.rx, s.epoch.clone());

    // The next reply write fails at the socket.
    fault::arm("net.write", Trigger::Nth(1), FaultAction::Error, 0);
    let mut a = connect(&s.net);
    let res = a.score(3, &[0.5]);
    assert!(res.is_err(),
            "torn connection must surface as a client error");
    assert_eq!(fault::fired("net.write"), 1);

    // Connection-scoped blast radius: a new connection works.
    let mut b = connect(&s.net);
    let sc = b.score(4, &[0.5]).expect("score").into_result()
        .expect("fresh connection served");
    assert_eq!(sc.logits, vec![4.0, 0.25]);

    fault::reset();
    drop(a);
    drop(b);
    let ns = s.net.drain(Duration::from_secs(5));
    assert!(ns.accepted >= 2);
    responder.join().expect("responder exits with the server");
}
