//! e03 — malformed frame rejection: every wire-contract violation is
//! answered with an explicit `bad_frame` error frame, the connection
//! is closed, and the violation is counted (`net.proto_errors`).
//! The server process survives all of it.

use repro::net::frame::{self, ErrorCode, Frame, FrameKind, WireError};
use repro::net::NetConfig;
use repro::util::json::{self, Value};

use crate::common::{auto_responder, connect, scripted, serial,
                    Scripted};

/// Send raw bytes on a fresh connection; expect one `bad_frame`
/// error frame followed by EOF.
fn expect_bad_frame_then_close(s: &Scripted, bytes: &[u8]) {
    let mut c = connect(&s.net);
    c.send_raw(bytes).expect("send");
    let reply = c.recv().expect("server answers before closing");
    assert_eq!(reply.kind, FrameKind::Error);
    assert_eq!(reply.error_code(), Some(ErrorCode::BadFrame),
               "payload: {:?}", reply.payload);
    assert_eq!(reply.epoch, 1, "error frames carry the epoch");
    match c.recv() {
        Err(WireError::Eof) => {}
        other => panic!("connection must close, got {other:?}"),
    }
}

#[test]
fn malformed_frames_get_error_frames_then_close() {
    let _guard = serial();
    let s = scripted(NetConfig::default());
    let responder = auto_responder(s.rx, s.epoch.clone());

    // Bad magic byte.
    expect_bad_frame_then_close(&s, &[0x99u8; 24]);

    // Right magic, unsupported version.
    let mut bytes = frame::encode_binary(
        &Frame::new(FrameKind::Ping, 1, 0, Value::Null));
    bytes[2] = 9;
    expect_bad_frame_then_close(&s, &bytes);

    // Unknown frame kind.
    let mut bytes = frame::encode_binary(
        &Frame::new(FrameKind::Ping, 1, 0, Value::Null));
    bytes[3] = 200;
    expect_bad_frame_then_close(&s, &bytes);

    // Payload bytes that are not JSON.
    let mut bytes = frame::encode_binary(
        &Frame::new(FrameKind::Ping, 1, 0, Value::Null));
    bytes[20..24].copy_from_slice(&3u32.to_le_bytes());
    bytes.extend_from_slice(b"}!{");
    expect_bad_frame_then_close(&s, &bytes);

    // Text line that is not a JSON object.
    expect_bad_frame_then_close(&s, b"{nonsense\n");

    // Well-framed score_req with a nonsense payload (no node).
    expect_bad_frame_then_close(&s, &frame::encode_binary(
        &Frame::new(FrameKind::ScoreReq, 2, 0,
                    json::obj(vec![("nope", json::num(1.0))]))));

    // Response kinds flowing client → server are protocol abuse.
    expect_bad_frame_then_close(&s, &frame::encode_binary(
        &Frame::new(FrameKind::Pong, 3, 0, Value::Null)));

    // Every violation was counted, and the server still serves: a
    // clean connection works after all of the above.
    assert_eq!(s.net.stats().protocol_errors, 7);
    let mut c = connect(&s.net);
    assert_eq!(c.ping().expect("still serving"), 1);

    drop(c);
    drop(s.net);
    responder.join().expect("responder exits");
}
