//! e09 — connection timeouts: idle connections are reclaimed after
//! `read_timeout`, a peer stalling mid-frame is rejected with a
//! `bad_frame` answer (not a held server thread), and outstanding
//! work holds an otherwise-quiet connection open.

use std::time::{Duration, Instant};

use repro::net::frame::{self, ErrorCode, Frame, FrameKind, WireError};
use repro::net::NetConfig;
use repro::util::json::{self, Value};

use crate::common::{connect, expect_score, reply_score, scripted,
                    serial};

fn short_timeout() -> NetConfig {
    NetConfig {
        read_timeout: Duration::from_millis(150),
        ..NetConfig::default()
    }
}

#[test]
fn idle_connections_are_closed() {
    let _guard = serial();
    let s = scripted(short_timeout());
    let mut c = connect(&s.net);
    let t0 = Instant::now();
    match c.recv() {
        Err(WireError::Eof) => {}
        other => panic!("expected idle close, got {other:?}"),
    }
    let waited = t0.elapsed();
    assert!(waited >= Duration::from_millis(100),
            "closed too eagerly ({waited:?})");
    assert!(waited < Duration::from_secs(4),
            "idle close took {waited:?}");
}

#[test]
fn midframe_stall_is_rejected_not_held() {
    let _guard = serial();
    let s = scripted(short_timeout());
    let mut c = connect(&s.net);

    // Ten bytes of a perfectly valid header… and then silence.
    let bytes = frame::encode_binary(
        &Frame::new(FrameKind::Ping, 1, 0, Value::Null));
    c.send_raw(&bytes[..10]).expect("send partial header");

    let reply = c.recv().expect("stall must be answered");
    assert_eq!(reply.kind, FrameKind::Error);
    assert_eq!(reply.error_code(), Some(ErrorCode::BadFrame));
    assert!(reply.message().unwrap_or("").contains("stalled"),
            "wrong reason: {:?}", reply.message());
    match c.recv() {
        Err(WireError::Eof) => {}
        other => panic!("connection must close, got {other:?}"),
    }
    assert_eq!(s.net.stats().protocol_errors, 1);
}

#[test]
fn outstanding_work_blocks_idle_close() {
    let _guard = serial();
    let s = scripted(short_timeout());
    let mut c = connect(&s.net);

    // One admitted request, then wire silence far past the idle
    // limit. The connection must survive until the answer flows.
    c.send(&Frame::new(
        FrameKind::ScoreReq, 1, 0,
        json::obj(vec![("node", json::num(6.0))])))
        .expect("send");
    let req = expect_score(s.rx.recv().expect("req"));
    std::thread::sleep(Duration::from_millis(400));
    reply_score(req, &s.epoch);

    let f = c.recv().expect("reply after quiet wait");
    assert_eq!(f.kind, FrameKind::ScoreOk);
    assert_eq!(f.request_id, 1);
    assert_eq!(f.payload.req_arr("logits").unwrap()[0].as_f64(),
               Some(6.0));
}
