//! e18 — snapshot cadence: a durable live server cuts a graph+HAG
//! snapshot at each configured plan-epoch boundary; the newest
//! snapshot parses, reflects the landed topology, and recovery
//! adopts it (WAL replay then starts after the snapshot's sequence).

use std::time::Duration;

use repro::durability::{recover, snapshot};

use crate::common::{connect, live_durable, serial, wait_epoch_above,
                    wal_dir};

#[test]
fn snapshots_land_on_the_epoch_cadence_and_parse() {
    let _guard = serial();
    repro::fault::reset();
    let dir = wal_dir("e18");
    let live = live_durable(&dir, 1); // snapshot on every landed epoch
    let mut c = connect(&live.net);

    c.node_add().expect("node_add").into_result().expect("acked");
    c.edge_insert(0, live.n).expect("edge_insert").into_result()
        .expect("acked");
    let e = wait_epoch_above(&mut c, 1);
    assert!(e > 1, "swap must land (epoch still {e})");

    drop(c);
    live.net.drain(Duration::from_secs(5));
    let stats = live.server.shutdown();
    assert!(stats.snapshots_written >= 1,
            "at least one epoch boundary cut a snapshot");

    // The newest snapshot parses and carries the landed topology:
    // the added node and its wired edge.
    let snap = snapshot::load_latest(&dir).expect("snapshot parses");
    assert_eq!(snap.seq, 2, "cut after both acked deltas");
    assert!(snap.epoch > 1, "cut at a post-swap boundary");
    assert_eq!(snap.graph.n(), live.n as usize + 1);
    assert_eq!(snap.graph.neighbors(live.n), &[0],
               "snapshot graph has the inserted edge");

    // Recovery adopts it: replay resumes after the snapshot seq.
    let rec = recover(&dir).expect("recover");
    let adopted = rec.snapshot.as_ref().expect("snapshot adopted");
    assert_eq!(adopted.seq, 2);
    assert_eq!(rec.tail_seq, 2);

    std::fs::remove_dir_all(&dir).ok();
}
