//! e10 — graceful drain: `begin_drain` stops admitting work, answers
//! stragglers with a `draining` error frame, still flushes requests
//! that were already in flight, and then closes connections once
//! they are idle. `drain()` reports the accounting.

use std::time::Duration;

use repro::net::frame::{ErrorCode, Frame, FrameKind, WireError};
use repro::net::NetConfig;
use repro::util::json;

use crate::common::{connect, expect_score, reply_score, scripted,
                    serial};

#[test]
fn drain_answers_inflight_and_refuses_new_work() {
    let _guard = serial();
    let s = scripted(NetConfig::default());
    let mut c = connect(&s.net);

    // One request in flight — the test holds its reply hostage.
    c.send(&Frame::new(
        FrameKind::ScoreReq, 1, 0,
        json::obj(vec![("node", json::num(1.0))])))
        .expect("send");
    let held = expect_score(s.rx.recv().expect("req 1"));

    s.net.begin_drain();

    // New work on the existing connection: answered with `draining`,
    // not queued, not hung. (The held reply guarantees this error is
    // the next frame on the wire.)
    c.send(&Frame::new(
        FrameKind::ScoreReq, 2, 0,
        json::obj(vec![("node", json::num(2.0))])))
        .expect("send during drain");
    let reply = c.recv().expect("straggler answered");
    assert_eq!(reply.kind, FrameKind::Error);
    assert_eq!(reply.request_id, 2);
    assert_eq!(reply.error_code(), Some(ErrorCode::Draining));

    // New connections are not accepted. The TCP handshake may still
    // land in the kernel backlog, so tolerate a successful connect —
    // but no frame may ever be answered on it.
    if let Ok(mut probe) = repro::net::Client::connect(
        s.net.local_addr())
    {
        probe.set_read_timeout(Duration::from_millis(300)).unwrap();
        assert!(probe.ping().is_err(),
                "drained server must not serve new connections");
    }

    // The in-flight request still completes: drain flushes, it does
    // not abandon.
    reply_score(held, &s.epoch);
    let f = c.recv().expect("in-flight reply during drain");
    assert_eq!(f.kind, FrameKind::ScoreOk);
    assert_eq!(f.request_id, 1);

    // With nothing left in flight, the server closes the connection.
    match c.recv() {
        Err(WireError::Eof) => {}
        other => panic!("expected close after flush, got {other:?}"),
    }

    let stats = s.net.drain(Duration::from_secs(5));
    assert!(stats.accepted >= 1);
    assert_eq!(stats.drained, 1);
    assert_eq!(stats.shed, 0, "drain is not a shed");
    drop(c);
}
