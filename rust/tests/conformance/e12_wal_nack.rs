//! e12 — journal-then-ack under a failing fsync: a WAL commit
//! failure nacks the whole update batch over the wire (an explicit
//! `Internal` error frame, not a hang and not a false ack), applies
//! nothing, and recovery replays exactly the acked deltas.

use std::time::Duration;

use repro::durability::recover;
use repro::fault::{self, FaultAction, Trigger};
use repro::incremental::GraphDelta;
use repro::net::frame::ErrorCode;

use crate::common::{connect, live_durable, serial, wal_dir};

#[test]
fn failed_wal_commit_nacks_the_batch_and_recovery_sees_only_acks() {
    let _guard = serial();
    fault::reset();
    let dir = wal_dir("e12");
    let live = live_durable(&dir, 0);
    let mut c = connect(&live.net);

    // First update lands durably.
    c.node_add().expect("node_add").into_result().expect("acked");

    // The next WAL commit's fsync fails. The ordering contract: no
    // ack before the fsync returns Ok, so this batch must be refused
    // wholesale — the reply channel is dropped and the listener
    // answers with an Internal error frame.
    fault::arm("wal.fsync", Trigger::Nth(1), FaultAction::Error, 0);
    let rej = c.edge_insert(0, live.n).expect("wire stays up")
        .into_result().expect_err("nacked, not acked");
    assert_eq!(rej.code, ErrorCode::Internal);
    assert_eq!(fault::fired("wal.fsync"), 1);

    // The failure was transient and scoped to that batch: the same
    // connection's next update lands durably.
    c.edge_insert(0, live.n).expect("edge_insert").into_result()
        .expect("acked after the nack");

    drop(c);
    live.net.drain(Duration::from_secs(5));
    let stats = live.server.shutdown();
    assert_eq!(stats.wal_nacked_batches, 1);
    assert_eq!(stats.updates, 2, "the nacked delta was never applied");
    fault::reset();

    // Recovery sees exactly what was acked — the nacked batch left
    // nothing behind (its staged bytes were rolled back; its burned
    // sequence number is a legal hole).
    let rec = recover(&dir).expect("recover");
    let deltas: Vec<GraphDelta> =
        rec.deltas.iter().map(|&(_, d)| d).collect();
    assert_eq!(
        deltas,
        vec![GraphDelta::NodeAdd,
             GraphDelta::EdgeInsert { src: 0, dst: live.n }],
        "acked deltas only");
    assert_eq!(rec.truncated_bytes, 0,
               "rollback left no torn bytes on disk");

    std::fs::remove_dir_all(&dir).ok();
}
