//! e13 — plan-swap failure is non-fatal: an injected `serve.swap`
//! fault rolls back cleanly (updates stay acked, serving continues
//! on the old plan at the old epoch) and a later flush retries the
//! swap and lands it.

use std::time::Duration;

use repro::fault::{self, FaultAction, Trigger};

use crate::common::{connect, live_swapping, serial, wait_epoch_above};

#[test]
fn failed_plan_swap_rolls_back_and_a_later_flush_lands_it() {
    let _guard = serial();
    fault::reset();
    let live = live_swapping();
    let mut c = connect(&live.net);
    assert_eq!(c.ping().expect("ping"), 1);

    // The first swap attempt fails after this flush.
    fault::arm("serve.swap", Trigger::Nth(1), FaultAction::Error, 0);
    c.node_add().expect("node_add").into_result().expect("acked");
    c.edge_insert(0, live.n).expect("edge_insert").into_result()
        .expect("update acks are independent of swap outcomes");

    // Wait for the failed attempt, then prove the rollback: the old
    // plan keeps serving at the old epoch.
    for _ in 0..250 {
        if fault::fired("serve.swap") >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(fault::fired("serve.swap"), 1, "swap was attempted");

    // Forced drift (threshold < 0) retries the swap on the next
    // flush; the retry must land and bump the epoch.
    c.edge_insert(1, live.n).expect("edge_insert").into_result()
        .expect("acked");
    let e = wait_epoch_above(&mut c, 1);
    assert!(e > 1, "retried swap must land (epoch still {e})");

    // Serving is correct on the new plan: the added node answers.
    let feats = vec![0.5f32; live.f_in];
    let s = c.score(live.n, &feats).expect("score").into_result()
        .expect("new node served post-swap");
    assert!(s.epoch >= e);
    assert_eq!(s.logits.len(), live.classes);

    fault::reset();
    drop(c);
    live.net.drain(Duration::from_secs(5));
    let stats = live.server.shutdown();
    assert!(stats.swaps_skipped >= 1,
            "the failed attempt is accounted as skipped");
    assert!(stats.plan_swaps >= 1, "the retry is a real swap");
    assert_eq!(stats.plan_matches_fresh, Some(true),
               "rollback left the session coherent");
}
