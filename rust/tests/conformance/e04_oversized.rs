//! e04 — payload caps: a frame whose declared payload exceeds
//! `max_payload` is refused **before** the payload is read (the
//! server never buffers it), with an `oversized` error frame carrying
//! the offending length and the cap. Over-long text lines get the
//! same answer.

use std::time::{Duration, Instant};

use repro::net::frame::{self, ErrorCode, Frame, FrameKind, WireError};
use repro::net::NetConfig;
use repro::util::json::Value;

use crate::common::{auto_responder, connect, scripted, serial};

#[test]
fn oversized_payloads_are_refused_without_buffering() {
    let _guard = serial();
    let cfg = NetConfig { max_payload: 256, ..NetConfig::default() };
    let s = scripted(cfg);
    let responder = auto_responder(s.rx, s.epoch.clone());

    // Binary: a header declaring a 1 MiB payload — which we never
    // send. The refusal must arrive anyway, promptly: the decoder
    // rejects on the declared length, it does not wait for bytes.
    let mut c = connect(&s.net);
    let mut hdr = frame::encode_binary(
        &Frame::new(FrameKind::ScoreReq, 1, 0, Value::Null));
    hdr[20..24].copy_from_slice(&((1u32 << 20).to_le_bytes()));
    let t0 = Instant::now();
    c.send_raw(&hdr).expect("send header");
    let reply = c.recv().expect("refusal without the payload");
    assert!(t0.elapsed() < Duration::from_secs(2),
            "refusal should not wait on payload bytes");
    assert_eq!(reply.kind, FrameKind::Error);
    assert_eq!(reply.error_code(), Some(ErrorCode::Oversized));
    assert_eq!(reply.payload.req_f64("len").unwrap(), (1u64 << 20) as f64);
    assert_eq!(reply.payload.req_f64("max").unwrap(), 256.0);
    match c.recv() {
        Err(WireError::Eof) => {}
        other => panic!("connection must close, got {other:?}"),
    }

    // Text: a line that runs past the cap before any newline.
    let mut c = connect(&s.net);
    let mut line = vec![b'{'];
    line.extend(std::iter::repeat(b'x').take(300));
    c.send_raw(&line).expect("send long line");
    let reply = c.recv().expect("refusal");
    assert_eq!(reply.kind, FrameKind::Error);
    assert_eq!(reply.error_code(), Some(ErrorCode::Oversized));
    match c.recv() {
        Err(WireError::Eof) => {}
        other => panic!("connection must close, got {other:?}"),
    }

    assert_eq!(s.net.stats().protocol_errors, 2);

    drop(c);
    drop(s.net);
    responder.join().expect("responder exits");
}
