//! e11 — torn-tail recovery: topology updates acknowledged over the
//! wire survive a crash that leaves garbage at the WAL tail.
//! Recovery truncates the torn bytes physically (a second recovery
//! of the same directory is clean) and replays exactly the acked
//! prefix, in order.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

use repro::durability::recover;
use repro::incremental::GraphDelta;

use crate::common::{connect, live_durable, serial, wal_dir};

fn newest_segment(dir: &Path) -> PathBuf {
    std::fs::read_dir(dir)
        .expect("wal dir")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| {
                    n.starts_with("wal-") && n.ends_with(".log")
                })
        })
        .max()
        .expect("at least one segment")
}

#[test]
fn acked_updates_survive_a_torn_wal_tail() {
    let _guard = serial();
    repro::fault::reset();
    let dir = wal_dir("e11");
    let live = live_durable(&dir, 0);
    let mut c = connect(&live.net);

    // Two acked updates: journal-then-ack means both are durable the
    // moment the client sees UpdateOk.
    c.node_add().expect("node_add").into_result().expect("acked");
    c.edge_insert(0, live.n).expect("edge_insert").into_result()
        .expect("acked");

    drop(c);
    live.net.drain(Duration::from_secs(5));
    let stats = live.server.shutdown();
    assert_eq!(stats.updates, 2);

    // Crash simulation: a torn record at the tail of the newest
    // segment (garbage length prefix, no valid CRC behind it).
    let seg = newest_segment(&dir);
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(&seg)
        .expect("open segment");
    f.write_all(&[0x5A; 13]).expect("tear tail");
    drop(f);

    let rec = recover(&dir).expect("recover");
    assert_eq!(rec.truncated_bytes, 13, "torn bytes truncated");
    assert_eq!(rec.tail_seq, 2);
    let deltas: Vec<GraphDelta> =
        rec.deltas.iter().map(|&(_, d)| d).collect();
    assert_eq!(
        deltas,
        vec![GraphDelta::NodeAdd,
             GraphDelta::EdgeInsert { src: 0, dst: live.n }],
        "exactly the acked prefix, in ack order");

    // Truncation was physical, not just logical: recovering again
    // finds a clean log.
    let rec2 = recover(&dir).expect("re-recover");
    assert_eq!(rec2.truncated_bytes, 0, "second recovery is clean");
    assert_eq!(rec2.deltas.len(), 2);
    assert_eq!(rec2.tail_seq, 2);

    std::fs::remove_dir_all(&dir).ok();
}
