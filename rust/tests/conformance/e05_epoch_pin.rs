//! e05 — epoch-pinned reads: a request whose header epoch is
//! non-zero is answered only by that exact plan epoch; after a swap,
//! the stale pin gets a well-formed `epoch_mismatch` error frame
//! carrying both the pinned and the current epoch. Unpinned requests
//! always ride the serving plan.

use std::sync::atomic::Ordering;

use repro::net::frame::{ErrorCode, FrameKind};
use repro::net::{NetConfig, Outcome};

use crate::common::{auto_responder, connect, scripted, serial};

#[test]
fn pinned_reads_answer_or_mismatch_after_swap() {
    let _guard = serial();
    let s = scripted(NetConfig::default());
    let responder = auto_responder(s.rx, s.epoch.clone());
    let mut c = connect(&s.net);

    // Pin at the serving epoch: answered, stamped with that epoch.
    match c.score_pinned(3, &[], Some(1)).expect("pinned score") {
        Outcome::Ok(score) => assert_eq!(score.epoch, 1),
        Outcome::Rejected(r) => panic!("fresh pin rejected: {r}"),
    }

    // Simulate a hot swap landing: the serving epoch moves to 5.
    s.epoch.store(5, Ordering::Release);

    // The stale pin is refused with a structured mismatch, not
    // silently served from the wrong plan.
    match c.score_pinned(3, &[], Some(1)).expect("stale pin") {
        Outcome::Ok(_) => panic!("stale pin must not be served"),
        Outcome::Rejected(rej) => {
            assert_eq!(rej.code, ErrorCode::EpochMismatch);
            assert_eq!(rej.pinned, Some(1));
            assert_eq!(rej.current, Some(5));
            assert_eq!(rej.epoch, 5,
                       "error header carries the serving epoch");
        }
    }

    // Unpinned (header epoch 0) rides the new plan.
    match c.score(3, &[]).expect("unpinned score") {
        Outcome::Ok(score) => assert_eq!(score.epoch, 5),
        Outcome::Rejected(r) => panic!("unpinned rejected: {r}"),
    }

    // Text mode spells the pin as payload.pin_epoch; same contract.
    c.send_raw(b"{\"type\":\"score_req\",\"id\":9,\
                 \"payload\":{\"node\":1,\"pin_epoch\":1}}\n")
        .expect("send text pin");
    let reply = c.recv().expect("text reply");
    assert_eq!(reply.kind, FrameKind::Error);
    assert_eq!(reply.request_id, 9);
    assert_eq!(reply.error_code(), Some(ErrorCode::EpochMismatch));
    assert_eq!(reply.payload.req_f64("pinned").unwrap(), 1.0);
    assert_eq!(reply.payload.req_f64("current").unwrap(), 5.0);

    drop(c);
    drop(s.net);
    responder.join().expect("responder exits");
}
