//! e15 — the restart budget is bounded: a worker that panics on
//! every batch is restarted at most `MAX_WORKER_RESTARTS` (3) times,
//! after which the batcher exits and every subsequent wire request
//! fails fast with `Internal` ("batcher is gone") instead of
//! crash-looping or hanging. Shutdown still joins cleanly.

use std::time::Duration;

use repro::fault::{self, FaultAction, Trigger};
use repro::net::frame::ErrorCode;

use crate::common::{connect, live_swapping, serial};

#[test]
fn worker_restart_budget_exhausts_to_fail_fast_rejections() {
    let _guard = serial();
    fault::reset();
    let live = live_swapping();
    let mut c = connect(&live.net);
    let feats = vec![0.5f32; live.f_in];

    fault::arm("batcher.exec", Trigger::Always, FaultAction::Panic, 0);

    // Every score triggers one panicking batch until the budget is
    // spent; after that the queue is closed and admission answers
    // for the dead batcher. Either way each attempt gets an explicit
    // Internal frame within the client deadline — never a hang.
    let mut gone = false;
    for _ in 0..50 {
        let rej = c.score(0, &feats).expect("wire stays up")
            .into_result().expect_err("no batch may succeed");
        assert_eq!(rej.code, ErrorCode::Internal);
        if rej.message.contains("batcher is gone") {
            gone = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(gone, "exhausted budget must fail fast, not retry");
    assert!(fault::fired("batcher.exec") >= 3,
            "budget allows exactly three panicking rounds");

    fault::reset();
    drop(c);
    live.net.drain(Duration::from_secs(5));
    let outcome = live.server.shutdown_outcome();
    assert_eq!(outcome.stats.worker_restarts, 3);
    assert!(outcome.resident.is_some(),
            "the resident pair survives the worker's death");
}
