//! Numbered wire-protocol conformance suite (`cargo test --test
//! conformance`): one file per client-visible contract guarantee,
//! e01 … e20, all runnable against the CPU-stub build (no PJRT
//! artifacts, no network beyond loopback).
//!
//! Most guarantees run against a **scripted** back end: the TCP
//! front end is spawned over a test-owned batcher channel, so the
//! test controls exactly when (and whether) each request is
//! answered — sheds, drains, and epoch flips become deterministic.
//! The epoch guarantees that depend on real hot swaps (e06) run
//! against a live `InferenceServer` with a forced-drift resident
//! session instead.
//!
//! e11–e20 are the **chaos** arm (DESIGN.md §14): deterministic
//! faults injected at named points (`repro::fault`) prove the
//! kill-at-any-point durability contract — acked deltas survive
//! crashes, failed fsyncs nack instead of lying, swap/exec/socket
//! failures are absorbed with bounded blast radius, and recovery
//! resumes identical serving. Because armed fault points are
//! process-global, every test serializes behind `common::serial()`.
//!
//! | file                  | guarantee                                |
//! |-----------------------|------------------------------------------|
//! | e01_framing           | binary frames: id correlation, all kinds |
//! | e02_text_fallback     | JSON text mode; reply matches req mode   |
//! | e03_malformed         | malformed frames: error frame + close    |
//! | e04_oversized         | payload caps enforced without buffering  |
//! | e05_epoch_pin         | pinned reads answer or EpochMismatch     |
//! | e06_epoch_monotone    | live swaps: epochs stamped, monotone     |
//! | e07_shed_pipeline     | per-connection cap sheds with RetryAfter |
//! | e08_shed_backlog      | server-wide cap + queue bound, no hang   |
//! | e09_timeouts          | idle close; mid-frame stall rejected     |
//! | e10_drain             | drain answers in-flight, refuses new     |
//! | e11_wal_torn_tail     | acked deltas survive a torn WAL tail     |
//! | e12_wal_nack          | failed fsync nacks batch; acks recovered |
//! | e13_swap_rollback     | failed swap rolls back; retry lands      |
//! | e14_worker_restart    | batch panic absorbed; worker restarts    |
//! | e15_restart_budget    | restart budget bounds; then fail-fast    |
//! | e16_write_failure     | reply-write failure tears only its conn  |
//! | e17_retry_backoff     | client retry honors the RetryAfter hint  |
//! | e18_snapshot_cadence  | snapshots cut on epoch cadence, parse    |
//! | e19_snapshot_failure  | snapshot failure never blocks acks       |
//! | e20_recovery_identity | recover → identical plan, serving, WAL   |

mod common;
mod e01_framing;
mod e02_text_fallback;
mod e03_malformed;
mod e04_oversized;
mod e05_epoch_pin;
mod e06_epoch_monotone;
mod e07_shed_pipeline;
mod e08_shed_backlog;
mod e09_timeouts;
mod e10_drain;
mod e11_wal_torn_tail;
mod e12_wal_nack;
mod e13_swap_rollback;
mod e14_worker_restart;
mod e15_restart_budget;
mod e16_write_failure;
mod e17_retry_backoff;
mod e18_snapshot_cadence;
mod e19_snapshot_failure;
mod e20_recovery_identity;
