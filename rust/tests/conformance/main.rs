//! Numbered wire-protocol conformance suite (`cargo test --test
//! conformance`): one file per client-visible contract guarantee,
//! e01 … e10, all runnable against the CPU-stub build (no PJRT
//! artifacts, no network beyond loopback).
//!
//! Most guarantees run against a **scripted** back end: the TCP
//! front end is spawned over a test-owned batcher channel, so the
//! test controls exactly when (and whether) each request is
//! answered — sheds, drains, and epoch flips become deterministic.
//! The epoch guarantees that depend on real hot swaps (e06) run
//! against a live `InferenceServer` with a forced-drift resident
//! session instead.
//!
//! | file                | guarantee                                  |
//! |---------------------|--------------------------------------------|
//! | e01_framing         | binary frames: id correlation, every kind  |
//! | e02_text_fallback   | JSON text mode; reply matches request mode |
//! | e03_malformed       | malformed frames: error frame + close      |
//! | e04_oversized       | payload caps enforced without buffering    |
//! | e05_epoch_pin       | pinned reads answer or EpochMismatch       |
//! | e06_epoch_monotone  | live swaps: epochs stamped, monotone       |
//! | e07_shed_pipeline   | per-connection cap sheds with RetryAfter   |
//! | e08_shed_backlog    | server-wide cap + queue bound, no hang     |
//! | e09_timeouts        | idle close; mid-frame stall rejected       |
//! | e10_drain           | drain answers in-flight, refuses new work  |

mod common;
mod e01_framing;
mod e02_text_fallback;
mod e03_malformed;
mod e04_oversized;
mod e05_epoch_pin;
mod e06_epoch_monotone;
mod e07_shed_pipeline;
mod e08_shed_backlog;
mod e09_timeouts;
mod e10_drain;
