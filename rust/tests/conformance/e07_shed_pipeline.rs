//! e07 — per-connection admission: pipelining past `max_inflight`
//! sheds the excess request with a `retry_after` error frame — the
//! server answers instead of buffering or hanging — and the
//! connection stays usable once the pipeline drains.

use std::collections::HashMap;

use repro::net::frame::{ErrorCode, Frame, FrameKind};
use repro::net::{NetConfig, Outcome};
use repro::util::json;

use crate::common::{connect, expect_score, reply_score, scripted,
                    serial};

#[test]
fn pipeline_overflow_sheds_with_retry_after() {
    let _guard = serial();
    let cfg = NetConfig {
        max_inflight: 2,
        shed_after: 100,
        ..NetConfig::default()
    };
    let s = scripted(cfg);
    let mut c = connect(&s.net);

    // Fire 3 scores without reading; the back end answers nothing
    // yet, so requests 1 and 2 fill the pipeline and request 3 must
    // be shed. The 5 s client deadline is the no-hang proof.
    for id in 1..=3u64 {
        c.send(&Frame::new(
            FrameKind::ScoreReq, id, 0,
            json::obj(vec![("node", json::num(id as f64))])))
            .expect("send");
    }
    let reply = c.recv().expect("shed answer arrives unprompted");
    assert_eq!(reply.kind, FrameKind::Error);
    assert_eq!(reply.request_id, 3);
    assert_eq!(reply.error_code(), Some(ErrorCode::RetryAfter));
    let msg = reply.message().unwrap_or("");
    assert!(msg.contains("pipeline"), "wrong shed reason: {msg:?}");
    assert!(reply.payload.get("retry_after_ms").is_some(),
            "retry_after frames must carry a back-off hint");

    // Now answer the two admitted requests and collect their oks.
    reply_score(expect_score(s.rx.recv().expect("req 1")), &s.epoch);
    reply_score(expect_score(s.rx.recv().expect("req 2")), &s.epoch);
    let mut got: HashMap<u64, Frame> = HashMap::new();
    for _ in 0..2 {
        let f = c.recv().expect("admitted reply");
        assert_eq!(f.kind, FrameKind::ScoreOk);
        assert!(got.insert(f.request_id, f).is_none());
    }
    assert!(got.contains_key(&1) && got.contains_key(&2));

    // The shed was transient: with the pipeline drained, the same
    // connection is admitted again.
    let epoch = s.epoch.clone();
    let rx = s.rx;
    let handle = std::thread::spawn(move || {
        reply_score(expect_score(rx.recv().expect("req 4")), &epoch);
    });
    match c.score(4, &[]).expect("score after drain") {
        Outcome::Ok(score) => assert_eq!(score.logits[0], 4.0),
        Outcome::Rejected(r) => panic!("re-admission failed: {r}"),
    }
    handle.join().expect("responder");

    assert_eq!(s.net.stats().shed, 1);
    assert_eq!(s.net.inflight(), 0, "shed must not leak inflight");
}
