//! e17 — client back-off honors the server's hint: a load-shed
//! `RetryAfter` (with its `retry_after_ms` hint) is absorbed by
//! `score_with_retry`, which waits at least the hinted back-off
//! before retrying and then succeeds once capacity frees up.

use std::time::{Duration, Instant};

use repro::net::frame::{Frame, FrameKind};
use repro::net::{Client, NetConfig, RetryPolicy};
use repro::util::json;

use crate::common::{connect, expect_score, reply_score, scripted,
                    serial};

#[test]
fn score_with_retry_absorbs_a_shed_and_honors_the_hint() {
    let _guard = serial();
    repro::fault::reset();
    // Server-wide budget of one outstanding request.
    let s = scripted(NetConfig {
        shed_after: 1,
        ..NetConfig::default()
    });

    // Connection A fills the budget: its request is admitted and
    // deliberately left unanswered.
    let mut a = connect(&s.net);
    a.send(&Frame::new(FrameKind::ScoreReq, 1, 0,
                       json::obj(vec![("node", json::num(1.0))])))
        .expect("send");
    let req_a = expect_score(
        s.rx.recv_timeout(Duration::from_secs(5)).expect("A admitted"));

    // Connection B retries through the shed on its own thread.
    let addr = s.net.local_addr();
    let b = std::thread::spawn(move || {
        let mut c = Client::connect(addr).expect("connect B");
        c.set_read_timeout(Duration::from_secs(5)).expect("timeout");
        let policy = RetryPolicy {
            max_attempts: 5,
            base: Duration::from_millis(1),
            cap: Duration::from_millis(200),
            jitter_seed: 17,
        };
        let t0 = Instant::now();
        let out = c.score_with_retry(7, &[0.5], &policy)
            .expect("wire stays up");
        (out, t0.elapsed())
    });

    // Wait until B has actually been shed, then free the budget.
    let t0 = Instant::now();
    while s.net.stats().shed < 1 {
        assert!(t0.elapsed() < Duration::from_secs(5),
                "B never hit the shed");
        std::thread::sleep(Duration::from_millis(2));
    }
    reply_score(req_a, &s.epoch);

    // B's retried attempt is admitted and served.
    let req_b = expect_score(
        s.rx.recv_timeout(Duration::from_secs(5)).expect("B retried"));
    reply_score(req_b, &s.epoch);

    let (out, elapsed) = b.join().expect("B thread");
    let score = out.into_result().expect("retry succeeded");
    assert_eq!(score.logits, vec![7.0, 0.25]);
    // The listener hints 50 ms on sheds; the back-off floor is the
    // hint even though the policy's own base is 1 ms.
    assert!(elapsed >= Duration::from_millis(50),
            "hint is the back-off floor, elapsed {elapsed:?}");
    assert!(s.net.stats().shed >= 1);

    drop(a);
}
