//! Failure-injection tests for the runtime layer: malformed manifests,
//! missing artifacts, and contract violations must produce descriptive
//! errors, never XLA crashes or silent wrong answers.

use std::path::{Path, PathBuf};

use repro::runtime::{HostTensor, Manifest, Runtime};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("repro_rt_fail_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let d = tmp_dir("nomanifest");
    let err = match Runtime::open(&d) {
        Err(e) => e,
        Ok(_) => panic!("open must fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest"), "unhelpful error: {msg}");
    assert!(msg.contains("make artifacts"), "no hint: {msg}");
}

#[test]
fn corrupt_manifest_is_a_parse_error() {
    let d = tmp_dir("corrupt");
    std::fs::write(d.join("manifest.json"), "{\"version\": 1,").unwrap();
    assert!(Runtime::open(&d).is_err());
}

#[test]
fn manifest_missing_fields_rejected() {
    assert!(Manifest::parse(r#"{"version": 1}"#).is_err());
    assert!(Manifest::parse(
        r#"{"version": 1, "artifacts": [{"name": "x"}]}"#).is_err());
}

#[test]
fn unknown_artifact_lists_alternatives() {
    if !artifacts_dir().join("manifest.json").exists() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let err = match rt.compile("gcn_train_nonexistent") {
        Err(e) => e,
        Ok(_) => panic!("compile must fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("not in manifest"), "{msg}");
    assert!(msg.contains("emit-buckets"), "no remediation hint: {msg}");
}

#[test]
fn missing_hlo_file_fails_at_compile() {
    if !artifacts_dir().join("manifest.json").exists() {
        return;
    }
    let d = tmp_dir("missingfile");
    // copy the manifest but none of the HLO files
    std::fs::copy(artifacts_dir().join("manifest.json"),
                  d.join("manifest.json")).unwrap();
    let rt = Runtime::open(&d).unwrap();
    let name = rt.artifact_names()[0].to_string();
    assert!(rt.compile(&name).is_err());
}

#[test]
fn wrong_arity_and_dtype_rejected_before_execution() {
    if !artifacts_dir().join("manifest.json").exists() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let exe = rt.compile("gcn_infer_tiny0").unwrap();
    // too few inputs
    assert!(rt.upload_checked(&exe, &[]).is_err());
    // right arity, one wrong dtype
    let mut inputs: Vec<HostTensor> = exe.spec.inputs.iter()
        .map(|s| match s.dtype.as_str() {
            "f32" => HostTensor::f32(vec![0.0; s.elements()], &s.shape),
            _ => HostTensor::i32(vec![0; s.elements()], &s.shape),
        })
        .collect();
    let flipped = match &inputs[0] {
        HostTensor::F32 { shape, .. } =>
            HostTensor::i32(vec![0; inputs[0].shape().iter().product()],
                            &shape.clone()),
        HostTensor::I32 { shape, .. } =>
            HostTensor::f32(vec![0.0; inputs[0].shape().iter().product()],
                            &shape.clone()),
    };
    inputs[0] = flipped;
    let err = match rt.upload_checked(&exe, &inputs) {
        Err(e) => e,
        Ok(_) => panic!("upload_checked must fail"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("expects"), "{msg}");
}

#[test]
fn valid_inputs_execute_and_match_spec() {
    if !artifacts_dir().join("manifest.json").exists() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let exe = rt.compile("gcn_infer_tiny0").unwrap();
    let zero_slot = (exe.spec.bucket.m_pad() - 1) as i32;
    let inputs: Vec<HostTensor> = exe.spec.inputs.iter()
        .map(|s| match s.dtype.as_str() {
            "f32" => HostTensor::f32(vec![0.0; s.elements()], &s.shape),
            // index tensors: point padding at the zero slot so gathers
            // stay in range
            _ if s.name.contains("col") || s.name.starts_with("lvl_") =>
                HostTensor::i32(vec![zero_slot; s.elements()], &s.shape),
            _ => HostTensor::i32(vec![0; s.elements()], &s.shape),
        })
        .collect();
    let outs = rt.run("gcn_infer_tiny0", &inputs).unwrap();
    assert_eq!(outs.len(), exe.spec.outputs.len());
    for (o, s) in outs.iter().zip(&exe.spec.outputs) {
        assert_eq!(o.shape(), s.shape.as_slice());
    }
    // zero inputs -> finite logits (bias-only path)
    assert!(outs[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
}
