//! Incremental-maintenance subsystem tests: the ISSUE-2 acceptance
//! run (10k random updates on the community generator), repaired-HAG
//! equivalence under randomized update sequences (exact *and*
//! probabilistic Theorem-1 oracles), the same oracles on *stitched*
//! HAGs built from streamed graphs, and the background-rebuild
//! snapshot/replay/swap path.
//!
//! Same convention as `properties.rs` / `partition.rs`: cases are
//! seeded and deterministic; failures print the case they came from.

use std::time::Instant;

use repro::datasets::{community_graph, CommunityCfg};
use repro::graph::Graph;
use repro::hag::{check_equivalence, check_equivalence_probabilistic,
                 hag_search};
use repro::incremental::{random_delta, GraphDelta, StreamConfig,
                         StreamEngine};
use repro::partition::search_sharded;
use repro::util::Rng;

fn community(n: usize, e: usize, seed: u64) -> Graph {
    let cfg = CommunityCfg {
        n,
        e,
        communities: (n / 125).max(4),
        intra_frac: 0.9,
        zipf_exp: 0.9,
        clone_frac: 0.5,
    };
    community_graph(&cfg, seed).0
}

/// ISSUE 2 acceptance: after 10k random edge updates on the community
/// generator, the repaired HAG (a) still validates and passes the
/// Theorem-1 oracle, (b) stays within 10% of a fresh full search's
/// `cost_core`, and (c) repairs at a median latency >= 10x faster than
/// a full re-search.
#[test]
fn acceptance_10k_updates_on_community_generator() {
    let g = community(1_500, 30_000, 42);
    let mut cfg = StreamConfig::default();
    // Whole-graph rebuilds: the 10% bound below is against a
    // single-threaded fresh search, so sharded rebuilds would stack
    // the shard cut gap on top of the drift allowance. The sharded
    // rebuild path is covered by the background-rebuild and property
    // tests in this file.
    cfg.shards = 1;
    cfg.policy.threshold = 0.05;
    let mut eng = StreamEngine::new(&g, cfg);
    let mut rng = Rng::seed_from_u64(42);
    let mut lat_s = Vec::with_capacity(10_000);
    for _ in 0..10_000 {
        let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.01);
        let t = Instant::now();
        eng.apply(d);
        lat_s.push(t.elapsed().as_secs_f64());
    }

    // (a) valid + Theorem-1 equivalent
    let g_now = eng.graph();
    let maintained = eng.to_hag();
    maintained.validate().unwrap();
    check_equivalence(&g_now, &maintained).unwrap();
    check_equivalence_probabilistic(&g_now, &maintained, 42).unwrap();

    // (b) cost within 10% of a fresh full search on the final graph
    let sc = eng.search_config();
    let (fresh, _) = hag_search(&g_now, &sc);
    let gap = maintained.cost_core() as f64
        / fresh.cost_core().max(1) as f64;
    assert!(gap <= 1.10,
            "maintained cost {} vs fresh {} (gap {:.3}); stats {:?}",
            maintained.cost_core(), fresh.cost_core(), gap,
            eng.stats());

    // (c) median repair latency >= 10x faster than a full re-search
    // (median over three searches vs median over 10k repairs)
    let mut full_s = Vec::new();
    for _ in 0..3 {
        let t = Instant::now();
        std::hint::black_box(hag_search(&g_now, &sc));
        full_s.push(t.elapsed().as_secs_f64());
    }
    full_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    lat_s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_repair = lat_s[lat_s.len() / 2];
    let median_full = full_s[1];
    assert!(median_full >= 10.0 * median_repair,
            "full re-search {:.3} ms is not >= 10x median repair \
             {:.6} ms",
            median_full * 1e3, median_repair * 1e3);

    // sanity on the stream itself: deletes actually hit covered edges
    // and the policy actually fired
    let s = eng.stats();
    assert!(s.fallbacks > 0, "stream never hit a covered edge: {s:?}");
    assert!(s.rebuild_swaps > 0,
            "drift policy never re-searched: {s:?}");
}

/// Satellite: the probabilistic (and exact) Theorem-1 oracles hold on
/// *repaired* HAGs after randomized update sequences — not just on
/// fresh-searched ones.
#[test]
fn prop_repaired_hags_pass_oracles_after_random_sequences() {
    for case in 0..8u64 {
        let mut rng = Rng::seed_from_u64(9000 + case);
        let n = rng.range_usize(100, 500);
        let g = community(n, n * rng.range_usize(4, 14),
                          rng.next_u64());
        let mut cfg = StreamConfig::default();
        cfg.shards = rng.range_usize(1, 4);
        cfg.remerge_every = rng.range_usize(8, 64);
        cfg.policy.threshold = match case % 3 {
            0 => 0.02,
            1 => 0.10,
            _ => f64::INFINITY,
        };
        let mut eng = StreamEngine::new(&g, cfg);
        let insert_frac = rng.range_f64(0.2, 0.8);
        for _ in 0..400 {
            let d = random_delta(&mut rng, eng.overlay(), insert_frac,
                                 0.02);
            eng.apply(d);
        }
        let g_now = eng.graph();
        let h = eng.to_hag();
        h.validate().unwrap_or_else(|e| {
            panic!("case {case}: invalid repaired HAG: {e}")
        });
        check_equivalence(&g_now, &h).unwrap_or_else(|e| {
            panic!("case {case}: exact oracle failed: {e}")
        });
        check_equivalence_probabilistic(&g_now, &h, case)
            .unwrap_or_else(|e| {
                panic!("case {case}: probabilistic oracle failed: {e}")
            });
    }
}

/// Satellite: the probabilistic oracle also holds on *stitched* HAGs
/// built by the partitioned search over a streamed (then materialized)
/// graph — stitching and repair compose.
#[test]
fn prop_stitched_hags_pass_oracles_on_streamed_graphs() {
    for case in 0..6u64 {
        let mut rng = Rng::seed_from_u64(9500 + case);
        let g = community(400, 6_000, rng.next_u64());
        let mut eng = StreamEngine::new(&g, StreamConfig::default());
        for _ in 0..300 {
            let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.02);
            eng.apply(d);
        }
        let g_now = eng.graph();
        for k in [2usize, 4] {
            let sc = eng.search_config();
            let (stitched, _) = search_sharded(&g_now, k, &sc);
            stitched.validate().unwrap_or_else(|e| {
                panic!("case {case} k={k}: invalid stitched HAG: {e}")
            });
            check_equivalence(&g_now, &stitched).unwrap_or_else(|e| {
                panic!("case {case} k={k}: exact oracle failed: {e}")
            });
            check_equivalence_probabilistic(&g_now, &stitched,
                                            case ^ k as u64)
                .unwrap_or_else(|e| {
                    panic!("case {case} k={k}: probabilistic oracle \
                            failed: {e}")
                });
        }
    }
}

/// Background rebuild: snapshot + delta replay + atomic swap must land
/// on a HAG equivalent to the live graph even while the stream keeps
/// mutating it mid-search.
#[test]
fn background_rebuild_swap_is_consistent_with_live_stream() {
    let g = community(600, 10_000, 7);
    let mut cfg = StreamConfig::default();
    cfg.shards = 2;
    cfg.policy.threshold = 0.0; // re-search at every policy check
    cfg.policy.check_every = 50;
    cfg.policy.background = true;
    let mut eng = StreamEngine::new(&g, cfg);
    let mut rng = Rng::seed_from_u64(7);
    for _ in 0..2_000 {
        let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.01);
        eng.apply(d);
    }
    eng.finish_rebuild();
    let s = eng.stats().clone();
    assert!(s.rebuild_starts >= 1, "no background rebuild ran: {s:?}");
    assert!(s.rebuild_swaps >= 1, "no rebuild ever swapped in: {s:?}");
    let g_now = eng.graph();
    let h = eng.to_hag();
    h.validate().unwrap();
    check_equivalence(&g_now, &h).unwrap();
    check_equivalence_probabilistic(&g_now, &h, 7).unwrap();
}

/// Node growth: NodeAdd extends the slot space without renumbering,
/// and inserts wiring the new nodes stay equivalent.
#[test]
fn node_adds_grow_the_graph_consistently() {
    let g = community(200, 2_400, 3);
    let n0 = g.n();
    let mut eng = StreamEngine::new(&g, StreamConfig::default());
    let mut rng = Rng::seed_from_u64(3);
    for i in 0..50u32 {
        let r = eng.apply(GraphDelta::NodeAdd);
        assert_eq!(r.outcome,
                   repro::incremental::ApplyOutcome::NodeAdded);
        let v = n0 as u32 + i;
        // wire each new node to a few random existing nodes, both ways
        for _ in 0..4 {
            let u = rng.range_u32(0, v);
            eng.apply(GraphDelta::EdgeInsert { src: u, dst: v });
            eng.apply(GraphDelta::EdgeInsert { src: v, dst: u });
        }
    }
    assert_eq!(eng.n(), n0 + 50);
    let g_now = eng.graph();
    assert_eq!(g_now.n(), n0 + 50);
    let h = eng.to_hag();
    h.validate().unwrap();
    check_equivalence(&g_now, &h).unwrap();
    assert!(g_now.neighbors(n0 as u32).len() >= 1,
            "new node never wired");
}
