//! haglint integration tests: the corpus-clean property (every
//! artifact the pipeline can legitimately produce verifies with zero
//! diagnostics) and the mutation-kill matrix (every public analysis
//! pass catches the one targeted corruption that owns it — the proof
//! the verifier is not vacuous). The incremental-IR kills live
//! in-crate (`analysis/incremental.rs`, they need private state);
//! `cost.gauges_match` is killed here against a real registry.

use repro::analysis::{self, corpus, mutate};
use repro::analysis::mutate::ALL_MUTANTS;
use repro::obs::metrics::MetricsRegistry;

/// Generator graphs x {exact, windowed, capacity-capped} x
/// {single, sharded/stitched, repaired} all verify clean — including
/// the incremental-IR stream case.
#[test]
fn corpus_verifies_clean() {
    let cases = corpus::verify_corpus();
    assert!(cases.len() >= 10, "corpus shrank to {}", cases.len());
    for (name, r) in cases {
        assert!(r.is_clean(), "{name}:\n{}", r.format());
        assert!(!r.passes_run.is_empty(), "{name}: no passes ran");
    }
}

/// Every mutant lands on at least one corpus artifact and is flagged
/// by exactly the pass that owns its corruption class (other passes
/// may fire too — gating only guarantees the owner sees it).
#[test]
fn mutation_kill_matrix() {
    let arts = corpus::corpus();
    let mut killed: Vec<&'static str> = Vec::new();
    for &m in ALL_MUTANTS {
        let pass = m.expected_pass();
        let mut applied = 0usize;
        for art in &arts {
            let mut corrupt = art.clone();
            if !mutate::apply(m, &mut corrupt) {
                continue;
            }
            applied += 1;
            let r = corrupt.verify();
            assert!(r.flagged(pass),
                    "{m:?} on {} escaped pass {pass}; report:\n{}",
                    art.name, r.format());
        }
        assert!(applied > 0,
                "{m:?} was inapplicable to every corpus artifact");
        if !killed.contains(&pass) {
            killed.push(pass);
        }
    }
    // coverage: every pass in the inventory has a kill somewhere —
    // here, or in the in-crate incremental/gauge suites
    for p in analysis::PASSES {
        if p.id.starts_with("incr.") || p.id == "cost.gauges_match" {
            continue;
        }
        assert!(killed.contains(&p.id),
                "pass {} has no mutation kill", p.id);
    }
}

/// `cost.gauges_match` kill: honest `cost.pred_*` gauges verify
/// clean; a one-off skew of a recorded gauge is caught.
#[test]
fn gauge_skew_is_killed() {
    let reg = MetricsRegistry::new();
    let arts = corpus::corpus();
    let art = arts.iter()
        .find(|a| !a.hag.agg_nodes.is_empty() && a.part.is_none())
        .expect("corpus has a single-shard hierarchical artifact");
    let terms = vec![(art.hag.aggregations(),
                      art.hag.data_transfers())];
    repro::obs::cost::record_plan_terms(&reg, &art.hag, &terms);
    let clean = analysis::check_cost_gauges(&reg.snapshot(),
                                            &art.hag, &terms);
    assert!(clean.is_clean(), "{}", clean.format());

    reg.gauge("cost.pred_transfers").add(1);
    let dirty = analysis::check_cost_gauges(&reg.snapshot(),
                                            &art.hag, &terms);
    assert!(dirty.flagged("cost.gauges_match"), "{}", dirty.format());
}

/// The corpus JSON envelope round-trips through the same checks CI's
/// `repro obs --check-verify` applies.
#[test]
fn corpus_report_envelope() {
    let cases = corpus::verify_corpus();
    let doc = analysis::corpus_report_json(&cases);
    assert_eq!(doc.req_str("schema").unwrap(), "haglint-v1");
    assert_eq!(doc.get("clean").and_then(|v| v.as_bool()),
               Some(true));
    assert_eq!(doc.req_f64("total_errors").unwrap(), 0.0);
    assert_eq!(doc.req_arr("cases").unwrap().len(), cases.len());
    assert_eq!(doc.req_arr("passes").unwrap().len(),
               analysis::PASSES.len());
}
