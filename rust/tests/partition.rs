//! Partition subsystem tests: partitioner invariants, stitched-HAG
//! validity/equivalence against the unpartitioned graph, and the cost
//! property (partitioning can only miss merges, never add
//! aggregations) over the seeded generator families from
//! `datasets/generators.rs`.
//!
//! Same convention as `properties.rs`: cases are seeded and
//! deterministic; a failure prints the case/seed it came from.

use repro::datasets::{community_graph, ego_clique_set, CommunityCfg,
                      EgoCliqueCfg};
use repro::graph::{Graph, GraphBuilder};
use repro::hag::{check_equivalence, check_equivalence_probabilistic,
                 hag_search, AggregateKind, Hag, SearchConfig};
use repro::partition::{partition_bfs, search_partitioned,
                       search_sharded, search_sharded_seeded,
                       PartitionConfig};
use repro::util::Rng;

const CASES: usize = 20;

/// Random graph families (mirrors `properties.rs::random_graph`).
fn random_graph(rng: &mut Rng) -> Graph {
    match rng.range_usize(0, 4) {
        0 => {
            let n = rng.range_usize(2, 120);
            let mut b = GraphBuilder::new(n);
            let e = rng.range_usize(0, n * 6 + 1);
            for _ in 0..e {
                let u = rng.range_usize(0, n) as u32;
                let v = rng.range_usize(0, n) as u32;
                if u != v {
                    b.edge(u, v);
                }
            }
            b.build()
        }
        1 => {
            let n = rng.range_usize(50, 400);
            let cfg = CommunityCfg {
                n,
                e: n * rng.range_usize(2, 12),
                communities: rng.range_usize(2, 9),
                intra_frac: rng.range_f64(0.6, 1.0),
                zipf_exp: rng.range_f64(0.5, 1.3),
                clone_frac: rng.range_f64(0.0, 0.9),
            };
            community_graph(&cfg, rng.next_u64()).0
        }
        2 => {
            let cfg = EgoCliqueCfg {
                num_graphs: rng.range_usize(2, 12),
                total_nodes: rng.range_usize(30, 200),
                total_edges: rng.range_usize(100, 2000),
                classes: 2,
            };
            let (gs, _) = ego_clique_set(&cfg, rng.next_u64());
            Graph::disjoint_union(&gs).0
        }
        _ => {
            // star + chain (hub-heavy, BFS-adversarial)
            let n = rng.range_usize(3, 60);
            let mut b = GraphBuilder::new(n);
            for v in 1..n as u32 {
                b.edge(0, v);
                if v > 1 {
                    b.edge(v - 1, v);
                }
            }
            b.build()
        }
    }
}

#[test]
fn prop_every_node_in_exactly_one_shard() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(7000 + case as u64);
        let g = random_graph(&mut rng);
        for k in [1usize, 2, 3, 4, 7] {
            let cfg = PartitionConfig::new(k)
                .with_seed(rng.next_u64());
            let p = partition_bfs(&g, &cfg);
            assert_eq!(p.members.len(), k.max(1));
            // shard_of is total and in-range
            assert_eq!(p.shard_of.len(), g.n());
            assert!(p.shard_of.iter()
                        .all(|&s| (s as usize) < k.max(1)),
                    "case {case} k={k}: out-of-range shard id");
            // members lists are a disjoint exhaustive cover
            let mut seen = vec![false; g.n()];
            for (s, mem) in p.members.iter().enumerate() {
                for &v in mem {
                    assert!(!seen[v as usize],
                            "case {case} k={k}: node {v} in 2 shards");
                    seen[v as usize] = true;
                    assert_eq!(p.shard_of[v as usize], s as u32);
                }
            }
            assert!(seen.iter().all(|&x| x),
                    "case {case} k={k}: unassigned node");
        }
    }
}

#[test]
fn prop_shard_weights_within_balance_factor() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(7100 + case as u64);
        let g = random_graph(&mut rng);
        if g.n() == 0 {
            continue;
        }
        let balance = 1.25;
        for k in [2usize, 4] {
            let cfg = PartitionConfig::new(k)
                .with_seed(rng.next_u64())
                .with_balance(balance);
            let p = partition_bfs(&g, &cfg);
            let r = p.report(&g);
            // Bound from the partitioner contract: a shard stops at
            // `ideal`, never admits a node past `ideal * balance`
            // (unless it is that node's only possible home), and the
            // leftover pass only tops up the lightest shard — so one
            // node of weight w_max is the worst overshoot. Node weight
            // is 1 + total (in + out) degree, computed exactly here.
            let mut tdeg = vec![0usize; g.n()];
            for (v, ns) in g.iter() {
                tdeg[v as usize] += ns.len();
                for &u in ns {
                    tdeg[u as usize] += 1;
                }
            }
            let w_max = tdeg.iter().map(|&d| 1.0 + d as f64)
                .fold(0.0f64, f64::max);
            let bound = r.ideal_weight * balance + w_max;
            for (s, &w) in r.shard_weight.iter().enumerate() {
                assert!(w <= bound + 1e-6,
                        "case {case} k={k} shard {s}: weight {w} > \
                         bound {bound} (ideal {})", r.ideal_weight);
            }
        }
    }
}

#[test]
fn prop_stitched_hag_valid_and_equivalent() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(7200 + case as u64);
        let g = random_graph(&mut rng);
        for k in [2usize, 3, 4] {
            let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
                capacity: match rng.range_usize(0, 3) {
                    0 => g.n() / 4,
                    1 => g.n(),
                    _ => usize::MAX,
                },
                kind: AggregateKind::Set,
                pair_cap: match rng.range_usize(0, 3) {
                    0 => 8,
                    1 => 64,
                    _ => usize::MAX,
                },
            };
            let (hag, stats) =
                search_sharded_seeded(&g, k, &cfg, 7200 + case as u64);
            hag.validate().unwrap_or_else(|e| {
                panic!("case {case} k={k}: invalid stitched HAG: {e}")
            });
            check_equivalence(&g, &hag).unwrap_or_else(|e| {
                panic!("case {case} k={k}: not equivalent: {e}")
            });
            check_equivalence_probabilistic(&g, &hag, case as u64)
                .unwrap();
            assert!(hag.agg_nodes.len() <= cfg.capacity,
                    "case {case} k={k}: global capacity violated");
            assert_eq!(stats.per_shard.len(), k);
        }
    }
}

/// Satellite property: the stitched HAG's `cost_core` is never worse
/// than the original graph's — partitioning can only miss merges,
/// never add aggregations.
#[test]
fn prop_stitched_cost_never_worse_than_graph() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(7300 + case as u64);
        let g = random_graph(&mut rng);
        let trivial = Hag::from_graph(&g, AggregateKind::Set);
        for k in [2usize, 4, 6] {
            let cfg = SearchConfig::paper_default(g.n());
            let (hag, _) =
                search_sharded_seeded(&g, k, &cfg, case as u64);
            assert!(hag.cost_core() <= trivial.cost_core(),
                    "case {case} k={k}: stitched cost {} > graph {}",
                    hag.cost_core(), trivial.cost_core());
            // and per-layer aggregations cannot increase either
            assert!(hag.aggregations() <= trivial.aggregations(),
                    "case {case} k={k}: aggregations increased");
        }
    }
}

/// Acceptance check: on a clique-structured generator graph (the
/// COLLAB/IMDB regime) 4-way sharding stays within 10% of the
/// single-shard search cost — the partitioner aligns shard boundaries
/// with the block structure, so almost no merge straddles the cut.
#[test]
fn sharded_cost_within_10pct_on_clique_generator() {
    let cfg = EgoCliqueCfg {
        num_graphs: 60,
        total_nodes: 1200,
        total_edges: 14_000,
        classes: 2,
    };
    let (gs, _) = ego_clique_set(&cfg, 7);
    let (g, _) = Graph::disjoint_union(&gs);
    let sc = SearchConfig::paper_default(g.n());
    let (single, _) = hag_search(&g, &sc);
    let (sharded, stats) = search_sharded(&g, 4, &sc);
    sharded.validate().unwrap();
    check_equivalence(&g, &sharded).unwrap();
    let gap = sharded.cost_core() as f64
        / single.cost_core().max(1) as f64;
    assert!(gap <= 1.10,
            "sharded cost {} vs single {} (gap {:.3}, cut {:.2}%)",
            sharded.cost_core(), single.cost_core(), gap,
            100.0 * stats.report.cut_frac);
}

/// Community graphs (the node-classification regime): the
/// locality-greedy partitioner must keep the cut small enough that the
/// sharded search retains most of the redundancy win.
#[test]
fn sharded_cost_close_on_community_generator() {
    let cfg = CommunityCfg {
        n: 2_000,
        e: 40_000,
        communities: 16,
        intra_frac: 0.9,
        zipf_exp: 0.9,
        clone_frac: 0.5,
    };
    let (g, _) = community_graph(&cfg, 42);
    let sc = SearchConfig::paper_default(g.n());
    let (single, _) = hag_search(&g, &sc);
    let (sharded, stats) = search_sharded(&g, 4, &sc);
    check_equivalence_probabilistic(&g, &sharded, 42).unwrap();
    let gap = sharded.cost_core() as f64
        / single.cost_core().max(1) as f64;
    // looser than the clique case: ~10% of edges are inter-community
    // by construction and a fraction of those must land in the cut
    assert!(gap <= 1.25,
            "sharded cost {} vs single {} (gap {:.3}, cut {:.2}%)",
            sharded.cost_core(), single.cost_core(), gap,
            100.0 * stats.report.cut_frac);
    // sharding must still beat the no-search baseline by a wide margin
    assert!(sharded.cost_core() < g.e(),
            "sharded search found no redundancy at all");
}

#[test]
fn search_partitioned_respects_custom_partition() {
    // two disconnected K6s: a precomputed partition must cut nothing,
    // and the per-shard searches must find everything the whole-graph
    // search finds
    let mut edges = Vec::new();
    for base in [0u32, 6] {
        for i in 0..6 {
            for j in 0..6 {
                if i != j {
                    edges.push((base + i, base + j));
                }
            }
        }
    }
    let g = Graph::from_edges(12, &edges);
    let part = partition_bfs(&g, &PartitionConfig::new(2));
    let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
        capacity: usize::MAX,
        kind: AggregateKind::Set,
        pair_cap: usize::MAX,
    };
    let (hag, stats) = search_partitioned(&g, &part, &cfg);
    check_equivalence(&g, &hag).unwrap();
    assert_eq!(stats.report.cut_edges, 0, "cliques are disconnected");
    let (single, _) = hag_search(&g, &cfg);
    assert_eq!(hag.cost_core(), single.cost_core());
}
