//! Integration tests across the full stack: search -> plan -> pack ->
//! PJRT execute. These need `make artifacts` (the default `tiny*` and
//! dataset buckets); they skip gracefully when artifacts are missing so
//! `cargo test` stays green on a fresh checkout.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use repro::coordinator::{self, pack_workload, Repr};
use repro::datasets;
use repro::hag::check_equivalence;
use repro::runtime::Runtime;
use repro::session::{LowerSpec, Session};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime_or_skip() -> Option<Arc<Runtime>> {
    match Runtime::open(artifacts_dir()) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e:#}");
            None
        }
    }
}

/// The fundamental §5.3 claim, end to end: identical loss trajectories
/// under GNN-graph and HAG representations (same math, same init).
#[test]
fn training_trajectories_identical_across_reprs() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("BZR", 0.05, 7);
    let mut finals = Vec::new();
    for repr in [Repr::GnnGraph, Repr::Hag] {
        let lowered = Session::new(&ds, LowerSpec::default()
            .with_repr(repr)).lower().unwrap();
        check_equivalence(&ds.graph, &lowered.hag).unwrap();
        let name = coordinator::artifact_name("gcn", "train",
                                              &lowered.bucket);
        if rt.spec(&name).is_err() {
            eprintln!("skipping: artifact {name} missing");
            return;
        }
        let workload =
            pack_workload(&ds, &lowered.plan, &lowered.bucket).unwrap();
        let mut trainer = coordinator::Trainer::new(
            rt.clone(), &name, &workload, 7).unwrap();
        let report = trainer.train(8, 0).unwrap();
        assert!(report.final_loss().is_finite());
        finals.push(report.epochs.iter().map(|e| e.loss)
            .collect::<Vec<_>>());
    }
    for (a, b) in finals[0].iter().zip(&finals[1]) {
        assert!((a - b).abs() < 2e-3,
                "loss trajectories diverged: {a} vs {b}");
    }
}

/// Training must actually learn: loss decreases substantially.
#[test]
fn training_converges_on_ppi() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("PPI", 0.05, 7);
    let lowered =
        Session::new(&ds, LowerSpec::default()).lower().unwrap();
    let name =
        coordinator::artifact_name("gcn", "train", &lowered.bucket);
    if rt.spec(&name).is_err() {
        eprintln!("skipping: artifact {name} missing");
        return;
    }
    let workload =
        pack_workload(&ds, &lowered.plan, &lowered.bucket).unwrap();
    let mut trainer =
        coordinator::Trainer::new(rt, &name, &workload, 7).unwrap();
    let report = trainer.train(30, 0).unwrap();
    let first = report.epochs[0].loss;
    let last = report.final_loss();
    assert!(last < first * 0.7,
            "no convergence: {first} -> {last}");
    assert!(report.final_accuracy() > 0.5,
            "accuracy too low: {}", report.final_accuracy());
}

/// Inference logits match across representations (forward equivalence
/// through the compiled artifacts, not just in-python).
#[test]
fn inference_logits_equivalent_across_reprs() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("BZR", 0.05, 7);
    let mut outputs: Vec<Vec<f32>> = Vec::new();
    for repr in [Repr::GnnGraph, Repr::Hag] {
        let lowered = Session::new(&ds, LowerSpec::default()
            .with_repr(repr)).lower().unwrap();
        let name = coordinator::artifact_name("gcn", "infer",
                                              &lowered.bucket);
        if rt.spec(&name).is_err() {
            eprintln!("skipping: artifact {name} missing");
            return;
        }
        let workload =
            pack_workload(&ds, &lowered.plan, &lowered.bucket).unwrap();
        let exe = rt.compile(&name).unwrap();
        // params: same seed => same host-side init for both reprs
        let pspecs: Vec<_> = exe.spec.inputs.iter()
            .filter(|s| !matches!(s.name.as_str(), "h0" | "deg")
                    && !s.name.starts_with("lvl_")
                    && !s.name.starts_with("band"))
            .cloned().collect();
        let params =
            coordinator::trainer::init_params(&pspecs, 99);
        let mut inputs = Vec::new();
        let mut pi = 0;
        for s in &exe.spec.inputs {
            if matches!(s.name.as_str(), "h0" | "deg")
                || s.name.starts_with("lvl_")
                || s.name.starts_with("band")
            {
                inputs.push(workload.get(&s.name).unwrap().clone());
            } else {
                inputs.push(params[pi].clone());
                pi += 1;
            }
        }
        let outs = rt.run(&name, &inputs).unwrap();
        let logits = outs[0].as_f32().unwrap();
        // un-permute to original node order for comparison
        let un = coordinator::unpermute_rows(&lowered.plan, logits,
                                             exe.spec.bucket.classes);
        outputs.push(un);
    }
    let (a, b) = (&outputs[0], &outputs[1]);
    assert_eq!(a.len(), b.len());
    let max_abs = a.iter().map(|x| x.abs()).fold(0f32, f32::max);
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < 1e-4 * (1.0 + max_abs),
                "logit mismatch: {x} vs {y}");
    }
}

/// Graph classification path end to end (IMDB stand-in).
#[test]
fn graph_classification_trains() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("IMDB", 0.05, 7);
    let lowered =
        Session::new(&ds, LowerSpec::default()).lower().unwrap();
    let name =
        coordinator::artifact_name("gcn", "train", &lowered.bucket);
    if rt.spec(&name).is_err() {
        eprintln!("skipping: artifact {name} missing");
        return;
    }
    let workload =
        pack_workload(&ds, &lowered.plan, &lowered.bucket).unwrap();
    let mut trainer =
        coordinator::Trainer::new(rt, &name, &workload, 7).unwrap();
    let report = trainer.train(25, 0).unwrap();
    assert!(report.final_loss() < report.epochs[0].loss,
            "graph-cls loss must decrease");
}

/// The serving path: spawn, drive concurrent clients, shut down.
#[test]
fn serving_path_round_trips() {
    if Runtime::open(artifacts_dir()).is_err() {
        return;
    }
    let ds = datasets::load("BZR", 0.05, 7);
    let lowered =
        Session::new(&ds, LowerSpec::default()).lower().unwrap();
    let name =
        coordinator::artifact_name("gcn", "infer", &lowered.bucket);
    {
        let rt = Runtime::open(artifacts_dir()).unwrap();
        if rt.spec(&name).is_err() {
            eprintln!("skipping: artifact {name} missing");
            return;
        }
    }
    let workload =
        pack_workload(&ds, &lowered.plan, &lowered.bucket).unwrap();
    let server = coordinator::InferenceServer::spawn(
        artifacts_dir(), &name, &workload, &lowered.plan,
        &lowered.bucket, coordinator::BatchPolicy::default(), 7,
        None).unwrap();
    let n = ds.n() as u32;
    let f_in = ds.f_in;
    let classes = ds.classes;
    let mut clients = Vec::new();
    for c in 0..4u64 {
        let tx = server.client();
        clients.push(std::thread::spawn(move || {
            let mut rng = repro::util::Rng::seed_from_u64(c);
            for _ in 0..25 {
                let (otx, orx) = coordinator::server::oneshot();
                tx.send(coordinator::ServerMsg::Score(
                    coordinator::ScoreRequest {
                        node: rng.range_u32(0, n),
                        features: (0..f_in)
                            .map(|_| rng.range_f32(-1.0, 1.0))
                            .collect(),
                        reply: otx,
                        submitted: std::time::Instant::now(),
                        pin_epoch: None,
                    })).unwrap();
                let ok = orx.recv().unwrap().into_result()
                    .expect("scored");
                assert_eq!(ok.logits.len(), classes);
                assert!(ok.logits.iter().all(|x| x.is_finite()));
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    let stats = server.shutdown();
    assert_eq!(stats.requests, 100);
    assert!(stats.batches >= 1);
    assert!(stats.p50_ms.is_finite());
}

/// Bucket/plan mismatch must fail loudly, not crash XLA.
#[test]
fn wrong_bucket_is_rejected_cleanly() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = datasets::load("BZR", 0.05, 7);
    // lower under HAG but address the GNN artifact: shapes differ
    let hag =
        Session::new(&ds, LowerSpec::default()).lower().unwrap();
    let gnn = Session::new(&ds, LowerSpec::default()
        .with_repr(Repr::GnnGraph)).lower().unwrap();
    let gnn_name =
        coordinator::artifact_name("gcn", "train", &gnn.bucket);
    if rt.spec(&gnn_name).is_err() {
        return;
    }
    // packing the HAG plan against the GNN bucket must error
    assert!(pack_workload(&ds, &hag.plan, &gnn.bucket).is_err());
}
