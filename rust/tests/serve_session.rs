//! Session-aware serving path, end to end on the host reference
//! executor (no artifacts needed — the artifacts dir deliberately does
//! not exist, so the worker always falls back): hardened request
//! validation, coalesced update flushes into the resident
//! engine+session pair, the session-fed hot plan swap, and the
//! serving-path plan-cache contract (`plan() == plan_fresh()` with
//! full tensor equality, `shard_cache_hits > 0` under a localized
//! stream).

use std::path::PathBuf;
use std::time::Instant;

use repro::coordinator::{self, BatchPolicy, Resident, ScoreReject,
                         ScoreResponse, SwapPolicy};
use repro::datasets::{self, Dataset};
use repro::incremental::{DriftPolicy, GraphDelta};
use repro::session::{LowerSpec, Session};
use repro::util::Rng;

/// Artifacts dir that does not exist: forces the reference executor
/// regardless of what the checkout has compiled.
fn no_artifacts() -> PathBuf {
    std::env::temp_dir().join("repro-serve-session-no-artifacts")
}

fn bzr() -> Dataset {
    datasets::load("BZR", 0.02, 7)
}

fn spawn(ds: &Dataset, spec: LowerSpec, swap: Option<SwapPolicy>)
         -> (coordinator::InferenceServer, usize) {
    let mut session = Session::new(ds, spec);
    let lowered = session.lower().unwrap();
    let resident = swap.map(|swap| {
        Resident::new(session, &ds.graph, &lowered.hag, swap)
    });
    let server = coordinator::InferenceServer::for_lowered(
        no_artifacts(), "gcn", ds, &lowered, BatchPolicy::default(),
        7, resident).unwrap();
    (server, ds.classes)
}

fn send_score(server: &coordinator::InferenceServer, node: u32,
              features: Vec<f32>) -> ScoreResponse {
    send_score_pinned(server, node, features, None)
}

fn send_score_pinned(server: &coordinator::InferenceServer, node: u32,
                     features: Vec<f32>, pin_epoch: Option<u64>)
                     -> ScoreResponse {
    let (otx, orx) = coordinator::server::oneshot();
    server.client()
        .send(coordinator::ServerMsg::Score(coordinator::ScoreRequest {
            node,
            features,
            reply: otx,
            submitted: Instant::now(),
            pin_epoch,
        }))
        .expect("queue open");
    orx.recv().expect("batcher alive")
}

fn send_update(server: &coordinator::InferenceServer,
               delta: GraphDelta) -> coordinator::UpdateResponse {
    let (otx, orx) = coordinator::server::update_oneshot();
    server.client()
        .send(coordinator::ServerMsg::Update(
            coordinator::UpdateRequest {
                delta,
                reply: Some(otx),
                submitted: Instant::now(),
            }))
        .expect("queue open");
    orx.recv().expect("batcher alive")
}

#[test]
fn hostile_requests_get_error_replies_not_panics() {
    let ds = bzr();
    let n = ds.n();
    let (server, classes) = spawn(&ds, LowerSpec::default(), None);
    // out-of-range node
    match send_score(&server, n as u32 + 42, Vec::new()) {
        ScoreResponse::Err(e) => assert_eq!(
            e.reject,
            ScoreReject::NodeOutOfRange { node: n as u32 + 42, n }),
        r => panic!("expected rejection, got ok={}", r.is_ok()),
    }
    // wrong-length feature row
    match send_score(&server, 0, vec![0.0; ds.f_in + 3]) {
        ScoreResponse::Err(e) => assert_eq!(
            e.reject,
            ScoreReject::FeatureLen { got: ds.f_in + 3,
                                      want: ds.f_in }),
        r => panic!("expected rejection, got ok={}", r.is_ok()),
    }
    // the batcher survived both: valid requests still score
    let ok = send_score(&server, 0, Vec::new())
        .into_result().expect("empty features keep current row");
    assert_eq!(ok.logits.len(), classes);
    let ok = send_score(&server, 1, vec![0.5; ds.f_in])
        .into_result().expect("valid request scored");
    assert!(ok.logits.iter().all(|x| x.is_finite()));
    let stats = server.shutdown();
    assert_eq!(stats.rejected, 2);
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.exec_failures, 0);
}

#[test]
fn node_add_is_rejected_before_swap() {
    let ds = bzr();
    let n = ds.n() as u32;
    // +INF threshold: the session rides along but never swaps, so the
    // serving plan stays pinned at the original n.
    let spec = LowerSpec::default().with_shards(2).with_drift(
        DriftPolicy::default().with_threshold(f64::INFINITY));
    let (server, _) = spawn(&ds, spec,
                            Some(SwapPolicy { swap_plans: true,
                                              max_pending: 1 }));
    let resp = send_update(&server, GraphDelta::NodeAdd);
    assert_eq!(resp.outcome,
               repro::incremental::ApplyOutcome::NodeAdded);
    assert_eq!(resp.seq, 1);
    // the added node exceeds the pinned plan: error outcome, no panic
    match send_score(&server, n, vec![0.1; ds.f_in]) {
        ScoreResponse::Err(e) => assert_eq!(
            e.reject,
            ScoreReject::NodeOutOfRange { node: n, n: n as usize }),
        r => panic!("pre-swap NodeAdd score must fail, got ok={}",
                    r.is_ok()),
    }
    let out = server.shutdown_outcome();
    assert_eq!(out.stats.plan_swaps, 0, "threshold INF never swaps");
    let res = out.resident.expect("resident handed back");
    assert_eq!(res.session.n(), n as usize + 1);
    assert_eq!(res.engine.n(), n as usize + 1);
}

#[test]
fn node_add_scores_after_session_fed_swap() {
    let ds = bzr();
    let n = ds.n() as u32;
    // negative threshold: swap at every flush
    let spec = LowerSpec::default().with_shards(2).with_drift(
        DriftPolicy::default().with_threshold(-1.0));
    let (server, classes) = spawn(&ds, spec,
                                  Some(SwapPolicy { swap_plans: true,
                                                    max_pending: 1 }));
    let resp = send_update(&server, GraphDelta::NodeAdd);
    assert_eq!(resp.outcome,
               repro::incremental::ApplyOutcome::NodeAdded);
    // wire the new node in (same flush granularity: max_pending 1)
    let resp = send_update(&server,
                           GraphDelta::EdgeInsert { src: 0, dst: n });
    assert_eq!(resp.outcome,
               repro::incremental::ApplyOutcome::Inserted);
    // the swap published a plan covering the added node
    let ok = send_score(&server, n, vec![0.25; ds.f_in])
        .into_result().expect("post-swap NodeAdd score succeeds");
    assert_eq!(ok.logits.len(), classes);
    assert!(ok.logits.iter().all(|x| x.is_finite()));
    let out = server.shutdown_outcome();
    assert!(out.stats.plan_swaps >= 1,
            "session-fed swap must have landed: {:?}", out.stats);
    assert_eq!(out.stats.plan_matches_fresh, Some(true));
    let res = out.resident.unwrap();
    assert_eq!(res.session.n(), n as usize + 1);
}

#[test]
fn epoch_pinned_reads_reject_after_forced_swap() {
    let ds = bzr();
    let n = ds.n() as u32;
    let spec = LowerSpec::default().with_shards(2).with_drift(
        DriftPolicy::default().with_threshold(-1.0));
    let (server, classes) = spawn(&ds, spec,
                                  Some(SwapPolicy { swap_plans: true,
                                                    max_pending: 1 }));

    // The setup plan serves as epoch 1 (0 is reserved for unpinned).
    assert_eq!(server.epoch(), 1);
    let ok = send_score(&server, 0, vec![0.5; ds.f_in])
        .into_result().expect("fresh plan scores");
    let e0 = ok.epoch;
    assert_eq!(e0, 1);

    // Pinning at the serving epoch answers normally.
    let ok = send_score_pinned(&server, 0, vec![0.5; ds.f_in],
                               Some(e0))
        .into_result().expect("current pin answers");
    assert_eq!(ok.epoch, e0);

    // Force a real plan change: grow the graph, then wire the new
    // node in (a bare edge insert can coalesce into a
    // tensor-identical plan, which must not bump the epoch).
    send_update(&server, GraphDelta::NodeAdd);
    send_update(&server, GraphDelta::EdgeInsert { src: 0, dst: n });

    let ok = send_score(&server, 0, vec![0.5; ds.f_in])
        .into_result().expect("post-swap scores");
    let e2 = ok.epoch;
    assert!(e2 > e0, "swap must bump the epoch: {e0} -> {e2}");
    assert_eq!(server.epoch(), e2);

    // A stale pin gets a structured mismatch carrying both epochs —
    // never a silent answer from the wrong plan.
    match send_score_pinned(&server, 0, vec![0.5; ds.f_in], Some(e0)) {
        ScoreResponse::Err(e) => {
            assert_eq!(e.reject,
                       ScoreReject::EpochMismatch { pinned: e0,
                                                    current: e2 });
            assert_eq!(e.epoch, e2);
        }
        r => panic!("stale pin must be rejected, got ok={}",
                    r.is_ok()),
    }

    // Re-pinning at the new epoch works.
    let ok = send_score_pinned(&server, 0, vec![0.5; ds.f_in],
                               Some(e2))
        .into_result().expect("re-pin answers");
    assert_eq!(ok.epoch, e2);
    assert_eq!(ok.logits.len(), classes);

    let stats = server.shutdown();
    assert!(stats.plan_swaps >= 1,
            "epoch bump must come from a real swap: {stats:?}");
    assert_eq!(stats.rejected, 1);
}

#[test]
fn localized_stream_serves_post_drift_plan_from_shard_cache() {
    let ds = bzr();
    let spec = LowerSpec::default().with_shards(4).with_drift(
        DriftPolicy::default().with_threshold(-1.0));
    // shard map from an identically specced session (deterministic
    // partition seed => same shards as the resident one)
    let probe = Session::new(&ds, spec.clone());
    let members: Vec<u32> = (0..ds.n() as u32)
        .filter(|&v| probe.shard_of(v) == 0)
        .collect();
    assert!(members.len() >= 2, "shard 0 too small to localize");
    let (server, _) = spawn(&ds, spec,
                            Some(SwapPolicy { swap_plans: true,
                                              max_pending: 4 }));
    let mut rng = Rng::seed_from_u64(23);
    for i in 0..48usize {
        let a = members[rng.range_usize(0, members.len())];
        let b = members[rng.range_usize(0, members.len())];
        if a == b {
            continue;
        }
        let _ = send_update(&server,
                            GraphDelta::EdgeInsert { src: a, dst: b });
        if i % 6 == 0 {
            // interleaved scoring keeps batches (and flushes) moving
            let node = rng.range_u32(0, ds.n() as u32);
            send_score(&server, node, vec![0.5; ds.f_in])
                .into_result().expect("scored");
        }
    }
    let out = server.shutdown_outcome();
    let stats = &out.stats;
    assert!(stats.plan_swaps >= 1, "drift must swap: {stats:?}");
    assert!(stats.shard_cache_hits > 0,
            "localized stream must hit clean-shard cache: {stats:?}");
    assert_eq!(stats.plan_matches_fresh, Some(true),
               "serving-path contract: {stats:?}");
    // …and the same contract asserted directly on the handed-back
    // session: full tensor identity of cached vs from-scratch plans.
    let mut res = out.resident.unwrap();
    let (hag_c, plan_c) = res.session.plan();
    let (hag_f, plan_f) = res.session.plan_fresh();
    assert_eq!(*hag_c, hag_f);
    assert_eq!(*plan_c, plan_f);
    // engine and session stayed in lockstep over the coalesced flushes
    assert_eq!(res.engine.n(), res.session.n());
    assert_eq!(res.engine.e(), res.session.e());
    assert_eq!(res.engine.graph(), res.session.graph());
}

#[test]
fn update_heavy_stream_with_node_adds_keeps_lockstep() {
    // Random mixed stream (inserts, deletes, NodeAdds) through the
    // public queue: coalescing barriers must preserve semantics, and
    // the swap must keep serving valid logits throughout.
    let ds = bzr();
    let spec = LowerSpec::default().with_shards(3).with_drift(
        DriftPolicy::default().with_threshold(-1.0));
    let (server, classes) = spawn(&ds, spec,
                                  Some(SwapPolicy { swap_plans: true,
                                                    max_pending: 8 }));
    let mut mirror = repro::incremental::OverlayGraph::new(
        ds.graph.clone());
    let mut rng = Rng::seed_from_u64(41);
    for i in 0..60usize {
        let d = repro::incremental::random_delta(&mut rng, &mirror,
                                                 0.6, 0.05);
        mirror.apply(d);
        let _ = send_update(&server, d);
        if i % 10 == 0 {
            let ok = send_score(&server,
                                rng.range_u32(0, ds.n() as u32),
                                vec![0.1; ds.f_in])
                .into_result().expect("scored mid-stream");
            assert_eq!(ok.logits.len(), classes);
        }
    }
    let out = server.shutdown_outcome();
    assert_eq!(out.stats.plan_matches_fresh, Some(true));
    let res = out.resident.unwrap();
    assert_eq!(res.engine.n(), mirror.n());
    assert_eq!(res.engine.e(), mirror.e());
    assert_eq!(res.session.n(), mirror.n());
    assert_eq!(res.session.e(), mirror.e());
}
