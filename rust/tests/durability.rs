//! Durability property tests (DESIGN.md §14): crash the WAL at
//! *every byte offset* and prove recovery never panics, recovers
//! exactly the longest valid record prefix, truncates the tail
//! physically, and replays into state that passes haglint and the
//! Theorem-1 equivalence oracle.

use std::collections::HashSet;
use std::path::PathBuf;

use repro::analysis::{verify, HagCtx};
use repro::durability::{recover, wal, Recovered, Wal};
use repro::graph::Graph;
use repro::hag::check_equivalence;
use repro::incremental::{GraphDelta, StreamConfig, StreamEngine};
use repro::session::{LowerSpec, Session};

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "repro-dur-prop-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Base graph the recorded history applies to.
fn base_graph() -> Graph {
    Graph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 2),
                           (1, 3), (4, 5)])
}

/// Mixed history: grow, wire, delete — every prefix is itself a
/// valid history, and several prefixes change the planned HAG.
fn history() -> Vec<GraphDelta> {
    vec![
        GraphDelta::NodeAdd, // node 6
        GraphDelta::EdgeInsert { src: 0, dst: 6 },
        GraphDelta::EdgeInsert { src: 1, dst: 6 },
        GraphDelta::EdgeDelete { src: 0, dst: 2 },
        GraphDelta::EdgeInsert { src: 6, dst: 5 },
        GraphDelta::EdgeDelete { src: 1, dst: 3 },
    ]
}

/// Record the history into a WAL, one commit per record (every
/// record boundary is a commit boundary). Returns the segment's full
/// byte image and `ends[k]` = the file length that covers exactly
/// `k` records (`ends[0]` = the magic).
fn record_reference_wal() -> (Vec<u8>, Vec<usize>) {
    let dir = tmpdir("ref");
    let mut w = Wal::open(&dir, 1).unwrap();
    let seg = wal::list_segments(&dir).unwrap().remove(0).1;
    let mut ends =
        vec![std::fs::metadata(&seg).unwrap().len() as usize];
    for &d in &history() {
        w.append(d).unwrap();
        w.commit().unwrap();
        ends.push(std::fs::metadata(&seg).unwrap().len() as usize);
    }
    drop(w);
    let bytes = std::fs::read(&seg).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(ends[0], wal::MAGIC.len());
    assert_eq!(*ends.last().unwrap(), bytes.len());
    (bytes, ends)
}

/// Replay a recovery result into a fresh engine/session pair and run
/// the full verification stack on the outcome: Theorem-1 equivalence
/// on the maintained HAG, haglint on the planned HAG + plan, and the
/// incremental-equals-from-scratch identity.
fn validate_replay(rec: &Recovered, expect: usize) {
    let g = base_graph();
    let cfg = StreamConfig::default();
    let mut engine = StreamEngine::new(&g, cfg.clone());
    let mut session = Session::from_graph(&g, LowerSpec::default());
    let rep = resume(rec, &mut engine, &mut session, &cfg);
    assert_eq!(rep, expect);

    let hag = engine.to_hag();
    check_equivalence(&engine.graph(), &hag)
        .unwrap_or_else(|e| panic!("prefix {expect}: {e}"));

    let cur = session.graph();
    let (shag, plan) = session.plan();
    let lint = verify(&HagCtx::new(&cur, &shag).with_plan(&plan));
    assert!(lint.is_clean(),
            "haglint at prefix {expect}:\n{}", lint.format());

    let (hag_f, plan_f) = session.plan_fresh();
    assert_eq!(*shag, hag_f, "prefix {expect}: HAG diverged");
    assert_eq!(*plan, plan_f, "prefix {expect}: plan diverged");
}

fn resume(rec: &Recovered, engine: &mut StreamEngine,
          session: &mut Session, cfg: &StreamConfig) -> usize {
    repro::durability::resume_pair(rec, engine, session, cfg)
        .expect("replay")
        .session_replayed
}

#[test]
fn truncation_at_every_byte_offset_recovers_the_valid_prefix() {
    let _g = repro::fault::exclusive();
    repro::fault::reset();
    let (bytes, ends) = record_reference_wal();
    let hist = history();

    let dir = tmpdir("trunc");
    let seg = dir.join(format!("wal-{:020}.log", 1));
    let mut validated: HashSet<usize> = HashSet::new();
    for cut in 0..=bytes.len() {
        std::fs::write(&seg, &bytes[..cut]).unwrap();
        let rec = recover(&dir)
            .unwrap_or_else(|e| panic!("cut {cut}: {e}"));

        // Exactly the records whose commit fit inside the cut.
        let expect =
            ends[1..].iter().filter(|&&e| e <= cut).count();
        assert_eq!(rec.deltas.len(), expect, "cut at byte {cut}");
        for (i, &(seq, d)) in rec.deltas.iter().enumerate() {
            assert_eq!(seq, i as u64 + 1, "cut {cut}: seq order");
            assert_eq!(d, hist[i], "cut {cut}: delta {i}");
        }
        assert_eq!(rec.tail_seq, expect as u64);

        // The torn bytes were truncated away, physically: the file
        // now ends at the last valid record, and a second recovery
        // finds nothing left to cut.
        let valid_end = if cut < ends[0] { 0 } else { ends[expect] };
        assert_eq!(rec.truncated_bytes as usize, cut - valid_end,
                   "cut at byte {cut}");
        assert_eq!(std::fs::metadata(&seg).unwrap().len() as usize,
                   valid_end);
        let rec2 = recover(&dir).unwrap();
        assert_eq!(rec2.truncated_bytes, 0, "cut {cut}: idempotent");
        assert_eq!(rec2.deltas.len(), expect);

        // Full verification once per distinct surviving prefix.
        if validated.insert(expect) {
            validate_replay(&rec, expect);
        }
    }
    assert_eq!(validated.len(), hist.len() + 1,
               "every prefix length was exercised");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corruption_at_every_byte_offset_yields_a_clean_prefix() {
    let _g = repro::fault::exclusive();
    repro::fault::reset();
    let (bytes, ends) = record_reference_wal();
    let hist = history();

    let dir = tmpdir("flip");
    let seg = dir.join(format!("wal-{:020}.log", 1));
    for pos in 0..bytes.len() {
        let mut b = bytes.clone();
        b[pos] ^= 0xFF;
        std::fs::write(&seg, &b).unwrap();
        let rec = recover(&dir)
            .unwrap_or_else(|e| panic!("flip {pos}: {e}"));

        // The record containing the flipped byte fails its CRC (or
        // the magic/length sanity checks); everything before it
        // survives, nothing after it is replayed.
        let intact =
            ends[1..].iter().filter(|&&e| e <= pos).count();
        assert_eq!(rec.deltas.len(), intact, "flip at byte {pos}");
        for (i, &(seq, d)) in rec.deltas.iter().enumerate() {
            assert_eq!(seq, i as u64 + 1);
            assert_eq!(d, hist[i], "flip {pos}: delta {i}");
        }
        assert!(rec.truncated_bytes > 0,
                "flip {pos}: the damage was cut away");
    }
    std::fs::remove_dir_all(&dir).ok();
}
