//! Randomized property tests over the HAG core (seeded, deterministic;
//! the proptest crate is not vendored here, so cases are generated with
//! the in-tree RNG — shrinkage is traded for a printed failing seed).
//!
//! Invariants covered, per random graph:
//! * Theorem 1: the searched HAG is equivalent (exact cover check);
//! * validity: topological agg-node order, no duplicate in-slots;
//! * cost model: search never increases cost; cost is monotone in
//!   capacity; every merge saves at least one aggregation;
//! * plan compiler: simulated plan execution reproduces CSR
//!   aggregation exactly (all padding/permutation/banding correct);
//! * determinism: search and plans are bit-identical across runs.

use repro::datasets::{community_graph, ego_clique_set, CommunityCfg,
                      EgoCliqueCfg};
use repro::graph::{Graph, GraphBuilder};
use repro::obs::cost::calibrated_cost;
use repro::hag::{build_plan, check_equivalence,
                 check_equivalence_probabilistic, hag_search,
                 hag_search_reference, hag_search_with_scratch,
                 AggregateKind, ExecutionPlan, Hag, PlanConfig,
                 SearchConfig, SearchScratch};
use repro::util::Rng;

const CASES: usize = 30;

/// Random graph families exercised by every property.
fn random_graph(rng: &mut Rng) -> Graph {
    match rng.range_usize(0, 4) {
        0 => {
            // Erdos-Renyi-ish
            let n = rng.range_usize(2, 120);
            let mut b = GraphBuilder::new(n);
            let e = rng.range_usize(0, n * 6 + 1);
            for _ in 0..e {
                let u = rng.range_usize(0, n) as u32;
                let v = rng.range_usize(0, n) as u32;
                if u != v {
                    b.edge(u, v);
                }
            }
            b.build()
        }
        1 => {
            // community (the HAG-friendly regime)
            let n = rng.range_usize(50, 400);
            let cfg = CommunityCfg {
                n,
                e: n * rng.range_usize(2, 12),
                communities: rng.range_usize(2, 9),
                intra_frac: rng.range_f64(0.6, 1.0),
                zipf_exp: rng.range_f64(0.5, 1.3),
                clone_frac: rng.range_f64(0.0, 0.9),
            };
            community_graph(&cfg, rng.next_u64()).0
        }
        2 => {
            // batched cliques (graph classification shape)
            let cfg = EgoCliqueCfg {
                num_graphs: rng.range_usize(2, 12),
                total_nodes: rng.range_usize(30, 200),
                total_edges: rng.range_usize(100, 2000),
                classes: 2,
            };
            let (gs, _) = ego_clique_set(&cfg, rng.next_u64());
            Graph::disjoint_union(&gs).0
        }
        _ => {
            // adversarial: star + chain + duplicate-heavy
            let n = rng.range_usize(3, 60);
            let mut b = GraphBuilder::new(n);
            for v in 1..n as u32 {
                b.edge(0, v);
                if v > 1 {
                    b.edge(v - 1, v);
                }
            }
            b.build()
        }
    }
}

fn cfg_for(rng: &mut Rng, g: &Graph, kind: AggregateKind) -> SearchConfig {
    SearchConfig { alpha: 1.0, beta: 1.0,
        capacity: match rng.range_usize(0, 3) {
            0 => g.n() / 4,
            1 => g.n(),
            _ => usize::MAX,
        },
        kind,
        pair_cap: match rng.range_usize(0, 3) {
            0 => 8,
            1 => 64,
            _ => usize::MAX,
        },
    }
}

#[test]
fn prop_search_result_is_equivalent_and_valid() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(1000 + case as u64);
        let g = random_graph(&mut rng);
        for kind in [AggregateKind::Set, AggregateKind::Sequential] {
            let cfg = cfg_for(&mut rng, &g, kind);
            let (hag, stats) = hag_search(&g, &cfg);
            hag.validate().unwrap_or_else(|e| {
                panic!("case {case} {kind:?}: invalid HAG: {e}")
            });
            check_equivalence(&g, &hag).unwrap_or_else(|e| {
                panic!("case {case} {kind:?}: not equivalent: {e}")
            });
            check_equivalence_probabilistic(&g, &hag, case as u64)
                .unwrap();
            assert!(hag.agg_nodes.len() <= cfg.capacity,
                    "case {case}: capacity violated");
            assert!(stats.aggregations_after
                    <= stats.aggregations_before,
                    "case {case}: aggregations increased");
            // every merge must pay for itself under the cost model
            let trivial = Hag::from_graph(&g, kind);
            assert!(hag.cost_core() <= trivial.cost_core(),
                    "case {case}: cost increased");
        }
    }
}

/// The cost-formula contract the audit layer (obs/cost.rs) stands
/// on, over the whole random corpus, for trivial *and* searched
/// HAGs:
/// * at `α = β = 1` the paper's cost (§4.1) collapses to the
///   integer `cost_core = ê − |V_A|`, **bit-exactly** — every term
///   is an integer below 2^53, so the f64 arithmetic is exact;
/// * for any α/β, `obs::cost::calibrated_cost(cost_core, n, α, β)`
///   reproduces `Hag::cost(α, β)` bit-exactly: both evaluate
///   `α·x + (β−α)·n` with the identical exact `x`. This is what
///   lets the audit price drift from `(cost_core, n)` alone
///   without re-walking the HAG.
#[test]
fn prop_cost_identity() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(8000 + case as u64);
        let g = random_graph(&mut rng);
        let cfg = cfg_for(&mut rng, &g, AggregateKind::Set);
        let (searched, _) = hag_search(&g, &cfg);
        let trivial = Hag::from_graph(&g, AggregateKind::Set);
        for hag in [&trivial, &searched] {
            assert_eq!(hag.cost(1.0, 1.0), hag.cost_core() as f64,
                       "case {case}: unit-coefficient cost must be \
                        cost_core exactly");
            for (alpha, beta) in [(1.0, 1.0), (0.5, 2.0),
                                  (3.25, 3.25), (2.0, 9.0),
                                  (1e-3, 7.5)] {
                let via_terms = calibrated_cost(
                    hag.cost_core(), hag.n, alpha, beta);
                assert_eq!(hag.cost(alpha, beta), via_terms,
                           "case {case}: calibrated_cost diverged \
                            at alpha {alpha} beta {beta}");
            }
            // Definition-2 sanity the attribution gauges rely on:
            // transfers = ê ≥ aggregations always.
            assert!(hag.data_transfers() >= hag.aggregations(),
                    "case {case}: transfers < aggregations");
        }
    }
}

#[test]
fn prop_cost_monotone_in_capacity() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(2000 + case as u64);
        let g = random_graph(&mut rng);
        let mut last = usize::MAX;
        for cap in [0usize, 2, 8, 32, 128, usize::MAX] {
            let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
                capacity: cap,
                kind: AggregateKind::Set,
                pair_cap: usize::MAX,
            };
            let (hag, _) = hag_search(&g, &cfg);
            let c = hag.cost_core();
            assert!(c <= last,
                    "case {case}: cost rose from {last} to {c} at \
                     capacity {cap}");
            last = c;
        }
    }
}

/// The flat arena kernel's determinism contract: over the whole
/// random-graph corpus, at exact *and* finite pair caps and under
/// tight capacities, the kernel and the retained naive reference
/// produce **byte-identical** HAGs — same merge order, same
/// `agg_nodes`, same `in_edges` — and the same round structure. One
/// scratch is carried across every case, so arena reuse is proven
/// pollution-free at corpus scale too. (This is the property the
/// session golden-buckets byte-identity test and
/// `Session::plan() == plan_fresh()` stand on.)
#[test]
fn prop_flat_kernel_matches_reference_byte_identical() {
    let mut scratch = SearchScratch::new();
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(7000 + case as u64);
        let g = random_graph(&mut rng);
        for pair_cap in [4usize, 64, usize::MAX] {
            for capacity in [g.n() / 4, usize::MAX] {
                let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
                    capacity,
                    kind: AggregateKind::Set,
                    pair_cap,
                };
                let (hr, sr) = hag_search_reference(&g, &cfg);
                let (hf, sf) =
                    hag_search_with_scratch(&g, &cfg, &mut scratch);
                assert_eq!(hr.agg_nodes, hf.agg_nodes,
                           "case {case} pair_cap {pair_cap} capacity \
                            {capacity}: merge order diverged");
                assert_eq!(hr.in_edges, hf.in_edges,
                           "case {case} pair_cap {pair_cap} capacity \
                            {capacity}: final lists diverged");
                assert_eq!(sr.iterations, sf.iterations,
                           "case {case}: iteration counts diverged");
                assert_eq!(sr.rounds, sf.rounds,
                           "case {case}: round counts diverged");
                // identical heap evolution, not just identical output
                assert_eq!((sr.heap_pops, sr.stale_pops),
                           (sf.heap_pops, sf.stale_pops),
                           "case {case}: pop sequences diverged");
                hf.validate().unwrap();
            }
        }
    }
}

#[test]
fn prop_search_deterministic() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(3000 + case as u64);
        let g = random_graph(&mut rng);
        let cfg = SearchConfig::paper_default(g.n());
        let (h1, _) = hag_search(&g, &cfg);
        let (h2, _) = hag_search(&g, &cfg);
        assert_eq!(h1.agg_nodes, h2.agg_nodes, "case {case}");
        assert_eq!(h1.in_edges, h2.in_edges, "case {case}");
    }
}

/// f64 simulation of exactly what the XLA artifact computes from the
/// plan tensors (levels then block-CSR bands, zero-slot padding).
fn simulate_plan(plan: &ExecutionPlan, x_old: &[f64]) -> Vec<f64> {
    let m = plan.m_pad();
    let mut buf = vec![0f64; m];
    for new in 0..plan.n {
        buf[new] = x_old[plan.perm[new] as usize];
    }
    for l in 0..plan.levels {
        let base = plan.n_pad + l * plan.l_pad;
        for j in 0..plan.l_pad {
            let li = plan.lvl_left[l * plan.l_pad + j] as usize;
            let ri = plan.lvl_right[l * plan.l_pad + j] as usize;
            buf[base + j] = buf[li] + buf[ri];
        }
    }
    let mut out_new = vec![0f64; plan.n_pad];
    let mut row0 = 0usize;
    for (bi, &(nb, nnzb)) in plan.bands.iter().enumerate() {
        for b in 0..nb {
            for j in 0..nnzb {
                let col = plan.band_cols[bi][b * nnzb + j] as usize;
                let r = plan.band_rows[bi][b * nnzb + j] as usize;
                out_new[row0 + b * plan.br + r] += buf[col];
            }
        }
        row0 += nb * plan.br;
    }
    let mut out = vec![0f64; plan.n];
    for new in 0..plan.n {
        out[plan.perm[new] as usize] = out_new[new];
    }
    out
}

#[test]
fn prop_plan_execution_matches_csr() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(4000 + case as u64);
        let g = random_graph(&mut rng);
        let cfg = cfg_for(&mut rng, &g, AggregateKind::Set);
        let (hag, _) = hag_search(&g, &cfg);
        let pc = PlanConfig {
            br: [4, 8, 16][rng.range_usize(0, 3)],
            lvl_block: [32, 128][rng.range_usize(0, 2)],
            max_bands: rng.range_usize(1, 5),
            nnzb_round: [8, 32][rng.range_usize(0, 2)],
        };
        let plan = build_plan(&g, &hag, &pc);
        assert_eq!(plan.bands.iter().map(|b| b.0).sum::<usize>()
                   * plan.br, plan.n_pad, "case {case}: bands tile");
        let x: Vec<f64> =
            (0..g.n()).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let got = simulate_plan(&plan, &x);
        for (v, ns) in g.iter() {
            let want: f64 = ns.iter().map(|&u| x[u as usize]).sum();
            assert!((got[v as usize] - want).abs() < 1e-9,
                    "case {case} node {v}: {} vs {want}",
                    got[v as usize]);
        }
    }
}

#[test]
fn prop_plans_deterministic() {
    for case in 0..10 {
        let mut rng = Rng::seed_from_u64(5000 + case as u64);
        let g = random_graph(&mut rng);
        let cfg = SearchConfig::paper_default(g.n());
        let (hag, _) = hag_search(&g, &cfg);
        let p1 = build_plan(&g, &hag, &PlanConfig::default());
        let p2 = build_plan(&g, &hag, &PlanConfig::default());
        assert_eq!(p1.lvl_left, p2.lvl_left);
        assert_eq!(p1.band_cols, p2.band_cols);
        assert_eq!(p1.perm, p2.perm);
    }
}

#[test]
fn prop_sequential_prefix_merges_preserve_order() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(6000 + case as u64);
        let g = random_graph(&mut rng);
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: usize::MAX,
            kind: AggregateKind::Sequential,
            pair_cap: usize::MAX,
        };
        let (hag, _) = hag_search(&g, &cfg);
        // exact ordered-cover equivalence (the probabilistic checker
        // cannot see order; this is the authoritative check)
        check_equivalence(&g, &hag).unwrap_or_else(|e| {
            panic!("case {case}: sequential order broken: {e}")
        });
    }
}
