//! Incremental HAG maintenance for streaming graphs.
//!
//! Algorithm 3 is a whole-graph batch pass; production graphs change
//! continuously. This subsystem keeps a valid, Theorem-1-equivalent
//! HAG under a feed of [`GraphDelta`]s without re-running the full
//! search per update:
//!
//! 1. [`delta`] — a delta log and a copy-on-write overlay over the CSR
//!    graph ([`OverlayGraph`]);
//! 2. [`repair`] — localized repair ([`IncrementalHag`]): an edge
//!    update touches exactly one final's in-list; covered deletes fall
//!    that final back to direct aggregation (refcount GC reaps dead
//!    aggregation nodes), and a windowed re-merge pass re-harvests
//!    redundancy in the stream-dirtied region with the same
//!    pair-redundancy rule as `hag/search.rs`;
//! 3. [`policy`] — cost-drift tracking that triggers a full re-search
//!    (through [`partition::search_sharded`](crate::partition) when
//!    sharded) once local repair has leaked more than `threshold` over
//!    the decayed fresh-search estimate, swapping the rebuilt HAG in
//!    atomically — inline, or on a background thread with snapshot +
//!    delta-replay so the serving path never blocks on a search.
//!
//! [`StreamEngine`] composes the three. Quality contract (asserted in
//! `rust/tests/incremental.rs`, measured in
//! `benches/stream_updates.rs`): after 10k random updates the repaired
//! HAG still validates and passes the Theorem-1 oracle, stays within
//! 10% of a from-scratch search's `cost_core`, and median repair
//! latency is orders of magnitude below a full re-search.

pub mod delta;
pub mod policy;
pub mod repair;

pub use delta::{DeltaLog, GraphDelta, OverlayGraph};
pub use policy::{DriftPolicy, DriftTracker};
pub use repair::IncrementalHag;

use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::graph::Graph;
use crate::hag::{hag_search, AggregateKind, Hag, SearchConfig};
use crate::obs::CostModel;
use crate::partition::search_sharded;
use crate::util::{FxHashSet, Rng};

/// Streaming-maintenance knobs.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Search capacity as a fraction of the *current* `|V|` (paper
    /// §5.2 default 0.25); re-evaluated at every rebuild so node
    /// growth raises the budget.
    pub capacity_frac: f64,
    /// Candidate-pair window (see [`SearchConfig::pair_cap`]); shared
    /// by rebuilds and the local re-merge pass.
    pub pair_cap: usize,
    /// `>= 2` routes rebuilds through the partitioned parallel driver.
    pub shards: usize,
    /// Drift-triggered re-search policy.
    pub policy: DriftPolicy,
    /// Local re-merge cadence, in applied deltas.
    pub remerge_every: usize,
    /// Max dirty finals consumed per re-merge pass (the window).
    pub remerge_window: usize,
    /// Max merges per re-merge pass.
    pub remerge_merges: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            capacity_frac: 0.25,
            pair_cap: 64,
            shards: 1,
            policy: DriftPolicy::default(),
            remerge_every: 32,
            remerge_window: 256,
            remerge_merges: 64,
        }
    }
}

impl StreamConfig {
    /// The [`SearchConfig`] a (re)build uses at node count `n`.
    pub fn search_config(&self, n: usize) -> SearchConfig {
        SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: (n as f64 * self.capacity_frac) as usize,
            kind: AggregateKind::Set,
            pair_cap: self.pair_cap,
        }
    }
}

/// What one [`StreamEngine::apply`] did to the HAG.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApplyOutcome {
    /// Direct slot appended.
    Inserted,
    /// Direct slot removed.
    Deleted,
    /// Deleted neighbor was covered by an aggregation node: the final
    /// fell back to direct aggregation.
    DeletedFallback,
    NodeAdded,
    /// Insert of an existing edge / delete of a missing one.
    NoOp,
}

/// Re-search activity piggybacked on an apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebuildEvent {
    None,
    /// Background re-search launched (snapshot taken).
    Started,
    /// A rebuilt HAG was swapped in (inline rebuilds report this
    /// directly; background ones when the replayed swap lands).
    Swapped,
}

/// Per-apply report.
#[derive(Debug, Clone, Copy)]
pub struct ApplyReport {
    pub seq: u64,
    pub outcome: ApplyOutcome,
    /// Merges made by a re-merge pass that ran on this apply.
    pub remerges: usize,
    pub rebuild: RebuildEvent,
    pub cost_core: usize,
}

/// Lifetime counters.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub applied: usize,
    pub noops: usize,
    pub inserts: usize,
    pub deletes: usize,
    pub node_adds: usize,
    /// Finals reset to direct aggregation by a covered delete.
    pub fallbacks: usize,
    pub remerge_passes: usize,
    pub remerge_merges: usize,
    pub rebuild_starts: usize,
    pub rebuild_swaps: usize,
    /// Externally planned HAGs adopted via
    /// [`StreamEngine::install_hag`] (counted in `rebuild_swaps` too —
    /// an install *is* a swap, sourced from a session's dirty-shard
    /// re-plan instead of a whole-graph re-search).
    pub installs: usize,
    /// Wall time of the initial full search, ms.
    pub init_search_ms: f64,
}

struct RebuildTask {
    rx: Receiver<(Graph, Hag)>,
    handle: JoinHandle<()>,
    #[allow(dead_code)]
    snapshot_seq: u64,
}

/// The streaming-maintenance engine: overlay graph + incremental HAG +
/// drift policy, fed one [`GraphDelta`] at a time.
pub struct StreamEngine {
    cfg: StreamConfig,
    overlay: OverlayGraph,
    hag: IncrementalHag,
    tracker: DriftTracker,
    dirty: FxHashSet<u32>,
    seq: u64,
    /// Deltas applied since the pending rebuild's snapshot (empty when
    /// no rebuild is in flight).
    log: DeltaLog,
    rebuild: Option<RebuildTask>,
    stats: StreamStats,
    /// Live α̂/β̂ source for calibrated drift (None = raw
    /// `cost_core` units; see [`Self::set_cost_model`]).
    cost_model: Option<Arc<CostModel>>,
}

impl StreamEngine {
    /// Run the initial full search on `g` and stand up the engine.
    pub fn new(g: &Graph, cfg: StreamConfig) -> Self {
        let t0 = std::time::Instant::now();
        let hag = run_search(g, &cfg);
        let mut eng = Self::from_hag(g, cfg, &hag);
        eng.stats.init_search_ms = t0.elapsed().as_secs_f64() * 1e3;
        eng
    }

    /// Stand up the engine over `g` adopting an externally searched
    /// HAG — e.g. the one a [`Session`](crate::session::Session) just
    /// lowered for serving — instead of paying a second initial
    /// search. `hag` must be a Set-AGGREGATE HAG over `g`.
    pub fn from_hag(g: &Graph, cfg: StreamConfig, hag: &Hag) -> Self {
        assert_eq!(hag.n, g.n(), "adopted HAG is not over this graph");
        let mut tracker = DriftTracker::new(cfg.policy.decay);
        tracker.record_search(hag.cost_core(), g.e());
        StreamEngine {
            cfg,
            overlay: OverlayGraph::new(g.clone()),
            hag: IncrementalHag::from_hag(hag),
            tracker,
            dirty: FxHashSet::default(),
            seq: 0,
            log: DeltaLog::default(),
            rebuild: None,
            stats: StreamStats::default(),
            cost_model: None,
        }
    }

    /// Adopt a live cost-model calibration: subsequent
    /// [`Self::drift`]/[`Self::estimated_fresh`] readings price the
    /// maintained HAG and the fresh-search estimate with
    /// `Hag::cost(α̂, β̂)` instead of raw `cost_core` (DESIGN.md
    /// §11). Until the model has enough samples to calibrate,
    /// `alpha_beta()` is `(1, 1)` and behavior is unchanged.
    pub fn set_cost_model(&mut self, model: Arc<CostModel>) {
        self.cost_model = Some(model);
    }

    fn alpha_beta(&self) -> (f64, f64) {
        self.cost_model.as_ref()
            .map_or((1.0, 1.0), |m| m.alpha_beta())
    }

    pub fn overlay(&self) -> &OverlayGraph {
        &self.overlay
    }

    pub fn n(&self) -> usize {
        self.overlay.n()
    }

    pub fn e(&self) -> usize {
        self.overlay.e()
    }

    pub fn seq(&self) -> u64 {
        self.seq
    }

    pub fn cost_core(&self) -> usize {
        self.hag.cost_core()
    }

    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }

    pub fn dirty_len(&self) -> usize {
        self.dirty.len()
    }

    /// Current drift over the decayed fresh-search estimate, in
    /// calibrated Definition-2 units when a cost model is attached
    /// (raw `cost_core` units otherwise — α̂=β̂=1 is the same
    /// number).
    pub fn drift(&self) -> f64 {
        let (alpha, beta) = self.alpha_beta();
        self.tracker.drift_calibrated(self.hag.cost_core(),
                                      self.overlay.e(),
                                      self.overlay.n(), alpha, beta)
    }

    /// Fresh-search cost estimate, same units as [`Self::drift`].
    pub fn estimated_fresh(&self) -> f64 {
        let (alpha, beta) = self.alpha_beta();
        self.tracker.estimated_fresh_calibrated(self.overlay.e(),
                                                self.overlay.n(),
                                                alpha, beta)
    }

    /// The search config a rebuild would use right now.
    pub fn search_config(&self) -> SearchConfig {
        self.cfg.search_config(self.overlay.n())
    }

    /// Materialize the current graph as a CSR.
    pub fn graph(&self) -> Graph {
        self.overlay.to_graph()
    }

    /// Export the maintained HAG in packed form.
    pub fn to_hag(&self) -> Hag {
        self.hag.to_hag()
    }

    /// Apply one delta: local repair, then (on cadence) the windowed
    /// re-merge and the drift-policy check.
    pub fn apply(&mut self, delta: GraphDelta) -> ApplyReport {
        self.seq += 1;
        let outcome = apply_delta(&mut self.overlay, &mut self.hag,
                                  &mut self.dirty, delta);
        self.count(outcome);
        if outcome != ApplyOutcome::NoOp && self.rebuild.is_some() {
            self.log.push(self.seq, delta);
        }

        let mut remerges = 0usize;
        if self.cfg.remerge_every > 0
            && self.seq % self.cfg.remerge_every as u64 == 0
            && !self.dirty.is_empty()
        {
            remerges = self.remerge();
        }

        let mut rebuild = RebuildEvent::None;
        if self.rebuild.is_some() {
            if self.poll_rebuild() {
                rebuild = RebuildEvent::Swapped;
            }
        } else if self.cfg.policy.due(self.seq) {
            let over = self.drift() > self.cfg.policy.threshold;
            crate::obs_event!("incr.drift_check", over as u64);
            if over {
                if self.cfg.policy.background {
                    self.start_rebuild();
                    rebuild = RebuildEvent::Started;
                } else {
                    self.rebuild_now();
                    rebuild = RebuildEvent::Swapped;
                }
            }
        }

        ApplyReport {
            seq: self.seq,
            outcome,
            remerges,
            rebuild,
            cost_core: self.hag.cost_core(),
        }
    }

    fn count(&mut self, outcome: ApplyOutcome) {
        self.stats.applied += 1;
        match outcome {
            ApplyOutcome::Inserted => self.stats.inserts += 1,
            ApplyOutcome::Deleted => self.stats.deletes += 1,
            ApplyOutcome::DeletedFallback => {
                self.stats.deletes += 1;
                self.stats.fallbacks += 1;
            }
            ApplyOutcome::NodeAdded => self.stats.node_adds += 1,
            ApplyOutcome::NoOp => self.stats.noops += 1,
        }
    }

    /// Windowed local re-merge over (a bounded slice of) the dirty
    /// region. Bounded by the same `|V_A|` capacity a rebuild would
    /// use, so the §3.2 a-hat memory budget holds even under a policy
    /// that never re-searches.
    fn remerge(&mut self) -> usize {
        // args: (dirty nodes visited, merges landed)
        let mut sp = crate::obs_span!("incr.remerge");
        let mut batch: Vec<u32> = self.dirty.iter().copied().collect();
        batch.sort_unstable();
        batch.truncate(self.cfg.remerge_window);
        for &v in &batch {
            self.dirty.remove(&v);
        }
        let capacity = self.search_config().capacity;
        let merges = self.hag.local_remerge(&batch, self.cfg.pair_cap,
                                            self.cfg.remerge_merges,
                                            capacity);
        sp.set_args(batch.len() as u64, merges as u64);
        self.stats.remerge_passes += 1;
        self.stats.remerge_merges += merges;
        merges
    }

    /// Adopt an externally planned HAG — e.g. a
    /// [`Session`](crate::session::Session)'s dirty-shard re-plan —
    /// as the maintained HAG, the per-shard alternative to
    /// [`Self::rebuild_now`]'s whole-graph re-search (ROADMAP item 1:
    /// re-search only the shards a delta touched and splice). `hag`
    /// must be over the engine's *current* graph. Returns `false`
    /// (and installs nothing) while a background rebuild is in
    /// flight — the in-flight swap owns the delta log, and racing it
    /// would replay stale deltas onto the installed HAG.
    pub fn install_hag(&mut self, hag: &Hag) -> bool {
        if self.rebuild.is_some() {
            return false;
        }
        assert_eq!(hag.n, self.overlay.n(),
                   "installed HAG is not over the current graph");
        if crate::analysis::verify_enabled() {
            let g = self.overlay.to_graph();
            if !crate::analysis::gate_hag(
                crate::obs::metrics::MetricsRegistry::global(),
                "incr.install", &g, hag)
            {
                return false;
            }
        }
        self.tracker.record_search(hag.cost_core(), self.overlay.e());
        self.hag = IncrementalHag::from_hag(hag);
        self.dirty.clear();
        self.log.clear();
        // an install is a start + swap in one step, so the
        // starts >= swaps ledger invariant holds
        self.stats.rebuild_starts += 1;
        self.stats.rebuild_swaps += 1;
        self.stats.installs += 1;
        true
    }

    /// Inline full re-search + swap.
    pub fn rebuild_now(&mut self) {
        let _sp = crate::obs_span!("incr.rebuild");
        let g = self.overlay.to_graph();
        let fresh = run_search(&g, &self.cfg);
        self.tracker.record_search(fresh.cost_core(), g.e());
        self.hag = IncrementalHag::from_hag(&fresh);
        self.dirty.clear();
        self.log.clear();
        self.stats.rebuild_starts += 1;
        self.stats.rebuild_swaps += 1;
    }

    /// Snapshot the graph and launch the re-search on a worker thread.
    /// Subsequent deltas keep applying to the live HAG *and* accumulate
    /// in the log; [`Self::poll_rebuild`] replays them onto the rebuilt
    /// HAG before the swap, so the swap is atomic w.r.t. the stream.
    pub fn start_rebuild(&mut self) {
        if self.rebuild.is_some() {
            return;
        }
        let g = self.overlay.to_graph();
        let cfg = self.cfg.clone();
        let (tx, rx) = channel();
        let handle = std::thread::spawn(move || {
            // records on the worker's own trace ring
            let _sp = crate::obs_span!("incr.rebuild", g.n(), g.e());
            let fresh = run_search(&g, &cfg);
            let _ = tx.send((g, fresh));
        });
        self.log.clear(); // the snapshot covers everything so far
        self.rebuild = Some(RebuildTask { rx, handle,
                                          snapshot_seq: self.seq });
        self.stats.rebuild_starts += 1;
    }

    pub fn rebuild_in_flight(&self) -> bool {
        self.rebuild.is_some()
    }

    /// Non-blocking: if the background re-search finished, replay the
    /// logged deltas onto it and swap. Returns `true` on swap.
    pub fn poll_rebuild(&mut self) -> bool {
        let result = match &self.rebuild {
            None => return false,
            Some(task) => task.rx.try_recv(),
        };
        match result {
            Err(TryRecvError::Empty) => false,
            Err(TryRecvError::Disconnected) => {
                // Worker died (panic in search): abandon this rebuild;
                // the live HAG is still valid and the policy will
                // re-trigger.
                if let Some(t) = self.rebuild.take() {
                    let _ = t.handle.join();
                }
                self.log.clear();
                false
            }
            Ok((snapshot, fresh)) => {
                if let Some(t) = self.rebuild.take() {
                    let _ = t.handle.join();
                }
                self.install(snapshot, fresh);
                true
            }
        }
    }

    /// Blocking variant of [`Self::poll_rebuild`] (tests, shutdown).
    pub fn finish_rebuild(&mut self) -> bool {
        let result = match &self.rebuild {
            None => return false,
            Some(task) => task.rx.recv(),
        };
        match result {
            Err(_) => {
                if let Some(t) = self.rebuild.take() {
                    let _ = t.handle.join();
                }
                self.log.clear();
                false
            }
            Ok((snapshot, fresh)) => {
                if let Some(t) = self.rebuild.take() {
                    let _ = t.handle.join();
                }
                self.install(snapshot, fresh);
                true
            }
        }
    }

    /// Replay the post-snapshot deltas onto the rebuilt HAG and swap
    /// both overlay and HAG in one step.
    fn install(&mut self, snapshot: Graph, fresh: Hag) {
        crate::obs_event!("incr.rebuild_swap");
        let e_snap = snapshot.e();
        self.tracker.record_search(fresh.cost_core(), e_snap);
        let mut overlay = OverlayGraph::new(snapshot);
        let mut hag = IncrementalHag::from_hag(&fresh);
        let mut dirty = FxHashSet::default();
        for &(_, d) in self.log.entries() {
            apply_delta(&mut overlay, &mut hag, &mut dirty, d);
        }
        debug_assert_eq!(overlay.n(), self.overlay.n());
        debug_assert_eq!(overlay.e(), self.overlay.e());
        self.overlay = overlay;
        self.hag = hag;
        // Replace, don't extend: pre-snapshot dirty finals were just
        // covered by the fresh search; only the replay window is
        // still dirty.
        self.dirty = dirty;
        self.log.clear();
        self.stats.rebuild_swaps += 1;
    }
}

fn run_search(g: &Graph, cfg: &StreamConfig) -> Hag {
    let sc = cfg.search_config(g.n());
    if cfg.shards >= 2 {
        search_sharded(g, cfg.shards, &sc).0
    } else {
        hag_search(g, &sc).0
    }
}

/// Shared per-delta repair: overlay first, then the HAG, then the
/// dirty set. Used by both the live apply path and background-rebuild
/// replay, so the two can never disagree.
fn apply_delta(overlay: &mut OverlayGraph, hag: &mut IncrementalHag,
               dirty: &mut FxHashSet<u32>,
               delta: GraphDelta) -> ApplyOutcome {
    match delta {
        GraphDelta::EdgeInsert { src, dst } => {
            if (src as usize) >= overlay.n()
                || (dst as usize) >= overlay.n()
                || !overlay.insert_edge(src, dst)
            {
                return ApplyOutcome::NoOp;
            }
            hag.insert_edge(src, dst);
            dirty.insert(dst);
            ApplyOutcome::Inserted
        }
        GraphDelta::EdgeDelete { src, dst } => {
            if (src as usize) >= overlay.n()
                || (dst as usize) >= overlay.n()
                || !overlay.delete_edge(src, dst)
            {
                return ApplyOutcome::NoOp;
            }
            let fell_back =
                hag.delete_edge(src, dst, overlay.neighbors(dst));
            dirty.insert(dst);
            if fell_back {
                ApplyOutcome::DeletedFallback
            } else {
                ApplyOutcome::Deleted
            }
        }
        GraphDelta::NodeAdd => {
            overlay.add_node();
            hag.add_node();
            ApplyOutcome::NodeAdded
        }
    }
}

/// Seeded random update generator for stress drivers (CLI `stream`,
/// `benches/stream_updates.rs`, `tests/incremental.rs`):
/// `node_add_frac` of deltas append a node; the rest split
/// `insert_frac` : `1 - insert_frac` between a uniform random insert
/// and a (degree-biased) delete of an existing edge.
pub fn random_delta(rng: &mut Rng, g: &OverlayGraph, insert_frac: f64,
                    node_add_frac: f64) -> GraphDelta {
    let n = g.n() as u32;
    if n < 2 || rng.bool(node_add_frac) {
        return GraphDelta::NodeAdd;
    }
    let insert = |rng: &mut Rng| -> GraphDelta {
        let src = rng.range_u32(0, n);
        let mut dst = rng.range_u32(0, n);
        if dst == src {
            dst = (dst + 1) % n;
        }
        GraphDelta::EdgeInsert { src, dst }
    };
    if rng.bool(insert_frac) {
        return insert(rng);
    }
    for _ in 0..32 {
        let v = rng.range_u32(0, n);
        let d = g.degree(v);
        if d > 0 {
            let u = g.neighbors(v)[rng.range_usize(0, d)];
            return GraphDelta::EdgeDelete { src: u, dst: v };
        }
    }
    insert(rng) // graph (nearly) empty: keep the stream moving
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{community_graph, CommunityCfg};
    use crate::hag::check_equivalence;

    fn small_community() -> Graph {
        let cfg = CommunityCfg {
            n: 300,
            e: 4_000,
            communities: 6,
            intra_frac: 0.9,
            zipf_exp: 0.9,
            clone_frac: 0.5,
        };
        community_graph(&cfg, 5).0
    }

    #[test]
    fn engine_tracks_graph_through_updates() {
        let g = small_community();
        let mut eng = StreamEngine::new(&g, StreamConfig::default());
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..500 {
            let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.02);
            eng.apply(d);
        }
        let now = eng.graph();
        assert_eq!(now.n(), eng.n());
        assert_eq!(now.e(), eng.e());
        let h = eng.to_hag();
        h.validate().unwrap();
        check_equivalence(&now, &h).unwrap();
        let s = eng.stats();
        assert_eq!(s.applied, 500);
        assert_eq!(s.applied,
                   s.inserts + s.deletes + s.node_adds + s.noops);
    }

    #[test]
    fn noop_deltas_change_nothing() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]);
        let mut eng = StreamEngine::new(&g, StreamConfig::default());
        let before = eng.cost_core();
        let r =
            eng.apply(GraphDelta::EdgeInsert { src: 0, dst: 1 });
        assert_eq!(r.outcome, ApplyOutcome::NoOp);
        let r =
            eng.apply(GraphDelta::EdgeDelete { src: 2, dst: 0 });
        assert_eq!(r.outcome, ApplyOutcome::NoOp);
        // out-of-range ids are ignored, not panics
        let r =
            eng.apply(GraphDelta::EdgeInsert { src: 99, dst: 0 });
        assert_eq!(r.outcome, ApplyOutcome::NoOp);
        assert_eq!(eng.cost_core(), before);
        assert_eq!(eng.e(), g.e());
    }

    #[test]
    fn inline_rebuild_resets_drift() {
        let g = small_community();
        let mut cfg = StreamConfig::default();
        cfg.policy.threshold = 0.0; // rebuild at every check
        cfg.policy.check_every = 50;
        let mut eng = StreamEngine::new(&g, cfg);
        let mut rng = Rng::seed_from_u64(13);
        for _ in 0..200 {
            let d = random_delta(&mut rng, eng.overlay(), 0.3, 0.0);
            eng.apply(d);
        }
        assert!(eng.stats().rebuild_swaps >= 1,
                "threshold 0 must trigger rebuilds: {:?}", eng.stats());
        let now = eng.graph();
        check_equivalence(&now, &eng.to_hag()).unwrap();
        // fresh searches were recorded, estimate tracks reality
        assert!(eng.drift() < 0.5, "drift {}", eng.drift());
    }

    #[test]
    fn background_rebuild_replays_and_swaps() {
        let g = small_community();
        let mut cfg = StreamConfig::default();
        cfg.policy.threshold = 0.0;
        cfg.policy.check_every = 40;
        cfg.policy.background = true;
        cfg.shards = 2;
        let mut eng = StreamEngine::new(&g, cfg);
        let mut rng = Rng::seed_from_u64(17);
        for _ in 0..400 {
            let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.01);
            eng.apply(d);
        }
        // drain any in-flight rebuild, then verify the swap landed on
        // a state equivalent to the live graph
        eng.finish_rebuild();
        assert!(eng.stats().rebuild_starts >= 1);
        let now = eng.graph();
        let h = eng.to_hag();
        h.validate().unwrap();
        check_equivalence(&now, &h).unwrap();
    }

    #[test]
    fn from_hag_adopts_without_initial_search() {
        let g = small_community();
        let cfg = StreamConfig::default();
        let (hag, _) = hag_search(&g, &cfg.search_config(g.n()));
        let mut eng = StreamEngine::from_hag(&g, cfg, &hag);
        assert_eq!(eng.cost_core(), hag.cost_core());
        assert_eq!(eng.stats().init_search_ms, 0.0,
                   "no initial search was paid");
        assert!(eng.drift().abs() < 1e-9,
                "tracker seeded from the adopted HAG");
        // repair keeps working on top of the adopted HAG
        let mut rng = Rng::seed_from_u64(31);
        for _ in 0..200 {
            let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.01);
            eng.apply(d);
        }
        let h = eng.to_hag();
        h.validate().unwrap();
        check_equivalence(&eng.graph(), &h).unwrap();
    }

    #[test]
    fn install_hag_swaps_and_repair_continues() {
        let g = small_community();
        let mut cfg = StreamConfig::default();
        cfg.policy.threshold = f64::INFINITY; // engine never self-rebuilds
        let mut eng = StreamEngine::new(&g, cfg);
        let mut rng = Rng::seed_from_u64(29);
        for _ in 0..300 {
            let d = random_delta(&mut rng, eng.overlay(), 0.4, 0.01);
            eng.apply(d);
        }
        let g_now = eng.graph();
        let (fresh, _) = hag_search(&g_now, &eng.search_config());
        assert!(eng.install_hag(&fresh));
        assert_eq!(eng.cost_core(), fresh.cost_core());
        assert_eq!(eng.stats().installs, 1);
        assert_eq!(eng.stats().rebuild_swaps, 1);
        check_equivalence(&g_now, &eng.to_hag()).unwrap();
        // repair keeps working on top of the installed HAG
        for _ in 0..200 {
            let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.01);
            eng.apply(d);
        }
        let h = eng.to_hag();
        h.validate().unwrap();
        check_equivalence(&eng.graph(), &h).unwrap();
    }

    #[test]
    fn remerge_recovers_after_fallbacks() {
        // Finals 5 and 6 share N = {0,1,2,3}; 7 and 8 share {0,1} so
        // the initial search merges. Deleting (0,5) and (0,6) — both
        // covered — falls 5 and 6 back to direct {1,2,3}; the re-merge
        // pass (cadence 2, so it fires right after the two deletes)
        // must re-harvest the shared {1,2,3} region.
        let mut edges = Vec::new();
        for v in [5u32, 6] {
            for u in [0u32, 1, 2, 3] {
                edges.push((u, v));
            }
        }
        edges.push((0, 7));
        edges.push((1, 7));
        edges.push((0, 8));
        edges.push((1, 8));
        let g = Graph::from_edges(9, &edges);
        let mut cfg = StreamConfig::default();
        cfg.remerge_every = 2;
        cfg.capacity_frac = 10.0; // unbounded for this toy graph
        cfg.policy.threshold = f64::INFINITY;
        let mut eng = StreamEngine::new(&g, cfg);
        let r1 = eng.apply(GraphDelta::EdgeDelete { src: 0, dst: 5 });
        assert_eq!(r1.outcome, ApplyOutcome::DeletedFallback);
        let before = eng.cost_core();
        let r2 = eng.apply(GraphDelta::EdgeDelete { src: 0, dst: 6 });
        assert_eq!(r2.outcome, ApplyOutcome::DeletedFallback);
        assert!(r2.remerges >= 1, "re-merge pass must fire and merge");
        assert!(eng.cost_core() < before,
                "cost {} did not recover below {before}",
                eng.cost_core());
        check_equivalence(&eng.graph(), &eng.to_hag()).unwrap();
    }

    #[test]
    fn remerge_is_equivalence_preserving_on_identical_streams() {
        // NB: no cost comparison between the two engines — a re-merge
        // can *re-cover* a slot that a later delete then hits (full
        // fallback) where the non-merging engine would have removed a
        // direct slot, so per-stream cost ordering is not an
        // invariant. What is invariant: identical streams (the delta
        // generator reads only the overlay, which evolves identically
        // in both engines), graph agreement, and Theorem-1
        // equivalence with re-merging active.
        let g = small_community();
        let mut no_remerge = StreamConfig::default();
        no_remerge.remerge_every = 0;
        no_remerge.policy.threshold = f64::INFINITY;
        let mut with_remerge = StreamConfig::default();
        with_remerge.remerge_every = 16;
        with_remerge.policy.threshold = f64::INFINITY;
        let mut a = StreamEngine::new(&g, no_remerge);
        let mut b = StreamEngine::new(&g, with_remerge);
        let mut rng_a = Rng::seed_from_u64(23);
        let mut rng_b = Rng::seed_from_u64(23);
        for _ in 0..800 {
            let da = random_delta(&mut rng_a, a.overlay(), 0.5, 0.0);
            let db = random_delta(&mut rng_b, b.overlay(), 0.5, 0.0);
            assert_eq!(da, db);
            a.apply(da);
            b.apply(db);
        }
        assert_eq!(a.e(), b.e());
        assert_eq!(a.graph(), b.graph());
        assert!(b.stats().remerge_passes > 0);
        // both maintained HAGs can never fall below trivial quality
        assert!(a.cost_core() <= a.e() && b.cost_core() <= b.e(),
                "worse than the trivial HAG: {} / {} vs e {}",
                a.cost_core(), b.cost_core(), a.e());
        check_equivalence(&a.graph(), &a.to_hag()).unwrap();
        check_equivalence(&b.graph(), &b.to_hag()).unwrap();
    }

    #[test]
    fn engine_drift_adopts_cost_model_calibration() {
        let g = small_community();
        let mut cfg = StreamConfig::default();
        cfg.policy = cfg.policy.clone().with_threshold(f64::INFINITY);
        let mut eng = StreamEngine::new(&g, cfg);
        let mut rng = Rng::seed_from_u64(23);
        for _ in 0..400 {
            let d = random_delta(&mut rng, eng.overlay(), 0.3, 0.02);
            eng.apply(d);
        }
        // raw-unit readings through the uncalibrated default path
        let est_core = eng.estimated_fresh();
        let (c_now, n_now) = (eng.cost_core(), eng.n());

        // noiseless β-heavy synthetic host: ns = 2·aggs + 9·transfers
        let model = Arc::new(CostModel::new());
        let mut srng = Rng::seed_from_u64(5);
        for _ in 0..64 {
            let a = 1_000 + srng.range_usize(0, 50_000) as u64;
            let t = 1_000 + srng.range_usize(0, 80_000) as u64;
            model.record_sample(a, t, 2 * a + 9 * t);
        }
        let (alpha, beta) = model.alpha_beta();
        assert!(beta > alpha,
                "β-heavy synthetic fit: α̂={alpha} β̂={beta}");
        eng.set_cost_model(model);

        // both readings now follow the Hag::cost identity exactly
        let want_est =
            alpha * est_core + (beta - alpha) * n_now as f64;
        assert!((eng.estimated_fresh() - want_est).abs()
                    < 1e-6 * want_est.max(1.0));
        let want = (alpha * c_now as f64
                        + (beta - alpha) * n_now as f64)
            / want_est.max(1.0) - 1.0;
        assert!((eng.drift() - want).abs() < 1e-9,
                "calibrated drift: {} vs {want}", eng.drift());
    }

    #[test]
    fn random_delta_is_in_range() {
        let g = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3)]);
        let ov = OverlayGraph::new(g);
        let mut rng = Rng::seed_from_u64(3);
        for _ in 0..1000 {
            match random_delta(&mut rng, &ov, 0.5, 0.05) {
                GraphDelta::EdgeInsert { src, dst } => {
                    assert!(src < 5 && dst < 5 && src != dst);
                }
                GraphDelta::EdgeDelete { src, dst } => {
                    assert!(ov.has_edge(src, dst));
                }
                GraphDelta::NodeAdd => {}
            }
        }
    }
}
