//! Cost-drift tracking and the re-search trigger.
//!
//! Local repair (repair.rs) keeps the HAG *valid* under a stream of
//! deltas but slowly leaks *quality*: every covered-edge delete falls a
//! final back to direct aggregation, and the windowed re-merge only
//! sees the dirty region. The policy quantifies the leak as **drift**:
//!
//! ```text
//! drift = cost_core(current) / est_fresh - 1
//! est_fresh = ratio * |E_now|,  ratio = EWMA of cost_core/|E| over
//!                                       past full searches
//! ```
//!
//! The ratio is a decayed estimate of what a fresh Algorithm-3 search
//! would achieve on the current graph: search cost scales with edge
//! count for a stationary-ish structure, and the EWMA (`decay` toward
//! past observations) smooths generator noise across rebuilds. When
//! drift exceeds `threshold`, the engine re-runs the full search —
//! through `partition::search_sharded` when sharding is configured —
//! and swaps the rebuilt HAG in (inline, or on a background thread
//! with delta replay; see `StreamEngine`).
//!
//! **Calibrated units** (DESIGN.md §11): raw drift prices both sides
//! in `cost_core` — the α=β=1 point of Definition 2. When a live
//! [`CostModel`](crate::obs::CostModel) calibration is available,
//! [`DriftTracker::drift_calibrated`] prices them with
//! `Hag::cost(α̂, β̂) = α̂·cost_core + (β̂−α̂)·|V|` instead. The EWMA
//! itself stays in dimensionless core units and α̂/β̂ are applied at
//! *evaluation* time to both the estimate and the current cost, so
//! evolving coefficients can never mix units across the ratio — and
//! at α̂=β̂ (the uncalibrated default, and the collinear-fit
//! fallback) calibrated drift reduces exactly to raw drift.

/// Re-search policy knobs.
#[derive(Debug, Clone)]
pub struct DriftPolicy {
    /// Drift fraction that triggers a re-search (e.g. `0.08` = rebuild
    /// once local repair has leaked 8% over the fresh-search estimate).
    /// `f64::INFINITY` disables re-search entirely; negative values
    /// trigger at every check (drift is always `> -1` on a non-empty
    /// graph — the forcing knob serving tests and the CI serve smoke
    /// use to exercise the swap path deterministically).
    pub threshold: f64,
    /// EWMA weight kept by old observations when a new full-search
    /// ratio is recorded (`0.0` = always trust the newest).
    pub decay: f64,
    /// Policy check cadence, in applied deltas.
    pub check_every: usize,
    /// Rebuild on a background thread (snapshot + delta replay +
    /// atomic swap) instead of inline.
    pub background: bool,
}

impl Default for DriftPolicy {
    fn default() -> Self {
        DriftPolicy {
            threshold: 0.08,
            decay: 0.5,
            check_every: 64,
            background: false,
        }
    }
}

impl DriftPolicy {
    pub fn with_threshold(mut self, threshold: f64) -> Self {
        self.threshold = threshold;
        self
    }

    pub fn with_background(mut self, background: bool) -> Self {
        self.background = background;
        self
    }

    pub fn with_check_every(mut self, check_every: usize) -> Self {
        self.check_every = check_every;
        self
    }

    /// Is a cadenced policy check due at stream sequence `seq`?
    /// (`check_every == 0` disables cadenced checks entirely.) Shared
    /// by the engine's apply path; the serving batcher instead checks
    /// at every coalesced update flush — flushes are already batched,
    /// so a per-delta cadence would only delay the swap.
    pub fn due(&self, seq: u64) -> bool {
        self.check_every > 0 && seq % self.check_every as u64 == 0
    }

    /// Deterministic fingerprint over every policy field, folded into
    /// [`LowerSpec::fingerprint`](crate::session::LowerSpec::fingerprint)
    /// — the policy is part of the lowering spec, so two sessions that
    /// differ only in drift policy must not share cache entries. Kept
    /// next to the fields so adding a knob without extending the hash
    /// is a local diff review, not an action at a distance.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::Hasher;
        let mut h = crate::util::fxhash::FxHasher::default();
        h.write_u64(self.threshold.to_bits());
        h.write_u64(self.decay.to_bits());
        h.write_u64(self.check_every as u64);
        h.write_u64(self.background as u64);
        h.finish()
    }
}

/// EWMA of observed fresh-search cost ratios.
#[derive(Debug, Clone)]
pub struct DriftTracker {
    decay: f64,
    ratio: f64,
    observations: usize,
}

impl DriftTracker {
    pub fn new(decay: f64) -> Self {
        DriftTracker { decay: decay.clamp(0.0, 1.0), ratio: 1.0,
                       observations: 0 }
    }

    /// Record the outcome of a full search: `cost_core` on a graph
    /// with `e` edges.
    pub fn record_search(&mut self, cost_core: usize, e: usize) {
        let r = cost_core as f64 / e.max(1) as f64;
        self.ratio = if self.observations == 0 {
            r
        } else {
            self.decay * self.ratio + (1.0 - self.decay) * r
        };
        self.observations += 1;
    }

    pub fn observations(&self) -> usize {
        self.observations
    }

    /// Decayed estimate of `cost_core(fresh search)` on a graph with
    /// `e_now` edges.
    pub fn estimated_fresh(&self, e_now: usize) -> f64 {
        self.ratio * e_now as f64
    }

    /// Relative cost excess of the repaired HAG over the fresh-search
    /// estimate; `0.0` until a search has been recorded.
    pub fn drift(&self, cost_now: usize, e_now: usize) -> f64 {
        if self.observations == 0 {
            return 0.0;
        }
        let est = self.estimated_fresh(e_now).max(1.0);
        cost_now as f64 / est - 1.0
    }

    /// [`Self::estimated_fresh`] re-priced in calibrated Definition-2
    /// units: `α·est_core + (β−α)·n_now` (the `Hag::cost` identity —
    /// node count is invariant under re-search, so only the core term
    /// needs the EWMA).
    pub fn estimated_fresh_calibrated(&self, e_now: usize,
                                      n_now: usize, alpha: f64,
                                      beta: f64) -> f64 {
        crate::obs::cost::calibrated_cost(0, n_now, alpha, beta)
            + alpha * self.estimated_fresh(e_now)
    }

    /// [`Self::drift`] with both sides priced by `Hag::cost(α, β)`.
    /// At `α == β == 1` this is bit-for-bit the raw drift; a real
    /// calibration shifts the trigger point by how heavily transfers
    /// (`β`) actually weigh against aggregations on this host.
    pub fn drift_calibrated(&self, cost_core_now: usize, e_now: usize,
                            n_now: usize, alpha: f64,
                            beta: f64) -> f64 {
        if self.observations == 0 {
            return 0.0;
        }
        let est = self
            .estimated_fresh_calibrated(e_now, n_now, alpha, beta)
            .max(1.0);
        crate::obs::cost::calibrated_cost(cost_core_now, n_now,
                                          alpha, beta) / est - 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_observation_sets_ratio() {
        let mut t = DriftTracker::new(0.5);
        assert_eq!(t.drift(100, 100), 0.0, "no observation yet");
        t.record_search(75, 100);
        assert!((t.estimated_fresh(200) - 150.0).abs() < 1e-9);
        assert!((t.drift(165, 200) - 0.1).abs() < 1e-9);
    }

    #[test]
    fn ewma_blends_observations() {
        let mut t = DriftTracker::new(0.5);
        t.record_search(80, 100); // ratio 0.8
        t.record_search(60, 100); // ratio 0.5*0.8 + 0.5*0.6 = 0.7
        assert!((t.estimated_fresh(100) - 70.0).abs() < 1e-9);
    }

    #[test]
    fn zero_decay_trusts_newest() {
        let mut t = DriftTracker::new(0.0);
        t.record_search(80, 100);
        t.record_search(50, 100);
        assert!((t.estimated_fresh(100) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn drift_negative_when_better_than_estimate() {
        let mut t = DriftTracker::new(0.5);
        t.record_search(90, 100);
        assert!(t.drift(45, 100) < 0.0);
    }

    #[test]
    fn fingerprint_separates_policies() {
        let a = DriftPolicy::default();
        assert_eq!(a.fingerprint(), DriftPolicy::default().fingerprint());
        assert_ne!(a.fingerprint(),
                   a.clone().with_threshold(0.5).fingerprint());
        assert_ne!(a.fingerprint(),
                   a.clone().with_threshold(f64::INFINITY).fingerprint());
        assert_ne!(a.fingerprint(),
                   a.clone().with_background(true).fingerprint());
        assert_ne!(a.fingerprint(),
                   a.clone().with_check_every(1).fingerprint());
    }

    #[test]
    fn due_respects_cadence_and_zero_disables() {
        let p = DriftPolicy::default().with_check_every(4);
        assert!(!p.due(1) && !p.due(3) && p.due(4) && p.due(8));
        let off = DriftPolicy::default().with_check_every(0);
        for s in 0..10 {
            assert!(!off.due(s));
        }
    }

    #[test]
    fn calibrated_drift_reduces_to_raw_at_unit_coefficients() {
        let mut t = DriftTracker::new(0.5);
        assert_eq!(t.drift_calibrated(100, 100, 30, 2.0, 3.0), 0.0,
                   "no observation yet");
        t.record_search(75, 100);
        for (c, e, n) in [(165usize, 200usize, 40usize), (75, 100, 40),
                          (10, 300, 7)] {
            let raw = t.drift(c, e);
            let cal = t.drift_calibrated(c, e, n, 1.0, 1.0);
            assert!((raw - cal).abs() < 1e-12,
                    "α=β=1 must be raw drift: {raw} vs {cal}");
            // shared non-unit rate: pure rescale, n term cancels
            let shared = t.drift_calibrated(c, e, n, 2.5, 2.5);
            assert!((raw - shared).abs() < 1e-9);
        }
    }

    #[test]
    fn calibrated_drift_weighs_transfers_via_beta() {
        let mut t = DriftTracker::new(0.5);
        t.record_search(100, 100); // est core = e_now
        // current core 20% over estimate; a large β·n floor shared by
        // both sides dilutes the relative excess below 20%
        let raw = t.drift(120, 100);
        assert!((raw - 0.2).abs() < 1e-9);
        let cal = t.drift_calibrated(120, 100, 1_000, 1.0, 5.0);
        assert!(cal > 0.0 && cal < raw,
                "β-heavy pricing dilutes core drift: {cal} vs {raw}");
        let est = t.estimated_fresh_calibrated(100, 1_000, 1.0, 5.0);
        assert!((est - (100.0 + 4.0 * 1_000.0)).abs() < 1e-9);
    }

    #[test]
    fn empty_graph_does_not_divide_by_zero() {
        let mut t = DriftTracker::new(0.5);
        t.record_search(0, 0);
        assert!(t.drift(0, 0).is_finite());
        assert!(t.drift(5, 0).is_finite());
    }
}
