//! Delta log + mutable overlay over the CSR graph substrate.
//!
//! [`Graph`](crate::graph::Graph) is an immutable CSR — the right
//! layout for search and plan compilation, the wrong one for a stream
//! of edge updates. [`OverlayGraph`] keeps the CSR as a frozen base and
//! materializes a private sorted in-neighbor row only for nodes the
//! stream has touched, so a long-lived serving graph pays O(dirty rows)
//! extra memory instead of a full copy, and `to_graph()` re-freezes the
//! current state into a fresh CSR for the drift-triggered re-search.
//!
//! Invariants mirrored from the CSR builder so the two stay
//! interchangeable: rows are sorted ascending and duplicate-free
//! (`Graph::from_edges` dedups; the overlay refuses duplicate inserts),
//! and isolated nodes are first-class (`graph::io` round-trips them via
//! the `# n=` header).

use crate::graph::Graph;
use crate::util::FxHashMap;

/// One streaming update. `src -> dst` is an aggregation edge ("src's
/// activations are aggregated into dst"), matching
/// [`Graph::from_edges`] orientation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphDelta {
    EdgeInsert { src: u32, dst: u32 },
    EdgeDelete { src: u32, dst: u32 },
    /// Append one isolated node (id = current `n`); subsequent inserts
    /// wire it in.
    NodeAdd,
}

/// Sequence-stamped delta log. Retained only while a background
/// re-search is in flight (the snapshot + replay window); otherwise the
/// engine clears it eagerly.
#[derive(Debug, Clone, Default)]
pub struct DeltaLog {
    entries: Vec<(u64, GraphDelta)>,
}

impl DeltaLog {
    pub fn push(&mut self, seq: u64, delta: GraphDelta) {
        debug_assert!(self.entries.last().map_or(true, |&(s, _)| s < seq),
                      "log sequence must be strictly increasing");
        self.entries.push((seq, delta));
    }

    pub fn entries(&self) -> &[(u64, GraphDelta)] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

/// A CSR base plus per-row copy-on-write overrides.
#[derive(Debug, Clone)]
pub struct OverlayGraph {
    base: Graph,
    /// Overridden in-neighbor rows (sorted ascending, duplicate-free).
    rows: FxHashMap<u32, Vec<u32>>,
    n: usize,
    e: usize,
}

impl OverlayGraph {
    pub fn new(base: Graph) -> Self {
        let (n, e) = (base.n(), base.e());
        OverlayGraph { base, rows: FxHashMap::default(), n, e }
    }

    /// Current node count (base nodes + `NodeAdd`s).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Current aggregation-edge count.
    pub fn e(&self) -> usize {
        self.e
    }

    /// Number of rows diverged from the base CSR.
    pub fn dirty_rows(&self) -> usize {
        self.rows.len()
    }

    /// Current in-neighbors of `v`, sorted ascending.
    pub fn neighbors(&self, v: u32) -> &[u32] {
        match self.rows.get(&v) {
            Some(row) => row.as_slice(),
            None if (v as usize) < self.base.n() => self.base.neighbors(v),
            None => &[],
        }
    }

    pub fn degree(&self, v: u32) -> usize {
        self.neighbors(v).len()
    }

    pub fn has_edge(&self, src: u32, dst: u32) -> bool {
        self.neighbors(dst).binary_search(&src).is_ok()
    }

    fn row_mut(&mut self, v: u32) -> &mut Vec<u32> {
        if !self.rows.contains_key(&v) {
            let init = if (v as usize) < self.base.n() {
                self.base.neighbors(v).to_vec()
            } else {
                Vec::new()
            };
            self.rows.insert(v, init);
        }
        self.rows.get_mut(&v).unwrap()
    }

    /// Insert `src -> dst`; `false` if the edge already exists (the
    /// CSR substrate is duplicate-free, so the overlay is too).
    pub fn insert_edge(&mut self, src: u32, dst: u32) -> bool {
        debug_assert!((src as usize) < self.n && (dst as usize) < self.n);
        let row = self.row_mut(dst);
        match row.binary_search(&src) {
            Ok(_) => false,
            Err(i) => {
                row.insert(i, src);
                self.e += 1;
                true
            }
        }
    }

    /// Delete `src -> dst`; `false` if absent.
    pub fn delete_edge(&mut self, src: u32, dst: u32) -> bool {
        debug_assert!((src as usize) < self.n && (dst as usize) < self.n);
        // Don't materialize a row just to discover the edge is absent.
        if !self.has_edge(src, dst) {
            return false;
        }
        let row = self.row_mut(dst);
        match row.binary_search(&src) {
            Ok(i) => {
                row.remove(i);
                self.e -= 1;
                true
            }
            Err(_) => false,
        }
    }

    /// Append one isolated node, returning its id.
    pub fn add_node(&mut self) -> u32 {
        let id = self.n as u32;
        self.n += 1;
        id
    }

    /// Apply one delta; `true` if it changed the graph (an insert of an
    /// existing edge / delete of a missing edge is a no-op).
    pub fn apply(&mut self, delta: GraphDelta) -> bool {
        match delta {
            GraphDelta::EdgeInsert { src, dst } => {
                self.insert_edge(src, dst)
            }
            GraphDelta::EdgeDelete { src, dst } => {
                self.delete_edge(src, dst)
            }
            GraphDelta::NodeAdd => {
                self.add_node();
                true
            }
        }
    }

    /// Freeze the current state into a fresh CSR [`Graph`].
    pub fn to_graph(&self) -> Graph {
        let mut offsets = Vec::with_capacity(self.n + 1);
        let mut neighbors = Vec::with_capacity(self.e);
        offsets.push(0u32);
        for v in 0..self.n as u32 {
            neighbors.extend_from_slice(self.neighbors(v));
            offsets.push(neighbors.len() as u32);
        }
        Graph::from_csr(offsets, neighbors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Graph {
        Graph::from_edges(4, &[(1, 0), (2, 0), (0, 2), (3, 2)])
    }

    #[test]
    fn passthrough_before_any_delta() {
        let ov = OverlayGraph::new(base());
        assert_eq!(ov.n(), 4);
        assert_eq!(ov.e(), 4);
        assert_eq!(ov.neighbors(0), &[1, 2]);
        assert_eq!(ov.neighbors(1), &[] as &[u32]);
        assert_eq!(ov.dirty_rows(), 0);
        assert_eq!(ov.to_graph(), base());
    }

    #[test]
    fn insert_delete_roundtrip() {
        let mut ov = OverlayGraph::new(base());
        assert!(ov.insert_edge(3, 0));
        assert!(!ov.insert_edge(3, 0), "duplicate insert must no-op");
        assert_eq!(ov.neighbors(0), &[1, 2, 3]);
        assert_eq!(ov.e(), 5);
        assert!(ov.delete_edge(3, 0));
        assert!(!ov.delete_edge(3, 0), "double delete must no-op");
        assert_eq!(ov.e(), 4);
        assert_eq!(ov.to_graph(), base());
    }

    #[test]
    fn node_add_and_wire() {
        let mut ov = OverlayGraph::new(base());
        let v = ov.add_node();
        assert_eq!(v, 4);
        assert_eq!(ov.n(), 5);
        assert_eq!(ov.neighbors(v), &[] as &[u32]);
        assert!(ov.insert_edge(0, v));
        assert!(ov.insert_edge(v, 0));
        assert_eq!(ov.neighbors(v), &[0]);
        assert_eq!(ov.neighbors(0), &[1, 2, 4]);
        let g = ov.to_graph();
        assert_eq!(g.n(), 5);
        assert_eq!(g.neighbors(4), &[0]);
    }

    #[test]
    fn to_graph_matches_builder_semantics() {
        // The overlay must agree with Graph::from_edges on the same
        // final edge set (sorted, deduped, isolated nodes kept).
        let mut ov = OverlayGraph::new(Graph::from_edges(3, &[(0, 1)]));
        ov.add_node(); // node 3, isolated
        ov.insert_edge(2, 1);
        ov.insert_edge(0, 2);
        let want = Graph::from_edges(4, &[(0, 1), (2, 1), (0, 2)]);
        assert_eq!(ov.to_graph(), want);
    }

    #[test]
    fn delta_log_orders() {
        let mut log = DeltaLog::default();
        log.push(1, GraphDelta::NodeAdd);
        log.push(2, GraphDelta::EdgeInsert { src: 0, dst: 1 });
        assert_eq!(log.len(), 2);
        assert_eq!(log.entries()[1].0, 2);
        log.clear();
        assert!(log.is_empty());
    }
}
