//! Localized HAG repair under streaming deltas.
//!
//! [`IncrementalHag`] is a mutable, reference-counted twin of
//! [`Hag`](crate::hag::Hag) built for point updates. The packed `Hag`
//! numbers aggregation slots `n..n+|V_A|`, so a single `NodeAdd` would
//! renumber every aggregation slot; here aggregation nodes instead live
//! in their own id space (bit 31 tags a slot as an aggregation id), so
//! node growth, merges, and garbage collection are all O(local).
//!
//! Repair invariant (what keeps Theorem 1 true under every delta):
//! `cover(v)` is a function of `in_edges[v]` alone — an edge update
//! `(u, v)` only changes `N(v)`, so only `v`'s in-list needs repair:
//! * insert `(u, v)` — append the direct slot `u` (it cannot already be
//!   covered, the HAG was equivalent to a graph without the edge);
//! * delete `(u, v)` — if `u` is a direct slot, drop it; otherwise `u`
//!   hides inside an aggregation cover shared with other consumers, and
//!   `v` *falls back to direct aggregation* over its new neighbor list.
//!   Released aggregation nodes are garbage-collected by refcount
//!   cascade, never mutated — other consumers keep their covers intact.
//!
//! Fallback costs redundancy, not correctness. [`local_remerge`]
//! (the windowed pass over stream-dirtied finals) re-harvests shared
//! pairs with the same pair-redundancy rule as Algorithm 3
//! (`hag/search.rs`), and the drift policy (`policy.rs`) re-runs the
//! full search when local repair has leaked too much cost.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::hag::search::norm;
use crate::hag::{AggNode, AggregateKind, Hag};
use crate::util::FxHashMap;

/// Bit 31 tags an internal slot as an aggregation id.
const AGG: u32 = 1 << 31;

#[inline]
pub(crate) fn is_agg(s: u32) -> bool {
    s & AGG != 0
}

#[inline]
pub(crate) fn agg_id(s: u32) -> usize {
    (s & !AGG) as usize
}

#[inline]
pub(crate) fn agg_slot(i: usize) -> u32 {
    debug_assert!((i as u32) < AGG);
    AGG | i as u32
}

/// Lazy max-heap entry: (count, pair) with smallest-pair tie-break,
/// same shape as `search_set`'s heap.
type PairHeap = BinaryHeap<(u32, Reverse<(u32, u32)>)>;

/// Count every windowed pair of `list` into the re-merge map, pushing
/// heap candidates as counts reach 2+ (mirror of `search.rs::
/// add_window_pairs`, over whole fresh lists instead of one appended
/// slot).
fn add_window_pairs(pc: &mut FxHashMap<(u32, u32), u32>,
                    heap: &mut PairHeap, list: &[u32],
                    pair_cap: usize) {
    let w = list.len().min(pair_cap);
    for i in 0..w {
        for j in (i + 1)..w {
            let p = norm(list[i], list[j]);
            let c = pc.entry(p).or_insert(0);
            *c += 1;
            if *c >= 2 {
                heap.push((*c, Reverse(p)));
            }
        }
    }
}

/// Remove every windowed pair of `list` from the re-merge map;
/// zero-count entries are dropped so stale heap entries die on pop
/// (mirror of `search.rs::remove_window_pairs`).
fn sub_window_pairs(pc: &mut FxHashMap<(u32, u32), u32>, list: &[u32],
                    pair_cap: usize) {
    let w = list.len().min(pair_cap);
    for i in 0..w {
        for j in (i + 1)..w {
            let p = norm(list[i], list[j]);
            if let Some(c) = pc.get_mut(&p) {
                *c = c.saturating_sub(1);
                if *c == 0 {
                    pc.remove(&p);
                }
            }
        }
    }
}

/// A repairable HAG: set-AGGREGATE only (ordered covers do not admit
/// local point repair — the sequential fallback is a full re-search).
#[derive(Debug, Clone)]
pub struct IncrementalHag {
    n: usize,
    /// Aggregation nodes by id; `None` = garbage-collected. Operands
    /// use the internal encoding. Ids are append-only, so id order is
    /// creation order and therefore topological.
    aggs: Vec<Option<AggNode>>,
    /// Per aggregation id: live references from final in-lists plus
    /// from other live aggregation nodes.
    refs: Vec<u32>,
    /// Per original node: in-list in internal encoding. Unordered
    /// (set AGGREGATE), duplicate-free.
    in_edges: Vec<Vec<u32>>,
    live: usize,
    /// Maintained `sum |in_edges[v]|`.
    final_edges: usize,
}

impl IncrementalHag {
    /// Import a searched (packed) HAG. Unreferenced aggregation nodes
    /// are collected immediately.
    pub fn from_hag(h: &Hag) -> Self {
        assert_eq!(h.kind, AggregateKind::Set,
                   "incremental repair is set-AGGREGATE only");
        let n = h.n;
        let enc = |s: u32| -> u32 {
            if (s as usize) < n { s } else { agg_slot(s as usize - n) }
        };
        let aggs: Vec<Option<AggNode>> = h
            .agg_nodes
            .iter()
            .map(|a| Some(AggNode { left: enc(a.left),
                                    right: enc(a.right) }))
            .collect();
        let in_edges: Vec<Vec<u32>> = h
            .in_edges
            .iter()
            .map(|l| l.iter().map(|&s| enc(s)).collect())
            .collect();
        let mut refs = vec![0u32; aggs.len()];
        for a in aggs.iter().flatten() {
            for op in [a.left, a.right] {
                if is_agg(op) {
                    refs[agg_id(op)] += 1;
                }
            }
        }
        for l in &in_edges {
            for &s in l {
                if is_agg(s) {
                    refs[agg_id(s)] += 1;
                }
            }
        }
        let final_edges = in_edges.iter().map(|l| l.len()).sum();
        let live = aggs.len();
        let mut ih = IncrementalHag { n, aggs, refs, in_edges, live,
                                      final_edges };
        // Collect anything the search left unreferenced (defensive;
        // Algorithm 3 only materializes referenced nodes).
        for i in 0..ih.aggs.len() {
            if ih.refs[i] == 0 && ih.aggs[i].is_some() {
                if let Some(a) = ih.aggs[i].take() {
                    ih.live -= 1;
                    ih.release(a.left);
                    ih.release(a.right);
                }
            }
        }
        ih
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Live aggregation-node count `|V_A|`.
    pub fn live_aggs(&self) -> usize {
        self.live
    }

    /// `|Ê| = 2|V_A| + final edges`.
    pub fn e_hat(&self) -> usize {
        2 * self.live + self.final_edges
    }

    /// The quantity Algorithm 3 minimizes: `|Ê| - |V_A|`.
    pub fn cost_core(&self) -> usize {
        self.live + self.final_edges
    }

    /// Drop one reference to `s`, cascading into operands when an
    /// aggregation node dies.
    fn release(&mut self, s: u32) {
        if !is_agg(s) {
            return;
        }
        let mut stack = vec![agg_id(s)];
        while let Some(i) = stack.pop() {
            debug_assert!(self.refs[i] > 0, "refcount underflow");
            self.refs[i] -= 1;
            if self.refs[i] == 0 {
                if let Some(a) = self.aggs[i].take() {
                    self.live -= 1;
                    for op in [a.left, a.right] {
                        if is_agg(op) {
                            stack.push(agg_id(op));
                        }
                    }
                }
            }
        }
    }

    fn acquire(&mut self, s: u32) {
        if is_agg(s) {
            debug_assert!(self.aggs[agg_id(s)].is_some(),
                          "acquiring a dead aggregation node");
            self.refs[agg_id(s)] += 1;
        }
    }

    /// Repair for `EdgeInsert { src: u, dst: v }` (the overlay already
    /// accepted the edge as new).
    pub fn insert_edge(&mut self, u: u32, v: u32) {
        debug_assert!(!self.in_edges[v as usize].contains(&u),
                      "insert of an already-covered neighbor");
        self.in_edges[v as usize].push(u);
        self.final_edges += 1;
    }

    /// Repair for `EdgeDelete { src: u, dst: v }`. `new_neighbors` is
    /// `N(v)` *after* the delete (from the overlay). Returns `true`
    /// when `v` fell back to direct aggregation (the deleted neighbor
    /// was hidden inside an aggregation cover).
    pub fn delete_edge(&mut self, u: u32, v: u32,
                       new_neighbors: &[u32]) -> bool {
        let list = &mut self.in_edges[v as usize];
        if let Some(pos) = list.iter().position(|&s| s == u) {
            list.swap_remove(pos);
            self.final_edges -= 1;
            return false;
        }
        // u is inside some aggregation cover: rebuild v's in-list as
        // direct edges and release every slot it held.
        let old = std::mem::take(&mut self.in_edges[v as usize]);
        self.final_edges -= old.len();
        for s in old {
            self.release(s);
        }
        self.in_edges[v as usize] = new_neighbors.to_vec();
        self.final_edges += new_neighbors.len();
        true
    }

    /// Repair for `NodeAdd`: one isolated final.
    pub fn add_node(&mut self) {
        self.in_edges.push(Vec::new());
        self.n += 1;
    }

    /// Windowed local re-merge over `dirty` finals (sorted, deduped by
    /// the caller): greedily materialize the pair of slots co-consumed
    /// by the most dirty finals — the same redundancy rule, and the
    /// same round / lazy-heap / incremental-count structure, as
    /// Algorithm 3's `search_set` in `hag/search.rs`, restricted to
    /// the dirty region. A decrement can orphan a still-mergeable pair
    /// from the heap (exactly as in `search_set_round`); the outer
    /// round loop recovers coverage by rebuilding, and terminates when
    /// a round makes no progress. `pair_cap` bounds per-consumer pair
    /// enumeration exactly like `SearchConfig::pair_cap`, and
    /// `capacity` bounds live `|V_A|` exactly like
    /// `SearchConfig::capacity` (the §3.2 a-hat memory budget must
    /// hold for the maintained HAG too, even when the drift policy
    /// never rebuilds). Returns merges made.
    pub fn local_remerge(&mut self, dirty: &[u32], pair_cap: usize,
                         max_merges: usize, capacity: usize) -> usize {
        debug_assert!(dirty.windows(2).all(|w| w[0] < w[1]));
        let mut total = 0usize;
        while total < max_merges && self.live < capacity {
            let made = self.remerge_round(dirty, pair_cap,
                                          max_merges - total, capacity);
            total += made;
            if made == 0 {
                break;
            }
        }
        total
    }

    /// One re-merge round: build windowed pair counts over the dirty
    /// finals, then drain the lazy heap, maintaining counts
    /// incrementally as consumers are rewired.
    fn remerge_round(&mut self, dirty: &[u32], pair_cap: usize,
                     budget: usize, capacity: usize) -> usize {
        let mut count: FxHashMap<(u32, u32), u32> = FxHashMap::default();
        let mut heap = PairHeap::new();
        for &v in dirty {
            add_window_pairs(&mut count, &mut heap,
                             &self.in_edges[v as usize], pair_cap);
        }
        let mut merges = 0usize;
        while merges < budget && self.live < capacity {
            // Pop the highest-redundancy non-stale pair (ties break to
            // the smallest pair, so the pass is deterministic).
            let (a, b) = loop {
                match heap.pop() {
                    None => return merges,
                    Some((c, Reverse(p))) => {
                        let cur =
                            count.get(&p).copied().unwrap_or(0);
                        if cur == c && c >= 2 {
                            break p;
                        }
                        // stale: a still-counted pair was re-pushed on
                        // its last update; just drop this entry
                    }
                }
            };
            // `contains` rechecks whole lists, so this can only find
            // *more* users than the windowed count promised, never
            // fewer.
            let users: Vec<u32> = dirty
                .iter()
                .copied()
                .filter(|&v| {
                    let l = &self.in_edges[v as usize];
                    l.contains(&a) && l.contains(&b)
                })
                .collect();
            if users.len() < 2 {
                // Defensive (see above: unreachable): drop the entry
                // so the heap cannot yield it again.
                count.remove(&norm(a, b));
                continue;
            }
            let w = agg_slot(self.aggs.len());
            self.aggs.push(Some(AggNode { left: a, right: b }));
            self.refs.push(0);
            self.live += 1;
            // The new node's operand references must exist before any
            // consumer releases a/b, so a cascade can never reap them.
            self.acquire(a);
            self.acquire(b);
            for &v in &users {
                sub_window_pairs(&mut count,
                                 &self.in_edges[v as usize], pair_cap);
                {
                    let l = &mut self.in_edges[v as usize];
                    l.retain(|&s| s != a && s != b);
                    l.push(w);
                }
                add_window_pairs(&mut count, &mut heap,
                                 &self.in_edges[v as usize], pair_cap);
                self.final_edges -= 1; // two slots out, one in
                self.refs[agg_id(w)] += 1;
                self.release(a);
                self.release(b);
            }
            merges += 1;
        }
        merges
    }

    /// Export as a packed [`Hag`]: live aggregation nodes compacted in
    /// id (= creation = topological) order into slots `n..n+live`.
    pub fn to_hag(&self) -> Hag {
        let mut slot_of = vec![u32::MAX; self.aggs.len()];
        let mut agg_nodes = Vec::with_capacity(self.live);
        let n = self.n;
        for (i, a) in self.aggs.iter().enumerate() {
            if let Some(a) = a {
                let dec = |s: u32| -> u32 {
                    if is_agg(s) {
                        let p = slot_of[agg_id(s)];
                        debug_assert!(p != u32::MAX,
                                      "live agg references dead operand");
                        p
                    } else {
                        s
                    }
                };
                let packed = AggNode { left: dec(a.left),
                                       right: dec(a.right) };
                slot_of[i] = (n + agg_nodes.len()) as u32;
                agg_nodes.push(packed);
            }
        }
        let in_edges: Vec<Vec<u32>> = self
            .in_edges
            .iter()
            .map(|l| {
                l.iter()
                    .map(|&s| {
                        if is_agg(s) { slot_of[agg_id(s)] } else { s }
                    })
                    .collect()
            })
            .collect();
        Hag { n, agg_nodes, in_edges, kind: AggregateKind::Set }
    }

    /// Internal consistency: refcounts exact, live count exact, live
    /// operands alive, finals reference live nodes, in-lists
    /// duplicate-free, maintained edge count exact.
    pub fn check(&self) -> Result<(), String> {
        let mut want_refs = vec![0u32; self.aggs.len()];
        let mut live = 0usize;
        for (i, a) in self.aggs.iter().enumerate() {
            if let Some(a) = a {
                live += 1;
                for op in [a.left, a.right] {
                    if is_agg(op) {
                        if self.aggs[agg_id(op)].is_none() {
                            return Err(format!(
                                "agg {i} references dead agg {}",
                                agg_id(op)));
                        }
                        if agg_id(op) >= i {
                            return Err(format!(
                                "agg {i} references non-earlier agg {}",
                                agg_id(op)));
                        }
                        want_refs[agg_id(op)] += 1;
                    } else if (op as usize) >= self.n {
                        return Err(format!(
                            "agg {i} references missing node {op}"));
                    }
                }
            }
        }
        let mut final_edges = 0usize;
        for (v, l) in self.in_edges.iter().enumerate() {
            final_edges += l.len();
            let mut sorted = l.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != l.len() {
                return Err(format!("node {v} has duplicate in-slots"));
            }
            for &s in l {
                if is_agg(s) {
                    if self.aggs[agg_id(s)].is_none() {
                        return Err(format!(
                            "node {v} references dead agg {}",
                            agg_id(s)));
                    }
                    want_refs[agg_id(s)] += 1;
                } else if (s as usize) >= self.n {
                    return Err(format!(
                        "node {v} references missing node {s}"));
                }
            }
        }
        if live != self.live {
            return Err(format!("live count {} != {}", self.live, live));
        }
        if final_edges != self.final_edges {
            return Err(format!("final edge count {} != {}",
                               self.final_edges, final_edges));
        }
        for (i, (&got, &want)) in
            self.refs.iter().zip(want_refs.iter()).enumerate()
        {
            if self.aggs[i].is_some() && got != want {
                return Err(format!(
                    "agg {i}: refcount {got} != actual {want}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::hag::{check_equivalence, hag_search, SearchConfig};

    fn searched(g: &Graph) -> IncrementalHag {
        let cfg = SearchConfig {
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, _) = hag_search(g, &cfg);
        IncrementalHag::from_hag(&h)
    }

    fn k5() -> Graph {
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in 0..5u32 {
                if u != v {
                    edges.push((u, v));
                }
            }
        }
        Graph::from_edges(5, &edges)
    }

    #[test]
    fn import_export_roundtrip() {
        let g = k5();
        let ih = searched(&g);
        ih.check().unwrap();
        let h = ih.to_hag();
        h.validate().unwrap();
        check_equivalence(&g, &h).unwrap();
        assert_eq!(h.cost_core(), ih.cost_core());
        assert_eq!(h.e_hat(), ih.e_hat());
    }

    #[test]
    fn insert_keeps_equivalence() {
        // K5 minus one edge; insert it back, expect cover == K5.
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in 0..5u32 {
                if u != v && !(u == 4 && v == 0) {
                    edges.push((u, v));
                }
            }
        }
        let g = Graph::from_edges(5, &edges);
        let mut ih = searched(&g);
        ih.insert_edge(4, 0);
        ih.check().unwrap();
        check_equivalence(&k5(), &ih.to_hag()).unwrap();
    }

    #[test]
    fn delete_direct_edge_no_fallback() {
        let g = Graph::from_edges(3, &[(1, 0), (2, 0)]);
        // trivial HAG (no redundancy): both slots direct
        let mut ih = searched(&g);
        let nn = [2u32];
        assert!(!ih.delete_edge(1, 0, &nn), "direct slot: no fallback");
        ih.check().unwrap();
        let want = Graph::from_edges(3, &[(2, 0)]);
        check_equivalence(&want, &ih.to_hag()).unwrap();
    }

    #[test]
    fn delete_covered_edge_falls_back_and_gc_runs() {
        let g = k5();
        let mut ih = searched(&g);
        let before_live = ih.live_aggs();
        assert!(before_live > 0, "K5 search must merge");
        // Find a consumer whose in-list holds an aggregation slot and
        // delete one neighbor hidden inside it.
        let v = (0..5u32)
            .find(|&v| ih.in_edges[v as usize].iter()
                  .any(|&s| is_agg(s)))
            .expect("some final consumes an agg node");
        let covered = ih.to_hag().node_cover(v);
        let direct: Vec<u32> = ih.in_edges[v as usize]
            .iter().copied().filter(|&s| !is_agg(s)).collect();
        let u = covered.iter().copied()
            .find(|&c| !direct.contains(&c)).unwrap();
        let nn: Vec<u32> = covered.iter().copied()
            .filter(|&c| c != u).collect();
        assert!(ih.delete_edge(u, v, &nn), "covered slot: fallback");
        ih.check().unwrap();
        // equivalence against the graph minus that one edge
        let mut edges = Vec::new();
        for a in 0..5u32 {
            for b in 0..5u32 {
                if a != b && !(a == u && b == v) {
                    edges.push((a, b));
                }
            }
        }
        check_equivalence(&Graph::from_edges(5, &edges),
                          &ih.to_hag()).unwrap();
    }

    #[test]
    fn remerge_recovers_shared_pair() {
        // 4 consumers share {0, 1}; trivial HAG, then remerge.
        let mut edges = Vec::new();
        for v in 2..6u32 {
            edges.push((0, v));
            edges.push((1, v));
        }
        let g = Graph::from_edges(6, &edges);
        let h = Hag::from_graph(&g, AggregateKind::Set);
        let mut ih = IncrementalHag::from_hag(&h);
        let before = ih.cost_core();
        let dirty: Vec<u32> = (2..6).collect();
        let merges = ih.local_remerge(&dirty, 64, 16, usize::MAX);
        assert_eq!(merges, 1, "one shared pair to merge");
        ih.check().unwrap();
        assert!(ih.cost_core() < before,
                "{} !< {before}", ih.cost_core());
        check_equivalence(&g, &ih.to_hag()).unwrap();
    }

    #[test]
    fn remerge_respects_capacity() {
        // finals 3,4,5 share {0,1,2}: two chained merges are possible
        // ((0,1) -> w, then (w,2) -> w2), but capacity must cap |V_A|
        // exactly like SearchConfig::capacity does for the full search.
        let mut edges = Vec::new();
        for v in 3..6u32 {
            for u in 0..3u32 {
                edges.push((u, v));
            }
        }
        let g = Graph::from_edges(6, &edges);
        let h = Hag::from_graph(&g, AggregateKind::Set);
        let dirty: Vec<u32> = (3..6).collect();

        let mut capped = IncrementalHag::from_hag(&h);
        assert_eq!(capped.local_remerge(&dirty, 64, 16, 0), 0);
        assert_eq!(capped.live_aggs(), 0, "capacity 0 forbids merges");
        assert_eq!(capped.local_remerge(&dirty, 64, 16, 1), 1);
        assert_eq!(capped.live_aggs(), 1);
        capped.check().unwrap();
        check_equivalence(&g, &capped.to_hag()).unwrap();

        let mut free = IncrementalHag::from_hag(&h);
        assert_eq!(free.local_remerge(&dirty, 64, 16, usize::MAX), 2);
        check_equivalence(&g, &free.to_hag()).unwrap();
    }

    #[test]
    fn node_add_extends_finals() {
        let g = k5();
        let mut ih = searched(&g);
        ih.add_node();
        ih.insert_edge(0, 5);
        ih.insert_edge(5, 0);
        ih.check().unwrap();
        let h = ih.to_hag();
        h.validate().unwrap();
        assert_eq!(h.n, 6);
        assert_eq!(h.node_cover(5), vec![0]);
        assert!(h.node_cover(0).contains(&5));
    }
}
