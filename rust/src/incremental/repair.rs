//! Localized HAG repair under streaming deltas.
//!
//! [`IncrementalHag`] is a mutable, reference-counted twin of
//! [`Hag`](crate::hag::Hag) built for point updates. The packed `Hag`
//! numbers aggregation slots `n..n+|V_A|`, so a single `NodeAdd` would
//! renumber every aggregation slot; here aggregation nodes instead live
//! in their own id space (bit 31 tags a slot as an aggregation id), so
//! node growth, merges, and garbage collection are all O(local).
//!
//! Repair invariant (what keeps Theorem 1 true under every delta):
//! `cover(v)` is a function of `in_edges[v]` alone — an edge update
//! `(u, v)` only changes `N(v)`, so only `v`'s in-list needs repair:
//! * insert `(u, v)` — append the direct slot `u` (it cannot already be
//!   covered, the HAG was equivalent to a graph without the edge);
//! * delete `(u, v)` — if `u` is a direct slot, drop it; otherwise `u`
//!   hides inside an aggregation cover shared with other consumers, and
//!   `v` *falls back to direct aggregation* over its new neighbor list.
//!   Released aggregation nodes are garbage-collected by refcount
//!   cascade, never mutated — other consumers keep their covers intact.
//!
//! Fallback costs redundancy, not correctness. [`local_remerge`]
//! (the windowed pass over stream-dirtied finals) re-harvests shared
//! pairs with the same pair-redundancy rule as Algorithm 3
//! (`hag/search.rs`), and the drift policy (`policy.rs`) re-runs the
//! full search when local repair has leaked too much cost.

use std::cmp::Reverse;

use crate::hag::search::{pack_pair, PairHeap, PairTable};
use crate::hag::{AggNode, AggregateKind, Hag};

/// Bit 31 tags an internal slot as an aggregation id.
const AGG: u32 = 1 << 31;

#[inline]
pub(crate) fn is_agg(s: u32) -> bool {
    s & AGG != 0
}

#[inline]
pub(crate) fn agg_id(s: u32) -> usize {
    (s & !AGG) as usize
}

#[inline]
pub(crate) fn agg_slot(i: usize) -> u32 {
    debug_assert!((i as u32) < AGG);
    AGG | i as u32
}

/// Count every windowed pair of `list` into the re-merge table,
/// pushing heap candidates as counts reach 2+. Same flat kernel
/// pieces as `hag/search.rs` ([`PairTable`], packed `u64` keys,
/// [`PairHeap`] with the packed-key tie-break — identical pop order
/// to the old `(u32, u32)` tuples), over whole fresh lists instead of
/// one appended slot.
fn add_window_pairs(pc: &mut PairTable, heap: &mut PairHeap,
                    list: &[u32], pair_cap: usize) {
    let w = list.len().min(pair_cap);
    for i in 0..w {
        for j in (i + 1)..w {
            let k = pack_pair(list[i], list[j]);
            let c = pc.incr(k);
            if c >= 2 {
                heap.push((c, Reverse(k)));
            }
        }
    }
}

/// Remove every windowed pair of `list` from the re-merge table;
/// zero-count entries read as absent, so stale heap entries die on
/// pop.
fn sub_window_pairs(pc: &mut PairTable, list: &[u32],
                    pair_cap: usize) {
    let w = list.len().min(pair_cap);
    for i in 0..w {
        for j in (i + 1)..w {
            pc.decr(pack_pair(list[i], list[j]));
        }
    }
}

/// Reusable buffers for [`IncrementalHag::local_remerge`]: the flat
/// pair-count table and heap (shared kernel layout with
/// `hag/search.rs`) plus the users buffer the old pass re-allocated
/// on every heap pop. Owned by the [`IncrementalHag`] so a stream
/// engine's re-merge cadence stops paying per-pass allocations.
#[derive(Debug, Clone, Default)]
struct RemergeScratch {
    count: PairTable,
    heap: PairHeap,
    users: Vec<u32>,
}

/// A repairable HAG: set-AGGREGATE only (ordered covers do not admit
/// local point repair — the sequential fallback is a full re-search).
#[derive(Debug, Clone)]
pub struct IncrementalHag {
    n: usize,
    /// Aggregation nodes by id; `None` = garbage-collected. Operands
    /// use the internal encoding. Ids are append-only, so id order is
    /// creation order and therefore topological.
    aggs: Vec<Option<AggNode>>,
    /// Per aggregation id: live references from final in-lists plus
    /// from other live aggregation nodes.
    refs: Vec<u32>,
    /// Per original node: in-list in internal encoding. Unordered
    /// (set AGGREGATE), duplicate-free.
    in_edges: Vec<Vec<u32>>,
    live: usize,
    /// Maintained `sum |in_edges[v]|`.
    final_edges: usize,
    /// Re-merge arena, recycled across passes.
    scratch: RemergeScratch,
}

impl IncrementalHag {
    /// Import a searched (packed) HAG. Unreferenced aggregation nodes
    /// are collected immediately.
    pub fn from_hag(h: &Hag) -> Self {
        assert_eq!(h.kind, AggregateKind::Set,
                   "incremental repair is set-AGGREGATE only");
        let n = h.n;
        let enc = |s: u32| -> u32 {
            if (s as usize) < n { s } else { agg_slot(s as usize - n) }
        };
        let aggs: Vec<Option<AggNode>> = h
            .agg_nodes
            .iter()
            .map(|a| Some(AggNode { left: enc(a.left),
                                    right: enc(a.right) }))
            .collect();
        let in_edges: Vec<Vec<u32>> = h
            .in_edges
            .iter()
            .map(|l| l.iter().map(|&s| enc(s)).collect())
            .collect();
        let mut refs = vec![0u32; aggs.len()];
        for a in aggs.iter().flatten() {
            for op in [a.left, a.right] {
                if is_agg(op) {
                    refs[agg_id(op)] += 1;
                }
            }
        }
        for l in &in_edges {
            for &s in l {
                if is_agg(s) {
                    refs[agg_id(s)] += 1;
                }
            }
        }
        let final_edges = in_edges.iter().map(|l| l.len()).sum();
        let live = aggs.len();
        let mut ih = IncrementalHag { n, aggs, refs, in_edges, live,
                                      final_edges,
                                      scratch:
                                          RemergeScratch::default() };
        // Collect anything the search left unreferenced (defensive;
        // Algorithm 3 only materializes referenced nodes).
        for i in 0..ih.aggs.len() {
            if ih.refs[i] == 0 && ih.aggs[i].is_some() {
                if let Some(a) = ih.aggs[i].take() {
                    ih.live -= 1;
                    ih.release(a.left);
                    ih.release(a.right);
                }
            }
        }
        ih
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Live aggregation-node count `|V_A|`.
    pub fn live_aggs(&self) -> usize {
        self.live
    }

    /// `|Ê| = 2|V_A| + final edges`.
    pub fn e_hat(&self) -> usize {
        2 * self.live + self.final_edges
    }

    /// The quantity Algorithm 3 minimizes: `|Ê| - |V_A|`.
    pub fn cost_core(&self) -> usize {
        self.live + self.final_edges
    }

    /// Drop one reference to `s`, cascading into operands when an
    /// aggregation node dies.
    fn release(&mut self, s: u32) {
        if !is_agg(s) {
            return;
        }
        let mut stack = vec![agg_id(s)];
        while let Some(i) = stack.pop() {
            debug_assert!(self.refs[i] > 0, "refcount underflow");
            self.refs[i] -= 1;
            if self.refs[i] == 0 {
                if let Some(a) = self.aggs[i].take() {
                    self.live -= 1;
                    for op in [a.left, a.right] {
                        if is_agg(op) {
                            stack.push(agg_id(op));
                        }
                    }
                }
            }
        }
    }

    fn acquire(&mut self, s: u32) {
        if is_agg(s) {
            debug_assert!(self.aggs[agg_id(s)].is_some(),
                          "acquiring a dead aggregation node");
            self.refs[agg_id(s)] += 1;
        }
    }

    /// Repair for `EdgeInsert { src: u, dst: v }` (the overlay already
    /// accepted the edge as new).
    pub fn insert_edge(&mut self, u: u32, v: u32) {
        debug_assert!(!self.in_edges[v as usize].contains(&u),
                      "insert of an already-covered neighbor");
        self.in_edges[v as usize].push(u);
        self.final_edges += 1;
    }

    /// Repair for `EdgeDelete { src: u, dst: v }`. `new_neighbors` is
    /// `N(v)` *after* the delete (from the overlay). Returns `true`
    /// when `v` fell back to direct aggregation (the deleted neighbor
    /// was hidden inside an aggregation cover).
    pub fn delete_edge(&mut self, u: u32, v: u32,
                       new_neighbors: &[u32]) -> bool {
        let list = &mut self.in_edges[v as usize];
        if let Some(pos) = list.iter().position(|&s| s == u) {
            list.swap_remove(pos);
            self.final_edges -= 1;
            return false;
        }
        // u is inside some aggregation cover: rebuild v's in-list as
        // direct edges and release every slot it held.
        let old = std::mem::take(&mut self.in_edges[v as usize]);
        self.final_edges -= old.len();
        for s in old {
            self.release(s);
        }
        self.in_edges[v as usize] = new_neighbors.to_vec();
        self.final_edges += new_neighbors.len();
        true
    }

    /// Repair for `NodeAdd`: one isolated final.
    pub fn add_node(&mut self) {
        self.in_edges.push(Vec::new());
        self.n += 1;
    }

    /// Windowed local re-merge over `dirty` finals (sorted, deduped by
    /// the caller): greedily materialize the pair of slots co-consumed
    /// by the most dirty finals — the same redundancy rule, and the
    /// same round / lazy-heap / incremental-count structure, as
    /// Algorithm 3's `search_set` in `hag/search.rs`, restricted to
    /// the dirty region. A decrement can orphan a still-mergeable pair
    /// from the heap (exactly as in `search_set_round`); the outer
    /// round loop recovers coverage by rebuilding, and terminates when
    /// a round makes no progress. `pair_cap` bounds per-consumer pair
    /// enumeration exactly like `SearchConfig::pair_cap`, and
    /// `capacity` bounds live `|V_A|` exactly like
    /// `SearchConfig::capacity` (the §3.2 a-hat memory budget must
    /// hold for the maintained HAG too, even when the drift policy
    /// never rebuilds). Returns merges made.
    pub fn local_remerge(&mut self, dirty: &[u32], pair_cap: usize,
                         max_merges: usize, capacity: usize) -> usize {
        debug_assert!(dirty.windows(2).all(|w| w[0] < w[1]));
        let mut total = 0usize;
        while total < max_merges && self.live < capacity {
            let made = self.remerge_round(dirty, pair_cap,
                                          max_merges - total, capacity);
            total += made;
            if made == 0 {
                break;
            }
        }
        total
    }

    /// One re-merge round: build windowed pair counts over the dirty
    /// finals, then drain the lazy heap, maintaining counts
    /// incrementally as consumers are rewired. The count table, heap,
    /// and users buffer all come from the recycled
    /// [`RemergeScratch`].
    fn remerge_round(&mut self, dirty: &[u32], pair_cap: usize,
                     budget: usize, capacity: usize) -> usize {
        let mut sc = std::mem::take(&mut self.scratch);
        sc.count.clear();
        sc.heap.clear();
        let merges = self.remerge_round_inner(&mut sc, dirty, pair_cap,
                                              budget, capacity);
        self.scratch = sc;
        merges
    }

    fn remerge_round_inner(&mut self, sc: &mut RemergeScratch,
                           dirty: &[u32], pair_cap: usize,
                           budget: usize, capacity: usize) -> usize {
        for &v in dirty {
            add_window_pairs(&mut sc.count, &mut sc.heap,
                             &self.in_edges[v as usize], pair_cap);
        }
        let mut merges = 0usize;
        while merges < budget && self.live < capacity {
            // Pop the highest-redundancy non-stale pair (ties break to
            // the smallest pair, so the pass is deterministic).
            let (a, b, key) = loop {
                match sc.heap.pop() {
                    None => return merges,
                    Some((c, Reverse(k))) => {
                        if sc.count.get(k) == c && c >= 2 {
                            break ((k >> 32) as u32, k as u32, k);
                        }
                        // stale: a still-counted pair was re-pushed on
                        // its last update; just drop this entry
                    }
                }
            };
            // `contains` rechecks whole lists, so this can only find
            // *more* users than the windowed count promised, never
            // fewer.
            sc.users.clear();
            for &v in dirty {
                let l = &self.in_edges[v as usize];
                if l.contains(&a) && l.contains(&b) {
                    sc.users.push(v);
                }
            }
            if sc.users.len() < 2 {
                // Defensive (see above: unreachable): drop the entry
                // so the heap cannot yield it again.
                sc.count.zero(key);
                continue;
            }
            let w = agg_slot(self.aggs.len());
            self.aggs.push(Some(AggNode { left: a, right: b }));
            self.refs.push(0);
            self.live += 1;
            // The new node's operand references must exist before any
            // consumer releases a/b, so a cascade can never reap them.
            self.acquire(a);
            self.acquire(b);
            for i in 0..sc.users.len() {
                let v = sc.users[i];
                sub_window_pairs(&mut sc.count,
                                 &self.in_edges[v as usize], pair_cap);
                {
                    let l = &mut self.in_edges[v as usize];
                    l.retain(|&s| s != a && s != b);
                    l.push(w);
                }
                add_window_pairs(&mut sc.count, &mut sc.heap,
                                 &self.in_edges[v as usize], pair_cap);
                self.final_edges -= 1; // two slots out, one in
                self.refs[agg_id(w)] += 1;
                self.release(a);
                self.release(b);
            }
            merges += 1;
        }
        merges
    }

    /// Export as a packed [`Hag`]: live aggregation nodes compacted in
    /// id (= creation = topological) order into slots `n..n+live`.
    pub fn to_hag(&self) -> Hag {
        let mut slot_of = vec![u32::MAX; self.aggs.len()];
        let mut agg_nodes = Vec::with_capacity(self.live);
        let n = self.n;
        for (i, a) in self.aggs.iter().enumerate() {
            if let Some(a) = a {
                let dec = |s: u32| -> u32 {
                    if is_agg(s) {
                        let p = slot_of[agg_id(s)];
                        debug_assert!(p != u32::MAX,
                                      "live agg references dead operand");
                        p
                    } else {
                        s
                    }
                };
                let packed = AggNode { left: dec(a.left),
                                       right: dec(a.right) };
                slot_of[i] = (n + agg_nodes.len()) as u32;
                agg_nodes.push(packed);
            }
        }
        let in_edges: Vec<Vec<u32>> = self
            .in_edges
            .iter()
            .map(|l| {
                l.iter()
                    .map(|&s| {
                        if is_agg(s) { slot_of[agg_id(s)] } else { s }
                    })
                    .collect()
            })
            .collect();
        Hag { n, agg_nodes, in_edges, kind: AggregateKind::Set }
    }

    /// Internal consistency: refcounts exact, live count exact, live
    /// operands alive, finals reference live nodes, in-lists
    /// duplicate-free, maintained edge count exact. Thin wrapper over
    /// the analysis incremental passes
    /// ([`crate::analysis::check_incremental`]: `incr.id_space`,
    /// `incr.topo_order`, `incr.refcounts`, `incr.counters`) so this
    /// method and the verifier can never disagree; the first error
    /// diagnostic becomes the `Err` message.
    pub fn check(&self) -> Result<(), String> {
        let report = crate::analysis::check_incremental(self);
        match report.diagnostics.iter().find(
            |d| d.severity == crate::analysis::Severity::Error)
        {
            None => Ok(()),
            Some(d) => Err(format!("[{}] {}: {}", d.pass, d.entity,
                                   d.message)),
        }
    }

    /// Raw field views for the analysis incremental passes
    /// (`analysis/incremental.rs`):
    /// `(n, aggs, refs, in_edges, live, final_edges)`. The fields
    /// stay private — this is a read-only window, crate-internal.
    pub(crate) fn raw_parts(&self)
        -> (usize, &[Option<AggNode>], &[u32], &[Vec<u32>], usize,
            usize)
    {
        (self.n, &self.aggs, &self.refs, &self.in_edges, self.live,
         self.final_edges)
    }

    /// Mutable field views for the mutation-kill tests only:
    /// `(aggs, refs, in_edges, live, final_edges)`. Corrupting these
    /// is how the tests prove the incremental passes are not vacuous.
    #[cfg(test)]
    pub(crate) fn raw_parts_mut(&mut self)
        -> (&mut Vec<Option<AggNode>>, &mut Vec<u32>,
            &mut Vec<Vec<u32>>, &mut usize, &mut usize)
    {
        (&mut self.aggs, &mut self.refs, &mut self.in_edges,
         &mut self.live, &mut self.final_edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::hag::{check_equivalence, hag_search, SearchConfig};

    fn searched(g: &Graph) -> IncrementalHag {
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, _) = hag_search(g, &cfg);
        IncrementalHag::from_hag(&h)
    }

    fn k5() -> Graph {
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in 0..5u32 {
                if u != v {
                    edges.push((u, v));
                }
            }
        }
        Graph::from_edges(5, &edges)
    }

    #[test]
    fn import_export_roundtrip() {
        let g = k5();
        let ih = searched(&g);
        ih.check().unwrap();
        let h = ih.to_hag();
        h.validate().unwrap();
        check_equivalence(&g, &h).unwrap();
        assert_eq!(h.cost_core(), ih.cost_core());
        assert_eq!(h.e_hat(), ih.e_hat());
    }

    #[test]
    fn insert_keeps_equivalence() {
        // K5 minus one edge; insert it back, expect cover == K5.
        let mut edges = Vec::new();
        for u in 0..5u32 {
            for v in 0..5u32 {
                if u != v && !(u == 4 && v == 0) {
                    edges.push((u, v));
                }
            }
        }
        let g = Graph::from_edges(5, &edges);
        let mut ih = searched(&g);
        ih.insert_edge(4, 0);
        ih.check().unwrap();
        check_equivalence(&k5(), &ih.to_hag()).unwrap();
    }

    #[test]
    fn delete_direct_edge_no_fallback() {
        let g = Graph::from_edges(3, &[(1, 0), (2, 0)]);
        // trivial HAG (no redundancy): both slots direct
        let mut ih = searched(&g);
        let nn = [2u32];
        assert!(!ih.delete_edge(1, 0, &nn), "direct slot: no fallback");
        ih.check().unwrap();
        let want = Graph::from_edges(3, &[(2, 0)]);
        check_equivalence(&want, &ih.to_hag()).unwrap();
    }

    #[test]
    fn delete_covered_edge_falls_back_and_gc_runs() {
        let g = k5();
        let mut ih = searched(&g);
        let before_live = ih.live_aggs();
        assert!(before_live > 0, "K5 search must merge");
        // Find a consumer whose in-list holds an aggregation slot and
        // delete one neighbor hidden inside it.
        let v = (0..5u32)
            .find(|&v| ih.in_edges[v as usize].iter()
                  .any(|&s| is_agg(s)))
            .expect("some final consumes an agg node");
        let covered = ih.to_hag().node_cover(v);
        let direct: Vec<u32> = ih.in_edges[v as usize]
            .iter().copied().filter(|&s| !is_agg(s)).collect();
        let u = covered.iter().copied()
            .find(|&c| !direct.contains(&c)).unwrap();
        let nn: Vec<u32> = covered.iter().copied()
            .filter(|&c| c != u).collect();
        assert!(ih.delete_edge(u, v, &nn), "covered slot: fallback");
        ih.check().unwrap();
        // equivalence against the graph minus that one edge
        let mut edges = Vec::new();
        for a in 0..5u32 {
            for b in 0..5u32 {
                if a != b && !(a == u && b == v) {
                    edges.push((a, b));
                }
            }
        }
        check_equivalence(&Graph::from_edges(5, &edges),
                          &ih.to_hag()).unwrap();
    }

    #[test]
    fn remerge_recovers_shared_pair() {
        // 4 consumers share {0, 1}; trivial HAG, then remerge.
        let mut edges = Vec::new();
        for v in 2..6u32 {
            edges.push((0, v));
            edges.push((1, v));
        }
        let g = Graph::from_edges(6, &edges);
        let h = Hag::from_graph(&g, AggregateKind::Set);
        let mut ih = IncrementalHag::from_hag(&h);
        let before = ih.cost_core();
        let dirty: Vec<u32> = (2..6).collect();
        let merges = ih.local_remerge(&dirty, 64, 16, usize::MAX);
        assert_eq!(merges, 1, "one shared pair to merge");
        ih.check().unwrap();
        assert!(ih.cost_core() < before,
                "{} !< {before}", ih.cost_core());
        check_equivalence(&g, &ih.to_hag()).unwrap();
    }

    #[test]
    fn remerge_respects_capacity() {
        // finals 3,4,5 share {0,1,2}: two chained merges are possible
        // ((0,1) -> w, then (w,2) -> w2), but capacity must cap |V_A|
        // exactly like SearchConfig::capacity does for the full search.
        let mut edges = Vec::new();
        for v in 3..6u32 {
            for u in 0..3u32 {
                edges.push((u, v));
            }
        }
        let g = Graph::from_edges(6, &edges);
        let h = Hag::from_graph(&g, AggregateKind::Set);
        let dirty: Vec<u32> = (3..6).collect();

        let mut capped = IncrementalHag::from_hag(&h);
        assert_eq!(capped.local_remerge(&dirty, 64, 16, 0), 0);
        assert_eq!(capped.live_aggs(), 0, "capacity 0 forbids merges");
        assert_eq!(capped.local_remerge(&dirty, 64, 16, 1), 1);
        assert_eq!(capped.live_aggs(), 1);
        capped.check().unwrap();
        check_equivalence(&g, &capped.to_hag()).unwrap();

        let mut free = IncrementalHag::from_hag(&h);
        assert_eq!(free.local_remerge(&dirty, 64, 16, usize::MAX), 2);
        check_equivalence(&g, &free.to_hag()).unwrap();
    }

    /// The pre-kernel re-merge pass (FxHashMap pair counts, fresh
    /// `users` Vec per heap pop), kept verbatim as a test oracle:
    /// [`IncrementalHag::local_remerge`] on the flat [`PairTable`]
    /// kernel must stay byte-identical to it.
    fn local_remerge_reference(ih: &mut IncrementalHag, dirty: &[u32],
                               pair_cap: usize, max_merges: usize,
                               capacity: usize) -> usize {
        let mut total = 0usize;
        while total < max_merges && ih.live < capacity {
            let made = remerge_round_reference(ih, dirty, pair_cap,
                                               max_merges - total,
                                               capacity);
            total += made;
            if made == 0 {
                break;
            }
        }
        total
    }

    fn remerge_round_reference(ih: &mut IncrementalHag, dirty: &[u32],
                               pair_cap: usize, budget: usize,
                               capacity: usize) -> usize {
        use crate::util::FxHashMap;
        use std::collections::BinaryHeap;
        type RefHeap = BinaryHeap<(u32, Reverse<(u32, u32)>)>;
        let norm =
            |a: u32, b: u32| if a < b { (a, b) } else { (b, a) };
        let add = |count: &mut FxHashMap<(u32, u32), u32>,
                   heap: &mut RefHeap, list: &[u32]| {
            let w = list.len().min(pair_cap);
            for i in 0..w {
                for j in (i + 1)..w {
                    let p = norm(list[i], list[j]);
                    let c = count.entry(p).or_insert(0);
                    *c += 1;
                    if *c >= 2 {
                        heap.push((*c, Reverse(p)));
                    }
                }
            }
        };
        let sub = |count: &mut FxHashMap<(u32, u32), u32>,
                   list: &[u32]| {
            let w = list.len().min(pair_cap);
            for i in 0..w {
                for j in (i + 1)..w {
                    let p = norm(list[i], list[j]);
                    if let Some(c) = count.get_mut(&p) {
                        *c = c.saturating_sub(1);
                        if *c == 0 {
                            count.remove(&p);
                        }
                    }
                }
            }
        };
        let mut count: FxHashMap<(u32, u32), u32> =
            FxHashMap::default();
        let mut heap = RefHeap::new();
        for &v in dirty {
            add(&mut count, &mut heap, &ih.in_edges[v as usize]);
        }
        let mut merges = 0usize;
        while merges < budget && ih.live < capacity {
            let (a, b) = loop {
                match heap.pop() {
                    None => return merges,
                    Some((c, Reverse(p))) => {
                        let cur = count.get(&p).copied().unwrap_or(0);
                        if cur == c && c >= 2 {
                            break p;
                        }
                    }
                }
            };
            let users: Vec<u32> = dirty
                .iter()
                .copied()
                .filter(|&v| {
                    let l = &ih.in_edges[v as usize];
                    l.contains(&a) && l.contains(&b)
                })
                .collect();
            if users.len() < 2 {
                count.remove(&norm(a, b));
                continue;
            }
            let w = agg_slot(ih.aggs.len());
            ih.aggs.push(Some(AggNode { left: a, right: b }));
            ih.refs.push(0);
            ih.live += 1;
            ih.acquire(a);
            ih.acquire(b);
            for &v in &users {
                sub(&mut count, &ih.in_edges[v as usize]);
                {
                    let l = &mut ih.in_edges[v as usize];
                    l.retain(|&s| s != a && s != b);
                    l.push(w);
                }
                add(&mut count, &mut heap, &ih.in_edges[v as usize]);
                ih.final_edges -= 1;
                ih.refs[agg_id(w)] += 1;
                ih.release(a);
                ih.release(b);
            }
            merges += 1;
        }
        merges
    }

    #[test]
    fn remerge_matches_prekernel_reference() {
        use crate::datasets::{community_graph, CommunityCfg};
        for seed in 0..4u64 {
            let gcfg = CommunityCfg {
                n: 120,
                e: 1500,
                communities: 4,
                intra_frac: 0.9,
                zipf_exp: 0.9,
                clone_frac: 0.5,
            };
            let (g, _) = community_graph(&gcfg, seed);
            let h = Hag::from_graph(&g, AggregateKind::Set);
            let mut a = IncrementalHag::from_hag(&h);
            let mut b = IncrementalHag::from_hag(&h);
            let dirty: Vec<u32> = (0..g.n() as u32)
                .filter(|v| v % 3 == 0)
                .collect();
            // Successive calls drive `a` through its recycled scratch
            // (exact, tiny-window, and capacity-capped configs) while
            // `b` replays the pre-kernel pass; every step must agree.
            for (cap, mm, vcap) in [
                (usize::MAX, 8, usize::MAX),
                (4, 16, usize::MAX),
                (64, 64, 12),
            ] {
                let ma = a.local_remerge(&dirty, cap, mm, vcap);
                let mb = local_remerge_reference(&mut b, &dirty, cap,
                                                 mm, vcap);
                assert_eq!(ma, mb, "seed {seed} cap {cap}: merge \
                                    counts diverged");
                assert_eq!(a.to_hag(), b.to_hag(),
                           "seed {seed} cap {cap}: results diverged");
                a.check().unwrap();
            }
            check_equivalence(&g, &a.to_hag()).unwrap();
        }
    }

    #[test]
    fn node_add_extends_finals() {
        let g = k5();
        let mut ih = searched(&g);
        ih.add_node();
        ih.insert_edge(0, 5);
        ih.insert_edge(5, 0);
        ih.check().unwrap();
        let h = ih.to_hag();
        h.validate().unwrap();
        assert_eq!(h.n, 6);
        assert_eq!(h.node_cover(5), vec![0]);
        assert!(h.node_cover(0).contains(&5));
    }
}
