//! Theorem 1 oracle: a GNN-graph and a HAG are equivalent iff
//! `cover(v) == N(v)` for every original node `v`.
//!
//! Two checkers:
//! * [`check_equivalence`] — exact: materializes each node's cover
//!   multiset (memoizing per-aggregation-node covers) and compares it to
//!   the CSR neighbor list. For `Set` aggregates the comparison is as
//!   sorted multisets; for `Sequential`, as ordered lists.
//! * [`check_equivalence_probabilistic`] — for very large graphs: runs
//!   one f64 sum-aggregation of random values through both
//!   representations. Sum aggregation is linear, so any cover mismatch
//!   perturbs the result; collision probability is negligible
//!   (~2^-40 per node with the tolerance used).

use crate::graph::Graph;
use crate::util::Rng;

use super::{AggregateKind, Hag};

/// Exact Theorem-1 check. Returns the first offending node on failure.
pub fn check_equivalence(g: &Graph, hag: &Hag) -> Result<(), String> {
    if g.n() != hag.n {
        return Err(format!("node count mismatch: {} vs {}", g.n(), hag.n));
    }
    hag.validate()?;

    // Memoize covers of aggregation nodes (sorted for Set).
    let na = hag.agg_nodes.len();
    let mut covers: Vec<Vec<u32>> = Vec::with_capacity(na);
    for (i, a) in hag.agg_nodes.iter().enumerate() {
        let mut c = Vec::new();
        for &s in &[a.left, a.right] {
            if (s as usize) < hag.n {
                c.push(s);
            } else {
                c.extend_from_slice(&covers[s as usize - hag.n]);
            }
        }
        if hag.kind == AggregateKind::Set {
            c.sort_unstable();
        }
        debug_assert!(i == covers.len());
        covers.push(c);
    }

    for v in 0..hag.n as u32 {
        let mut cover = Vec::new();
        for &s in &hag.in_edges[v as usize] {
            if (s as usize) < hag.n {
                cover.push(s);
            } else {
                cover.extend_from_slice(&covers[s as usize - hag.n]);
            }
        }
        let mut want = g.neighbors(v).to_vec();
        match hag.kind {
            AggregateKind::Set => {
                cover.sort_unstable();
                // CSR neighbor lists are already sorted.
            }
            AggregateKind::Sequential => {
                // order is semantic; `want` is the CSR (ascending) order,
                // which is the canonical sequential order in this repo.
                want = g.neighbors(v).to_vec();
            }
        }
        if cover != want {
            return Err(format!(
                "node {v}: cover(v) = {:?} != N(v) = {:?}",
                &cover[..cover.len().min(16)],
                &want[..want.len().min(16)]
            ));
        }
    }
    Ok(())
}

/// Probabilistic Theorem-1 check via one linear aggregation pass in f64.
pub fn check_equivalence_probabilistic(g: &Graph, hag: &Hag,
                                       seed: u64) -> Result<(), String> {
    if g.n() != hag.n {
        return Err(format!("node count mismatch: {} vs {}", g.n(), hag.n));
    }
    hag.validate()?;
    let mut rng = Rng::seed_from_u64(seed);
    let x: Vec<f64> =
        (0..g.n()).map(|_| rng.range_f64(-1.0, 1.0)).collect();

    // Reference: CSR aggregation.
    let mut want = vec![0f64; g.n()];
    for (v, ns) in g.iter() {
        want[v as usize] = ns.iter().map(|&u| x[u as usize]).sum();
    }

    // HAG aggregation: agg-node slots in creation (= topo) order.
    let mut ahat = vec![0f64; hag.agg_nodes.len()];
    let val = |s: u32, ahat: &[f64]| -> f64 {
        if (s as usize) < hag.n {
            x[s as usize]
        } else {
            ahat[s as usize - hag.n]
        }
    };
    for (i, a) in hag.agg_nodes.iter().enumerate() {
        ahat[i] = val(a.left, &ahat) + val(a.right, &ahat);
    }
    for v in 0..hag.n {
        let got: f64 = hag.in_edges[v].iter().map(|&s| val(s, &ahat)).sum();
        // covers are small-integer sums of unit-range values; 1e-6 is
        // far above accumulated rounding yet far below any structural
        // difference detectable at this precision.
        if (got - want[v]).abs() > 1e-6 * (1.0 + want[v].abs()) {
            return Err(format!(
                "node {v}: aggregate {got} != reference {}", want[v]));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hag::AggNode;

    fn g5() -> Graph {
        Graph::from_edges(5, &[(1, 0), (2, 0), (1, 3), (2, 3), (0, 2)])
    }

    #[test]
    fn trivial_hag_is_equivalent() {
        let g = g5();
        let h = Hag::from_graph(&g, AggregateKind::Set);
        check_equivalence(&g, &h).unwrap();
        check_equivalence_probabilistic(&g, &h, 1).unwrap();
    }

    #[test]
    fn valid_merge_is_equivalent() {
        let g = g5();
        let mut h = Hag::from_graph(&g, AggregateKind::Set);
        h.agg_nodes.push(AggNode { left: 1, right: 2 });
        h.in_edges[0] = vec![5];
        h.in_edges[3] = vec![5];
        check_equivalence(&g, &h).unwrap();
        check_equivalence_probabilistic(&g, &h, 2).unwrap();
    }

    #[test]
    fn broken_cover_detected() {
        let g = g5();
        let mut h = Hag::from_graph(&g, AggregateKind::Set);
        h.in_edges[0] = vec![1]; // dropped neighbor 2
        assert!(check_equivalence(&g, &h).is_err());
        assert!(check_equivalence_probabilistic(&g, &h, 3).is_err());
    }

    #[test]
    fn duplicate_cover_detected() {
        let g = g5();
        let mut h = Hag::from_graph(&g, AggregateKind::Set);
        h.agg_nodes.push(AggNode { left: 1, right: 2 });
        h.in_edges[0] = vec![1, 5]; // covers {1,1,2}: duplicate
        assert!(check_equivalence(&g, &h).is_err());
        assert!(check_equivalence_probabilistic(&g, &h, 4).is_err());
    }

    #[test]
    fn sequential_order_mismatch_detected() {
        let g = g5(); // N(0) = [1, 2] in canonical order
        let mut h = Hag::from_graph(&g, AggregateKind::Sequential);
        h.in_edges[0] = vec![2, 1]; // wrong order
        assert!(check_equivalence(&g, &h).is_err());
        // NB: the probabilistic checker uses a sum (commutative), so it
        // cannot see ordering — exact checker is authoritative for
        // Sequential.
        h.in_edges[0] = vec![1, 2];
        check_equivalence(&g, &h).unwrap();
    }
}
