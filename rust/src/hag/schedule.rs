//! Plan compiler: lower a [`Hag`] into the padded index tensors the
//! AOT-compiled XLA executables consume (see python/compile/buckets.py
//! for the other side of the contract).
//!
//! Pipeline:
//! 1. **Leveling** — aggregation nodes are grouped into topological
//!    levels (`level(w) = 1 + max(level(left), level(right))`); within a
//!    level all binary combines are independent and execute as one
//!    `level_combine` kernel call. Slots are allocated level-major so the
//!    scatter back into the value buffer is a dense slice update.
//! 2. **Degree sort** — original nodes are relabeled by *final* in-edge
//!    count (descending) so that consecutive rows have similar nnz; the
//!    permutation is recorded for the data packer.
//! 3. **Banding** — row blocks (`br` rows each) are partitioned into a
//!    few contiguous *bands*; each band is padded to its own max
//!    block-nnz. Banding bounds the padding waste a single hub row would
//!    otherwise impose on every block.
//! 4. **Padding** — all index padding points at the pinned zero slot
//!    `m_pad - 1`, making padded contributions exactly zero.

use crate::graph::Graph;

use super::Hag;

/// Static layout knobs (must match the bucket the artifact was built
/// with; see `Bucket` in python/compile/buckets.py).
#[derive(Debug, Clone)]
pub struct PlanConfig {
    /// Rows per block-CSR block (output tile height).
    pub br: usize,
    /// Level tensor quantum (`l_pad` is a multiple of this).
    pub lvl_block: usize,
    /// Maximum number of degree bands.
    pub max_bands: usize,
    /// nnzb values are rounded up to a multiple of this.
    pub nnzb_round: usize,
}

impl Default for PlanConfig {
    fn default() -> Self {
        // max_bands=6: under the scatter implementation the aggregation
        // cost is proportional to *padded* slots, so banding must track
        // the degree distribution tightly (perf pass; EXPERIMENTS.md
        // §Perf).
        PlanConfig { br: 8, lvl_block: 128, max_bands: 6, nnzb_round: 32 }
    }
}

/// The lowered plan: everything the runtime needs to pack literals.
/// `PartialEq` compares every field — the plan-cache correctness
/// contract ("dirty-shard re-planning is identical to from-scratch")
/// is asserted with full structural equality, index tensors included.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    /// Real node count.
    pub n: usize,
    /// Padded node count (multiple of 128 and of `br`).
    pub n_pad: usize,
    /// Number of HAG levels (0 for the GNN-graph baseline).
    pub levels: usize,
    /// Slots per level (multiple of `lvl_block`; 0 when `levels == 0`).
    pub l_pad: usize,
    /// Per band `(nb, nnzb)`; `sum(nb) * br == n_pad`.
    pub bands: Vec<(usize, usize)>,
    pub br: usize,
    pub lvl_block: usize,
    /// `perm[new_id] = old_id` (degree sort); data packers use this.
    pub perm: Vec<u32>,
    /// `inv_perm[old_id] = new_id`.
    pub inv_perm: Vec<u32>,
    /// Level combine operands, `[levels * l_pad]` row-major, buffer-slot
    /// indices (padding -> zero slot).
    pub lvl_left: Vec<i32>,
    pub lvl_right: Vec<i32>,
    /// Per band: gather indices `[nb * nnzb]` row-major.
    pub band_cols: Vec<Vec<i32>>,
    /// Per band: local destination rows `[nb * nnzb]`.
    pub band_rows: Vec<Vec<i32>>,
    /// True in-degree per *permuted* node, `[n_pad]` (GCN normalizer).
    pub deg: Vec<f32>,
}

impl ExecutionPlan {
    /// Value-buffer length: `n_pad + levels * l_pad + 1` (zero slot last).
    pub fn m_pad(&self) -> usize {
        self.n_pad + self.levels * self.l_pad + 1
    }

    /// Index of the pinned zero slot (all padding points here).
    pub fn zero_slot(&self) -> i32 {
        (self.m_pad() - 1) as i32
    }

    /// Bytes of index tensors (plan memory; §3.2 accounting).
    pub fn plan_bytes(&self) -> usize {
        4 * (self.lvl_left.len() + self.lvl_right.len()
            + self.band_cols.iter().map(|b| b.len()).sum::<usize>()
            + self.band_rows.iter().map(|b| b.len()).sum::<usize>()
            + self.deg.len())
    }

    /// Total padded index slots vs real entries (padding-waste ratio).
    pub fn padding_ratio(&self, hag: &Hag) -> f64 {
        let real = hag.e_hat() as f64;
        let padded = (self.levels * self.l_pad * 2
            + self.bands.iter().map(|&(nb, nnzb)| nb * nnzb).sum::<usize>())
            as f64;
        if real == 0.0 { 1.0 } else { padded / real }
    }
}

fn round_up(x: usize, q: usize) -> usize {
    if q == 0 { x } else { x.div_ceil(q) * q }
}

/// Lower `hag` (over input graph `g`, for true degrees) into an
/// [`ExecutionPlan`].
pub fn build_plan(g: &Graph, hag: &Hag, cfg: &PlanConfig) -> ExecutionPlan {
    assert_eq!(g.n(), hag.n);
    let n = hag.n;
    let n_pad = round_up(n.max(1), 128_usize.max(cfg.br));
    let na = hag.agg_nodes.len();

    // ---- 1. leveling ----------------------------------------------
    // level[i] for agg node i (1-based); originals are level 0.
    let mut level = vec![0u32; na];
    let mut max_level = 0u32;
    for (i, a) in hag.agg_nodes.iter().enumerate() {
        let lv = |s: u32| -> u32 {
            if (s as usize) < n { 0 } else { level[s as usize - n] }
        };
        level[i] = 1 + lv(a.left).max(lv(a.right));
        max_level = max_level.max(level[i]);
    }
    let levels = max_level as usize;
    // index within level, assigned in creation order
    let mut level_sizes = vec![0usize; levels + 1];
    let mut idx_in_level = vec![0usize; na];
    for i in 0..na {
        let l = level[i] as usize;
        idx_in_level[i] = level_sizes[l];
        level_sizes[l] += 1;
    }
    let l_pad = if levels == 0 {
        0
    } else {
        round_up(level_sizes[1..].iter().copied().max().unwrap_or(0)
                 .max(1), cfg.lvl_block)
    };

    // ---- 2. degree sort (by final in-edge count, desc) --------------
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&v| std::cmp::Reverse(hag.in_edges[v as usize].len()));
    let perm = order; // perm[new] = old
    let mut inv_perm = vec![0u32; n];
    for (new, &old) in perm.iter().enumerate() {
        inv_perm[old as usize] = new as u32;
    }

    let m_pad = n_pad + levels * l_pad + 1;
    let zero = (m_pad - 1) as i32;

    // buffer slot of a HAG slot id
    let slot_of = |s: u32| -> i32 {
        if (s as usize) < n {
            inv_perm[s as usize] as i32
        } else {
            let i = s as usize - n;
            (n_pad + (level[i] as usize - 1) * l_pad + idx_in_level[i])
                as i32
        }
    };

    // ---- level tensors ----------------------------------------------
    let mut lvl_left = vec![zero; levels * l_pad];
    let mut lvl_right = vec![zero; levels * l_pad];
    for (i, a) in hag.agg_nodes.iter().enumerate() {
        let l = level[i] as usize - 1;
        let j = idx_in_level[i];
        lvl_left[l * l_pad + j] = slot_of(a.left);
        lvl_right[l * l_pad + j] = slot_of(a.right);
    }

    // ---- 3. banding ---------------------------------------------------
    let nb_total = n_pad / cfg.br;
    // nnz per block (over permuted rows)
    let mut block_nnz = vec![0usize; nb_total];
    for new in 0..n {
        let old = perm[new] as usize;
        block_nnz[new / cfg.br] += hag.in_edges[old].len();
    }
    let boundaries = band_boundaries(&block_nnz, cfg.max_bands);
    let mut bands = Vec::with_capacity(boundaries.len());
    for w in boundaries.windows(2) {
        let (s, e) = (w[0], w[1]);
        let maxnnz = block_nnz[s..e].iter().copied().max().unwrap_or(0);
        let nnzb = round_up(maxnnz.max(1), cfg.nnzb_round).max(8);
        bands.push((e - s, nnzb));
    }

    // ---- 4. fill band tensors ----------------------------------------
    let mut band_cols: Vec<Vec<i32>> = Vec::with_capacity(bands.len());
    let mut band_rows: Vec<Vec<i32>> = Vec::with_capacity(bands.len());
    let mut block0 = 0usize;
    for &(nb, nnzb) in &bands {
        let mut cols = vec![zero; nb * nnzb];
        let mut rows = vec![0i32; nb * nnzb];
        let mut fill = vec![0usize; nb];
        for b in 0..nb {
            let gblock = block0 + b;
            for r in 0..cfg.br {
                let new = gblock * cfg.br + r;
                if new >= n {
                    continue;
                }
                let old = perm[new] as usize;
                for &s in &hag.in_edges[old] {
                    let j = fill[b];
                    debug_assert!(j < nnzb, "band nnzb overflow");
                    cols[b * nnzb + j] = slot_of(s);
                    rows[b * nnzb + j] = r as i32;
                    fill[b] = j + 1;
                }
            }
        }
        band_cols.push(cols);
        band_rows.push(rows);
        block0 += nb;
    }

    // ---- degrees (true graph degree, permuted) -----------------------
    let mut deg = vec![0f32; n_pad];
    for new in 0..n {
        deg[new] = g.degree(perm[new]) as f32;
    }

    ExecutionPlan {
        n,
        n_pad,
        levels,
        l_pad,
        bands,
        br: cfg.br,
        lvl_block: cfg.lvl_block,
        perm,
        inv_perm,
        lvl_left,
        lvl_right,
        band_cols,
        band_rows,
        deg,
    }
}

/// Choose contiguous band boundaries over (descending-ish) block nnz,
/// minimizing total padded slots `sum(len * max)`. Exhaustive DP over a
/// bounded candidate-boundary set (log-spaced) keeps this O(C^2 * bands)
/// regardless of graph size.
fn band_boundaries(block_nnz: &[usize], max_bands: usize) -> Vec<usize> {
    let nb = block_nnz.len();
    if nb == 0 {
        return vec![0, 0];
    }
    if max_bands <= 1 {
        return vec![0, nb];
    }
    // Candidate boundaries: log-spaced positions.
    let mut cands: Vec<usize> = vec![0, nb];
    let mut x = 1usize;
    while x < nb {
        cands.push(x);
        x = (x * 3).div_ceil(2); // ~1.5x growth
    }
    cands.sort_unstable();
    cands.dedup();
    let c = cands.len();
    // cost of a single band covering cands[i]..cands[j]
    let seg_cost = |i: usize, j: usize| -> u64 {
        let (s, e) = (cands[i], cands[j]);
        let m = block_nnz[s..e].iter().copied().max().unwrap_or(0);
        ((e - s) as u64) * (m.max(1) as u64)
    };
    // dp[k][i] = min cost to cover cands[i]..nb with k bands
    let inf = u64::MAX / 2;
    let mut dp = vec![vec![inf; c]; max_bands + 1];
    let mut nxt = vec![vec![0usize; c]; max_bands + 1];
    for k in 1..=max_bands {
        for i in (0..c - 1).rev() {
            for j in (i + 1)..c {
                let tail = if j == c - 1 {
                    0
                } else if k > 1 {
                    dp[k - 1][j]
                } else {
                    continue;
                };
                if tail >= inf {
                    continue;
                }
                let cost = seg_cost(i, j).saturating_add(tail);
                if cost < dp[k][i] {
                    dp[k][i] = cost;
                    nxt[k][i] = j;
                }
            }
        }
    }
    // walk
    let mut best_k = 1;
    for k in 2..=max_bands {
        if dp[k][0] < dp[best_k][0] {
            best_k = k;
        }
    }
    let mut out = vec![0usize];
    let (mut i, mut k) = (0usize, best_k);
    while cands[i] != nb {
        let j = nxt[k][i];
        out.push(cands[j]);
        i = j;
        k -= 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hag::{hag_search, AggregateKind, SearchConfig};

    fn grid_graph(w: usize, h: usize) -> Graph {
        let mut edges = Vec::new();
        let id = |x: usize, y: usize| (y * w + x) as u32;
        for y in 0..h {
            for x in 0..w {
                if x + 1 < w {
                    edges.push((id(x, y), id(x + 1, y)));
                }
                if y + 1 < h {
                    edges.push((id(x, y), id(x, y + 1)));
                }
            }
        }
        Graph::from_undirected_edges(w * h, &edges)
    }

    /// Reference sum-aggregation through plan tensors in f64 — mirrors
    /// exactly what the XLA artifact computes.
    fn simulate_plan(plan: &ExecutionPlan, x_old: &[f64]) -> Vec<f64> {
        let m = plan.m_pad();
        let mut buf = vec![0f64; m];
        for new in 0..plan.n {
            buf[new] = x_old[plan.perm[new] as usize];
        }
        for l in 0..plan.levels {
            let base = plan.n_pad + l * plan.l_pad;
            for j in 0..plan.l_pad {
                let li = plan.lvl_left[l * plan.l_pad + j] as usize;
                let ri = plan.lvl_right[l * plan.l_pad + j] as usize;
                buf[base + j] = buf[li] + buf[ri];
            }
        }
        let mut out_new = vec![0f64; plan.n_pad];
        let mut row0 = 0usize;
        for (bi, &(nb, nnzb)) in plan.bands.iter().enumerate() {
            for b in 0..nb {
                for j in 0..nnzb {
                    let col = plan.band_cols[bi][b * nnzb + j] as usize;
                    let r = plan.band_rows[bi][b * nnzb + j] as usize;
                    out_new[row0 + b * plan.br + r] += buf[col];
                }
            }
            row0 += nb * plan.br;
        }
        // un-permute
        let mut out = vec![0f64; plan.n];
        for new in 0..plan.n {
            out[plan.perm[new] as usize] = out_new[new];
        }
        out
    }

    fn check_plan_matches_graph(g: &Graph, plan: &ExecutionPlan) {
        let mut rng = crate::util::Rng::seed_from_u64(9);
        let x: Vec<f64> =
            (0..g.n()).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let got = simulate_plan(plan, &x);
        for (v, ns) in g.iter() {
            let want: f64 = ns.iter().map(|&u| x[u as usize]).sum();
            assert!((got[v as usize] - want).abs() < 1e-9,
                    "node {v}: {} vs {want}", got[v as usize]);
        }
    }

    #[test]
    fn plan_of_trivial_hag_matches_graph() {
        let g = grid_graph(7, 5);
        let hag = Hag::from_graph(&g, AggregateKind::Set);
        let plan = build_plan(&g, &hag, &PlanConfig::default());
        assert_eq!(plan.levels, 0);
        assert_eq!(plan.n_pad % 128, 0);
        check_plan_matches_graph(&g, &plan);
    }

    #[test]
    fn plan_of_searched_hag_matches_graph() {
        let g = grid_graph(9, 9);
        let (hag, _) = hag_search(
            &g, &SearchConfig::paper_default(g.n()).exact());
        let plan = build_plan(&g, &hag, &PlanConfig::default());
        if !hag.agg_nodes.is_empty() {
            assert!(plan.levels >= 1);
        }
        check_plan_matches_graph(&g, &plan);
    }

    #[test]
    fn plan_of_clique_hag_matches_graph() {
        let mut edges = Vec::new();
        for u in 0..20u32 {
            for v in 0..20u32 {
                if u != v {
                    edges.push((u, v));
                }
            }
        }
        let g = Graph::from_edges(20, &edges);
        let (hag, _) = hag_search(
            &g,
            &SearchConfig { alpha: 1.0, beta: 1.0, capacity: usize::MAX, kind: AggregateKind::Set,
                            pair_cap: usize::MAX });
        let plan = build_plan(&g, &hag, &PlanConfig::default());
        assert!(plan.levels >= 1, "clique must produce hierarchy");
        check_plan_matches_graph(&g, &plan);
    }

    #[test]
    fn degree_sort_orders_rows() {
        // one hub + leaves: hub must land in row 0 after permutation
        let mut edges = Vec::new();
        for u in 1..50u32 {
            edges.push((u, 0));
        }
        let g = Graph::from_edges(50, &edges);
        let hag = Hag::from_graph(&g, AggregateKind::Set);
        let plan = build_plan(&g, &hag, &PlanConfig::default());
        assert_eq!(plan.perm[0], 0, "hub first");
        assert_eq!(plan.deg[0], 49.0);
        check_plan_matches_graph(&g, &plan);
    }

    #[test]
    fn banding_reduces_padding_on_skewed_degrees() {
        // hub of degree 500 + 2000 degree-2 nodes
        let mut edges = Vec::new();
        for u in 1..=500u32 {
            edges.push((u, 0));
        }
        for v in 501..2501u32 {
            edges.push((v - 500, v));
            edges.push((v - 499, v));
        }
        let g = Graph::from_edges(2501, &edges);
        let hag = Hag::from_graph(&g, AggregateKind::Set);
        let multi = build_plan(&g, &hag, &PlanConfig::default());
        let single = build_plan(
            &g, &hag,
            &PlanConfig { max_bands: 1, ..PlanConfig::default() });
        let slots = |p: &ExecutionPlan| p.bands.iter()
            .map(|&(nb, nnzb)| nb * nnzb).sum::<usize>();
        assert!(slots(&multi) < slots(&single),
                "banding must reduce padded slots: {} vs {}",
                slots(&multi), slots(&single));
        check_plan_matches_graph(&g, &multi);
        check_plan_matches_graph(&g, &single);
    }

    #[test]
    fn l_pad_quantized() {
        let g = grid_graph(9, 9);
        let (hag, _) = hag_search(
            &g, &SearchConfig::paper_default(g.n()).exact());
        let plan = build_plan(&g, &hag, &PlanConfig::default());
        if plan.levels > 0 {
            assert_eq!(plan.l_pad % plan.lvl_block, 0);
        }
        assert_eq!(plan.m_pad(),
                   plan.n_pad + plan.levels * plan.l_pad + 1);
    }
}
