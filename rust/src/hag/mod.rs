//! Hierarchically Aggregated computation Graphs (paper §3).
//!
//! A [`Hag`] augments an input [`Graph`](crate::graph::Graph) with
//! *aggregation nodes* `V_A`, each holding the intermediate aggregate of
//! exactly two operands (Algorithm 3 only ever materializes binary
//! merges). Buffer-slot ids ("slots") index `0..n` for original nodes and
//! `n..n+|V_A|` for aggregation nodes, in creation order — creation order
//! is topological by construction, since a merge can only reference slots
//! that already exist.

pub mod equivalence;
pub mod schedule;
pub mod search;

pub use equivalence::{check_equivalence, check_equivalence_probabilistic};
pub use schedule::{build_plan, ExecutionPlan, PlanConfig};
pub use search::{hag_search, hag_search_reference,
                 hag_search_with_scratch, SearchConfig, SearchScratch,
                 SearchStats};

use crate::graph::Graph;

/// Slot id: original node (`< n`) or aggregation node (`>= n`).
pub type Slot = u32;

/// An aggregation node: the (set or sequential) aggregate of two slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AggNode {
    pub left: Slot,
    pub right: Slot,
}

/// Which AGGREGATE class the HAG was built for (paper Table 1).
///
/// * `Set` — associative + commutative (GCN sum, GraphSAGE-P max):
///   aggregation nodes may cover any subset, in any order.
/// * `Sequential` — order-sensitive (GraphSAGE-LSTM, Tree-LSTM):
///   aggregation nodes must cover *prefixes* of each node's ordered
///   neighbor list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggregateKind {
    Set,
    Sequential,
}

/// A HAG equivalent to some input GNN-graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hag {
    /// Original node count `|V|`.
    pub n: usize,
    /// Aggregation nodes, creation (= topological) order.
    pub agg_nodes: Vec<AggNode>,
    /// Per original node: its current in-neighbor slot list. For
    /// `Sequential`, order is semantic (the aggregation order).
    pub in_edges: Vec<Vec<Slot>>,
    pub kind: AggregateKind,
}

impl Hag {
    /// The trivial HAG: the GNN-graph itself (`V_A = {}`, paper §3.1).
    pub fn from_graph(g: &Graph, kind: AggregateKind) -> Self {
        Hag {
            n: g.n(),
            agg_nodes: Vec::new(),
            in_edges: g.iter().map(|(_, ns)| ns.to_vec()).collect(),
            kind,
        }
    }

    /// Total slot count `|V| + |V_A|`.
    pub fn slots(&self) -> usize {
        self.n + self.agg_nodes.len()
    }

    /// `|Ê|`: HAG edges = 2 per aggregation node + remaining final edges.
    pub fn e_hat(&self) -> usize {
        2 * self.agg_nodes.len()
            + self.in_edges.iter().map(|l| l.len()).sum::<usize>()
    }

    /// Number of binary aggregations per GNN layer:
    /// `sum over v in V u V_A of max(|N_hat(v)| - 1, 0)`.
    pub fn aggregations(&self) -> usize {
        self.agg_nodes.len()
            + self
                .in_edges
                .iter()
                .map(|l| l.len().saturating_sub(1))
                .sum::<usize>()
    }

    /// Operand reads per GNN layer — the paper's "data transfers between
    /// GPU threads" metric, in unit rows (multiply by `4 * hidden_dim`
    /// for bytes; DESIGN.md §Hardware-Adaptation maps this to HBM->VMEM
    /// row reads on TPU).
    pub fn data_transfers(&self) -> usize {
        self.e_hat()
    }

    /// The paper's cost function (§4.1):
    /// `cost = alpha * (|E_hat| - |V_A|) + (beta - alpha) * |V|`.
    pub fn cost(&self, alpha: f64, beta: f64) -> f64 {
        alpha * (self.e_hat() as f64 - self.agg_nodes.len() as f64)
            + (beta - alpha) * self.n as f64
    }

    /// The quantity Algorithm 3 minimizes: `|E_hat| - |V_A|`.
    pub fn cost_core(&self) -> usize {
        self.e_hat() - self.agg_nodes.len()
    }

    /// Expand `cover(slot)` (paper Eq. 2/3): the multiset of original
    /// nodes whose layer-(k-1) activations feed this slot's aggregate.
    /// Returned sorted for `Set`, in aggregation order for `Sequential`.
    pub fn cover(&self, slot: Slot) -> Vec<u32> {
        let mut out = Vec::new();
        self.cover_into(slot, &mut out);
        if self.kind == AggregateKind::Set {
            out.sort_unstable();
        }
        out
    }

    fn cover_into(&self, slot: Slot, out: &mut Vec<u32>) {
        if (slot as usize) < self.n {
            out.push(slot);
        } else {
            let a = self.agg_nodes[slot as usize - self.n];
            self.cover_into(a.left, out);
            self.cover_into(a.right, out);
        }
    }

    /// `cover` of an original node's *neighborhood*: what Theorem 1
    /// compares against `N(v)`.
    pub fn node_cover(&self, v: u32) -> Vec<u32> {
        let mut out = Vec::new();
        for &s in &self.in_edges[v as usize] {
            self.cover_into(s, &mut out);
        }
        if self.kind == AggregateKind::Set {
            out.sort_unstable();
        }
        out
    }

    /// Memory overhead of the intermediate `a-hat` buffers in bytes for a
    /// given hidden dim (paper §3.2: constant across layers, not saved
    /// for backprop).
    pub fn ahat_memory_bytes(&self, hidden: usize) -> usize {
        self.agg_nodes.len() * hidden * 4
    }

    /// Structural sanity: every agg node references earlier slots only,
    /// every final edge references a valid slot, and (for `Set`) no
    /// duplicate slots in a node's in-list.
    ///
    /// Thin wrapper over the structural passes of
    /// [`crate::analysis`] (`hag.topo_order`, `hag.slot_range`,
    /// `hag.dup_inslots`, `hag.orphan_agg`) — the self-check and the
    /// standalone verifier share one implementation so they can never
    /// disagree. Use [`crate::analysis::verify_hag`] directly for the
    /// full typed diagnostics (and the Theorem-1 exactness pass,
    /// which needs the source graph).
    pub fn validate(&self) -> Result<(), String> {
        crate::analysis::validate_hag(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_fig1_graph() -> Graph {
        // Fig 1a: A..E = 0..4; neighbors:
        // A<-{B,C,D}, B<-{A,C}, C<-{A,B,E}, D<-{B,C}, E<-{C,D}
        Graph::from_edges(
            5,
            &[
                (1, 0), (2, 0), (3, 0),
                (0, 1), (2, 1),
                (0, 2), (1, 2), (4, 2),
                (1, 3), (2, 3),
                (2, 4), (3, 4),
            ],
        )
    }

    #[test]
    fn trivial_hag_matches_graph_cost() {
        let g = paper_fig1_graph();
        let h = Hag::from_graph(&g, AggregateKind::Set);
        assert_eq!(h.e_hat(), g.e());
        assert_eq!(h.aggregations(), 12 - 5); // sum (deg-1) = |E|-|V|
        assert_eq!(h.data_transfers(), 12);
        assert_eq!(h.cost_core(), 12);
    }

    #[test]
    fn manual_merge_reduces_cost() {
        let g = paper_fig1_graph();
        let mut h = Hag::from_graph(&g, AggregateKind::Set);
        // merge {B, C} (slots 1, 2), shared by A and D
        let w = h.slots() as u32;
        h.agg_nodes.push(AggNode { left: 1, right: 2 });
        for v in [0usize, 3] {
            h.in_edges[v].retain(|&s| s != 1 && s != 2);
            h.in_edges[v].push(w);
        }
        h.validate().unwrap();
        // edges: 12 - 4 + 2 (consumers) + 2 (agg inputs) = 12; |V_A|=1
        assert_eq!(h.e_hat(), 12);
        assert_eq!(h.cost_core(), 11);
        assert_eq!(h.node_cover(0), vec![1, 2, 3]);
        assert_eq!(h.node_cover(3), vec![1, 2]);
    }

    #[test]
    fn cover_nested() {
        let mut h = Hag {
            n: 4,
            agg_nodes: vec![],
            in_edges: vec![vec![]; 4],
            kind: AggregateKind::Set,
        };
        h.agg_nodes.push(AggNode { left: 1, right: 2 }); // slot 4 = {1,2}
        h.agg_nodes.push(AggNode { left: 4, right: 3 }); // slot 5 = {1,2,3}
        h.in_edges[0] = vec![5];
        assert_eq!(h.cover(5), vec![1, 2, 3]);
        assert_eq!(h.node_cover(0), vec![1, 2, 3]);
    }

    #[test]
    fn sequential_cover_preserves_order() {
        let mut h = Hag {
            n: 4,
            agg_nodes: vec![],
            in_edges: vec![vec![]; 4],
            kind: AggregateKind::Sequential,
        };
        h.agg_nodes.push(AggNode { left: 3, right: 1 }); // slot 4 = (3,1)
        h.in_edges[0] = vec![4, 2]; // cover = (3,1,2)
        assert_eq!(h.node_cover(0), vec![3, 1, 2]);
    }

    #[test]
    fn validate_rejects_forward_reference() {
        let mut h = Hag {
            n: 2,
            agg_nodes: vec![AggNode { left: 3, right: 0 }],
            in_edges: vec![vec![2], vec![]], // node 0 consumes the agg
            kind: AggregateKind::Set,
        };
        assert!(h.validate().is_err());
        h.agg_nodes[0] = AggNode { left: 1, right: 0 };
        assert!(h.validate().is_ok());
    }

    #[test]
    fn cost_function_formula() {
        let g = paper_fig1_graph();
        let h = Hag::from_graph(&g, AggregateKind::Set);
        // alpha=1, beta=2: cost = (12-0) + (2-1)*5 = 17
        assert!((h.cost(1.0, 2.0) - 17.0).abs() < 1e-12);
    }
}
