//! Algorithm 3 — the HAG search algorithm.
//!
//! Greedy redundancy elimination: repeatedly find the pair of slots
//! `(v1, v2)` co-aggregated by the most consumers, materialize a new
//! aggregation node `w = v1 (+) v2`, and rewire every consumer of both to
//! consume `w` instead. Each iteration removes `redundancy - 1` binary
//! aggregations. Guarantees (paper §4): global optimum for sequential
//! AGGREGATE (Theorem 2), `(1 - 1/e)`-approximation for set AGGREGATE
//! (Theorem 3).
//!
//! Implementation notes (Appendix D realized):
//! * a lazy max-heap keyed by redundancy holds candidate pairs; stale
//!   entries are dropped on pop by consulting the exact count map;
//! * set-AGGREGATE pair counts are maintained incrementally: a merge
//!   touches only the consumers of the merged pair, so only pairs
//!   involving `v1`, `v2`, or `w` within those consumers' lists change;
//! * for hub consumers, enumerating all `C(deg, 2)` pairs is quadratic —
//!   `pair_cap` bounds the per-consumer window (the first `pair_cap`
//!   list positions generate pairs). Exact when every degree fits the
//!   cap; on hub-heavy graphs this trades a slightly smaller search
//!   space for near-linear runtime. The window re-fills as merges shrink
//!   the lists, so coverage recovers as the search progresses.
//!
//! Two set-AGGREGATE implementations live here:
//!
//! * [`hag_search`] runs the **flat kernel** (`search_set_flat`): a
//!   [`SearchScratch`] arena holding CSR in-edge/consumer tables over
//!   single backing buffers, a [`PairTable`] (open-addressing counts
//!   keyed by `u64`-packed pairs, no tuple hashing), a reusable
//!   intersection buffer, and a dirty-list bitmap that refreshes only
//!   rewired lists between windowed rounds instead of re-enumerating
//!   every list's `O(w^2)` pairs. The scratch is reusable across calls
//!   ([`hag_search_with_scratch`]) so a worker pays allocation once
//!   per pool, not once per shard.
//! * [`hag_search_reference`] retains the original hash-map search
//!   (`FxHashMap<(Slot, Slot), u32>` counts, per-round consumer-list
//!   and count rebuilds, a fresh `Vec` per intersection). It is the
//!   determinism oracle: the kernel's merge order is **byte-identical**
//!   to it — same lazy heap, same smallest-pair tie-break, same
//!   windowed-count drift semantics — which the differential tests in
//!   this module and `tests/properties.rs` pin down. The session
//!   golden-buckets test and `Session::plan() == plan_fresh()` both
//!   ride on this contract.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::graph::Graph;
use crate::util::FxHashMap as HashMap;

use super::{AggNode, AggregateKind, Hag, Slot};

/// Tuning knobs for [`hag_search`].
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Upper bound on `|V_A|`. The paper's default for the evaluation is
    /// `|V| / 4` (§5.2); `usize::MAX` means unbounded (Theorem 2 setting
    /// requires `capacity >= |E|`).
    pub capacity: usize,
    /// Set or sequential AGGREGATE (drives the redundancy definition).
    pub kind: AggregateKind,
    /// Per-consumer candidate-pair window (set AGGREGATE only); see
    /// module docs. `usize::MAX` = exact.
    pub pair_cap: usize,
    /// Definition-2 aggregation weight α the search prices merges
    /// with (live α̂ from [`crate::obs::CostModel`] when the caller
    /// is calibrated; `1.0` otherwise). A merge of redundancy `r`
    /// removes `r-1` aggregations and `r-2` transfers, so its
    /// calibrated gain is `α(r-1) + β(r-2)` — strictly increasing in
    /// `r` and positive exactly when `r >= 2` for any positive
    /// weights. Greedy order and the acceptance threshold are
    /// therefore *provably invariant* across all positive `(α, β)`
    /// (the `calibrated_weights_never_change_the_search` test pins
    /// this), which is what keeps the kernel byte-identical to
    /// [`hag_search_reference`] while still reporting costs and
    /// gains in calibrated units. [`SearchConfig::with_weights`]
    /// clamps non-finite or non-positive inputs back to `1.0`.
    pub alpha: f64,
    /// Definition-2 transfer weight β (see `alpha`).
    pub beta: f64,
}

impl SearchConfig {
    /// Paper §5.2 defaults: capacity = |V|/4, set aggregate,
    /// uncalibrated (α = β = 1, the `cost_core` point).
    pub fn paper_default(n: usize) -> Self {
        SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: n / 4,
            kind: AggregateKind::Set,
            pair_cap: 64,
        }
    }

    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity;
        self
    }

    pub fn with_kind(mut self, kind: AggregateKind) -> Self {
        self.kind = kind;
        self
    }

    pub fn exact(mut self) -> Self {
        self.pair_cap = usize::MAX;
        self
    }

    /// Price merges with a live calibration (α̂, β̂). Non-finite or
    /// non-positive weights are clamped back to `1.0` each — a
    /// degenerate fit must never zero out a cost axis and change
    /// what the search would accept (see the `alpha` field docs for
    /// why any *positive* pair leaves the search result untouched).
    pub fn with_weights(mut self, alpha: f64, beta: f64) -> Self {
        self.alpha = if alpha.is_finite() && alpha > 0.0 {
            alpha
        } else {
            1.0
        };
        self.beta = if beta.is_finite() && beta > 0.0 {
            beta
        } else {
            1.0
        };
        self
    }

    /// Calibrated gain of one merge with redundancy `r`:
    /// `α(r-1) + β(r-2)` (Definition 2: `r-1` aggregations and
    /// `r-2` transfers eliminated). At α = β = 1 this is the
    /// `cost_core` saving `2r - 3`.
    pub fn merge_gain(&self, r: u32) -> f64 {
        self.alpha * (r as f64 - 1.0) + self.beta * (r as f64 - 2.0)
    }
}

/// Search statistics, reported by benches and `repro search`.
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    pub iterations: usize,
    pub agg_nodes: usize,
    pub aggregations_before: usize,
    pub aggregations_after: usize,
    pub transfers_before: usize,
    pub transfers_after: usize,
    pub elapsed_ms: f64,
    /// Merge-loop rounds run (always 1 in exact mode; windowed
    /// searches re-seed the heap per round until no merge lands).
    pub rounds: usize,
    /// Total lazy-heap pops (live + stale).
    pub heap_pops: usize,
    /// Pops discarded because the entry's count had gone stale.
    pub stale_pops: usize,
    /// Resident bytes of the [`SearchScratch`] arena after the run.
    /// Monotone within a run; when a scratch is shared across shards
    /// this includes capacity carried over from earlier searches
    /// (that carried capacity is the point of the reuse). Zero for
    /// sequential AGGREGATE and for [`hag_search_reference`].
    pub peak_scratch_bytes: usize,
}

impl SearchStats {
    /// What the search saved in `cfg`'s calibrated units:
    /// `α·Δaggregations + β·Δtransfers`. At α = β = 1 this equals
    /// the `cost_core` reduction; with a live (α̂, β̂) it is the
    /// predicted wall-time saving per layer pass, in the fit's
    /// ns-per-element units.
    pub fn calibrated_saving(&self, cfg: &SearchConfig) -> f64 {
        cfg.alpha
            * (self.aggregations_before as f64
                - self.aggregations_after as f64)
            + cfg.beta
                * (self.transfers_before as f64
                    - self.transfers_after as f64)
    }
}

/// Run Algorithm 3 on `g`, returning the optimized HAG and stats.
/// Allocates a private [`SearchScratch`]; loops that search many
/// graphs should hold one scratch and call
/// [`hag_search_with_scratch`] instead.
pub fn hag_search(g: &Graph, cfg: &SearchConfig) -> (Hag, SearchStats) {
    let mut scratch = SearchScratch::default();
    hag_search_with_scratch(g, cfg, &mut scratch)
}

/// [`hag_search`] through a caller-owned arena: buffers and tables are
/// recycled across calls, so per-shard searches stop paying setup
/// allocations. Output is identical to [`hag_search`] for any scratch
/// state (the kernel fully re-initializes lengths; only capacity is
/// reused).
pub fn hag_search_with_scratch(g: &Graph, cfg: &SearchConfig,
                               scratch: &mut SearchScratch)
                               -> (Hag, SearchStats) {
    let t0 = std::time::Instant::now();
    let mut hag = Hag::from_graph(g, cfg.kind);
    let before_aggs = hag.aggregations();
    let before_tx = hag.data_transfers();
    let mut ks = KernelStats::default();
    let iterations = match cfg.kind {
        AggregateKind::Set => {
            search_set_flat(&mut hag, cfg, scratch, &mut ks)
        }
        AggregateKind::Sequential => {
            search_sequential(&mut hag, cfg, &mut ks)
        }
    };
    let peak = match cfg.kind {
        AggregateKind::Set => scratch.bytes(),
        AggregateKind::Sequential => 0,
    };
    let stats = SearchStats {
        iterations,
        agg_nodes: hag.agg_nodes.len(),
        aggregations_before: before_aggs,
        aggregations_after: hag.aggregations(),
        transfers_before: before_tx,
        transfers_after: hag.data_transfers(),
        elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
        rounds: ks.rounds,
        heap_pops: ks.heap_pops,
        stale_pops: ks.stale_pops,
        peak_scratch_bytes: peak,
    };
    (hag, stats)
}

/// The retained naive reference: hash-map pair counts, per-round
/// consumer-list and count rebuilds, a fresh allocation per
/// intersection. Kept (not cfg(test)-gated) so the differential tests
/// and the old-vs-new bench rows can pin the flat kernel's
/// byte-identical merge order against it. `heap_pops`/`stale_pops`
/// are reported for comparability; `peak_scratch_bytes` is 0.
pub fn hag_search_reference(g: &Graph, cfg: &SearchConfig)
                            -> (Hag, SearchStats) {
    let t0 = std::time::Instant::now();
    let mut hag = Hag::from_graph(g, cfg.kind);
    let before_aggs = hag.aggregations();
    let before_tx = hag.data_transfers();
    let mut ks = KernelStats::default();
    let iterations = match cfg.kind {
        AggregateKind::Set => {
            search_set_reference(&mut hag, cfg, &mut ks)
        }
        AggregateKind::Sequential => {
            search_sequential(&mut hag, cfg, &mut ks)
        }
    };
    let stats = SearchStats {
        iterations,
        agg_nodes: hag.agg_nodes.len(),
        aggregations_before: before_aggs,
        aggregations_after: hag.aggregations(),
        transfers_before: before_tx,
        transfers_after: hag.data_transfers(),
        elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
        rounds: ks.rounds,
        heap_pops: ks.heap_pops,
        stale_pops: ks.stale_pops,
        peak_scratch_bytes: 0,
    };
    (hag, stats)
}

/// Normalize an unordered pair to `(lo, hi)` (tuple form, used by the
/// retained reference; the kernel and the incremental-repair re-merge
/// pass go through the packed [`pack_pair`] form).
#[inline]
pub(crate) fn norm(a: Slot, b: Slot) -> (Slot, Slot) {
    if a < b { (a, b) } else { (b, a) }
}

/// Pack an unordered slot pair into the flat table's key:
/// `(lo << 32) | hi`. A `u64` compares exactly like the lexicographic
/// `(lo, hi)` tuple, so heap tie-breaks are unchanged versus the
/// reference. `lo < hi` strictly (a set in-list never holds duplicate
/// slots), so a packed key is never 0 and 0 serves as the
/// open-addressing empty sentinel.
#[inline]
pub(crate) fn pack_pair(a: Slot, b: Slot) -> u64 {
    let (lo, hi) = norm(a, b);
    ((lo as u64) << 32) | hi as u64
}

/// Lazy max-heap over packed pairs: `(count, Reverse(key))` pops the
/// highest count first, the smallest pair on ties — the same pop
/// order as the reference's `(count, Reverse((Slot, Slot)))` heap.
pub(crate) type PairHeap = BinaryHeap<(u32, Reverse<u64>)>;

/// Kernel observability counters, folded into [`SearchStats`].
#[derive(Debug, Default)]
struct KernelStats {
    rounds: usize,
    heap_pops: usize,
    stale_pops: usize,
}

// ===================================================================
// Flat pair-count table
// ===================================================================

/// Smallest non-empty table: 1024 slots (12 KiB) — below the point
/// where growth churn would show up on real graphs.
const MIN_TABLE: usize = 1 << 10;

/// Flat open-addressing pair-count table keyed by [`pack_pair`] keys.
/// Replaces the `FxHashMap<(Slot, Slot), u32>` on the hottest path:
/// one multiply-mix hash, linear probing over a power-of-two slot
/// array, no per-entry tuple hashing. Count 0 reads as "absent" (the
/// reference removes zero-count entries; here they linger in their
/// slot until the next rehash or [`Self::clear`], which is
/// observationally identical through [`Self::get`]).
#[derive(Debug, Clone)]
pub(crate) struct PairTable {
    keys: Vec<u64>,
    vals: Vec<u32>,
    mask: usize,
    /// Slots holding a key (zero-count entries included until rehash).
    occupied: usize,
}

impl Default for PairTable {
    /// Starts empty (no allocation); the first insert grows to
    /// [`MIN_TABLE`].
    fn default() -> Self {
        PairTable { keys: Vec::new(), vals: Vec::new(), mask: 0,
                    occupied: 0 }
    }
}

impl PairTable {
    /// Probe for `key`: its slot if present, else the first empty
    /// slot. The load-factor guard in [`Self::incr`] keeps at least
    /// one slot empty, so the walk always terminates.
    #[inline]
    fn idx(&self, key: u64) -> usize {
        debug_assert!(key != 0 && !self.keys.is_empty());
        let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut i = ((h >> 32) ^ h) as usize & self.mask;
        loop {
            let k = self.keys[i];
            if k == key || k == 0 {
                return i;
            }
            i = (i + 1) & self.mask;
        }
    }

    #[inline]
    pub(crate) fn get(&self, key: u64) -> u32 {
        if self.keys.is_empty() {
            return 0;
        }
        let i = self.idx(key);
        if self.keys[i] == key { self.vals[i] } else { 0 }
    }

    /// `+= 1`, inserting the key if absent; returns the new count.
    #[inline]
    pub(crate) fn incr(&mut self, key: u64) -> u32 {
        if (self.occupied + 1) * 8 > self.keys.len() * 7 {
            self.grow();
        }
        let i = self.idx(key);
        if self.keys[i] == 0 {
            self.keys[i] = key;
            self.vals[i] = 0;
            self.occupied += 1;
        }
        self.vals[i] += 1;
        self.vals[i]
    }

    /// Saturating `-= 1`; absent keys are a no-op (mirrors the
    /// reference's `get_mut` miss — windowed drift legitimately
    /// decrements pairs that were never counted).
    #[inline]
    pub(crate) fn decr(&mut self, key: u64) {
        if self.keys.is_empty() {
            return;
        }
        let i = self.idx(key);
        if self.keys[i] == key {
            self.vals[i] = self.vals[i].saturating_sub(1);
        }
    }

    /// The reference's `remove`: the count drops to 0 and the key
    /// reads as absent.
    #[inline]
    pub(crate) fn zero(&mut self, key: u64) {
        if self.keys.is_empty() {
            return;
        }
        let i = self.idx(key);
        if self.keys[i] == key {
            self.vals[i] = 0;
        }
    }

    fn grow(&mut self) {
        let slots = (self.keys.len() * 2).max(MIN_TABLE);
        let keys = std::mem::replace(&mut self.keys, vec![0; slots]);
        let vals = std::mem::replace(&mut self.vals, vec![0; slots]);
        self.mask = slots - 1;
        self.occupied = 0;
        for (k, v) in keys.into_iter().zip(vals) {
            // zero-count entries die on rehash (reads are unchanged)
            if k != 0 && v != 0 {
                let i = self.idx(k);
                self.keys[i] = k;
                self.vals[i] = v;
                self.occupied += 1;
            }
        }
    }

    /// Drop every entry, keeping the allocation.
    pub(crate) fn clear(&mut self) {
        self.keys.fill(0);
        self.occupied = 0;
    }

    /// Visit every `(key, count)` with `count > 0`, in slot order.
    /// Callers must not depend on the order (the search heap imposes
    /// a total order of its own).
    pub(crate) fn for_each(&self, mut f: impl FnMut(u64, u32)) {
        for (i, &k) in self.keys.iter().enumerate() {
            if k != 0 && self.vals[i] > 0 {
                f(k, self.vals[i]);
            }
        }
    }

    /// Reuse-friendly deep copy (`Vec::clone_from` keeps fitting
    /// allocations).
    fn copy_from(&mut self, other: &PairTable) {
        self.keys.clone_from(&other.keys);
        self.vals.clone_from(&other.vals);
        self.mask = other.mask;
        self.occupied = other.occupied;
    }

    fn bytes(&self) -> usize {
        self.keys.capacity() * 8 + self.vals.capacity() * 4
    }
}

// ===================================================================
// Set AGGREGATE — flat kernel
// ===================================================================

/// Reusable arena for the set-AGGREGATE kernel. One scratch per
/// worker: `partition::search_sharded` threads one through every
/// shard a worker drains, and a `Session` holds one for its
/// single-shard re-searches, so the tables below are allocated once
/// per pool — not once per shard, and never once per round.
#[derive(Debug, Default)]
pub struct SearchScratch {
    /// Final in-lists as CSR over one backing buffer. Each list keeps
    /// its initial extent: a merge shrinks a list by exactly one slot
    /// (two operands out, `w` in), so every rewrite fits in place and
    /// the freed tail is the per-list slack.
    in_off: Vec<u32>,
    in_len: Vec<u32>,
    in_buf: Vec<Slot>,
    /// Per-slot consumer lists (finals consuming the slot, sorted
    /// ascending) as CSR; slots materialized by merges append their
    /// lists at the buffer tail. Consumer lists only ever shrink, so
    /// these also rewrite in place.
    cons_off: Vec<u32>,
    cons_len: Vec<u32>,
    cons_buf: Vec<u32>,
    /// Heap-driving pair counts — the reference's lazily-maintained
    /// map, with its exact drift semantics.
    live: PairTable,
    /// Exact windowed pair counts (windowed mode only), corrected per
    /// dirty list so the next round seeds without re-enumerating
    /// every list's `O(w^2)` pairs.
    base: PairTable,
    heap: PairHeap,
    /// Reusable consumer-intersection buffer.
    shared: Vec<u32>,
    /// Bitmap over finals: list rewired since the round started.
    dirty: Vec<u64>,
    dirty_list: Vec<u32>,
}

impl SearchScratch {
    pub fn new() -> SearchScratch {
        SearchScratch::default()
    }

    /// Resident arena footprint in bytes (capacities, not lengths).
    pub fn bytes(&self) -> usize {
        (self.in_off.capacity() + self.in_len.capacity()
         + self.in_buf.capacity() + self.cons_off.capacity()
         + self.cons_len.capacity() + self.cons_buf.capacity()
         + self.shared.capacity() + self.dirty_list.capacity()) * 4
            + self.dirty.capacity() * 8
            + self.live.bytes()
            + self.base.bytes()
            + self.heap.capacity()
                * std::mem::size_of::<(u32, Reverse<u64>)>()
    }
}

/// Set a final's dirty bit; returns whether it was already set.
#[inline]
fn bit_test_set(words: &mut [u64], v: u32) -> bool {
    let i = (v >> 6) as usize;
    let m = 1u64 << (v & 63);
    let was = words[i] & m != 0;
    words[i] |= m;
    was
}

/// Flat-kernel set-AGGREGATE search. The merge sequence is
/// byte-identical to [`search_set_reference`]: identical heap entries
/// (same seeding rule, same incremental pushes), identical stale
/// semantics, identical windowed-count drift — only the data layout
/// changed. Between windowed rounds, instead of re-enumerating every
/// list, the exact `base` table is corrected for just the lists the
/// round rewired (subtract the round-start window at first touch, add
/// the final window at round end), then `live` re-seeds from it.
fn search_set_flat(hag: &mut Hag, cfg: &SearchConfig,
                   sc: &mut SearchScratch, ks: &mut KernelStats)
                   -> usize {
    let n = hag.n;
    let cap = cfg.pair_cap;
    let exact = cap == usize::MAX;
    let windowed = !exact;

    let SearchScratch {
        in_off, in_len, in_buf, cons_off, cons_len, cons_buf,
        live, base, heap, shared, dirty, dirty_list,
    } = sc;

    // ---- arena load -----------------------------------------------
    let e_total: usize = hag.in_edges.iter().map(|l| l.len()).sum();
    // Offsets are u32: in entries are bounded by e_total, consumer
    // entries by 2 * e_total (every appended consumer entry pairs
    // with a final in-edge the same rewire removes, so total appends
    // = sum |shared| <= e_total on top of the initial e_total).
    assert!(e_total <= (u32::MAX / 2) as usize,
            "graph too large for u32 arena offsets");
    let slots0 = hag.slots();

    in_off.clear();
    in_len.clear();
    in_buf.clear();
    for l in hag.in_edges.iter() {
        in_off.push(in_buf.len() as u32);
        in_len.push(l.len() as u32);
        in_buf.extend_from_slice(l);
    }

    // Consumer CSR: count, prefix-sum, then fill with cons_len as the
    // write cursor (finals ascending => lists sorted ascending).
    cons_len.clear();
    cons_len.resize(slots0, 0);
    for &s in in_buf.iter() {
        cons_len[s as usize] += 1;
    }
    cons_off.clear();
    cons_off.resize(slots0, 0);
    let mut acc = 0u32;
    for s in 0..slots0 {
        cons_off[s] = acc;
        acc += cons_len[s];
        cons_len[s] = 0;
    }
    cons_buf.clear();
    cons_buf.resize(e_total, 0);
    for v in 0..n {
        let off = in_off[v] as usize;
        let len = in_len[v] as usize;
        for i in off..off + len {
            let s = in_buf[i] as usize;
            cons_buf[(cons_off[s] + cons_len[s]) as usize] = v as u32;
            cons_len[s] += 1;
        }
    }

    // ---- initial windowed pair counts + heap seed -----------------
    live.clear();
    for v in 0..n {
        let off = in_off[v] as usize;
        let len = in_len[v] as usize;
        let list = &in_buf[off..off + len];
        let w = len.min(cap);
        for i in 0..w {
            for j in (i + 1)..w {
                live.incr(pack_pair(list[i], list[j]));
            }
        }
    }
    if windowed {
        base.copy_from(live);
    }
    heap.clear();
    live.for_each(|k, c| {
        if c >= 2 {
            heap.push((c, Reverse(k)));
        }
    });
    shared.clear();
    dirty.clear();
    dirty.resize(n.div_ceil(64), 0);
    dirty_list.clear();

    // ---- merge rounds ---------------------------------------------
    let mut total = 0usize;
    'rounds: loop {
        ks.rounds += 1;
        // One trace span per merge round: args are (merges landed,
        // heap pops) for this round.
        let mut sp = crate::obs_span!("search.round");
        let round_pops0 = ks.heap_pops;
        let mut made = 0usize;
        while hag.agg_nodes.len() < cfg.capacity {
            // Pop the highest-redundancy non-stale pair.
            let popped = loop {
                match heap.pop() {
                    None => break None,
                    Some((c, Reverse(k))) => {
                        ks.heap_pops += 1;
                        if live.get(k) == c && c >= 2 {
                            break Some(k);
                        }
                        // stale: if the current count is still >= 2
                        // the pair was re-pushed on update; just drop
                        // this entry.
                        ks.stale_pops += 1;
                    }
                }
            };
            let key = match popped {
                None => break,
                Some(k) => k,
            };
            let v1 = (key >> 32) as Slot;
            let v2 = key as Slot;

            // The merge is driven by the *live* consumer intersection:
            // with a finite pair_cap the windowed count can drift
            // below the true redundancy, so the intersection is the
            // source of truth.
            shared.clear();
            {
                let a1 = cons_off[v1 as usize] as usize
                    + cons_len[v1 as usize] as usize;
                let b1 = cons_off[v2 as usize] as usize
                    + cons_len[v2 as usize] as usize;
                let mut i = cons_off[v1 as usize] as usize;
                let mut j = cons_off[v2 as usize] as usize;
                while i < a1 && j < b1 {
                    let (a, b) = (cons_buf[i], cons_buf[j]);
                    if a < b {
                        i += 1;
                    } else if a > b {
                        j += 1;
                    } else {
                        shared.push(a);
                        i += 1;
                        j += 1;
                    }
                }
            }
            if exact {
                debug_assert_eq!(shared.len() as u32, live.get(key),
                                 "exact mode: count must match \
                                  intersection");
            }
            live.zero(key);
            if shared.len() < 2 {
                // Windowed count drifted: merging would add a node
                // that saves nothing. Skip.
                continue;
            }

            // Materialize w = v1 (+) v2.
            let w = hag.slots() as Slot;
            hag.agg_nodes.push(AggNode { left: v1, right: v2 });
            cons_off.push(cons_buf.len() as u32);
            cons_len.push(0);

            let shared_v = std::mem::take(shared);
            for &u in &shared_v {
                let off = in_off[u as usize] as usize;
                let len = in_len[u as usize] as usize;
                let old_w = len.min(cap);

                if windowed && !bit_test_set(dirty, u) {
                    dirty_list.push(u);
                    // First touch this round: the list still holds
                    // its round-start content — retire its windowed
                    // pairs from the exact base table.
                    let list = &in_buf[off..off + len];
                    for i in 0..old_w {
                        for j in (i + 1)..old_w {
                            base.decr(pack_pair(list[i], list[j]));
                        }
                    }
                }

                // Pairs inside the old window disappear for v1/v2
                // entries.
                {
                    let list = &in_buf[off..off + len];
                    for i in 0..old_w {
                        for j in (i + 1)..old_w {
                            let (a, b) = (list[i], list[j]);
                            if a == v1 || a == v2 || b == v1 || b == v2
                            {
                                live.decr(pack_pair(a, b));
                            }
                        }
                    }
                }

                // Rewrite in place: drop v1 and v2, append w. Net
                // -1 (u consumes both operands), so the write stays
                // inside the list's extent.
                let mut out = off;
                for i in off..off + len {
                    let s = in_buf[i];
                    if s != v1 && s != v2 {
                        in_buf[out] = s;
                        out += 1;
                    }
                }
                debug_assert_eq!(out, off + len - 2,
                                 "shared consumer missing an operand");
                in_buf[out] = w;
                out += 1;
                let new_len = out - off;
                in_len[u as usize] = new_len as u32;

                // Count pairs of the just-appended w inside the
                // window; if the list outgrew the window the new
                // element is outside it and no pairs are added (the
                // tolerated underestimate).
                if new_len <= cap {
                    let list = &in_buf[off..off + new_len];
                    let last = new_len - 1;
                    for i in 0..last {
                        let k2 = pack_pair(list[i], list[last]);
                        let c = live.incr(k2);
                        if c >= 2 {
                            heap.push((c, Reverse(k2)));
                        }
                    }
                }

                cons_buf.push(u);
                cons_len[w as usize] += 1;
            }

            // The rewired consumers leave v1/v2's consumer lists
            // (both sides sorted: one linear merge-filter each).
            for &v in &[v1, v2] {
                let off = cons_off[v as usize] as usize;
                let len = cons_len[v as usize] as usize;
                let mut out = off;
                let mut r = 0usize;
                for i in off..off + len {
                    let c = cons_buf[i];
                    while r < shared_v.len() && shared_v[r] < c {
                        r += 1;
                    }
                    if r < shared_v.len() && shared_v[r] == c {
                        continue;
                    }
                    cons_buf[out] = c;
                    out += 1;
                }
                cons_len[v as usize] = (out - off) as u32;
            }
            *shared = shared_v;
            made += 1;
        }

        total += made;
        sp.set_args(made as u64,
                    (ks.heap_pops - round_pops0) as u64);
        if made == 0 || hag.agg_nodes.len() >= cfg.capacity || exact {
            break 'rounds;
        }

        // Dirty-round refresh: fold only the rewired lists into the
        // exact base table, then reseed live + heap from it — what
        // the reference achieves by re-enumerating *every* list.
        for &u in dirty_list.iter() {
            let off = in_off[u as usize] as usize;
            let len = in_len[u as usize] as usize;
            let list = &in_buf[off..off + len];
            let w = len.min(cap);
            for i in 0..w {
                for j in (i + 1)..w {
                    base.incr(pack_pair(list[i], list[j]));
                }
            }
            dirty[(u >> 6) as usize] &= !(1u64 << (u & 63));
        }
        dirty_list.clear();
        live.copy_from(base);
        heap.clear();
        live.for_each(|k, c| {
            if c >= 2 {
                heap.push((c, Reverse(k)));
            }
        });
    }

    // ---- write the rewired lists back -----------------------------
    for v in 0..n {
        let off = in_off[v] as usize;
        let len = in_len[v] as usize;
        let dst = &mut hag.in_edges[v];
        dst.clear();
        dst.extend_from_slice(&in_buf[off..off + len]);
    }
    total
}

// ===================================================================
// Set AGGREGATE — retained naive reference
// ===================================================================

struct SetState {
    /// consumers[slot] -> sorted Vec of original-node consumers.
    consumers: Vec<Vec<u32>>,
    /// Exact redundancy count per candidate pair.
    pair_count: HashMap<(Slot, Slot), u32>,
    /// Lazy max-heap of (count, pair); entries may be stale.
    heap: BinaryHeap<(u32, Reverse<(Slot, Slot)>)>,
}

fn search_set_reference(hag: &mut Hag, cfg: &SearchConfig,
                        ks: &mut KernelStats) -> usize {
    // With a finite pair_cap the candidate window misses pairs beyond
    // the first `cap` list positions. Merges shrink lists, so
    // re-scanning after the heap drains recovers coverage: run rounds
    // until a round makes no progress or capacity is reached.
    let mut total = 0usize;
    loop {
        ks.rounds += 1;
        let made = search_set_round_reference(hag, cfg, ks);
        total += made;
        if made == 0 || hag.agg_nodes.len() >= cfg.capacity
            || cfg.pair_cap == usize::MAX
        {
            return total;
        }
    }
}

fn search_set_round_reference(hag: &mut Hag, cfg: &SearchConfig,
                              ks: &mut KernelStats) -> usize {
    let slots = hag.slots();
    // Build consumer lists over *all* current slots (merges may pair an
    // aggregation node with anything).
    let mut consumers: Vec<Vec<u32>> = vec![Vec::new(); slots];
    for (v, l) in hag.in_edges.iter().enumerate() {
        for &s in l {
            consumers[s as usize].push(v as u32);
        }
    }
    debug_assert!(consumers.iter()
        .all(|c| c.windows(2).all(|p| p[0] < p[1])));
    let mut st = SetState {
        consumers,
        pair_count: HashMap::default(),
        heap: BinaryHeap::new(),
    };
    for l in hag.in_edges.iter() {
        let w = l.len().min(cfg.pair_cap);
        for i in 0..w {
            for j in (i + 1)..w {
                let p = norm(l[i], l[j]);
                *st.pair_count.entry(p).or_insert(0) += 1;
            }
        }
    }
    for (&p, &c) in st.pair_count.iter() {
        if c >= 2 {
            st.heap.push((c, Reverse(p)));
        }
    }

    let exact = cfg.pair_cap == usize::MAX;
    let mut iterations = 0usize;
    while hag.agg_nodes.len() < cfg.capacity {
        // Pop the highest-redundancy non-stale pair.
        let (v1, v2, red) = loop {
            match st.heap.pop() {
                None => return iterations,
                Some((c, Reverse(p))) => {
                    ks.heap_pops += 1;
                    let cur = st.pair_count.get(&p).copied().unwrap_or(0);
                    if cur == c && c >= 2 {
                        break (p.0, p.1, c);
                    }
                    // stale: if the current count is still >= 2 the pair
                    // was re-pushed on update; just drop this entry.
                    ks.stale_pops += 1;
                }
            }
        };

        // The merge is driven by the *live* consumer intersection: with
        // a finite pair_cap the windowed count can drift below the true
        // redundancy, so the intersection is the source of truth.
        let shared = intersect_sorted(&st.consumers[v1 as usize],
                                      &st.consumers[v2 as usize]);
        if exact {
            debug_assert_eq!(shared.len() as u32, red,
                             "exact mode: count must match intersection");
        }
        st.pair_count.remove(&norm(v1, v2));
        if shared.len() < 2 {
            // Windowed count drifted: merging would add a node that
            // saves nothing. Skip.
            continue;
        }

        // Materialize w = v1 (+) v2.
        let w = hag.slots() as Slot;
        hag.agg_nodes.push(AggNode { left: v1, right: v2 });
        st.consumers.push(Vec::new());

        for &u in &shared {
            let list = &mut hag.in_edges[u as usize];
            let old_w = list.len().min(cfg.pair_cap);
            // Pairs inside the old window disappear for v1/v2 entries.
            remove_window_pairs_ref(&mut st.pair_count, list, old_w,
                                    v1, v2);
            list.retain(|&s| s != v1 && s != v2);
            list.push(w);
            add_window_pairs_ref(&mut st.pair_count, &mut st.heap, list,
                                 cfg.pair_cap);
            st.consumers[w as usize].push(u);
        }
        // Remove the rewired consumers from v1/v2 consumer lists
        // (`shared` is sorted, so binary_search is valid).
        for &v in &[v1, v2] {
            let cs = &mut st.consumers[v as usize];
            cs.retain(|u| shared.binary_search(u).is_err());
        }
        debug_assert!(st.consumers[w as usize].windows(2)
            .all(|p| p[0] < p[1]));

        iterations += 1;
    }
    iterations
}

/// Remove every windowed pair of `list` that involves `v1` or `v2`
/// (the entries about to be rewired), decrementing counts.
fn remove_window_pairs_ref(pc: &mut HashMap<(Slot, Slot), u32>,
                           list: &[Slot], w: usize, v1: Slot, v2: Slot) {
    for i in 0..w {
        for j in (i + 1)..w {
            let (a, b) = (list[i], list[j]);
            if a == v1 || a == v2 || b == v1 || b == v2 {
                let p = norm(a, b);
                if let Some(c) = pc.get_mut(&p) {
                    *c = c.saturating_sub(1);
                    if *c == 0 {
                        pc.remove(&p);
                    }
                }
            }
        }
    }
}

/// Count windowed pairs involving the just-appended last element (the
/// new `w` slot). If the list outgrew the window the new element is
/// outside it and no pairs are added — with a finite `pair_cap` counts
/// may *under*estimate true redundancy (never overestimate it from this
/// path), which the merge loop tolerates by re-checking the live
/// intersection.
fn add_window_pairs_ref(pc: &mut HashMap<(Slot, Slot), u32>,
                        heap: &mut BinaryHeap<(u32,
                                               Reverse<(Slot, Slot)>)>,
                        list: &[Slot], cap: usize) {
    if list.len() > cap {
        return; // appended element is outside the window
    }
    let last = list.len() - 1;
    for i in 0..last {
        let p = norm(list[i], list[last]);
        let c = pc.entry(p).or_insert(0);
        *c += 1;
        if *c >= 2 {
            heap.push((*c, Reverse(p)));
        }
    }
}

fn intersect_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out
}

// ===================================================================
// Sequential AGGREGATE (common-prefix merging, Algorithm 3 line 8)
// ===================================================================

fn search_sequential(hag: &mut Hag, cfg: &SearchConfig,
                     ks: &mut KernelStats) -> usize {
    // Redundancy of (v1, v2) = #consumers whose list starts (v1, v2).
    // A merge replaces that prefix with (w, rest...), so each consumer's
    // first-two pair changes — counts update in O(1) per consumer.
    ks.rounds = 1;
    let mut pair_count: HashMap<(Slot, Slot), u32> = HashMap::default();
    let mut members: HashMap<(Slot, Slot), Vec<u32>> = HashMap::default();
    for (v, l) in hag.in_edges.iter().enumerate() {
        if l.len() >= 2 {
            let p = (l[0], l[1]); // ordered pair!
            *pair_count.entry(p).or_insert(0) += 1;
            members.entry(p).or_default().push(v as u32);
        }
    }
    let mut heap: BinaryHeap<(u32, Reverse<(Slot, Slot)>)> = pair_count
        .iter()
        .filter(|(_, &c)| c >= 2)
        .map(|(&p, &c)| (c, Reverse(p)))
        .collect();

    let mut iterations = 0usize;
    while hag.agg_nodes.len() < cfg.capacity {
        let (p, _red) = loop {
            match heap.pop() {
                None => return iterations,
                Some((c, Reverse(p))) => {
                    ks.heap_pops += 1;
                    let cur = pair_count.get(&p).copied().unwrap_or(0);
                    if cur == c && c >= 2 {
                        break (p, c);
                    }
                    ks.stale_pops += 1;
                }
            }
        };
        let w = hag.slots() as Slot;
        hag.agg_nodes.push(AggNode { left: p.0, right: p.1 });
        let users = members.remove(&p).unwrap_or_default();
        pair_count.remove(&p);
        for u in users {
            let list = &mut hag.in_edges[u as usize];
            // Membership lists are kept exact (a consumer's prefix only
            // changes when its pair is merged, which consumes the
            // membership), but guard defensively.
            if list.len() < 2 || (list[0], list[1]) != p {
                debug_assert!(false, "stale sequential membership");
                continue;
            }
            list.splice(0..2, [w]);
            if list.len() >= 2 {
                let np = (list[0], list[1]);
                let c = pair_count.entry(np).or_insert(0);
                *c += 1;
                members.entry(np).or_default().push(u);
                if *c >= 2 {
                    heap.push((*c, Reverse(np)));
                }
            }
        }
        iterations += 1;
    }
    iterations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hag::check_equivalence;

    fn fig1() -> Graph {
        Graph::from_edges(
            5,
            &[
                (1, 0), (2, 0), (3, 0),
                (0, 1), (2, 1),
                (0, 2), (1, 2), (4, 2),
                (1, 3), (2, 3),
                (2, 4), (3, 4),
            ],
        )
    }

    /// K6 with a few extra hub edges: enough overlap that windowed
    /// searches run multiple rounds at tiny pair caps.
    fn dense() -> Graph {
        let mut edges = Vec::new();
        for u in 0..8u32 {
            for v in 0..8u32 {
                if u != v && (u < 6 || v < 3) {
                    edges.push((u, v));
                }
            }
        }
        Graph::from_edges(8, &edges)
    }

    #[test]
    fn set_search_on_fig1_finds_shared_pairs() {
        let g = fig1();
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        h.validate().unwrap();
        check_equivalence(&g, &h).unwrap();
        // Fig 1: {B,C} (consumers A, D) and {C,D} (consumers A, E) both
        // have redundancy 2, but they overlap in consumer A — greedy
        // takes one of them, after which the other drops below 2. One
        // merge, one aggregation saved.
        assert_eq!(stats.agg_nodes, 1, "{stats:?}");
        assert_eq!(h.aggregations(),
                   Hag::from_graph(&g, AggregateKind::Set)
                       .aggregations() - 1);
    }

    #[test]
    fn set_search_respects_capacity() {
        let g = fig1();
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: 1,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        assert_eq!(h.agg_nodes.len(), 1);
        assert_eq!(stats.iterations, 1);
        check_equivalence(&g, &h).unwrap();
    }

    #[test]
    fn set_search_zero_capacity_is_identity() {
        let g = fig1();
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: 0,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        assert_eq!(h.agg_nodes.len(), 0);
        assert_eq!(stats.aggregations_after, stats.aggregations_before);
        check_equivalence(&g, &h).unwrap();
    }

    #[test]
    fn set_search_no_redundancy_no_merges() {
        // path graph: no two nodes share 2+ common in-neighbors
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, _) = hag_search(&g, &cfg);
        assert_eq!(h.agg_nodes.len(), 0);
    }

    #[test]
    fn set_search_clique_saves_many() {
        // K6: every node aggregates the other 5; massive overlap.
        let mut edges = Vec::new();
        for u in 0..6u32 {
            for v in 0..6u32 {
                if u != v {
                    edges.push((u, v));
                }
            }
        }
        let g = Graph::from_edges(6, &edges);
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        check_equivalence(&g, &h).unwrap();
        assert!(stats.aggregations_after < stats.aggregations_before,
                "{stats:?}");
    }

    #[test]
    fn seq_search_merges_common_prefixes() {
        // Three nodes aggregate the ordered prefix (5, 6):
        let mut edges_by_node: Vec<Vec<u32>> = vec![vec![]; 8];
        edges_by_node[0] = vec![5, 6, 7];
        edges_by_node[1] = vec![5, 6];
        edges_by_node[2] = vec![5, 6, 3];
        let mut b = crate::graph::GraphBuilder::new(8);
        for (v, l) in edges_by_node.iter().enumerate() {
            for &u in l {
                b.edge(u, v as u32);
            }
        }
        let g = b.build();
        // NB: CSR sorts neighbors ascending, so ordered lists here are
        // the sorted ones; prefix (5,6) is shared by nodes 0 and 1; node
        // 2's sorted list is (3,5,6) — prefix (3,5).
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: usize::MAX,
            kind: AggregateKind::Sequential,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        h.validate().unwrap();
        check_equivalence(&g, &h).unwrap();
        assert!(stats.agg_nodes >= 1);
        assert!(stats.aggregations_after <= stats.aggregations_before);
    }

    #[test]
    fn seq_search_chains_prefixes() {
        // Two nodes share a long ordered prefix (1,2,3,4): expect chained
        // merges w1=(1,2), w2=(w1,3), w3=(w2,4).
        let mut b = crate::graph::GraphBuilder::new(7);
        for v in [5u32, 6u32] {
            for u in [1u32, 2, 3, 4] {
                b.edge(u, v);
            }
        }
        let g = b.build();
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: usize::MAX,
            kind: AggregateKind::Sequential,
            pair_cap: usize::MAX,
        };
        let (h, _) = hag_search(&g, &cfg);
        check_equivalence(&g, &h).unwrap();
        assert_eq!(h.agg_nodes.len(), 3);
        // each consumer now aggregates exactly one slot
        assert_eq!(h.in_edges[5].len(), 1);
        assert_eq!(h.in_edges[6].len(), 1);
        // aggregations: 3 (chain) vs 6 before
        assert_eq!(h.aggregations(), 3);
    }

    #[test]
    fn search_is_deterministic() {
        let g = fig1();
        let cfg = SearchConfig::paper_default(g.n());
        let (h1, _) = hag_search(&g, &cfg);
        let (h2, _) = hag_search(&g, &cfg);
        assert_eq!(h1.agg_nodes, h2.agg_nodes);
        assert_eq!(h1.in_edges, h2.in_edges);
    }

    /// The determinism contract is stronger than run-to-run: the flat
    /// kernel must replay the retained reference's merge sequence
    /// byte-for-byte, across exact, windowed (multi-round), and
    /// capacity-capped configs. `tests/properties.rs` widens this to
    /// the random-graph corpus.
    #[test]
    fn flat_kernel_matches_reference_byte_identical() {
        let mut scratch = SearchScratch::new();
        for g in [fig1(), dense()] {
            for pair_cap in [2usize, 3, 64, usize::MAX] {
                for capacity in [0usize, 1, g.n() / 4, usize::MAX] {
                    let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
                        capacity,
                        kind: AggregateKind::Set,
                        pair_cap,
                    };
                    let (hr, sr) = hag_search_reference(&g, &cfg);
                    let (hf, sf) =
                        hag_search_with_scratch(&g, &cfg, &mut scratch);
                    assert_eq!(hr.agg_nodes, hf.agg_nodes,
                               "merge order diverged at pair_cap \
                                {pair_cap} capacity {capacity}");
                    assert_eq!(hr.in_edges, hf.in_edges,
                               "final lists diverged at pair_cap \
                                {pair_cap} capacity {capacity}");
                    assert_eq!(sr.iterations, sf.iterations);
                    assert_eq!(sr.rounds, sf.rounds,
                               "round count diverged at pair_cap \
                                {pair_cap} capacity {capacity}");
                    assert_eq!((sr.heap_pops, sr.stale_pops),
                               (sf.heap_pops, sf.stale_pops),
                               "pop sequences diverged at pair_cap \
                                {pair_cap} capacity {capacity}");
                    hf.validate().unwrap();
                    check_equivalence(&g, &hf).unwrap();
                }
            }
        }
    }

    /// A scratch carried across graphs of different shapes must not
    /// leak state between runs.
    #[test]
    fn scratch_reuse_is_pollution_free() {
        let mut scratch = SearchScratch::new();
        let cfg_small = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: 2,
        };
        // big graph first so every buffer is oversized for fig1
        let (_, _) = hag_search_with_scratch(&dense(), &cfg_small,
                                             &mut scratch);
        let g = fig1();
        for pair_cap in [2usize, usize::MAX] {
            let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
                capacity: usize::MAX,
                kind: AggregateKind::Set,
                pair_cap,
            };
            let (fresh, _) = hag_search(&g, &cfg);
            let (reused, _) =
                hag_search_with_scratch(&g, &cfg, &mut scratch);
            assert_eq!(fresh.agg_nodes, reused.agg_nodes);
            assert_eq!(fresh.in_edges, reused.in_edges);
        }
        assert!(scratch.bytes() > 0);
    }

    #[test]
    fn kernel_stats_are_coherent() {
        let g = dense();
        let mut cfg = SearchConfig::paper_default(g.n());
        cfg.capacity = usize::MAX;
        cfg.pair_cap = 2; // force multiple windowed rounds
        let (_, stats) = hag_search(&g, &cfg);
        assert!(stats.rounds >= 2, "tiny window must need rounds: \
                                    {stats:?}");
        assert!(stats.heap_pops >= stats.iterations);
        assert!(stats.heap_pops >= stats.stale_pops);
        assert!(stats.peak_scratch_bytes > 0);
        // reference reports the same round structure
        let (_, rstats) = hag_search_reference(&g, &cfg);
        assert_eq!(stats.rounds, rstats.rounds);
        assert_eq!(stats.iterations, rstats.iterations);
    }

    #[test]
    fn pair_table_counts_and_clears() {
        let mut t = PairTable::default();
        assert_eq!(t.get(pack_pair(3, 9)), 0);
        t.decr(pack_pair(3, 9)); // absent: no-op
        assert_eq!(t.incr(pack_pair(3, 9)), 1);
        assert_eq!(t.incr(pack_pair(9, 3)), 2, "unordered key");
        t.decr(pack_pair(3, 9));
        assert_eq!(t.get(pack_pair(3, 9)), 1);
        t.zero(pack_pair(3, 9));
        assert_eq!(t.get(pack_pair(3, 9)), 0);
        assert_eq!(t.incr(pack_pair(3, 9)), 1, "zeroed key revives");
        t.clear();
        assert_eq!(t.get(pack_pair(3, 9)), 0);
        let mut seen = 0usize;
        t.for_each(|_, _| seen += 1);
        assert_eq!(seen, 0);
    }

    #[test]
    fn pair_table_grows_past_min_size() {
        let mut t = PairTable::default();
        let m = (MIN_TABLE * 2) as u32;
        for a in 0..m {
            assert_eq!(t.incr(pack_pair(a, a + 1)), 1);
        }
        for a in 0..m {
            assert_eq!(t.get(pack_pair(a, a + 1)), 1, "lost key {a}");
        }
        let mut n = 0usize;
        t.for_each(|_, c| {
            assert_eq!(c, 1);
            n += 1;
        });
        assert_eq!(n, m as usize);
    }

    #[test]
    fn monotone_cost_in_capacity() {
        // More capacity can never hurt under the cost model (f monotone,
        // Theorem 3's premise).
        let mut edges = Vec::new();
        for u in 0..12u32 {
            for v in 0..12u32 {
                if u != v && (u + v) % 3 != 0 {
                    edges.push((u, v));
                }
            }
        }
        let g = Graph::from_edges(12, &edges);
        let mut last = usize::MAX;
        for cap in [0usize, 1, 2, 4, 8, 16, 64] {
            let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
                capacity: cap,
                kind: AggregateKind::Set,
                pair_cap: usize::MAX,
            };
            let (h, _) = hag_search(&g, &cfg);
            check_equivalence(&g, &h).unwrap();
            let c = h.cost_core();
            assert!(c <= last, "cost went up at capacity {cap}");
            last = c;
        }
    }

    /// The calibration-consuming contract: for any positive (α, β)
    /// the merge gain `α(r-1) + β(r-2)` is monotone in `r` and
    /// positive exactly on the `r >= 2` acceptance set, so the greedy
    /// search result is *identical* across weights — calibrated
    /// pricing changes what the stats report, never what the search
    /// builds. Degenerate weights are clamped rather than honored.
    #[test]
    fn calibrated_weights_never_change_the_search() {
        let g = dense();
        let base = SearchConfig::paper_default(g.n());
        let (h0, s0) = hag_search(&g, &base);
        for (a, b) in [(2.5, 0.8), (0.01, 300.0), (1e6, 1e-6)] {
            let cfg = base.clone().with_weights(a, b);
            assert_eq!(cfg.alpha, a);
            assert_eq!(cfg.beta, b);
            let (h, s) = hag_search(&g, &cfg);
            assert_eq!(h, h0, "weights ({a}, {b}) changed the HAG");
            assert_eq!(s.iterations, s0.iterations);
            // gain ordering/acceptance invariants the equality above
            // rides on
            assert!(cfg.merge_gain(3) > cfg.merge_gain(2));
            assert!(cfg.merge_gain(2) > 0.0);
            // stats price in calibrated units
            let want = a * (s.aggregations_before
                            - s.aggregations_after) as f64
                + b * (s.transfers_before
                       - s.transfers_after) as f64;
            assert!((s.calibrated_saving(&cfg) - want).abs() < 1e-9);
        }
        // at (1, 1) the saving is the cost_core reduction
        let saved = Hag::from_graph(&g, AggregateKind::Set).cost_core()
            - h0.cost_core();
        assert_eq!(s0.calibrated_saving(&base), saved as f64);
        // clamping: zero/NaN/negative weights fall back to 1.0
        let clamped = base.clone()
            .with_weights(0.0, f64::NAN)
            .with_weights(-3.0, f64::INFINITY);
        assert_eq!((clamped.alpha, clamped.beta), (1.0, 1.0));
    }
}
