//! Algorithm 3 — the HAG search algorithm.
//!
//! Greedy redundancy elimination: repeatedly find the pair of slots
//! `(v1, v2)` co-aggregated by the most consumers, materialize a new
//! aggregation node `w = v1 (+) v2`, and rewire every consumer of both to
//! consume `w` instead. Each iteration removes `redundancy - 1` binary
//! aggregations. Guarantees (paper §4): global optimum for sequential
//! AGGREGATE (Theorem 2), `(1 - 1/e)`-approximation for set AGGREGATE
//! (Theorem 3).
//!
//! Implementation notes (Appendix D realized):
//! * a lazy max-heap keyed by redundancy holds candidate pairs; stale
//!   entries are dropped on pop by consulting the exact count map;
//! * set-AGGREGATE pair counts are maintained incrementally: a merge
//!   touches only the consumers of the merged pair, so only pairs
//!   involving `v1`, `v2`, or `w` within those consumers' lists change;
//! * for hub consumers, enumerating all `C(deg, 2)` pairs is quadratic —
//!   `pair_cap` bounds the per-consumer window (the first `pair_cap`
//!   list positions generate pairs). Exact when every degree fits the
//!   cap; on hub-heavy graphs this trades a slightly smaller search
//!   space for near-linear runtime. The window re-fills as merges shrink
//!   the lists, so coverage recovers as the search progresses.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::graph::Graph;
use crate::util::FxHashMap as HashMap;

use super::{AggNode, AggregateKind, Hag, Slot};

/// Tuning knobs for [`hag_search`].
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Upper bound on `|V_A|`. The paper's default for the evaluation is
    /// `|V| / 4` (§5.2); `usize::MAX` means unbounded (Theorem 2 setting
    /// requires `capacity >= |E|`).
    pub capacity: usize,
    /// Set or sequential AGGREGATE (drives the redundancy definition).
    pub kind: AggregateKind,
    /// Per-consumer candidate-pair window (set AGGREGATE only); see
    /// module docs. `usize::MAX` = exact.
    pub pair_cap: usize,
}

impl SearchConfig {
    /// Paper §5.2 defaults: capacity = |V|/4, set aggregate.
    pub fn paper_default(n: usize) -> Self {
        SearchConfig {
            capacity: n / 4,
            kind: AggregateKind::Set,
            pair_cap: 64,
        }
    }

    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity;
        self
    }

    pub fn with_kind(mut self, kind: AggregateKind) -> Self {
        self.kind = kind;
        self
    }

    pub fn exact(mut self) -> Self {
        self.pair_cap = usize::MAX;
        self
    }
}

/// Search statistics, reported by benches and `repro search`.
#[derive(Debug, Clone, Default)]
pub struct SearchStats {
    pub iterations: usize,
    pub agg_nodes: usize,
    pub aggregations_before: usize,
    pub aggregations_after: usize,
    pub transfers_before: usize,
    pub transfers_after: usize,
    pub elapsed_ms: f64,
}

/// Run Algorithm 3 on `g`, returning the optimized HAG and stats.
pub fn hag_search(g: &Graph, cfg: &SearchConfig) -> (Hag, SearchStats) {
    let t0 = std::time::Instant::now();
    let mut hag = Hag::from_graph(g, cfg.kind);
    let before_aggs = hag.aggregations();
    let before_tx = hag.data_transfers();
    let iterations = match cfg.kind {
        AggregateKind::Set => search_set(&mut hag, cfg),
        AggregateKind::Sequential => search_sequential(&mut hag, cfg),
    };
    let stats = SearchStats {
        iterations,
        agg_nodes: hag.agg_nodes.len(),
        aggregations_before: before_aggs,
        aggregations_after: hag.aggregations(),
        transfers_before: before_tx,
        transfers_after: hag.data_transfers(),
        elapsed_ms: t0.elapsed().as_secs_f64() * 1e3,
    };
    (hag, stats)
}

/// Normalize an unordered pair to `(lo, hi)`. Shared with the
/// incremental-repair re-merge pass (`incremental/repair.rs`), which
/// applies the same pair-redundancy rule over stream-dirtied finals.
#[inline]
pub(crate) fn norm(a: Slot, b: Slot) -> (Slot, Slot) {
    if a < b { (a, b) } else { (b, a) }
}

// ===================================================================
// Set AGGREGATE
// ===================================================================

struct SetState {
    /// consumers[slot] -> sorted Vec of original-node consumers.
    consumers: Vec<Vec<u32>>,
    /// Exact redundancy count per candidate pair.
    pair_count: HashMap<(Slot, Slot), u32>,
    /// Lazy max-heap of (count, pair); entries may be stale.
    heap: BinaryHeap<(u32, Reverse<(Slot, Slot)>)>,
}

fn search_set(hag: &mut Hag, cfg: &SearchConfig) -> usize {
    // With a finite pair_cap the candidate window misses pairs beyond
    // the first `cap` list positions. Merges shrink lists, so
    // re-scanning after the heap drains recovers coverage: run rounds
    // until a round makes no progress or capacity is reached.
    let mut total = 0usize;
    loop {
        let made = search_set_round(hag, cfg);
        total += made;
        if made == 0 || hag.agg_nodes.len() >= cfg.capacity
            || cfg.pair_cap == usize::MAX
        {
            return total;
        }
    }
}

fn search_set_round(hag: &mut Hag, cfg: &SearchConfig) -> usize {
    let slots = hag.slots();
    // Build consumer lists over *all* current slots (merges may pair an
    // aggregation node with anything).
    let mut consumers: Vec<Vec<u32>> = vec![Vec::new(); slots];
    for (v, l) in hag.in_edges.iter().enumerate() {
        for &s in l {
            consumers[s as usize].push(v as u32);
        }
    }
    debug_assert!(consumers.iter()
        .all(|c| c.windows(2).all(|p| p[0] < p[1])));
    let mut st = SetState {
        consumers,
        pair_count: HashMap::default(),
        heap: BinaryHeap::new(),
    };
    for l in hag.in_edges.iter() {
        let w = l.len().min(cfg.pair_cap);
        for i in 0..w {
            for j in (i + 1)..w {
                let p = norm(l[i], l[j]);
                *st.pair_count.entry(p).or_insert(0) += 1;
            }
        }
    }
    for (&p, &c) in st.pair_count.iter() {
        if c >= 2 {
            st.heap.push((c, Reverse(p)));
        }
    }

    let exact = cfg.pair_cap == usize::MAX;
    let mut iterations = 0usize;
    while hag.agg_nodes.len() < cfg.capacity {
        // Pop the highest-redundancy non-stale pair.
        let (v1, v2, red) = loop {
            match st.heap.pop() {
                None => return iterations,
                Some((c, Reverse(p))) => {
                    let cur = st.pair_count.get(&p).copied().unwrap_or(0);
                    if cur == c && c >= 2 {
                        break (p.0, p.1, c);
                    }
                    // stale: if the current count is still >= 2 the pair
                    // was re-pushed on update; just drop this entry.
                }
            }
        };

        // The merge is driven by the *live* consumer intersection: with
        // a finite pair_cap the windowed count can drift below the true
        // redundancy, so the intersection is the source of truth.
        let shared = intersect_sorted(&st.consumers[v1 as usize],
                                      &st.consumers[v2 as usize]);
        if exact {
            debug_assert_eq!(shared.len() as u32, red,
                             "exact mode: count must match intersection");
        }
        st.pair_count.remove(&norm(v1, v2));
        if shared.len() < 2 {
            // Windowed count drifted: merging would add a node that
            // saves nothing. Skip.
            continue;
        }

        // Materialize w = v1 (+) v2.
        let w = hag.slots() as Slot;
        hag.agg_nodes.push(AggNode { left: v1, right: v2 });
        st.consumers.push(Vec::new());

        for &u in &shared {
            let list = &mut hag.in_edges[u as usize];
            let old_w = list.len().min(cfg.pair_cap);
            // Pairs inside the old window disappear for v1/v2 entries.
            remove_window_pairs(&mut st.pair_count, list, old_w, v1, v2);
            list.retain(|&s| s != v1 && s != v2);
            list.push(w);
            add_window_pairs(&mut st.pair_count, &mut st.heap, list,
                             cfg.pair_cap);
            st.consumers[w as usize].push(u);
        }
        // Remove the rewired consumers from v1/v2 consumer lists
        // (`shared` is sorted, so binary_search is valid).
        for &v in &[v1, v2] {
            let cs = &mut st.consumers[v as usize];
            cs.retain(|u| shared.binary_search(u).is_err());
        }
        debug_assert!(st.consumers[w as usize].windows(2)
            .all(|p| p[0] < p[1]));

        iterations += 1;
    }
    iterations
}

/// Remove every windowed pair of `list` that involves `v1` or `v2`
/// (the entries about to be rewired), decrementing counts.
fn remove_window_pairs(pc: &mut HashMap<(Slot, Slot), u32>, list: &[Slot],
                       w: usize, v1: Slot, v2: Slot) {
    for i in 0..w {
        for j in (i + 1)..w {
            let (a, b) = (list[i], list[j]);
            if a == v1 || a == v2 || b == v1 || b == v2 {
                let p = norm(a, b);
                if let Some(c) = pc.get_mut(&p) {
                    *c = c.saturating_sub(1);
                    if *c == 0 {
                        pc.remove(&p);
                    }
                }
            }
        }
    }
}

/// Count windowed pairs involving the just-appended last element (the
/// new `w` slot). If the list outgrew the window the new element is
/// outside it and no pairs are added — with a finite `pair_cap` counts
/// may *under*estimate true redundancy (never overestimate it from this
/// path), which the merge loop tolerates by re-checking the live
/// intersection.
fn add_window_pairs(pc: &mut HashMap<(Slot, Slot), u32>,
                    heap: &mut BinaryHeap<(u32, Reverse<(Slot, Slot)>)>,
                    list: &[Slot], cap: usize) {
    if list.len() > cap {
        return; // appended element is outside the window
    }
    let last = list.len() - 1;
    for i in 0..last {
        let p = norm(list[i], list[last]);
        let c = pc.entry(p).or_insert(0);
        *c += 1;
        if *c >= 2 {
            heap.push((*c, Reverse(p)));
        }
    }
}

fn intersect_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out
}

// ===================================================================
// Sequential AGGREGATE (common-prefix merging, Algorithm 3 line 8)
// ===================================================================

fn search_sequential(hag: &mut Hag, cfg: &SearchConfig) -> usize {
    // Redundancy of (v1, v2) = #consumers whose list starts (v1, v2).
    // A merge replaces that prefix with (w, rest...), so each consumer's
    // first-two pair changes — counts update in O(1) per consumer.
    let mut pair_count: HashMap<(Slot, Slot), u32> = HashMap::default();
    let mut members: HashMap<(Slot, Slot), Vec<u32>> = HashMap::default();
    for (v, l) in hag.in_edges.iter().enumerate() {
        if l.len() >= 2 {
            let p = (l[0], l[1]); // ordered pair!
            *pair_count.entry(p).or_insert(0) += 1;
            members.entry(p).or_default().push(v as u32);
        }
    }
    let mut heap: BinaryHeap<(u32, Reverse<(Slot, Slot)>)> = pair_count
        .iter()
        .filter(|(_, &c)| c >= 2)
        .map(|(&p, &c)| (c, Reverse(p)))
        .collect();

    let mut iterations = 0usize;
    while hag.agg_nodes.len() < cfg.capacity {
        let (p, _red) = loop {
            match heap.pop() {
                None => return iterations,
                Some((c, Reverse(p))) => {
                    let cur = pair_count.get(&p).copied().unwrap_or(0);
                    if cur == c && c >= 2 {
                        break (p, c);
                    }
                }
            }
        };
        let w = hag.slots() as Slot;
        hag.agg_nodes.push(AggNode { left: p.0, right: p.1 });
        let users = members.remove(&p).unwrap_or_default();
        pair_count.remove(&p);
        for u in users {
            let list = &mut hag.in_edges[u as usize];
            // Membership lists are kept exact (a consumer's prefix only
            // changes when its pair is merged, which consumes the
            // membership), but guard defensively.
            if list.len() < 2 || (list[0], list[1]) != p {
                debug_assert!(false, "stale sequential membership");
                continue;
            }
            list.splice(0..2, [w]);
            if list.len() >= 2 {
                let np = (list[0], list[1]);
                let c = pair_count.entry(np).or_insert(0);
                *c += 1;
                members.entry(np).or_default().push(u);
                if *c >= 2 {
                    heap.push((*c, Reverse(np)));
                }
            }
        }
        iterations += 1;
    }
    iterations
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hag::check_equivalence;

    fn fig1() -> Graph {
        Graph::from_edges(
            5,
            &[
                (1, 0), (2, 0), (3, 0),
                (0, 1), (2, 1),
                (0, 2), (1, 2), (4, 2),
                (1, 3), (2, 3),
                (2, 4), (3, 4),
            ],
        )
    }

    #[test]
    fn set_search_on_fig1_finds_shared_pairs() {
        let g = fig1();
        let cfg = SearchConfig {
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        h.validate().unwrap();
        check_equivalence(&g, &h).unwrap();
        // Fig 1: {B,C} (consumers A, D) and {C,D} (consumers A, E) both
        // have redundancy 2, but they overlap in consumer A — greedy
        // takes one of them, after which the other drops below 2. One
        // merge, one aggregation saved.
        assert_eq!(stats.agg_nodes, 1, "{stats:?}");
        assert_eq!(h.aggregations(),
                   Hag::from_graph(&g, AggregateKind::Set)
                       .aggregations() - 1);
    }

    #[test]
    fn set_search_respects_capacity() {
        let g = fig1();
        let cfg = SearchConfig {
            capacity: 1,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        assert_eq!(h.agg_nodes.len(), 1);
        assert_eq!(stats.iterations, 1);
        check_equivalence(&g, &h).unwrap();
    }

    #[test]
    fn set_search_zero_capacity_is_identity() {
        let g = fig1();
        let cfg = SearchConfig {
            capacity: 0,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        assert_eq!(h.agg_nodes.len(), 0);
        assert_eq!(stats.aggregations_after, stats.aggregations_before);
        check_equivalence(&g, &h).unwrap();
    }

    #[test]
    fn set_search_no_redundancy_no_merges() {
        // path graph: no two nodes share 2+ common in-neighbors
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let cfg = SearchConfig {
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, _) = hag_search(&g, &cfg);
        assert_eq!(h.agg_nodes.len(), 0);
    }

    #[test]
    fn set_search_clique_saves_many() {
        // K6: every node aggregates the other 5; massive overlap.
        let mut edges = Vec::new();
        for u in 0..6u32 {
            for v in 0..6u32 {
                if u != v {
                    edges.push((u, v));
                }
            }
        }
        let g = Graph::from_edges(6, &edges);
        let cfg = SearchConfig {
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        check_equivalence(&g, &h).unwrap();
        assert!(stats.aggregations_after < stats.aggregations_before,
                "{stats:?}");
    }

    #[test]
    fn seq_search_merges_common_prefixes() {
        // Three nodes aggregate the ordered prefix (5, 6):
        let mut edges_by_node: Vec<Vec<u32>> = vec![vec![]; 8];
        edges_by_node[0] = vec![5, 6, 7];
        edges_by_node[1] = vec![5, 6];
        edges_by_node[2] = vec![5, 6, 3];
        let mut b = crate::graph::GraphBuilder::new(8);
        for (v, l) in edges_by_node.iter().enumerate() {
            for &u in l {
                b.edge(u, v as u32);
            }
        }
        let g = b.build();
        // NB: CSR sorts neighbors ascending, so ordered lists here are
        // the sorted ones; prefix (5,6) is shared by nodes 0 and 1; node
        // 2's sorted list is (3,5,6) — prefix (3,5).
        let cfg = SearchConfig {
            capacity: usize::MAX,
            kind: AggregateKind::Sequential,
            pair_cap: usize::MAX,
        };
        let (h, stats) = hag_search(&g, &cfg);
        h.validate().unwrap();
        check_equivalence(&g, &h).unwrap();
        assert!(stats.agg_nodes >= 1);
        assert!(stats.aggregations_after <= stats.aggregations_before);
    }

    #[test]
    fn seq_search_chains_prefixes() {
        // Two nodes share a long ordered prefix (1,2,3,4): expect chained
        // merges w1=(1,2), w2=(w1,3), w3=(w2,4).
        let mut b = crate::graph::GraphBuilder::new(7);
        for v in [5u32, 6u32] {
            for u in [1u32, 2, 3, 4] {
                b.edge(u, v);
            }
        }
        let g = b.build();
        let cfg = SearchConfig {
            capacity: usize::MAX,
            kind: AggregateKind::Sequential,
            pair_cap: usize::MAX,
        };
        let (h, _) = hag_search(&g, &cfg);
        check_equivalence(&g, &h).unwrap();
        assert_eq!(h.agg_nodes.len(), 3);
        // each consumer now aggregates exactly one slot
        assert_eq!(h.in_edges[5].len(), 1);
        assert_eq!(h.in_edges[6].len(), 1);
        // aggregations: 3 (chain) vs 6 before
        assert_eq!(h.aggregations(), 3);
    }

    #[test]
    fn search_is_deterministic() {
        let g = fig1();
        let cfg = SearchConfig::paper_default(g.n());
        let (h1, _) = hag_search(&g, &cfg);
        let (h2, _) = hag_search(&g, &cfg);
        assert_eq!(h1.agg_nodes, h2.agg_nodes);
        assert_eq!(h1.in_edges, h2.in_edges);
    }

    #[test]
    fn monotone_cost_in_capacity() {
        // More capacity can never hurt under the cost model (f monotone,
        // Theorem 3's premise).
        let mut edges = Vec::new();
        for u in 0..12u32 {
            for v in 0..12u32 {
                if u != v && (u + v) % 3 != 0 {
                    edges.push((u, v));
                }
            }
        }
        let g = Graph::from_edges(12, &edges);
        let mut last = usize::MAX;
        for cap in [0usize, 1, 2, 4, 8, 16, 64] {
            let cfg = SearchConfig {
                capacity: cap,
                kind: AggregateKind::Set,
                pair_cap: usize::MAX,
            };
            let (h, _) = hag_search(&g, &cfg);
            check_equivalence(&g, &h).unwrap();
            let c = h.cost_core();
            assert!(c <= last, "cost went up at capacity {cap}");
            last = c;
        }
    }
}
