//! CPU stub for the `xla` crate — compiled when the off-by-default
//! `xla` cargo feature is disabled (the default build everywhere the
//! PJRT native closure is not vendored).
//!
//! Mirrors exactly the API surface the runtime/coordinator layers use
//! (`PjRtClient::cpu -> HloModuleProto::from_text_file -> compile ->
//! execute_b`), so every call site type-checks unchanged; entry points
//! fail at runtime with a descriptive error instead of at link time.
//! Structure-only workflows (search, plan compilation, partition
//! stats, Fig 3 benches) never touch this module and run fully.

use std::fmt;

/// Stub error: carries the "built without the `xla` feature" message.
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: repro was built without the `xla` feature (PJRT \
         runtime stubbed out). Structure-only workflows (search, \
         partition-stats, bench-fig3) work; executing artifacts needs \
         a build with the vendored xla crate — see rust/Cargo.toml."
    ))
}

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Host-side literal (stub: never constructed).
pub struct Literal {
    _p: (),
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Device buffer handle (stub: never constructed).
pub struct PjRtBuffer {
    _p: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle (stub: never constructed).
pub struct PjRtLoadedExecutable {
    _p: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer])
                     -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Parsed HLO module (stub: never constructed).
pub struct HloModuleProto {
    _p: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _p: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _p: () }
    }
}

/// PJRT client (stub: `cpu()` is the single failing entry point, so
/// `Runtime::open` reports a clear error after the manifest loads).
pub struct PjRtClient {
    _p: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self, _data: &[T], _shape: &[usize], _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_entry_points_error_descriptively() {
        let e = PjRtClient::cpu().err().unwrap();
        let msg = format!("{e:?}");
        assert!(msg.contains("xla") && msg.contains("feature"), "{msg}");
        assert!(HloModuleProto::from_text_file("/x").is_err());
    }
}
