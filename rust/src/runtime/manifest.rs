//! `artifacts/manifest.json` schema — the contract written by
//! `python/compile/aot.py` and consumed by the runtime and coordinator.
//! Parsed with the in-tree JSON substrate (`util::json`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// One tensor in the flat input/output layout.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<TensorSpec> {
        let shape = v
            .req_arr("shape")?
            .iter()
            .map(|x| x.as_usize().context("bad shape entry"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v.req_str("dtype")?.to_string();
        if dtype != "f32" && dtype != "i32" {
            bail!("unsupported dtype {dtype:?}");
        }
        Ok(TensorSpec { name: v.req_str("name")?.to_string(), shape,
                        dtype })
    }
}

/// Static bucket dims, mirroring `python/compile/buckets.py::Bucket`.
#[derive(Debug, Clone)]
pub struct BucketSpec {
    pub name: String,
    pub n_pad: usize,
    pub f_in: usize,
    pub hidden: usize,
    pub classes: usize,
    pub levels: usize,
    pub l_pad: usize,
    pub bands: Vec<(usize, usize)>,
    pub br: usize,
    pub lvl_block: usize,
    pub g_pad: usize,
    /// Band segment-sum implementation: "mxu" (Pallas one-hot matmul,
    /// the TPU-shaped path) or "scatter" (XLA scatter-add, CPU-optimal
    /// — see EXPERIMENTS.md §Perf).
    pub impl_: String,
}

impl BucketSpec {
    pub fn m_pad(&self) -> usize {
        self.n_pad + self.levels * self.l_pad + 1
    }

    pub fn is_graph_cls(&self) -> bool {
        self.g_pad > 0
    }

    /// Does a lowered [`ExecutionPlan`](crate::hag::ExecutionPlan) fit
    /// this bucket exactly? (Plans are built to the bucket; this guards
    /// drift between `emit-buckets` output and a later search run.)
    pub fn fits(&self, plan: &crate::hag::ExecutionPlan) -> bool {
        self.n_pad == plan.n_pad
            && self.levels == plan.levels
            && self.l_pad == plan.l_pad
            && self.br == plan.br
            && self.bands.len() == plan.bands.len()
            && self.bands.iter().zip(&plan.bands)
                .all(|(a, b)| a == b)
    }

    pub fn from_json(v: &Value) -> Result<BucketSpec> {
        let bands = v
            .req_arr("bands")?
            .iter()
            .map(|b| {
                let p = b.as_arr().filter(|p| p.len() == 2)
                    .context("band must be [nb, nnzb]")?;
                Ok((p[0].as_usize().context("bad nb")?,
                    p[1].as_usize().context("bad nnzb")?))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(BucketSpec {
            name: v.req_str("name")?.to_string(),
            n_pad: v.req_usize("n_pad")?,
            f_in: v.req_usize("f_in")?,
            hidden: v.req_usize("hidden")?,
            classes: v.req_usize("classes")?,
            levels: v.req_usize("levels")?,
            l_pad: v.req_usize("l_pad")?,
            bands,
            br: v.req_usize("br")?,
            lvl_block: v.req_usize("lvl_block")?,
            g_pad: v.req_usize("g_pad")?,
            impl_: v.get("impl").and_then(|x| x.as_str())
                .unwrap_or("mxu").to_string(),
        })
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("name", json::str_(self.name.clone())),
            ("n_pad", json::num(self.n_pad as f64)),
            ("f_in", json::num(self.f_in as f64)),
            ("hidden", json::num(self.hidden as f64)),
            ("classes", json::num(self.classes as f64)),
            ("levels", json::num(self.levels as f64)),
            ("l_pad", json::num(self.l_pad as f64)),
            ("bands", Value::Arr(
                self.bands.iter()
                    .map(|&(nb, nnzb)| Value::Arr(vec![
                        json::num(nb as f64), json::num(nnzb as f64)]))
                    .collect())),
            ("br", json::num(self.br as f64)),
            ("lvl_block", json::num(self.lvl_block as f64)),
            ("g_pad", json::num(self.g_pad as f64)),
            ("impl", json::str_(self.impl_.clone())),
        ])
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// "gcn" | "sage"
    pub model: String,
    /// "train" | "infer"
    pub kind: String,
    pub bucket: BucketSpec,
    pub lr: f64,
    pub key: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Index of the named input in the flat layout.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name == name)
    }

    fn from_json(v: &Value) -> Result<ArtifactSpec> {
        let tensors = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req_arr(key)?.iter().map(TensorSpec::from_json).collect()
        };
        Ok(ArtifactSpec {
            name: v.req_str("name")?.to_string(),
            file: v.req_str("file")?.to_string(),
            model: v.req_str("model")?.to_string(),
            kind: v.req_str("kind")?.to_string(),
            bucket: BucketSpec::from_json(v.req("bucket")?)?,
            lr: v.req_f64("lr")?,
            key: v.get("key").and_then(|k| k.as_str()).unwrap_or("")
                .to_string(),
            inputs: tensors("inputs")?,
            outputs: tensors("outputs")?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let data = std::fs::read_to_string(path).with_context(|| {
            format!("reading manifest {} — run `make artifacts`",
                    path.display())
        })?;
        Self::parse(&data)
            .with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(data: &str) -> Result<Manifest> {
        let v = json::parse(data).map_err(anyhow::Error::from)?;
        let artifacts = v
            .req_arr("artifacts")?
            .iter()
            .map(ArtifactSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { version: v.req_usize("version")?, artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let json = r#"{
          "version": 1,
          "artifacts": [{
            "name": "gcn_train_x", "file": "x.hlo.txt",
            "model": "gcn", "kind": "train",
            "bucket": {"name": "x", "n_pad": 128, "f_in": 8,
                       "hidden": 16, "classes": 4, "levels": 0,
                       "l_pad": 0, "bands": [[16, 16]], "br": 8,
                       "lvl_block": 128, "g_pad": 0},
            "lr": 0.01,
            "inputs": [{"name": "w1", "shape": [8, 16],
                        "dtype": "f32"}],
            "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]
          }]
        }"#;
        let m = Manifest::parse(json).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.bucket.m_pad(), 129);
        assert_eq!(a.input_index("w1"), Some(0));
        assert_eq!(a.inputs[0].elements(), 128);
        assert_eq!(a.bucket.bands, vec![(16, 16)]);
    }

    #[test]
    fn bucket_json_roundtrip() {
        let b = BucketSpec {
            name: "bzr_hag".into(), n_pad: 6528, f_in: 16, hidden: 16,
            classes: 4, levels: 9, l_pad: 512,
            bands: vec![(16, 512), (800, 64)], br: 8, lvl_block: 128,
            g_pad: 0, impl_: "scatter".into(),
        };
        let v = b.to_json();
        let b2 = BucketSpec::from_json(&v).unwrap();
        assert_eq!(b2.name, b.name);
        assert_eq!(b2.bands, b.bands);
        assert_eq!(b2.m_pad(), b.m_pad());
    }

    #[test]
    fn rejects_bad_dtype() {
        let v = json::parse(r#"{"name": "x", "shape": [2],
                                "dtype": "f64"}"#).unwrap();
        assert!(TensorSpec::from_json(&v).is_err());
    }
}
