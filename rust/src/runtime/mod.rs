//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `compile` -> `execute_b`). Text is
//! the interchange format — the image's xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos (64-bit instruction ids), while the text
//! parser reassigns ids cleanly.
//!
//! The runtime enforces the manifest contract: every execute call is
//! checked against the artifact's declared input arity, shapes and
//! dtypes, so a plan-compiler bug surfaces as a descriptive error rather
//! than an XLA shape crash.

mod manifest;
#[cfg(not(feature = "xla"))]
pub(crate) mod xla_stub;

pub use manifest::{ArtifactSpec, BucketSpec, Manifest, TensorSpec};

// Single switch point between the real PJRT bindings and the CPU
// stub; everything else in the crate imports `crate::runtime::xla`.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature needs the real PJRT bindings, which are not \
     wired up yet: vendor the xla crate (e.g. at rust/vendor/xla), \
     add `xla = { path = \"vendor/xla\", optional = true }` to \
     [dependencies], change the feature to `xla = [\"dep:xla\"]` in \
     rust/Cargo.toml, and delete this compile_error."
);
#[cfg(feature = "xla")]
pub(crate) use ::xla;
#[cfg(not(feature = "xla"))]
pub(crate) use self::xla_stub as xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

/// A host-side tensor heading into (or out of) an executable.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { data, shape: shape.to_vec() }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::I32 { data, shape: shape.to_vec() }
    }

    pub fn scalar_f32(x: f32) -> Self {
        HostTensor::F32 { data: vec![x], shape: vec![] }
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostTensor::I32 { data: vec![x], shape: vec![] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => {
                shape
            }
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostTensor::F32 { .. } => "f32",
            HostTensor::I32 { .. } => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is f32, expected i32"),
        }
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        self.dtype() == spec.dtype && self.shape() == spec.shape.as_slice()
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Self> {
        let shape = spec.shape.clone();
        Ok(match spec.dtype.as_str() {
            "f32" => HostTensor::F32 { data: lit.to_vec::<f32>()?, shape },
            "i32" => HostTensor::I32 { data: lit.to_vec::<i32>()?, shape },
            other => bail!("unsupported dtype {other}"),
        })
    }
}

/// A compiled artifact plus its manifest spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client, lazily compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    compiled: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`, compiles
    /// nothing yet).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let specs = manifest
            .artifacts
            .into_iter()
            .map(|a| (a.name.clone(), a))
            .collect();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            specs,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str())
            .collect();
        v.sort_unstable();
        v
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs.get(name).ok_or_else(|| {
            anyhow!("artifact {name:?} not in manifest (have: {:?}). \
                   Run `repro emit-buckets` then `make artifacts`.",
                  self.artifact_names())
        })
    }

    /// Compile (or fetch the cached) executable for `name`.
    pub fn compile(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.spec(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let arc = Arc::new(Executable { spec, exe });
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), arc.clone());
        crate::obs_info!("[runtime] compiled {name} in {:.2}s",
                         t0.elapsed().as_secs_f64());
        Ok(arc)
    }

    /// Upload a host tensor to a device buffer (reusable across
    /// executions — upload plan tensors once, not per step).
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let buf = match t {
            HostTensor::F32 { data, shape } => self
                .client
                .buffer_from_host_buffer::<f32>(data, shape, None),
            HostTensor::I32 { data, shape } => self
                .client
                .buffer_from_host_buffer::<i32>(data, shape, None),
        };
        buf.map_err(|e| anyhow!("uploading buffer: {e:?}"))
    }

    /// Validate `inputs` against the spec and upload them all.
    pub fn upload_checked(&self, exe: &Executable, inputs: &[HostTensor])
                          -> Result<Vec<xla::PjRtBuffer>> {
        check_inputs(&exe.spec, inputs)?;
        inputs.iter().map(|t| self.upload(t)).collect()
    }

    /// Execute with pre-uploaded buffers; returns host tensors per the
    /// manifest output spec.
    pub fn execute(&self, exe: &Executable, args: &[&xla::PjRtBuffer])
                   -> Result<Vec<HostTensor>> {
        if args.len() != exe.spec.inputs.len() {
            bail!("{}: got {} args, expected {}", exe.spec.name,
                  args.len(), exe.spec.inputs.len());
        }
        let out = exe
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {}: {e:?}", exe.spec.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result: {e:?}"))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untupling result: {e:?}"))?;
        if parts.len() != exe.spec.outputs.len() {
            bail!("{}: got {} outputs, manifest says {}", exe.spec.name,
                  parts.len(), exe.spec.outputs.len());
        }
        parts
            .iter()
            .zip(&exe.spec.outputs)
            .map(|(l, s)| HostTensor::from_literal(l, s))
            .collect()
    }

    /// One-shot convenience: upload + execute host tensors.
    pub fn run(&self, name: &str, inputs: &[HostTensor])
               -> Result<Vec<HostTensor>> {
        let exe = self.compile(name)?;
        let bufs = self.upload_checked(&exe, inputs)?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.execute(&exe, &refs)
    }
}

fn check_inputs(spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!("{}: got {} inputs, expected {} ({:?})", spec.name,
              inputs.len(), spec.inputs.len(),
              spec.inputs.iter().map(|s| s.name.as_str())
                  .collect::<Vec<_>>());
    }
    for (t, s) in inputs.iter().zip(&spec.inputs) {
        if !t.matches(s) {
            bail!("{}: input {:?} expects {}{:?}, got {}{:?}", spec.name,
                  s.name, s.dtype, s.shape, t.dtype(), t.shape());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_opens() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        assert!(rt.artifact_names().iter()
            .any(|n| n.starts_with("gcn_train")));
    }

    #[test]
    fn input_check_catches_wrong_shape() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::open(artifacts_dir()).unwrap();
        let name = "gcn_infer_tiny0";
        let spec = rt.spec(name).unwrap();
        let mut inputs: Vec<HostTensor> = spec.inputs.iter()
            .map(|s| match s.dtype.as_str() {
                "f32" => HostTensor::f32(
                    vec![0.0; s.shape.iter().product()], &s.shape),
                _ => HostTensor::i32(
                    vec![0; s.shape.iter().product()], &s.shape),
            })
            .collect();
        // break one shape
        inputs[0] = HostTensor::f32(vec![0.0; 4], &[2, 2]);
        let exe = rt.compile(name).unwrap();
        assert!(rt.upload_checked(&exe, &inputs).is_err());
    }
}
