//! `repro` — CLI for the HAG reproduction.
//!
//! Typical flow:
//! ```text
//! repro stats                         # Table 2 (dataset statistics)
//! repro search --dataset BZR         # run Algorithm 3, print savings
//! repro emit-buckets --scale 0.05    # phase 1 of the AOT build
//! make artifacts                     # phase 2 (python, once)
//! repro train --dataset BZR --repr hag --epochs 50
//! repro serve --dataset BZR --requests 500
//! repro bench-fig2 / bench-fig3 / bench-fig4
//! ```
//!
//! Every lowering subcommand parses the same spec flags
//! ([`SpecArgs`]) into a [`LowerSpec`], so `--capacity` / `--shards` /
//! `--partition-seed` mean the same thing everywhere and the bucket a
//! spec emits is exactly the bucket the same spec trains or serves
//! against.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use repro::coordinator::{self, pack_workload};
use repro::datasets;
use repro::hag::hag_search;
use repro::incremental::{random_delta, OverlayGraph, StreamEngine};
use repro::partition::{partition_bfs, search_partitioned,
                       PartitionConfig};
use repro::runtime::Runtime;
use repro::session::{LowerSpec, Session};
use repro::util::cli::{Args, SpecArgs};
use repro::util::Rng;

const USAGE: &str = "\
repro — Redundancy-free GNN computation graphs (HAG)

USAGE: repro <subcommand> [options]

SUBCOMMANDS
  stats          Table 2: dataset stand-in statistics
  search         run Algorithm 3, report savings + equivalence
  partition-stats  shard the graph, report edge-cut/halo/balance and
                 per-shard redundancy elimination vs single-shard
  stream         apply a random update stream through the incremental
                 engine + lowering session; report repair latency,
                 per-shard plan-cache activity, and the dirty-shard
                 re-plan == from-scratch check
  stream-stats   drift trajectory table (cost vs decayed fresh-search
                 estimate, dirty shards, session re-plan activity)
  emit-buckets   write artifacts/buckets.json (AOT build phase 1)
  train          train a 2-layer GCN (gnn-graph or hag repr)
  infer          one-shot full-graph inference latency
  serve          batched scoring server with latency percentiles;
                 runs on the host reference executor when PJRT
                 artifacts are absent (--updates N streams topology
                 deltas while serving; --plan-swap hot-swaps drifted
                 serving plans from the resident session's per-shard
                 plan cache)
  recover        scan a WAL directory (repairing any torn tail in
                 place, exactly as serve --recover would) and report
                 what survives; --check additionally replays onto the
                 dataset's base graph and fails unless the recovered
                 plan is haglint-clean and identical to a
                 from-scratch plan at the same topology
  obs            telemetry tools: demo the metrics registry + event
                 tracer on a small search, or validate exported
                 artifacts (--check-snapshot / --check-trace /
                 --check-cost / --check-verify, used by CI on the
                 serve smoke's exports and the verify gate)
  verify         haglint: multi-pass static verification of HAGs and
                 execution plans (--corpus runs the seeded artifact
                 corpus — the hard CI gate; --dataset verifies one
                 session lowering; --list prints the pass inventory;
                 --json P writes a haglint-v1 report)
  lint-src       source-convention lint over rust/src: no
                 unwrap/expect/panic! in the request path, metric
                 names shaped subsystem.noun_verb, no deprecated
                 wrapper references (allowlist:
                 tools/srclint-allow.txt; hard CI gate)
  cost-audit     measured-vs-predicted cost-model audit: run the host
                 reference executor over the generator corpus, meter
                 every batch into the online α̂/β̂ calibration, and
                 report Definition-2 predicted terms next to executed
                 (padded) op counts (--json P writes a benchkit-v1
                 line validatable by obs --check-cost)
  bench-fig2     Fig 2: end-to-end train + inference comparison
  bench-fig3     Fig 3: aggregation/data-transfer reductions
  bench-fig4     Fig 4: capacity sweep on COLLAB

SPEC OPTIONS (shared by search / partition-stats / stream /
stream-stats / emit-buckets / train / infer / serve)
  --repr R          gnn | hag                 [hag]
  --kind K          set | seq                 [set]
  --capacity N      explicit |V_A| budget (overrides --capacity-frac;
                    carried end-to-end through buckets.json)
  --capacity-frac F search capacity / |V|     [0.25]
  --shards N        partitioned parallel search; N>=2 shards,
                    1 = whole-graph
  --partition-seed S BFS partitioner seed
  --drift-threshold F  re-plan trigger           [0.08]
  --background      whole-graph rebuilds on a background thread; on
                    stream/stream-stats this keeps the engine's own
                    drift rebuilds instead of the session's inline
                    dirty-shard re-plan installs

COMMON OPTIONS
  --artifacts DIR   artifact directory        [artifacts]
  --dataset NAME    BZR | PPI | REDDIT | IMDB | COLLAB
  --datasets NAME   (repeatable) subset for emit-buckets / bench-fig2
  --scale F         dataset scale factor      [0.05]
  --seed N          generator seed            [7]
  --epochs N        training epochs           [20]
  --model M         gcn | sage                [gcn]
  --fig4            (emit-buckets) include Fig-4 sweep buckets
  --requests N --max-batch N --concurrency N  (serve)
  --listen ADDR     (serve) expose the wire protocol on ADDR while
                    the internal load runs (127.0.0.1:0 picks an
                    ephemeral port, printed as 'listening'; frame
                    format + error codes in DESIGN.md §12)
  --max-inflight N  (serve --listen) per-connection pipeline cap [32]
  --shed-after N    (serve --listen) server-wide outstanding-request
                    cap; load past it is answered with explicit
                    RetryAfter error frames             [256]
  --linger-secs N   (serve --listen) keep the wire front end up this
                    many seconds after the internal load finishes
                    (lets external clients, e.g. the CI smoke's
                    serve_client example, connect)      [0]
  --plan-swap       (serve) session-aware serving: drift past the
                    threshold swaps the session's spliced dirty-shard
                    re-plan into the live worker (negative
                    --drift-threshold forces a swap at every flush)
  --update-batch N  (serve) pending topology deltas coalesced (by
                    shard) per flush outside the batch window  [64]
  --wal DIR         (serve, recover) crash-safe delta durability:
                    journal every update batch into an append-only
                    WAL in DIR before acknowledging it, and cut
                    graph+HAG snapshots on the epoch cadence
                    (DESIGN.md §14)
  --snapshot-every N  (serve --wal) snapshot every N landed plan
                    epochs; 0 disables snapshots          [4]
  --recover         (serve --wal) replay the WAL (and newest valid
                    snapshot) into the resident pair before serving,
                    truncating any torn tail; serving resumes at the
                    recovered topology and sequence numbering
  --check           (recover) replay + verify the recovered plan
                    (haglint + from-scratch identity); needs the
                    same --dataset / spec flags the serve run used
  --updates N       update stream length (stream / stream-stats /
                    serve)                  [10000 / 2000 / 0]
  --plan-every N    session re-plan cadence, in updates (stream)
                    [1000]
  --insert-frac F   insert share of edge updates  [0.5]
  --node-add-frac F NodeAdd share of updates      [0.01]
  --report-memory   (bench-fig4) print §3.2 memory accounting

TELEMETRY (DESIGN.md §10-11; log level via
REPRO_LOG=error|warn|info|trace)
  --obs-snapshot P  (serve) export periodic benchkit-v1 registry
                    snapshots to P as JSONL while serving, plus one
                    final snapshot at shutdown
  --cost-audit P    (serve) write a one-line benchkit-v1 JSONL
                    cost-audit sidecar to P at shutdown: live α̂/β̂,
                    model error, predicted vs measured Definition-2
                    terms (reference executor only — the XLA path
                    does not meter per-batch op counts)
  --batches N       (cost-audit) reference batches per dataset  [8]
  --json P          (cost-audit) write the audit as one benchkit-v1
                    JSONL line to P
  --trace P         (serve, obs) enable event tracing and write a
                    Chrome trace_event JSON to P at exit
  --snapshot P      (obs) write the demo's registry snapshot to P
  --check-snapshot P  (obs) validate a --obs-snapshot JSONL export
  --check-trace P   (obs) validate a --trace Chrome JSON export
  --check-cost P    (obs) validate a --cost-audit / cost-audit --json
                    export: calibration populated, predicted and
                    measured terms present and positive
  --check-verify P  (obs) validate a verify --json haglint-v1 export:
                    clean, zero errors, non-empty pass inventory and
                    case list
  --corpus          (verify) run the seeded verification corpus
  --list            (verify) print the pass inventory
  --src-root DIR    (lint-src) source root         [src]
  --allowlist P     (lint-src) known-good exceptions
                    [tools/srclint-allow.txt]
";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let artifacts: PathBuf = args
        .get_or::<String>("artifacts", "artifacts".into())?.into();
    let scale = args.get_or("scale", 0.05)?;
    let seed = args.get_or("seed", 7u64)?;
    let sub = args.subcommand.clone().unwrap_or_default();
    let r = match sub.as_str() {
        "stats" => cmd_stats(scale, seed),
        "search" => cmd_search(&args, scale, seed),
        "partition-stats" => cmd_partition_stats(&args, scale, seed),
        "stream" => cmd_stream(&args, scale, seed),
        "stream-stats" => cmd_stream_stats(&args, scale, seed),
        "emit-buckets" => cmd_emit_buckets(&args, &artifacts, scale,
                                           seed),
        "train" => cmd_train(&args, &artifacts, scale, seed),
        "infer" => cmd_infer(&args, &artifacts, scale, seed),
        "serve" => cmd_serve(&args, &artifacts, scale, seed),
        "recover" => cmd_recover(&args, scale, seed),
        "obs" => cmd_obs(&args, scale, seed),
        "verify" => cmd_verify(&args, scale, seed),
        "lint-src" => cmd_lint_src(&args),
        "cost-audit" => cmd_cost_audit(&args, scale, seed),
        "bench-fig2" => repro::bench::fig2(
            &artifacts, args.get_all("datasets"), scale, seed,
            args.get_or("epochs", 10usize)?),
        "bench-fig3" => repro::bench::fig3(
            SpecArgs::parse(&args)?.spec.kind, scale, seed),
        "bench-fig4" => repro::bench::fig4(
            &artifacts, args.get_or("scale", 0.02)?, seed,
            args.get_or("epochs", 5usize)?,
            args.flag("report-memory")?),
        "" | "help" | "--help" => {
            print!("{USAGE}");
            return Ok(());
        }
        other => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    };
    args.finish()?;
    r
}

fn req_dataset(args: &Args) -> Result<String> {
    args.get::<String>("dataset")?
        .context("--dataset is required (BZR|PPI|REDDIT|IMDB|COLLAB)")
}

fn cmd_stats(scale: f64, seed: u64) -> Result<()> {
    println!("Table 2 — dataset stand-ins at scale {scale} (paper-scale \
              targets in parentheses)");
    println!("{:<10} {:>10} {:>12} {:>8} {:>8}  task", "name", "nodes",
             "edges", "deg", "dens%");
    for &(name, n0, e0, task) in datasets::PAPER_TABLE2 {
        let ds = datasets::load(
            name, repro::bench::effective_scale(name, scale), seed);
        let (_, mean_deg, _) = ds.graph.degree_stats();
        println!(
            "{:<10} {:>10} {:>12} {:>8.1} {:>8.3}  {:?}  (paper: {} / {})",
            name, ds.n(), ds.e(), mean_deg,
            100.0 * ds.graph.density(), task, n0, e0);
    }
    Ok(())
}

fn cmd_search(args: &Args, scale: f64, seed: u64) -> Result<()> {
    let name = req_dataset(args)?;
    let ds = datasets::load(&name, scale, seed);
    let spec = SpecArgs::parse(args)?.spec;
    let kind = spec.kind;
    let cfg = spec.search_config(ds.graph.n());
    let (hag, stats) = match spec.shards {
        k if k >= 2 => {
            let (hag, sh) = repro::partition::search_sharded_seeded(
                &ds.graph, k, &cfg, spec.partition_seed);
            if sh.per_shard.len() > 1 {
                println!("sharding      : {k} shards, {} cut edges \
                          ({:.1}%), {} threads",
                         sh.report.cut_edges,
                         100.0 * sh.report.cut_frac, sh.threads);
            } else {
                // sequential AGGREGATE does not decompose across a
                // cut; the driver ran one whole-graph search instead
                println!("sharding      : requested {k} shards, but \
                          {kind:?} AGGREGATE does not shard — ran \
                          whole-graph search");
            }
            (hag, sh.total)
        }
        _ => hag_search(&ds.graph, &cfg),
    };
    repro::hag::check_equivalence_probabilistic(&ds.graph, &hag, seed)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("dataset       : {} (n={}, e={})", ds.name, ds.n(), ds.e());
    println!("kind          : {kind:?}   capacity: {}", cfg.capacity);
    println!("agg nodes     : {}", stats.agg_nodes);
    println!("aggregations  : {} -> {}  ({:.2}x)",
             stats.aggregations_before, stats.aggregations_after,
             stats.aggregations_before as f64
                 / stats.aggregations_after.max(1) as f64);
    println!("data transfers: {} -> {}  ({:.2}x)",
             stats.transfers_before, stats.transfers_after,
             stats.transfers_before as f64
                 / stats.transfers_after.max(1) as f64);
    println!("search time   : {:.1} ms  ({} merges)", stats.elapsed_ms,
             stats.iterations);
    println!("kernel        : {} rounds, {} heap pops ({} stale), \
              scratch peak {:.1} KiB",
             stats.rounds, stats.heap_pops, stats.stale_pops,
             stats.peak_scratch_bytes as f64 / 1024.0);
    println!("equivalence   : OK (probabilistic, Theorem 1)");
    Ok(())
}

fn cmd_partition_stats(args: &Args, scale: f64, seed: u64) -> Result<()> {
    let name = req_dataset(args)?;
    let ds = datasets::load(&name, scale, seed);
    let spec = SpecArgs::parse(args)?.spec;
    let kind = spec.kind;
    // partition-stats is about sharding, so absent --shards means a
    // representative 4, not the lowering default of 1
    let k = args.get::<usize>("shards")?.unwrap_or(4).max(1);
    let pseed = spec.partition_seed;
    let t_part = std::time::Instant::now();
    let part = partition_bfs(
        &ds.graph, &PartitionConfig::new(k).with_seed(pseed));
    let partition_ms = t_part.elapsed().as_secs_f64() * 1e3;

    // Per-shard redundancy elimination + stitched vs single-shard.
    // (search_partitioned computes the partition report itself —
    // print from its copy instead of paying the O(n+e) pass twice.)
    let cfg = spec.search_config(ds.graph.n());
    let (sharded, sh) = search_partitioned(&ds.graph, &part, &cfg);
    let report = &sh.report;
    repro::hag::check_equivalence_probabilistic(&ds.graph, &sharded,
                                                seed)
        .map_err(|e| anyhow::anyhow!(e))?;

    println!("dataset   : {} (n={}, e={})", ds.name, ds.n(), ds.e());
    println!("partition : {k} shards, seed {pseed}");
    println!("{:>6} {:>8} {:>12} {:>8} {:>10}", "shard", "nodes",
             "intra edges", "halo", "weight");
    for s in 0..report.n_shards {
        println!("{:>6} {:>8} {:>12} {:>8} {:>10.0}", s,
                 report.shard_nodes[s], report.shard_intra_edges[s],
                 report.shard_halo[s], report.shard_weight[s]);
    }
    println!("edge cut  : {} / {} ({:.2}%)", report.cut_edges, ds.e(),
             100.0 * report.cut_frac);
    println!("balance   : {:.3} (max shard weight / ideal {:.0})",
             report.balance, report.ideal_weight);
    if sh.per_shard.len() == 1 && k > 1 {
        println!("\nNOTE: {kind:?} AGGREGATE does not shard (ordered \
                  covers cannot cross the cut); stats below are one \
                  whole-graph search.");
    }
    println!("\nper-shard redundancy elimination ({kind:?}, capacity \
              {}):", cfg.capacity);
    println!("{:>6} {:>12} {:>12} {:>10} {:>7} {:>10} {:>10}", "shard",
             "aggs gnn", "aggs hag", "agg nodes", "rounds", "pops",
             "ms");
    for (s, st) in sh.per_shard.iter().enumerate() {
        println!("{:>6} {:>12} {:>12} {:>10} {:>7} {:>10} {:>10.1}", s,
                 st.aggregations_before, st.aggregations_after,
                 st.agg_nodes, st.rounds, st.heap_pops,
                 st.elapsed_ms);
    }
    println!("kernel    : {} rounds, {} heap pops ({} stale) across \
              shards; max worker scratch {:.1} KiB",
             sh.total.rounds, sh.total.heap_pops, sh.total.stale_pops,
             sh.total.peak_scratch_bytes as f64 / 1024.0);
    let (single, ss) = hag_search(&ds.graph, &cfg);
    println!("\nstitched vs single-shard:");
    println!("  cost |E|-|VA| : {} vs {} ({:+.2}% gap)",
             sharded.cost_core(), single.cost_core(),
             100.0 * (sharded.cost_core() as f64
                 / single.cost_core().max(1) as f64 - 1.0));
    println!("  aggregations  : {} vs {}", sharded.aggregations(),
             single.aggregations());
    println!("  wall time     : {:.1} ms search + {:.1} ms partition \
              ({} threads) vs {:.1} ms single ({:.2}x speedup)",
             sh.wall_ms, partition_ms, sh.threads, ss.elapsed_ms,
             ss.elapsed_ms / (sh.wall_ms + partition_ms).max(1e-9));
    println!("  equivalence   : OK (probabilistic, Theorem 1)");
    Ok(())
}

/// Shared stream-option parsing for `stream` / `stream-stats`:
/// the lowering spec plus the delta-generator knobs.
fn stream_opts(args: &Args) -> Result<(LowerSpec, f64, f64)> {
    let spec = SpecArgs::parse(args)?.spec;
    let insert_frac = args.get_or("insert-frac", 0.5)?;
    let node_add_frac = args.get_or("node-add-frac", 0.01)?;
    Ok((spec, insert_frac, node_add_frac))
}

/// Engine + session lockstep for `stream` / `stream-stats`. Owns the
/// two invariants the commands would otherwise each re-encode:
///
/// * every delta is applied to *both* objects (the session's
///   graph must match the engine's for `install_hag`);
/// * exactly one party owns re-planning. By default the session does
///   (`repro::incremental`'s whole-graph drift rebuild is disabled and
///   drift past the threshold swaps in the session's spliced
///   dirty-shard re-plan — ROADMAP item 1). With `--background`, or
///   under the GNN baseline (whose session "plan" is the trivial HAG
///   and must never replace the engine's repaired one), the engine
///   keeps its own drift policy and the session only measures the
///   plan cache.
struct SessionStream {
    eng: StreamEngine,
    session: Session,
    installs: bool,
    threshold: f64,
}

impl SessionStream {
    fn new(g: &repro::graph::Graph, spec: &LowerSpec) -> SessionStream {
        // Set-AGGREGATE HAG sessions only: the GNN baseline's "plan"
        // is the trivial HAG, and IncrementalHag::from_hag rejects
        // sequential HAGs (ordered covers don't admit point repair).
        let installs = spec.repr == repro::coordinator::Repr::Hag
            && spec.kind == repro::hag::AggregateKind::Set
            && !spec.drift.background;
        let mut ecfg = spec.stream_config();
        if installs {
            ecfg.policy.threshold = f64::INFINITY;
        }
        SessionStream {
            eng: StreamEngine::new(g, ecfg),
            session: Session::from_graph(g, spec.clone()),
            installs,
            threshold: spec.drift.threshold,
        }
    }

    fn apply(&mut self, d: repro::incremental::GraphDelta) {
        self.eng.apply(d);
        self.session.apply(d);
    }

    /// Cadenced re-plan: cached dirty-shard plan; the engine adopts it
    /// when the session owns re-planning and drift crossed the
    /// threshold.
    fn replan(&mut self) {
        let (hag, _plan) = self.session.plan();
        if self.installs
            && self.eng.drift() > self.threshold
            && !self.eng.rebuild_in_flight()
        {
            self.eng.install_hag(&hag);
        }
    }
}

fn cmd_stream(args: &Args, scale: f64, seed: u64) -> Result<()> {
    let name = req_dataset(args)?;
    let updates = args.get_or("updates", 10_000usize)?;
    let plan_every = args.get_or("plan-every", 1_000usize)?;
    let (spec, insert_frac, node_add_frac) = stream_opts(args)?;
    let ds = datasets::load(
        &name, repro::bench::effective_scale(&name, scale), seed);
    // The engine repairs the HAG per delta; the session re-plans only
    // dirty shards on the --plan-every cadence (and, by default,
    // supplies the drift rebuilds — see SessionStream).
    let mut ss = SessionStream::new(&ds.graph, &spec);
    println!("dataset      : {} (n={}, e={})", ds.name, ds.n(), ds.e());
    println!("initial HAG  : cost {} vs trivial {}  ({:.1} ms search)",
             ss.eng.cost_core(), ds.e(),
             ss.eng.stats().init_search_ms);

    let mut rng = Rng::seed_from_u64(seed ^ 0x57e4);
    let mut lat_us: Vec<f64> = Vec::with_capacity(updates);
    for i in 0..updates {
        let d = random_delta(&mut rng, ss.eng.overlay(), insert_frac,
                             node_add_frac);
        let t = std::time::Instant::now();
        ss.eng.apply(d);
        lat_us.push(t.elapsed().as_secs_f64() * 1e6);
        ss.session.apply(d);
        if plan_every > 0 && (i + 1) % plan_every == 0 {
            ss.replan();
        }
    }
    ss.eng.finish_rebuild(); // land any in-flight background re-search
    let SessionStream { eng, mut session, .. } = ss;

    let g_now = eng.graph();
    let hag = eng.to_hag();
    hag.validate().map_err(|e| anyhow::anyhow!(e))?;
    repro::hag::check_equivalence_probabilistic(&g_now, &hag, seed)
        .map_err(|e| anyhow::anyhow!(e))?;
    let t = std::time::Instant::now();
    let (fresh, _) = hag_search(&g_now, &eng.search_config());
    let full_ms = t.elapsed().as_secs_f64() * 1e3;

    let s = eng.stats();
    println!("updates      : {} applied ({} ins, {} del, {} node-add, \
              {} noop)",
             s.applied, s.inserts, s.deletes, s.node_adds, s.noops);
    println!("repair       : {} fallbacks; {} re-merge passes \
              ({} merges); {} rebuilds ({} swapped, {} of them \
              session installs)",
             s.fallbacks, s.remerge_passes, s.remerge_merges,
             s.rebuild_starts, s.rebuild_swaps, s.installs);
    if !lat_us.is_empty() {
        lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            lat_us[((lat_us.len() as f64 - 1.0) * p) as usize]
        };
        println!("repair lat   : p50 {:.1} us  p99 {:.1} us  \
                  (full re-search: {:.1} ms, {:.0}x median)",
                 pct(0.5), pct(0.99), full_ms,
                 full_ms * 1e3 / pct(0.5).max(1e-9));
    }
    println!("graph now    : n={} e={}", g_now.n(), g_now.e());
    println!("cost         : maintained {} vs fresh search {} \
              ({:+.2}% gap)",
             hag.cost_core(), fresh.cost_core(),
             100.0 * (hag.cost_core() as f64
                 / fresh.cost_core().max(1) as f64 - 1.0));
    println!("equivalence  : OK (probabilistic, Theorem 1)");

    // Per-shard plan-cache acceptance: the cached dirty-shard-only
    // re-plan must be identical to a from-scratch build_plan over the
    // session's maintained HAG.
    let (hag_c, plan_c) = session.plan();
    let (hag_f, plan_f) = session.plan_fresh();
    let st = session.stats();
    println!("plan cache   : {} plans; {} shard re-searches vs {} \
              updates; {} shard cache hits; {} cross-shard deltas",
             st.plans, st.shard_searches, updates,
             st.shard_cache_hits, st.cross_shard_deltas);
    if *hag_c == hag_f && *plan_c == plan_f {
        println!("replan check : OK (cached dirty-shard re-plan == \
                  from-scratch build_plan)");
    } else {
        bail!("plan cache MISMATCH: cached re-plan differs from the \
               from-scratch build_plan");
    }
    Ok(())
}

fn cmd_stream_stats(args: &Args, scale: f64, seed: u64) -> Result<()> {
    let name = req_dataset(args)?;
    let updates = args.get_or("updates", 2_000usize)?;
    let (spec, insert_frac, node_add_frac) = stream_opts(args)?;
    let ds = datasets::load(
        &name, repro::bench::effective_scale(&name, scale), seed);
    let threshold = spec.drift.threshold;
    let mut ss = SessionStream::new(&ds.graph, &spec);
    println!("dataset : {} (n={}, e={}); drift threshold {:.3}",
             ds.name, ds.n(), ds.e(), threshold);
    println!("{:>8} {:>8} {:>10} {:>10} {:>12} {:>8} {:>7} {:>8} {:>8}",
             "seq", "n", "e", "cost", "est fresh", "drift%", "dirty",
             "replans", "installs");
    let mut rng = Rng::seed_from_u64(seed ^ 0x57e4);
    let every = (updates / 20).max(1);
    for i in 0..updates {
        let d = random_delta(&mut rng, ss.eng.overlay(), insert_frac,
                             node_add_frac);
        ss.apply(d);
        if (i + 1) % every == 0 || i + 1 == updates {
            let dirty = ss.session.dirty_shards();
            ss.replan();
            println!("{:>8} {:>8} {:>10} {:>10} {:>12.0} {:>8.2} \
                      {:>7} {:>8} {:>8}",
                     ss.eng.seq(), ss.eng.n(), ss.eng.e(),
                     ss.eng.cost_core(), ss.eng.estimated_fresh(),
                     100.0 * ss.eng.drift(), dirty,
                     ss.session.stats().shard_searches,
                     ss.eng.stats().installs);
        }
    }
    ss.eng.finish_rebuild();
    let SessionStream { eng, session, .. } = ss;
    let s = eng.stats();
    let st = session.stats();
    println!("\ntotals  : {} fallbacks, {} re-merge merges, \
              {} rebuilds started / {} swapped ({} session installs); \
              {} session plans, {} shard re-searches (vs {} updates), \
              {} shard cache hits",
             s.fallbacks, s.remerge_merges, s.rebuild_starts,
             s.rebuild_swaps, s.installs, st.plans,
             st.shard_searches, updates, st.shard_cache_hits);
    repro::hag::check_equivalence_probabilistic(
        &eng.graph(), &eng.to_hag(), seed)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("equivalence: OK (probabilistic, Theorem 1)");
    Ok(())
}

fn cmd_emit_buckets(args: &Args, artifacts: &PathBuf, scale: f64,
                    seed: u64) -> Result<()> {
    let mut names = args.get_all("datasets");
    if names.is_empty() {
        names = datasets::names().iter().map(|s| s.to_string()).collect();
    }
    let mut sets = Vec::new();
    for name in &names {
        let s = repro::bench::effective_scale(name, scale);
        repro::obs_info!("[emit-buckets] generating {name} at scale \
                          {s:.4}");
        sets.push(datasets::load(name, s, seed));
    }
    let spec = SpecArgs::parse(args)?.spec;
    let out = artifacts.join("buckets.json");
    let mut buckets = repro::session::emit_buckets(&sets, &spec, &out)?;
    if args.flag("fig4")? {
        repro::obs_info!("[emit-buckets] adding Fig-4 capacity sweep \
                          buckets");
        buckets.extend(repro::bench::fig4_buckets(
            args.get_or("fig4-scale", 0.02)?, seed)?);
        coordinator::write_buckets_json(&buckets, &out)?;
    }
    println!("wrote {} buckets -> {}", buckets.len(), out.display());
    println!("now run: make artifacts");
    Ok(())
}

fn cmd_train(args: &Args, artifacts: &PathBuf, scale: f64,
             seed: u64) -> Result<()> {
    let name = req_dataset(args)?;
    let spec = SpecArgs::parse(args)?.spec;
    let epochs = args.get_or("epochs", 20usize)?;
    let model = args.get_or::<String>("model", "gcn".into())?;
    let ds = datasets::load(
        &name, repro::bench::effective_scale(&name, scale), seed);
    let lowered = Session::new(&ds, spec).lower()?;
    let runtime = Arc::new(Runtime::open(artifacts)?);
    let mut trainer = coordinator::Trainer::for_lowered(
        runtime, &model, &ds, &lowered, seed)?;
    let report = trainer.train(epochs, 1.max(epochs / 10))?;
    println!("artifact      : {}", report.artifact);
    println!("epochs        : {}", report.epochs.len());
    println!("final loss    : {:.4}", report.final_loss());
    println!("final accuracy: {:.3}", report.final_accuracy());
    println!("mean epoch    : {:.1} ms", report.mean_epoch_ms);
    Ok(())
}

fn cmd_infer(args: &Args, artifacts: &PathBuf, scale: f64,
             seed: u64) -> Result<()> {
    let name = req_dataset(args)?;
    let spec = SpecArgs::parse(args)?.spec;
    let repeats = args.get_or("repeats", 10usize)?;
    let model = args.get_or::<String>("model", "gcn".into())?;
    let ds = datasets::load(
        &name, repro::bench::effective_scale(&name, scale), seed);
    let lowered = Session::new(&ds, spec).lower()?;
    let runtime = Arc::new(Runtime::open(artifacts)?);
    let aname = coordinator::artifact_name(&model, "infer",
                                           &lowered.bucket);
    let workload = pack_workload(&ds, &lowered.plan, &lowered.bucket)?;
    let ms = repro::bench::measure_inference(&runtime, &aname, &workload,
                                             seed, repeats)?;
    println!("artifact : {aname}");
    println!("inference: median {ms:.2} ms ({} nodes)", ds.n());
    Ok(())
}

fn cmd_serve(args: &Args, artifacts: &PathBuf, scale: f64,
             seed: u64) -> Result<()> {
    let name = req_dataset(args)?;
    let requests = args.get_or("requests", 500usize)?;
    let max_batch = args.get_or("max-batch", 64usize)?;
    let concurrency = args.get_or("concurrency", 8usize)?;
    let updates = args.get_or("updates", 0usize)?;
    let plan_swap = args.flag("plan-swap")?;
    let update_batch = args.get_or("update-batch", 64usize)?;
    let obs_snapshot = args.get::<String>("obs-snapshot")?;
    let cost_audit = args.get::<String>("cost-audit")?;
    let trace_path = args.get::<String>("trace")?;
    let listen = args.get::<String>("listen")?;
    let max_inflight = args.get_or("max-inflight", 32usize)?;
    let shed_after = args.get_or("shed-after", 256usize)?;
    let linger_secs = args.get_or("linger-secs", 0u64)?;
    let wal_dir = args.get::<String>("wal")?;
    let snapshot_every = args.get_or("snapshot-every", 4u64)?;
    let do_recover = args.flag("recover")?;
    if do_recover && wal_dir.is_none() {
        bail!("--recover requires --wal DIR");
    }
    if trace_path.is_some() {
        repro::obs::trace::set_enabled(true);
    }
    let (spec, insert_frac, node_add_frac) = stream_opts(args)?;
    let ds = datasets::load(
        &name, repro::bench::effective_scale(&name, scale), seed);
    // One session both lowers the serving workload and rides into the
    // batcher: the per-shard cache its lower() warms is the cache the
    // first drift re-plan hits. With --updates the server maintains
    // the HAG online (deltas flow to engine + session, coalesced by
    // shard between batches); with --plan-swap drift past
    // --drift-threshold hot-swaps the session's spliced dirty-shard
    // re-plan into the live worker (DESIGN.md §8). Without
    // --plan-swap the engine keeps its own drift policy, rebuilds
    // forced onto a background thread so the batcher never stalls.
    let mut session = Session::new(&ds, spec.clone());
    let lowered = session.lower()?;
    let resident = if updates > 0 || plan_swap || wal_dir.is_some() {
        let mut r = coordinator::Resident::new(
            session, &ds.graph, &lowered.hag,
            coordinator::SwapPolicy {
                swap_plans: plan_swap,
                max_pending: update_batch,
            });
        // Crash-safe journaling (DESIGN.md §14): --recover first
        // replays the WAL (and newest snapshot) into the resident
        // pair, then the WAL reopens after the recovered tail so the
        // journal-then-ack update path resumes where the crashed
        // process stopped.
        if let Some(dir) = &wal_dir {
            let dir = std::path::Path::new(dir);
            let mut tail_seq = 0u64;
            if do_recover {
                let rec = repro::durability::recover(dir)
                    .map_err(anyhow::Error::msg)?;
                let report =
                    r.resume(&rec).map_err(anyhow::Error::msg)?;
                tail_seq = rec.tail_seq;
                println!(
                    "recovered  : {} deltas ({} replayed into the \
                     engine past snapshot seq {}), {}B torn tail \
                     truncated, {} stale segments removed",
                    report.session_replayed, report.engine_replayed,
                    report.snapshot_seq, rec.truncated_bytes,
                    rec.removed_segments);
                if report.session_replayed > 0
                    || rec.snapshot.is_some()
                {
                    r = r.with_initial_swap();
                }
            }
            let dur = repro::durability::DurabilityState::open(
                dir, tail_seq, snapshot_every)
                .with_context(|| format!("opening WAL in {}",
                                         dir.display()))?;
            println!("durability : WAL at {} (journal-then-ack; \
                      snapshot every {} epochs; next seq {})",
                     dir.display(), snapshot_every, tail_seq + 1);
            r = r.with_durability(dur);
        }
        Some(r)
    } else {
        None
    };
    let server = coordinator::InferenceServer::for_lowered(
        artifacts.clone(), "gcn", &ds, &lowered,
        coordinator::BatchPolicy {
            max_batch,
            max_wait: std::time::Duration::from_millis(2),
        },
        seed, resident)?;
    let n = ds.n() as u32;
    let f_in = ds.f_in;

    // Hardened-path probes: malformed requests must come back as
    // explicit error outcomes, never kill the batcher.
    let probe = |node: u32, features: Vec<f32>| -> Result<bool> {
        let tx = server.client();
        let (otx, orx) = coordinator::server::oneshot();
        let req = coordinator::ScoreRequest {
            node,
            features,
            reply: otx,
            submitted: std::time::Instant::now(),
            pin_epoch: None,
        };
        if tx.send(coordinator::ServerMsg::Score(req)).is_err() {
            bail!("server queue closed during probes");
        }
        match orx.recv() {
            Ok(resp) => Ok(resp.is_ok()),
            Err(_) => bail!("batcher died on a malformed request"),
        }
    };
    if probe(n + 999, Vec::new())? {
        bail!("out-of-range node probe was not rejected");
    }
    if probe(0, vec![0.0; f_in + 1])? {
        bail!("wrong-length feature probe was not rejected");
    }
    println!("hardened   : 2 malformed probes rejected with error \
              replies");

    // Wire front end (DESIGN.md §12): the TCP listener feeds the same
    // batcher queue as the in-process load below, so external clients
    // see the same admission + plan-epoch contract the conformance
    // suite pins. Its net.* metrics live in their own registry (the
    // batcher's serve.* registry is only reachable over StatsReq).
    let net = if let Some(addr) = &listen {
        let reg = Arc::new(repro::obs::metrics::MetricsRegistry::new());
        let srv = repro::net::NetServer::spawn(
            addr.as_str(), server.client(), server.epoch_cell(), reg,
            repro::net::NetConfig {
                max_inflight,
                shed_after,
                ..Default::default()
            })
            .with_context(|| format!("binding {addr}"))?;
        println!("listening  : {} (max-inflight {max_inflight}, \
                  shed-after {shed_after})", srv.local_addr());
        Some(srv)
    } else {
        None
    };

    // Periodic benchkit-v1 snapshot export: a poller thread asks the
    // worker for a live StatsSnapshot over the same queue the scoring
    // traffic uses and appends one JSONL line per poll; the main
    // thread appends a final line after the load finishes.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut poller = None;
    if let Some(path) = obs_snapshot.clone() {
        std::fs::write(&path, "")
            .with_context(|| format!("truncating {path}"))?;
        let tx = server.client();
        let stop2 = stop.clone();
        poller = Some(std::thread::spawn(move || {
            while !stop2.load(std::sync::atomic::Ordering::Relaxed) {
                std::thread::sleep(
                    std::time::Duration::from_millis(200));
                let (stx, srx) = coordinator::server::stats_oneshot();
                let msg = coordinator::ServerMsg::Stats(
                    coordinator::StatsRequest { reply: stx });
                if tx.send(msg).is_err() {
                    break;
                }
                match srx.recv() {
                    Ok(snap) => {
                        let line =
                            snap.to_benchkit_value().to_string();
                        if append_line(&path, &line).is_err() {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
        }));
    }
    let mut handles = Vec::new();
    for c in 0..concurrency {
        let tx = server.client();
        let per = requests / concurrency.max(1);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::seed_from_u64(seed + c as u64);
            for _ in 0..per {
                let (otx, orx) = coordinator::server::oneshot();
                let req = coordinator::ScoreRequest {
                    node: rng.range_u32(0, n),
                    features: (0..f_in)
                        .map(|_| rng.range_f32(-1.0, 1.0)).collect(),
                    reply: otx,
                    submitted: std::time::Instant::now(),
                    pin_epoch: None,
                };
                if tx.send(coordinator::ServerMsg::Score(req)).is_err() {
                    break;
                }
                let _ = orx.recv();
            }
        }));
    }
    if updates > 0 {
        // Topology updater: generates deltas against a local mirror
        // (the engine's overlay lives on the batcher thread) and
        // streams them interleaved with the scoring traffic.
        let tx = server.client();
        let g = ds.graph.clone();
        handles.push(std::thread::spawn(move || {
            let mut mirror = OverlayGraph::new(g);
            let mut rng = Rng::seed_from_u64(seed ^ 0xde17a);
            for _ in 0..updates {
                let d = random_delta(&mut rng, &mirror, insert_frac,
                                     node_add_frac);
                mirror.apply(d);
                let req = coordinator::UpdateRequest {
                    delta: d,
                    reply: None,
                    submitted: std::time::Instant::now(),
                };
                if tx.send(coordinator::ServerMsg::Update(req)).is_err()
                {
                    break;
                }
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    // Hold the wire front end open for external clients (the CI smoke
    // connects serve_client during this window), then drain it:
    // accepting stops, in-flight wire requests flush through the
    // still-live batcher, stragglers get Draining frames.
    if net.is_some() && linger_secs > 0 {
        std::thread::sleep(std::time::Duration::from_secs(linger_secs));
    }
    let net_stats =
        net.map(|n| n.drain(std::time::Duration::from_secs(5)));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    if let Some(p) = poller {
        let _ = p.join();
    }
    // Final live snapshot: taken after the load drains (every reply
    // received means every counter moved) and appended as the export's
    // last JSONL line, then cross-checked against shutdown stats. The
    // cost-audit sidecar reads the same snapshot.
    let mut final_snap = None;
    if obs_snapshot.is_some() || cost_audit.is_some() {
        let (stx, srx) = coordinator::server::stats_oneshot();
        let msg = coordinator::ServerMsg::Stats(
            coordinator::StatsRequest { reply: stx });
        if server.client().send(msg).is_err() {
            bail!("server queue closed before the final obs snapshot");
        }
        let snap = srx.recv()
            .context("server died answering the final obs snapshot")?;
        if let Some(path) = &obs_snapshot {
            append_line(path, &snap.to_benchkit_value().to_string())
                .with_context(|| format!("appending to {path}"))?;
        }
        final_snap = Some(snap);
    }
    if let (Some(path), Some(snap)) = (&cost_audit, &final_snap) {
        let doc = cost_sidecar_value(snap, &lowered.hag);
        std::fs::write(path, doc.to_string() + "\n")
            .with_context(|| format!("writing {path}"))?;
        let scale = repro::obs::cost::GAUGE_SCALE;
        println!("cost audit : benchkit-v1 JSONL -> {path} \
                  (alpha {:.4} beta {:.4} ns/elem, model error \
                  {:.1}%, {} samples)",
                 snap.gauge("cost.alpha") as f64 / scale,
                 snap.gauge("cost.beta") as f64 / scale,
                 100.0 * snap.gauge("cost.model_error") as f64 / scale,
                 snap.gauge("cost.samples"));
    }
    let stats = server.shutdown();
    if let Some(ns) = net_stats {
        println!("wire       : {} conns accepted, {} shed, {} drained, \
                  {} protocol errors",
                 ns.accepted, ns.shed, ns.drained, ns.protocol_errors);
    }
    println!("requests   : {} ok, {} rejected, {} failed",
             stats.requests, stats.rejected, stats.failed);
    println!("batches    : {} (mean size {:.1}, {} exec failures)",
             stats.batches, stats.mean_batch, stats.exec_failures);
    println!("latency    : p50 {:.2} ms  p99 {:.2} ms", stats.p50_ms,
             stats.p99_ms);
    println!("exec       : mean {:.2} ms/batch", stats.mean_exec_ms);
    println!("throughput : {:.0} req/s", stats.throughput_rps);
    if updates > 0 {
        println!("updates    : {} applied in {} coalesced flushes \
                  ({} HAG rebuilds/installs swapped)",
                 stats.updates, stats.update_batches,
                 stats.rebuild_swaps);
    }
    if plan_swap {
        println!("plan swaps : {} hot-swapped, {} skipped; session \
                  ran {} shard re-searches, {} shard cache hits",
                 stats.plan_swaps, stats.swaps_skipped,
                 stats.shard_searches, stats.shard_cache_hits);
        match stats.plan_matches_fresh {
            Some(true) => println!("replan check: OK (session plan == \
                                    from-scratch on the serving path)"),
            Some(false) => bail!("serving-path plan cache MISMATCH: \
                                  session plan != from-scratch"),
            None => {}
        }
    }
    // The final snapshot line and the shutdown stats read the same
    // registry with no traffic in between — disagreement means the
    // stats views drifted apart, so fail loudly.
    if let (Some(snap), Some(path)) = (&final_snap, &obs_snapshot) {
        let sr = snap.counter("serve.requests") as usize;
        if sr != stats.requests {
            bail!("obs snapshot disagrees with shutdown stats: \
                   serve.requests {sr} != {}", stats.requests);
        }
        let (p50, p99) = snap.hist("serve.latency")
            .map(|h| (h.p50_ns / 1.0e6, h.p99_ns / 1.0e6))
            .unwrap_or((f64::NAN, f64::NAN));
        if stats.requests > 0
            && ((p50 - stats.p50_ms).abs() > 1e-6
                || (p99 - stats.p99_ms).abs() > 1e-6)
        {
            bail!("obs snapshot disagrees with shutdown stats: \
                   p50/p99 {p50:.3}/{p99:.3} ms vs {:.3}/{:.3} ms",
                  stats.p50_ms, stats.p99_ms);
        }
        println!("obs snap   : benchkit-v1 JSONL -> {path} (final \
                  line agrees with shutdown stats)");
    }
    if let Some(path) = &trace_path {
        repro::obs::trace::write_chrome_trace(
            std::path::Path::new(path))
            .with_context(|| format!("writing trace {path}"))?;
        println!("trace      : Chrome trace_event JSON -> {path}");
    }
    Ok(())
}

/// `repro recover --wal DIR [--check --dataset NAME]`: scan a WAL
/// directory (truncating any torn tail in place, exactly as serve
/// `--recover` would), report what survives, and with `--check`
/// replay onto the dataset's base graph and hold the recovered plan
/// to the serving bar: haglint clean and identical to a from-scratch
/// plan at the same topology. Non-zero exit on any violation.
fn cmd_recover(args: &Args, scale: f64, seed: u64) -> Result<()> {
    let wal: String = args.get::<String>("wal")?
        .context("--wal DIR is required")?;
    let check = args.flag("check")?;
    let dir = std::path::PathBuf::from(&wal);
    let rec = repro::durability::recover(&dir)
        .map_err(anyhow::Error::msg)?;
    println!("wal        : {} valid deltas, tail seq {}, {} B \
              torn/stale truncated, {} stale segments removed",
             rec.deltas.len(), rec.tail_seq, rec.truncated_bytes,
             rec.removed_segments);
    match &rec.snapshot {
        Some(s) => println!("snapshot   : seq {} at epoch {} \
                             (n {}, |V_A| {})",
                            s.seq, s.epoch, s.graph.n(),
                            s.hag.agg_nodes.len()),
        None => println!("snapshot   : none (replay starts at the \
                          base graph)"),
    }
    if !check {
        return Ok(());
    }
    let name = req_dataset(args)?;
    let spec = SpecArgs::parse(args)?.spec;
    let ds = datasets::load(
        &name, repro::bench::effective_scale(&name, scale), seed);
    let mut session = Session::new(&ds, spec.clone());
    let lowered = session.lower()?;
    let mut engine = StreamEngine::from_hag(
        &ds.graph, spec.stream_config(), &lowered.hag);
    let report = repro::durability::resume_pair(
        &rec, &mut engine, &mut session, &spec.stream_config())
        .map_err(anyhow::Error::msg)?;
    println!("replayed   : {} deltas into the session, {} into the \
              engine (snapshot seq {})",
             report.session_replayed, report.engine_replayed,
             report.snapshot_seq);
    let (hag, plan) = session.plan();
    let g = session.graph();
    let lint = repro::analysis::verify(
        &repro::analysis::HagCtx::new(&g, &hag).with_plan(&plan));
    if !lint.is_clean() {
        bail!("recovered plan fails haglint:\n{}", lint.format());
    }
    let (_, fresh_plan) = session.plan_fresh();
    if *plan != fresh_plan {
        bail!("recovered plan != from-scratch plan at the same \
               topology");
    }
    println!("check      : OK — haglint clean ({} passes), plan == \
              from-scratch (n {}, e {}, |V_A| {})",
             lint.passes_run.len(), g.n(), g.e(),
             hag.agg_nodes.len());
    Ok(())
}

/// Append one line to a JSONL file, creating it if needed.
fn append_line(path: &str, line: &str) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    writeln!(f, "{line}")
}

/// One benchkit-v1 cost-audit document from a live serve snapshot:
/// wall-time buckets as entries, calibration gauges de-scaled from
/// their fixed-point encoding, measured tallies, and predicted
/// Definition-2 terms. A serve without a resident pair records no
/// plan-term gauges, so predictions fall back to the initially
/// lowered HAG — the plan the worker is in fact serving.
fn cost_sidecar_value(snap: &repro::obs::StatsSnapshot,
                      hag: &repro::hag::Hag)
                      -> repro::util::json::Value {
    let mut bj = repro::util::benchkit::BenchJson::new();
    for name in ["cost.pack", "cost.exec", "cost.repair",
                 "cost.plan"] {
        if let Some(h) = snap.hist(name) {
            bj.push_entry(name, h.count, h.p50_ns / 1e9,
                          h.mean_ns / 1e9, h.min_ns as f64 / 1e9,
                          h.max_ns as f64 / 1e9);
        }
    }
    let scale = repro::obs::cost::GAUGE_SCALE;
    bj.derived_num("cost.alpha",
                   snap.gauge("cost.alpha") as f64 / scale);
    bj.derived_num("cost.beta",
                   snap.gauge("cost.beta") as f64 / scale);
    bj.derived_num("cost.model_error",
                   snap.gauge("cost.model_error") as f64 / scale);
    bj.derived_num("cost.samples", snap.gauge("cost.samples") as f64);
    bj.derived_num("cost.calibrated",
                   snap.gauge("cost.calibrated") as f64);
    let pred_a = snap.gauge("cost.pred_aggregations");
    let (pa, pt) = if pred_a > 0 {
        (pred_a as f64, snap.gauge("cost.pred_transfers") as f64)
    } else {
        (hag.aggregations() as f64, hag.data_transfers() as f64)
    };
    bj.derived_num("cost.pred_aggregations", pa);
    bj.derived_num("cost.pred_transfers", pt);
    bj.derived_num("cost.meas_aggregations",
                   snap.counter("cost.meas_aggregations") as f64);
    bj.derived_num("cost.meas_transfers",
                   snap.counter("cost.meas_transfers") as f64);
    bj.to_value()
}

fn cmd_cost_audit(args: &Args, scale: f64, seed: u64) -> Result<()> {
    use repro::coordinator::server::cost_probe;
    let batches = args.get_or("batches", 8usize)?;
    let json_out = args.get::<String>("json")?;
    let mut names = args.get_all("datasets");
    if names.is_empty() {
        names =
            datasets::names().iter().map(|s| s.to_string()).collect();
    }
    // One model across the sweep: plans of different sizes give the
    // fit non-collinear (aggs, transfers) rows, unlike a single
    // fixed-plan serve.
    let model = Arc::new(repro::obs::CostModel::new());
    let mut probes = Vec::new();
    println!("cost-model audit — Definition-2 predicted terms vs the \
              reference executor ({batches} batches per dataset; \
              executed rows include plan padding)");
    println!("{:<8} {:>8} {:>10} {:>12} {:>12} {:>9} {:>13} {:>10}",
             "dataset", "n", "e", "pred aggs", "exec rows", "overhd",
             "pred xfers", "exec ms");
    for name in &names {
        let ds = datasets::load(
            name, repro::bench::effective_scale(name, scale), seed);
        let p = cost_probe(name, &ds.graph, ds.f_in, 64, ds.classes,
                           batches, &model);
        println!("{:<8} {:>8} {:>10} {:>12} {:>12} {:>8.2}x {:>13} \
                  {:>10.2}",
                 p.name, p.n, p.e, p.pred_aggregations,
                 p.plan_agg_rows, p.agg_overhead(), p.pred_transfers,
                 p.exec.mean_ns / 1e6);
        probes.push(p);
    }
    match model.calibration() {
        Some(c) => println!(
            "calibration : alpha {:.4} beta {:.4} ns/elem, model \
             error {:.1}% ({} samples)",
            c.alpha, c.beta, 100.0 * c.model_error, c.samples),
        None => println!("calibration : insufficient samples ({} < \
                          {})", model.samples(),
                         repro::obs::cost::MIN_SAMPLES),
    }
    if let Some(path) = json_out {
        let mut bj = repro::util::benchkit::BenchJson::new();
        let mut sums = [0f64; 4];
        for p in &probes {
            bj.push_entry(&format!("cost.{}", p.name), p.exec.count,
                          p.exec.p50_ns / 1e9, p.exec.mean_ns / 1e9,
                          p.exec.min_ns as f64 / 1e9,
                          p.exec.max_ns as f64 / 1e9);
            let pre = format!("cost.{}", p.name);
            bj.derived_num(&format!("{pre}.pred_aggregations"),
                           p.pred_aggregations as f64);
            bj.derived_num(&format!("{pre}.pred_transfers"),
                           p.pred_transfers as f64);
            bj.derived_num(&format!("{pre}.meas_aggregations"),
                           p.meas_aggregations as f64);
            bj.derived_num(&format!("{pre}.meas_transfers"),
                           p.meas_transfers as f64);
            bj.derived_num(&format!("{pre}.agg_overhead"),
                           p.agg_overhead());
            sums[0] += p.pred_aggregations as f64;
            sums[1] += p.pred_transfers as f64;
            sums[2] += p.meas_aggregations as f64;
            sums[3] += p.meas_transfers as f64;
        }
        bj.derived_num("cost.pred_aggregations", sums[0]);
        bj.derived_num("cost.pred_transfers", sums[1]);
        bj.derived_num("cost.meas_aggregations", sums[2]);
        bj.derived_num("cost.meas_transfers", sums[3]);
        let c = model.calibration();
        bj.derived_num("cost.alpha", c.map_or(1.0, |c| c.alpha));
        bj.derived_num("cost.beta", c.map_or(1.0, |c| c.beta));
        bj.derived_num("cost.model_error",
                       c.map_or(0.0, |c| c.model_error));
        bj.derived_num("cost.samples", model.samples() as f64);
        bj.derived_num("cost.calibrated", c.is_some() as u8 as f64);
        std::fs::write(&path, bj.to_value().to_string() + "\n")
            .with_context(|| format!("writing {path}"))?;
        println!("cost json   : benchkit-v1 -> {path}");
    }
    Ok(())
}

fn cmd_obs(args: &Args, scale: f64, seed: u64) -> Result<()> {
    // Validation modes (CI runs these on the serve smoke's exports).
    let check_snap = args.get::<String>("check-snapshot")?;
    let check_trace = args.get::<String>("check-trace")?;
    let check_cost = args.get::<String>("check-cost")?;
    let check_verify = args.get::<String>("check-verify")?;
    if check_snap.is_some() || check_trace.is_some()
        || check_cost.is_some() || check_verify.is_some()
    {
        if let Some(path) = check_snap {
            obs_check_snapshot(&path)?;
        }
        if let Some(path) = check_trace {
            obs_check_trace(&path)?;
        }
        if let Some(path) = check_cost {
            obs_check_cost(&path)?;
        }
        if let Some(path) = check_verify {
            obs_check_verify(&path)?;
        }
        return Ok(());
    }

    // Demo mode: trace + time a few searches through the global
    // registry, then print the snapshot via the shared formatter.
    let name = args.get_or::<String>("dataset", "BZR".into())?;
    let snap_out = args.get::<String>("snapshot")?;
    let trace_out = args.get::<String>("trace")?;
    let repeats = args.get_or("repeats", 3usize)?.max(1);
    repro::obs::trace::set_enabled(true);
    let ds = datasets::load(
        &name, repro::bench::effective_scale(&name, scale), seed);
    let spec = SpecArgs::parse(args)?.spec;
    let cfg = spec.search_config(ds.graph.n());
    let reg = repro::obs::MetricsRegistry::global();
    let hist = reg.histogram("obs.demo_search");
    let mut cost = 0u64;
    for _ in 0..repeats {
        let t = std::time::Instant::now();
        let (hag, _) = hag_search(&ds.graph, &cfg);
        hist.record(t.elapsed());
        reg.counter("obs.demo_runs").inc();
        cost = hag.cost_core() as u64;
    }
    reg.gauge("obs.demo_cost").set(cost as i64);
    let snap = reg.snapshot();
    println!("registry snapshot after {repeats} searches of {} \
              (n={}, e={}):", ds.name, ds.n(), ds.e());
    print!("{}", snap.format());
    let events = repro::obs::trace::collect();
    let spans = events.iter()
        .filter(|e| e.kind == repro::obs::trace::KIND_SPAN)
        .count();
    println!("trace      : {} events buffered ({} spans, {} instants)",
             events.len(), spans, events.len() - spans);
    if let Some(path) = snap_out {
        std::fs::write(&path,
                       snap.to_benchkit_value().to_string_pretty())
            .with_context(|| format!("writing {path}"))?;
        println!("obs snap   : benchkit-v1 JSON -> {path}");
    }
    if let Some(path) = trace_out {
        repro::obs::trace::write_chrome_trace(
            std::path::Path::new(&path))
            .with_context(|| format!("writing trace {path}"))?;
        println!("trace      : Chrome trace_event JSON -> {path}");
    }
    Ok(())
}

/// CI check: every JSONL line must be a benchkit-v1 document whose
/// `derived` map carries the serve counters.
fn obs_check_snapshot(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    let mut lines = 0usize;
    let mut last_requests = 0.0f64;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let doc = repro::util::json::parse(line)
            .with_context(|| format!("{path}:{}: invalid JSON", i + 1))?;
        let schema = doc.req_str("schema")
            .with_context(|| format!("{path}:{}", i + 1))?;
        if schema != "benchkit-v1" {
            bail!("{path}:{}: schema {schema:?}, want benchkit-v1",
                  i + 1);
        }
        let derived = doc.req("derived")
            .with_context(|| format!("{path}:{}", i + 1))?;
        last_requests = derived.req_f64("serve.requests")
            .with_context(|| format!("{path}:{}", i + 1))?;
        doc.req_arr("entries")
            .with_context(|| format!("{path}:{}", i + 1))?;
        lines += 1;
    }
    if lines == 0 {
        bail!("{path}: no snapshot lines");
    }
    println!("check-snapshot OK: {lines} benchkit-v1 lines, final \
              serve.requests = {last_requests}");
    Ok(())
}

/// CI check: a cost-audit export must be benchkit-v1 documents whose
/// `derived` maps carry a populated calibration (α̂/β̂ > 0, finite
/// non-negative model error) and positive predicted + measured
/// Definition-2 terms. Accepts both artifact shapes: the serve /
/// cost-audit sidecars are JSONL (one document per line), while the
/// `cost_model` bench writes one pretty-printed document — the
/// whole-file parse is tried first (the JSON parser rejects trailing
/// characters, so multi-document JSONL cannot be misread as one).
fn obs_check_cost(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    let docs: Vec<(String, repro::util::json::Value)> =
        match repro::util::json::parse(&text) {
            Ok(doc) => vec![(path.to_string(), doc)],
            Err(_) => {
                let mut v = Vec::new();
                for (i, line) in text.lines().enumerate() {
                    if line.trim().is_empty() {
                        continue;
                    }
                    let at = format!("{path}:{}", i + 1);
                    let doc = repro::util::json::parse(line)
                        .with_context(
                            || format!("{at}: invalid JSON"))?;
                    v.push((at, doc));
                }
                v
            }
        };
    let (mut alpha, mut beta, mut err) = (0.0f64, 0.0f64, 0.0f64);
    for (at, doc) in &docs {
        let ctx = || at.clone();
        let schema = doc.req_str("schema").with_context(ctx)?;
        if schema != "benchkit-v1" {
            bail!("{at}: schema {schema:?}, want benchkit-v1");
        }
        doc.req_arr("entries").with_context(ctx)?;
        let d = doc.req("derived").with_context(ctx)?;
        alpha = d.req_f64("cost.alpha").with_context(ctx)?;
        beta = d.req_f64("cost.beta").with_context(ctx)?;
        err = d.req_f64("cost.model_error").with_context(ctx)?;
        if alpha <= 0.0 || beta <= 0.0 {
            bail!("{at}: calibration not populated (alpha {alpha}, \
                   beta {beta})");
        }
        if !err.is_finite() || err < 0.0 {
            bail!("{at}: bad model error {err}");
        }
        for key in ["cost.pred_aggregations", "cost.pred_transfers",
                    "cost.meas_aggregations", "cost.meas_transfers"] {
            let v = d.req_f64(key).with_context(ctx)?;
            if v <= 0.0 {
                bail!("{at}: {key} = {v}, want > 0");
            }
        }
    }
    if docs.is_empty() {
        bail!("{path}: no cost-audit documents");
    }
    println!("check-cost OK: {} documents, alpha {alpha:.4} beta \
              {beta:.4} ns/elem, model error {:.1}%",
             docs.len(), 100.0 * err);
    Ok(())
}

/// CI check: the Chrome export must parse and contain at least one
/// completed span (`ph == \"X\"`).
fn obs_check_trace(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    let doc = repro::util::json::parse(&text)
        .with_context(|| format!("{path}: invalid JSON"))?;
    let events = doc.req_arr("traceEvents")
        .with_context(|| path.to_string())?;
    let spans = events.iter()
        .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X"))
        .count();
    let instants = events.len() - spans;
    if spans == 0 {
        bail!("{path}: no completed spans in {} events", events.len());
    }
    println!("check-trace OK: {spans} spans + {instants} instants");
    Ok(())
}

/// CI check: a `repro verify --json` export must be one `haglint-v1`
/// document that is clean — zero total errors, zero per-case errors —
/// with a non-empty pass inventory and case list.
fn obs_check_verify(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path}"))?;
    let doc = repro::util::json::parse(&text)
        .with_context(|| format!("{path}: invalid JSON"))?;
    let schema = doc.req_str("schema")
        .with_context(|| path.to_string())?;
    if schema != "haglint-v1" {
        bail!("{path}: schema {schema:?}, want haglint-v1");
    }
    if doc.get("clean").and_then(|v| v.as_bool()) != Some(true) {
        bail!("{path}: report is not clean");
    }
    let total = doc.req_f64("total_errors")
        .with_context(|| path.to_string())?;
    if total != 0.0 {
        bail!("{path}: total_errors = {total}, want 0");
    }
    let passes = doc.req_arr("passes")
        .with_context(|| path.to_string())?;
    if passes.is_empty() {
        bail!("{path}: empty pass inventory");
    }
    let cases = doc.req_arr("cases")
        .with_context(|| path.to_string())?;
    if cases.is_empty() {
        bail!("{path}: no verification cases");
    }
    for (i, c) in cases.iter().enumerate() {
        let errs = c.req_f64("errors")
            .with_context(|| format!("{path}: case {i}"))?;
        if errs != 0.0 {
            bail!("{path}: case {i} carries {errs} error(s)");
        }
        if c.req_arr("passes_run")
            .with_context(|| format!("{path}: case {i}"))?
            .is_empty()
        {
            bail!("{path}: case {i} ran no passes");
        }
    }
    println!("check-verify OK: {} case(s) clean across {} pass(es)",
             cases.len(), passes.len());
    Ok(())
}

/// `repro verify` — run haglint over the seeded corpus (`--corpus`,
/// the hard CI gate) or one dataset lowering (`--dataset`), print a
/// per-case table, optionally export the `haglint-v1` report
/// (`--json P`), and fail on any error diagnostic.
fn cmd_verify(args: &Args, scale: f64, seed: u64) -> Result<()> {
    use repro::analysis;

    if args.flag("list")? {
        println!("haglint pass inventory ({} passes):",
                 analysis::PASSES.len());
        for p in analysis::PASSES {
            println!("  {:<22} [{:<11}] {}", p.id, p.class.as_str(),
                     p.desc);
        }
        return Ok(());
    }
    let json_out = args.get::<String>("json")?;
    let cases: Vec<(String, analysis::Report)> =
        if args.flag("corpus")? {
            analysis::corpus::verify_corpus()
        } else {
            let name = req_dataset(args)?;
            let ds = datasets::load(
                &name, repro::bench::effective_scale(&name, scale),
                seed);
            let spec = SpecArgs::parse(args)?.spec;
            let capacity = spec.resolved_capacity(ds.graph.n());
            let mut sess = Session::new(&ds, spec);
            let (hag, plan) = sess.plan();
            let g = sess.graph();
            let ctx = analysis::HagCtx::new(&g, &hag)
                .with_plan(&plan)
                .with_capacity(capacity);
            vec![(format!("{}/session", ds.name),
                  analysis::verify(&ctx))]
        };

    println!("{:<28} {:>6} {:>7}", "case", "passes", "errors");
    let mut total = 0usize;
    for (name, r) in &cases {
        total += r.errors();
        println!("{:<28} {:>6} {:>7}", name, r.passes_run.len(),
                 r.errors());
        if !r.is_clean() {
            print!("{}", r.format());
        }
    }
    if let Some(path) = json_out {
        let doc = analysis::corpus_report_json(&cases);
        std::fs::write(&path, doc.to_string_pretty())
            .with_context(|| format!("writing {path}"))?;
        println!("verify json : haglint-v1 -> {path}");
    }
    if total > 0 {
        bail!("haglint: {total} error(s) across {} case(s)",
              cases.len());
    }
    println!("haglint OK: {} case(s) clean", cases.len());
    Ok(())
}

/// `repro lint-src` — source-convention lint (see
/// `analysis::srclint`). Run from `rust/` (CI) or the repo root; the
/// defaults probe both layouts.
fn cmd_lint_src(args: &Args) -> Result<()> {
    use repro::analysis::srclint;

    let root = match args.get::<String>("src-root")? {
        Some(r) => PathBuf::from(r),
        None => {
            let local = PathBuf::from("src");
            if local.join("lib.rs").is_file() {
                local
            } else {
                PathBuf::from("rust/src")
            }
        }
    };
    let allow_path = match args.get::<String>("allowlist")? {
        Some(p) => PathBuf::from(p),
        None => {
            let local = PathBuf::from("tools/srclint-allow.txt");
            if local.is_file() {
                local
            } else {
                PathBuf::from("../tools/srclint-allow.txt")
            }
        }
    };
    let allow = srclint::load_allowlist(&allow_path);
    let findings = srclint::run(&root, &allow)
        .map_err(|e| anyhow::anyhow!(e))?;
    for f in &findings {
        println!("{}", f.format());
    }
    if !findings.is_empty() {
        bail!("lint-src: {} finding(s) (allowlist: {})",
              findings.len(), allow_path.display());
    }
    println!("lint-src OK: {} clean ({} allowlist entries)",
             root.display(), allow.len());
    Ok(())
}
