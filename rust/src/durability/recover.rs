//! Startup recovery: newest valid snapshot + torn-tail truncation +
//! delta replay.
//!
//! The invariant recovery restores is exactly the acknowledgement
//! contract: every delta whose WAL commit succeeded (and was
//! therefore acked to a client) survives; everything after the last
//! valid record is physically truncated away so a half-written batch
//! can never be half-replayed. Recovery NEVER panics on corrupt
//! input — a torn tail, a bit-flipped record, garbage appended by a
//! crashed writer, or a damaged snapshot all degrade gracefully
//! (the torn-WAL property test in `tests/durability.rs` drives a
//! truncation at every byte offset to prove it).

use std::path::Path;

use crate::incremental::{GraphDelta, StreamConfig, StreamEngine};
use crate::session::Session;

use super::{snapshot, wal};

/// Everything recovery learned from a WAL directory.
#[derive(Debug)]
pub struct Recovered {
    /// Newest valid snapshot, if any.
    pub snapshot: Option<snapshot::Snapshot>,
    /// Every valid delta, in sequence order, across all segments
    /// (including those already folded into the snapshot — the
    /// resident session replays from the base graph).
    pub deltas: Vec<(u64, GraphDelta)>,
    /// Bytes physically truncated off the torn tail (plus the byte
    /// count of any whole later segments that were removed).
    pub truncated_bytes: u64,
    /// Number of whole segments removed after the torn one.
    pub removed_segments: usize,
    /// Highest valid sequence number (0 if the log is empty).
    pub tail_seq: u64,
}

/// What [`resume_pair`] replayed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplayReport {
    /// Deltas replayed into the engine (suffix after the snapshot).
    pub engine_replayed: usize,
    /// Deltas replayed into the session (full history).
    pub session_replayed: usize,
    /// Snapshot sequence adopted by the engine (0 = cold start).
    pub snapshot_seq: u64,
    /// Sequence the WAL should resume from (`tail_seq + 1`).
    pub resume_seq: u64,
}

/// Scan a WAL directory: load the newest valid snapshot, collect the
/// longest valid record prefix across segments, truncate the torn
/// tail in place, and delete any segments after the torn one.
/// Returns `Err` only for environmental failures (directory
/// unreadable, truncation refused) — corruption itself is never an
/// error.
pub fn recover(dir: &Path) -> Result<Recovered, String> {
    if !dir.exists() {
        return Ok(Recovered {
            snapshot: None,
            deltas: Vec::new(),
            truncated_bytes: 0,
            removed_segments: 0,
            tail_seq: 0,
        });
    }
    let snap = snapshot::load_latest(dir);
    let segs = wal::list_segments(dir)
        .map_err(|e| format!("wal dir {}: {e}", dir.display()))?;

    let mut deltas: Vec<(u64, GraphDelta)> = Vec::new();
    let mut truncated_bytes = 0u64;
    let mut removed_segments = 0usize;
    let mut last_seq = 0u64;
    let mut torn_at: Option<usize> = None;

    for (i, (_, path)) in segs.iter().enumerate() {
        let (records, mut valid_len) = wal::read_segment(path);
        // Enforce strictly increasing sequence numbers across the
        // whole log. Holes are legal (a failed group commit burns
        // its sequence numbers); regressions mean a stale or foreign
        // segment — cut the valid prefix there.
        let mut keep = records.len();
        for (j, &(seq, _)) in records.iter().enumerate() {
            if seq <= last_seq {
                keep = j;
                break;
            }
            last_seq = seq;
        }
        if keep < records.len() {
            valid_len = wal::MAGIC.len() as u64
                + records[..keep]
                    .iter()
                    .map(|&(s, d)| {
                        8 + wal::encode_payload(s, d).len() as u64
                    })
                    .sum::<u64>();
        }
        deltas.extend(records.into_iter().take(keep));

        let file_len = std::fs::metadata(path)
            .map(|m| m.len())
            .unwrap_or(valid_len);
        if valid_len < file_len || (keep == 0 && valid_len == 0) {
            // Torn (or wholly invalid) segment: truncate to the
            // valid prefix and drop everything after it.
            truncated_bytes += file_len.saturating_sub(valid_len);
            truncate_to(path, valid_len)?;
            torn_at = Some(i);
            break;
        }
    }

    if let Some(i) = torn_at {
        for (_, path) in &segs[i + 1..] {
            let len = std::fs::metadata(path)
                .map(|m| m.len())
                .unwrap_or(0);
            std::fs::remove_file(path).map_err(|e| {
                format!("removing stale segment {}: {e}",
                        path.display())
            })?;
            truncated_bytes += len;
            removed_segments += 1;
        }
    }

    if truncated_bytes > 0 {
        crate::obs_warn!("[recover] truncated {truncated_bytes}B of \
                          torn/stale WAL ({removed_segments} whole \
                          segments removed)");
    }
    crate::obs_event!("durability.recover", deltas.len() as u64,
                      truncated_bytes);
    Ok(Recovered {
        snapshot: snap,
        deltas,
        truncated_bytes,
        removed_segments,
        tail_seq: last_seq,
    })
}

fn truncate_to(path: &Path, len: u64) -> Result<(), String> {
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(path)
        .map_err(|e| format!("open {} for truncate: {e}",
                             path.display()))?;
    f.set_len(len)
        .map_err(|e| format!("truncate {}: {e}", path.display()))?;
    f.sync_data()
        .map_err(|e| format!("fsync {}: {e}", path.display()))
}

/// Rebuild a resident engine/session pair from a recovery result.
///
/// The session replays the FULL delta history onto its existing base
/// graph (cheap bookkeeping — its search is lazy, run at the next
/// `plan()`), while the engine either adopts the snapshot HAG via
/// [`StreamEngine::from_hag`] (no cold search) and replays only the
/// suffix `seq > snapshot.seq`, or replays everything when no
/// snapshot exists. Afterward the two graphs must be identical —
/// divergence means the WAL and the base dataset disagree and is
/// returned as an error, never papered over.
pub fn resume_pair(
    rec: &Recovered,
    engine: &mut StreamEngine,
    session: &mut Session,
    cfg: &StreamConfig,
) -> Result<ReplayReport, String> {
    let snap_seq = match &rec.snapshot {
        Some(s) => {
            if s.seq > rec.tail_seq && !rec.deltas.is_empty() {
                return Err(format!(
                    "snapshot seq {} beyond WAL tail {}",
                    s.seq, rec.tail_seq));
            }
            *engine = StreamEngine::from_hag(
                &s.graph, cfg.clone(), &s.hag);
            s.seq
        }
        None => 0,
    };

    let mut engine_replayed = 0usize;
    let mut session_replayed = 0usize;
    for &(seq, delta) in &rec.deltas {
        if seq > snap_seq {
            engine.apply(delta);
            engine_replayed += 1;
        }
        session.apply(delta);
        session_replayed += 1;
    }

    if engine.graph() != session.graph() {
        return Err(format!(
            "recovered engine graph (n={}, e={}) != session graph \
             (n={}, e={})",
            engine.n(), engine.e(), session.n(), session.e()));
    }
    Ok(ReplayReport {
        engine_replayed,
        session_replayed,
        snapshot_seq: snap_seq,
        resume_seq: rec.tail_seq + 1,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::hag::AggregateKind;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(
            format!("repro-recover-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn base_graph() -> Graph {
        Graph::from_edges(
            6,
            &[(1, 0), (2, 0), (3, 0), (0, 1), (2, 1), (0, 2), (1, 2),
              (4, 2), (1, 3), (2, 3), (2, 4), (3, 4), (4, 5)],
        )
    }

    #[test]
    fn empty_dir_recovers_to_nothing() {
        let d = tmpdir("empty");
        let rec = recover(&d).unwrap();
        assert!(rec.snapshot.is_none());
        assert!(rec.deltas.is_empty());
        assert_eq!(rec.tail_seq, 0);
        // And a directory that does not exist at all:
        let rec = recover(&d.join("missing")).unwrap();
        assert_eq!(rec.tail_seq, 0);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn full_replay_without_snapshot() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("replay");
        let g = base_graph();
        let deltas = [
            GraphDelta::EdgeInsert { src: 5, dst: 0 },
            GraphDelta::EdgeDelete { src: 2, dst: 0 },
            GraphDelta::NodeAdd,
            GraphDelta::EdgeInsert { src: 6, dst: 1 },
        ];
        let mut w = wal::Wal::open(&d, 1).unwrap();
        for &dl in &deltas {
            w.append(dl).unwrap();
        }
        w.commit().unwrap();
        drop(w);

        let rec = recover(&d).unwrap();
        assert_eq!(rec.deltas.len(), 4);
        assert_eq!(rec.tail_seq, 4);
        assert_eq!(rec.truncated_bytes, 0);

        let cfg = StreamConfig::default();
        let mut engine = StreamEngine::new(&g, cfg.clone());
        let mut session = Session::from_graph(
            &g, crate::session::LowerSpec::default());
        let rep =
            resume_pair(&rec, &mut engine, &mut session, &cfg)
                .unwrap();
        assert_eq!(rep.engine_replayed, 4);
        assert_eq!(rep.session_replayed, 4);
        assert_eq!(rep.resume_seq, 5);
        assert_eq!(engine.n(), 7);
        crate::hag::check_equivalence(
            &engine.graph(), &engine.to_hag()).unwrap();
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn snapshot_short_circuits_engine_replay() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("snap");
        let g = base_graph();
        let cfg = StreamConfig::default();
        let mut live = StreamEngine::new(&g, cfg.clone());
        let mut w = wal::Wal::open(&d, 1).unwrap();
        let script = [
            GraphDelta::EdgeInsert { src: 5, dst: 0 },
            GraphDelta::EdgeInsert { src: 3, dst: 5 },
            GraphDelta::EdgeDelete { src: 1, dst: 0 },
            GraphDelta::EdgeInsert { src: 0, dst: 5 },
        ];
        // First two deltas, then a snapshot at seq 2.
        for &dl in &script[..2] {
            let seq = w.append(dl).unwrap();
            w.commit().unwrap();
            live.apply(dl);
            if seq == 2 {
                snapshot::write(&d, &snapshot::Snapshot {
                    seq,
                    epoch: 1,
                    graph: live.graph(),
                    hag: live.to_hag(),
                }).unwrap();
            }
        }
        for &dl in &script[2..] {
            w.append(dl).unwrap();
            w.commit().unwrap();
            live.apply(dl);
        }
        drop(w);

        let rec = recover(&d).unwrap();
        assert_eq!(rec.snapshot.as_ref().map(|s| s.seq), Some(2));
        assert_eq!(rec.deltas.len(), 4);

        let mut engine = StreamEngine::new(&g, cfg.clone());
        let mut session = Session::from_graph(
            &g, crate::session::LowerSpec::default());
        let rep =
            resume_pair(&rec, &mut engine, &mut session, &cfg)
                .unwrap();
        assert_eq!(rep.snapshot_seq, 2);
        assert_eq!(rep.engine_replayed, 2, "suffix only");
        assert_eq!(rep.session_replayed, 4, "full history");
        assert_eq!(engine.graph(), live.graph());
        crate::hag::check_equivalence(
            &engine.graph(), &engine.to_hag()).unwrap();
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn torn_tail_is_truncated_and_stale_segments_removed() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("torn");
        let mut w = wal::Wal::open(&d, 1).unwrap();
        w.set_segment_bytes(64);
        for i in 0..10u32 {
            w.append(GraphDelta::EdgeInsert { src: i, dst: i + 1 })
                .unwrap();
            w.commit().unwrap();
        }
        drop(w);
        let segs = wal::list_segments(&d).unwrap();
        assert!(segs.len() >= 3, "need several segments");
        // Corrupt the middle segment's first record CRC.
        let victim = &segs[1].1;
        let mut bytes = std::fs::read(victim).unwrap();
        let crc_off = wal::MAGIC.len() + 4;
        bytes[crc_off] ^= 0xFF;
        std::fs::write(victim, &bytes).unwrap();

        let rec = recover(&d).unwrap();
        // Everything from the corrupt record onward is gone.
        let (first_valid, _) = wal::read_segment(&segs[0].1);
        assert_eq!(rec.deltas.len(), first_valid.len());
        assert!(rec.truncated_bytes > 0);
        assert_eq!(rec.removed_segments, segs.len() - 2);
        // The victim was truncated to just its magic.
        assert_eq!(std::fs::metadata(victim).unwrap().len(),
                   wal::MAGIC.len() as u64);
        // Later segments are gone from disk.
        assert_eq!(wal::list_segments(&d).unwrap().len(), 2);
        // Recovery is idempotent: a second pass finds nothing new
        // to cut.
        let rec2 = recover(&d).unwrap();
        assert_eq!(rec2.truncated_bytes, 0);
        assert_eq!(rec2.deltas.len(), rec.deltas.len());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn seq_regression_cuts_prefix() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("regress");
        let mut w = wal::Wal::open(&d, 5).unwrap();
        w.append(GraphDelta::NodeAdd).unwrap();
        w.commit().unwrap();
        drop(w);
        // Hand-craft a record with a regressed seq and append it.
        let payload = wal::encode_payload(3, GraphDelta::NodeAdd);
        let seg = wal::list_segments(&d).unwrap().remove(0).1;
        let mut bytes = std::fs::read(&seg).unwrap();
        bytes.extend_from_slice(
            &(payload.len() as u32).to_le_bytes());
        bytes.extend_from_slice(
            &wal::crc32(&payload).to_le_bytes());
        bytes.extend_from_slice(&payload);
        std::fs::write(&seg, &bytes).unwrap();

        let rec = recover(&d).unwrap();
        assert_eq!(rec.deltas.len(), 1);
        assert_eq!(rec.tail_seq, 5);
        assert!(rec.truncated_bytes > 0, "regressed record cut");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn recovered_engine_matches_fresh_search_equivalence() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("equiv");
        let g = base_graph();
        let cfg = StreamConfig::default();
        let mut live = StreamEngine::new(&g, cfg.clone());
        let mut rng = crate::util::Rng::seed_from_u64(11);
        let mut w = wal::Wal::open(&d, 1).unwrap();
        for _ in 0..32 {
            let dl = crate::incremental::random_delta(
                &mut rng, live.overlay(), 0.7, 0.1);
            w.append(dl).unwrap();
            w.commit().unwrap();
            live.apply(dl);
        }
        drop(w);
        let rec = recover(&d).unwrap();
        let mut engine = StreamEngine::new(&g, cfg.clone());
        let mut session = Session::from_graph(
            &g, crate::session::LowerSpec::default());
        resume_pair(&rec, &mut engine, &mut session, &cfg).unwrap();
        assert_eq!(engine.graph(), live.graph());
        let hag = engine.to_hag();
        hag.validate().unwrap();
        crate::hag::check_equivalence(&engine.graph(), &hag)
            .unwrap();
        // Theorem-1 oracle on the session's plan path too.
        let (shag, _plan) = session.plan();
        shag.validate().unwrap();
        std::fs::remove_dir_all(&d).ok();
    }
}
