//! Periodic graph + HAG snapshots.
//!
//! A snapshot bounds replay work at recovery: instead of replaying
//! the whole WAL from an empty base, recovery loads the newest valid
//! snapshot and replays only the delta suffix with `seq >
//! snapshot.seq`. Snapshots are cut at plan-epoch boundaries (right
//! after a hot swap lands), so the saved HAG is exactly the engine's
//! maintained HAG at a served epoch — recovery can adopt it via
//! `StreamEngine::from_hag` without a cold search.
//!
//! Format: one JSON document (`schema: repro-snap-v1`) written with
//! [`crate::util::atomic_write`], named `snap-<seq:020>.json` in the
//! WAL directory. The newest [`KEEP`] snapshots are retained; older
//! ones are best-effort deleted. Snapshots are *best effort*:
//! every bit of state is reconstructible from the WAL alone, so a
//! failed snapshot write degrades recovery time, never correctness
//! (conformance e19 proves this with an always-on snapshot fault).

use std::path::{Path, PathBuf};

use crate::graph::Graph;
use crate::hag::{AggNode, AggregateKind, Hag};
use crate::util::json::{self, Value};

/// Retained snapshot generations.
pub const KEEP: usize = 4;

/// Schema tag inside every snapshot document.
pub const SCHEMA: &str = "repro-snap-v1";

/// A materialized snapshot: everything needed to rebuild the resident
/// engine/session pair without replaying history before `seq`.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Highest WAL sequence number folded into this state.
    pub seq: u64,
    /// Serving epoch at the time of the cut (informational).
    pub epoch: u64,
    pub graph: Graph,
    pub hag: Hag,
}

/// Snapshot file name for a WAL sequence number.
pub fn snapshot_name(seq: u64) -> String {
    format!("snap-{seq:020}.json")
}

/// Parse a snapshot file name back to its sequence number.
pub fn parse_snapshot_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("snap-")?.strip_suffix(".json")?;
    if digits.len() != 20 || !digits.bytes().all(|b| b.is_ascii_digit())
    {
        return None;
    }
    digits.parse().ok()
}

fn kind_str(k: AggregateKind) -> &'static str {
    match k {
        AggregateKind::Set => "set",
        AggregateKind::Sequential => "seq",
    }
}

fn kind_from_str(s: &str) -> Result<AggregateKind, String> {
    match s {
        "set" => Ok(AggregateKind::Set),
        "seq" => Ok(AggregateKind::Sequential),
        other => Err(format!("unknown hag kind {other:?}")),
    }
}

/// Serialize a snapshot to its JSON document.
pub fn to_json(s: &Snapshot) -> Value {
    let mut edges = Vec::with_capacity(s.graph.e());
    for (v, ns) in s.graph.iter() {
        for &u in ns {
            edges.push(json::arr(vec![
                json::num(u as f64),
                json::num(v as f64),
            ]));
        }
    }
    let aggs = s
        .hag
        .agg_nodes
        .iter()
        .map(|a| json::arr(vec![
            json::num(a.left as f64),
            json::num(a.right as f64),
        ]))
        .collect();
    let in_edges = s
        .hag
        .in_edges
        .iter()
        .map(|l| json::arr(
            l.iter().map(|&x| json::num(x as f64)).collect()))
        .collect();
    json::obj(vec![
        ("schema", json::str_(SCHEMA)),
        ("seq", json::num(s.seq as f64)),
        ("epoch", json::num(s.epoch as f64)),
        ("graph", json::obj(vec![
            ("n", json::num(s.graph.n() as f64)),
            ("edges", json::arr(edges)),
        ])),
        ("hag", json::obj(vec![
            ("n", json::num(s.hag.n as f64)),
            ("kind", json::str_(kind_str(s.hag.kind))),
            ("aggs", json::arr(aggs)),
            ("in_edges", json::arr(in_edges)),
        ])),
    ])
}

/// Parse and structurally validate a snapshot document. The returned
/// HAG has passed [`Hag::validate`]; the Theorem-1 equivalence check
/// against the graph is the caller's job (recovery runs it under the
/// verify gate).
pub fn from_json(doc: &Value) -> Result<Snapshot, String> {
    let schema = doc.req_str("schema")?;
    if schema != SCHEMA {
        return Err(format!("snapshot schema {schema:?}, \
                            want {SCHEMA:?}"));
    }
    let seq = doc.req_f64("seq")? as u64;
    let epoch = doc.req_f64("epoch")? as u64;

    let gv = doc.req("graph")?;
    let n = gv.req_usize("n")?;
    let mut edges = Vec::new();
    for e in gv.req_arr("edges")? {
        let pair = e.as_arr().ok_or("graph edge is not an array")?;
        if pair.len() != 2 {
            return Err("graph edge arity != 2".into());
        }
        let u = pair[0].as_usize().ok_or("bad edge src")?;
        let v = pair[1].as_usize().ok_or("bad edge dst")?;
        if u >= n || v >= n {
            return Err(format!("edge ({u},{v}) out of range n={n}"));
        }
        edges.push((u as u32, v as u32));
    }
    let graph = Graph::from_edges(n, &edges);
    if graph.e() != edges.len() {
        return Err("snapshot edge list has duplicates".into());
    }

    let hv = doc.req("hag")?;
    let hn = hv.req_usize("n")?;
    if hn != n {
        return Err(format!("hag n={hn} != graph n={n}"));
    }
    let kind = kind_from_str(hv.req_str("kind")?)?;
    let mut agg_nodes = Vec::new();
    for a in hv.req_arr("aggs")? {
        let pair = a.as_arr().ok_or("agg node is not an array")?;
        if pair.len() != 2 {
            return Err("agg node arity != 2".into());
        }
        let l = pair[0].as_usize().ok_or("bad agg left")?;
        let r = pair[1].as_usize().ok_or("bad agg right")?;
        agg_nodes.push(AggNode { left: l as u32, right: r as u32 });
    }
    let mut in_edges = Vec::new();
    for l in hv.req_arr("in_edges")? {
        let slots = l.as_arr().ok_or("in_edges row is not an array")?;
        let mut row = Vec::with_capacity(slots.len());
        for s in slots {
            row.push(s.as_usize().ok_or("bad in-edge slot")? as u32);
        }
        in_edges.push(row);
    }
    if in_edges.len() != n {
        return Err(format!("hag in_edges rows {} != n={n}",
                           in_edges.len()));
    }
    let hag = Hag { n, agg_nodes, in_edges, kind };
    hag.validate()
        .map_err(|e| format!("snapshot hag invalid: {e}"))?;
    Ok(Snapshot { seq, epoch, graph, hag })
}

/// Write a snapshot atomically into `dir` and rotate old generations
/// down to [`KEEP`].
pub fn write(dir: &Path, s: &Snapshot) -> std::io::Result<PathBuf> {
    crate::fault::point("snapshot.write")?;
    let path = dir.join(snapshot_name(s.seq));
    crate::util::atomic_write(
        &path, to_json(s).to_string().as_bytes())?;
    crate::obs_event!("durability.snapshot", s.seq);
    // Rotation is best effort — a stale extra snapshot is harmless.
    if let Ok(mut snaps) = list(dir) {
        while snaps.len() > KEEP {
            let (_, old) = snaps.remove(0);
            let _ = std::fs::remove_file(old);
        }
    }
    Ok(path)
}

/// List snapshot files sorted by sequence (oldest first).
pub fn list(dir: &Path) -> std::io::Result<Vec<(u64, PathBuf)>> {
    let mut snaps = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(seq) = parse_snapshot_name(name) {
            snaps.push((seq, entry.path()));
        }
    }
    snaps.sort_unstable_by_key(|&(s, _)| s);
    Ok(snaps)
}

/// Load the newest snapshot that parses and validates, skipping (and
/// reporting) corrupt ones — a torn or damaged snapshot must degrade
/// to the next older generation, never abort recovery.
pub fn load_latest(dir: &Path) -> Option<Snapshot> {
    let snaps = list(dir).ok()?;
    for (seq, path) in snaps.iter().rev() {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                crate::obs_warn!("[snapshot] unreadable {}: {e}",
                                 path.display());
                continue;
            }
        };
        let parsed = json::parse(&text)
            .map_err(|e| e.to_string())
            .and_then(|doc| from_json(&doc));
        match parsed {
            Ok(s) => {
                debug_assert_eq!(s.seq, *seq);
                return Some(s);
            }
            Err(e) => {
                crate::obs_warn!("[snapshot] invalid {}: {e}",
                                 path.display());
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hag::SearchConfig;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("repro-snap-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample() -> Snapshot {
        let g = Graph::from_edges(
            5,
            &[(1, 0), (2, 0), (3, 0), (0, 1), (2, 1), (0, 2), (1, 2),
              (4, 2), (1, 3), (2, 3), (2, 4), (3, 4)],
        );
        let (hag, _) = crate::hag::hag_search(
            &g, &SearchConfig::paper_default(g.n()));
        Snapshot { seq: 42, epoch: 3, graph: g, hag }
    }

    #[test]
    fn json_round_trip_is_identity() {
        let s = sample();
        let doc = to_json(&s);
        let text = doc.to_string();
        let back = from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.seq, 42);
        assert_eq!(back.epoch, 3);
        assert_eq!(back.graph, s.graph);
        assert_eq!(back.hag, s.hag);
        crate::hag::check_equivalence(&s.graph, &back.hag).unwrap();
    }

    #[test]
    fn write_load_and_rotate() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("rot");
        let mut s = sample();
        for seq in 1..=(KEEP as u64 + 3) {
            s.seq = seq;
            write(&d, &s).unwrap();
        }
        let snaps = list(&d).unwrap();
        assert_eq!(snaps.len(), KEEP, "rotated down to KEEP");
        assert_eq!(snaps.last().unwrap().0, KEEP as u64 + 3);
        let latest = load_latest(&d).unwrap();
        assert_eq!(latest.seq, KEEP as u64 + 3);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn corrupt_latest_falls_back_to_older() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("corrupt");
        let mut s = sample();
        s.seq = 1;
        write(&d, &s).unwrap();
        s.seq = 2;
        let newest = write(&d, &s).unwrap();
        // Tear the newest snapshot mid-document.
        let text = std::fs::read_to_string(&newest).unwrap();
        std::fs::write(&newest, &text[..text.len() / 2]).unwrap();
        let latest = load_latest(&d).unwrap();
        assert_eq!(latest.seq, 1, "fell back past the torn file");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn invalid_hag_is_rejected() {
        let s = sample();
        let mut doc = to_json(&s);
        // Point an in-edge at a nonexistent slot.
        if let Value::Obj(ref mut kv) = doc {
            for (k, v) in kv.iter_mut() {
                if k == "hag" {
                    if let Value::Obj(ref mut hkv) = v {
                        for (hk, hv) in hkv.iter_mut() {
                            if hk == "in_edges" {
                                if let Value::Arr(rows) = hv {
                                    if let Some(Value::Arr(r0)) =
                                        rows.first_mut()
                                    {
                                        r0.push(json::num(9999.0));
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        let err = from_json(&doc).unwrap_err();
        assert!(err.contains("invalid"), "{err}");
    }

    #[test]
    fn snapshot_fault_point_surfaces() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("fault");
        crate::fault::arm("snapshot.write",
                          crate::fault::Trigger::Always,
                          crate::fault::FaultAction::Error, 0);
        assert!(write(&d, &sample()).is_err());
        crate::fault::reset();
        assert!(load_latest(&d).is_none(), "nothing was written");
        std::fs::remove_dir_all(&d).ok();
    }
}
