//! Append-only delta write-ahead log.
//!
//! Layout (DESIGN.md §14): a WAL directory holds segment files named
//! `wal-<start_seq:020>.log`. Each segment starts with the 8-byte
//! magic `RPWAL01\n`, followed by records:
//!
//! ```text
//! [len: u32 LE] [crc32: u32 LE] [payload: len bytes]
//! payload := [seq: u64 LE] [tag: u8] ([src: u32 LE] [dst: u32 LE])?
//! ```
//!
//! Tags: 1 = EdgeInsert, 2 = EdgeDelete (payload 17 bytes),
//! 3 = NodeAdd (payload 9 bytes). The CRC (IEEE 802.3, reflected)
//! covers the payload only; `len` is validated against
//! [`MAX_RECORD_LEN`] before any allocation so a corrupt length can
//! never balloon a read.
//!
//! Durability contract: [`Wal::append`] stages a record in memory and
//! assigns its sequence number; [`Wal::commit`] writes all staged
//! records and fsyncs once (group commit). Only after `commit`
//! returns `Ok` may the caller acknowledge the deltas. If the fsync
//! fails (retried once — transient EINTR-class failures are real),
//! the file is truncated back to the last durable length and the
//! staged deltas are reported lost via the error; the WAL remains
//! valid at its previous commit point.
//!
//! Segments rotate at commit boundaries once the live segment exceeds
//! the configured byte budget, so a torn tail can only ever afflict
//! the newest segment. Old segments are never deleted here — recovery
//! may need the full suffix since the latest snapshot; GC of segments
//! older than the oldest retained snapshot is a noted follow-up
//! (ROADMAP).

use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::incremental::GraphDelta;

/// Segment magic: 8 bytes, versioned.
pub const MAGIC: &[u8; 8] = b"RPWAL01\n";

/// Upper bound on a record payload; anything larger is corruption by
/// definition (our largest payload is 17 bytes, but leave headroom
/// for future record kinds).
pub const MAX_RECORD_LEN: u32 = 4096;

/// Default segment rotation threshold (~1 MiB ≈ 40k delta records).
pub const DEFAULT_SEGMENT_BYTES: u64 = 1 << 20;

const TAG_EDGE_INSERT: u8 = 1;
const TAG_EDGE_DELETE: u8 = 2;
const TAG_NODE_ADD: u8 = 3;

/// Table-driven CRC32 (IEEE, reflected) — the std library has no
/// checksum, and this must match across versions forever.
fn crc32_table() -> &'static [u32; 256] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 {
                    0xEDB8_8320 ^ (c >> 1)
                } else {
                    c >> 1
                };
            }
            *e = c;
        }
        t
    })
}

/// CRC32 (IEEE 802.3) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let t = crc32_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Encode one delta record payload (seq + tag + operands).
pub fn encode_payload(seq: u64, delta: GraphDelta) -> Vec<u8> {
    let mut p = Vec::with_capacity(17);
    p.extend_from_slice(&seq.to_le_bytes());
    match delta {
        GraphDelta::EdgeInsert { src, dst } => {
            p.push(TAG_EDGE_INSERT);
            p.extend_from_slice(&src.to_le_bytes());
            p.extend_from_slice(&dst.to_le_bytes());
        }
        GraphDelta::EdgeDelete { src, dst } => {
            p.push(TAG_EDGE_DELETE);
            p.extend_from_slice(&src.to_le_bytes());
            p.extend_from_slice(&dst.to_le_bytes());
        }
        GraphDelta::NodeAdd => p.push(TAG_NODE_ADD),
    }
    p
}

/// Decode one record payload. `None` on any structural violation —
/// recovery treats that the same as a CRC mismatch (end of valid
/// prefix).
pub fn decode_payload(p: &[u8]) -> Option<(u64, GraphDelta)> {
    if p.len() < 9 {
        return None;
    }
    let seq = u64::from_le_bytes(p[0..8].try_into().ok()?);
    let tag = p[8];
    let delta = match tag {
        TAG_EDGE_INSERT | TAG_EDGE_DELETE => {
            if p.len() != 17 {
                return None;
            }
            let src = u32::from_le_bytes(p[9..13].try_into().ok()?);
            let dst = u32::from_le_bytes(p[13..17].try_into().ok()?);
            if tag == TAG_EDGE_INSERT {
                GraphDelta::EdgeInsert { src, dst }
            } else {
                GraphDelta::EdgeDelete { src, dst }
            }
        }
        TAG_NODE_ADD => {
            if p.len() != 9 {
                return None;
            }
            GraphDelta::NodeAdd
        }
        _ => return None,
    };
    Some((seq, delta))
}

/// Segment file name for a starting sequence number.
pub fn segment_name(start_seq: u64) -> String {
    format!("wal-{start_seq:020}.log")
}

/// Parse a segment file name back to its starting sequence number.
pub fn parse_segment_name(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("wal-")?.strip_suffix(".log")?;
    if digits.len() != 20 || !digits.bytes().all(|b| b.is_ascii_digit())
    {
        return None;
    }
    digits.parse().ok()
}

/// List a WAL directory's segments sorted by starting sequence.
pub fn list_segments(dir: &Path) -> io::Result<Vec<(u64, PathBuf)>> {
    let mut segs = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(start) = parse_segment_name(name) {
            segs.push((start, entry.path()));
        }
    }
    segs.sort_unstable_by_key(|&(s, _)| s);
    Ok(segs)
}

/// Open, writable WAL. One writer per directory; concurrent writers
/// are a deployment error this layer does not arbitrate.
pub struct Wal {
    dir: PathBuf,
    file: File,
    /// Path of the live (newest) segment.
    seg_path: PathBuf,
    /// Bytes of the live segment known durable (committed).
    committed_len: u64,
    /// Staged-but-uncommitted record bytes.
    buf: Vec<u8>,
    /// Sequence numbers staged in `buf`, for error reporting.
    staged: Vec<u64>,
    /// Next sequence number to assign.
    next_seq: u64,
    /// Rotation threshold for the live segment.
    segment_bytes: u64,
    appended: crate::obs::metrics::Counter,
    commits: crate::obs::metrics::Counter,
    fsync_retries: crate::obs::metrics::Counter,
}

impl Wal {
    /// Open a WAL for appending, creating the directory if absent.
    /// `next_seq` is where sequence numbering resumes — after
    /// recovery, pass `recovered_tail_seq + 1` (or 1 for a fresh
    /// log). A new segment is always started: recovery has already
    /// truncated the old tail, and starting fresh means an append
    /// can never collide with a half-trusted tail.
    pub fn open(dir: &Path, next_seq: u64) -> io::Result<Wal> {
        std::fs::create_dir_all(dir)?;
        let seg_path = dir.join(segment_name(next_seq));
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&seg_path)?;
        let len = file.seek(SeekFrom::End(0))?;
        let committed_len = if len == 0 {
            file.write_all(MAGIC)?;
            file.sync_data()?;
            MAGIC.len() as u64
        } else {
            // Re-opening the exact segment we would create (crash
            // between recovery-truncate and first commit): trust the
            // truncated length.
            len
        };
        let reg = crate::obs::metrics::MetricsRegistry::global();
        Ok(Wal {
            dir: dir.to_path_buf(),
            file,
            seg_path,
            committed_len,
            buf: Vec::new(),
            staged: Vec::new(),
            next_seq,
            segment_bytes: DEFAULT_SEGMENT_BYTES,
            appended: reg.counter("wal.appended"),
            commits: reg.counter("wal.commits"),
            fsync_retries: reg.counter("wal.fsync_retries"),
        })
    }

    /// Override the segment rotation threshold (tests use tiny
    /// segments to exercise rotation cheaply).
    pub fn set_segment_bytes(&mut self, bytes: u64) {
        self.segment_bytes = bytes.max(MAGIC.len() as u64 + 32);
    }

    /// WAL directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Next sequence number [`append`](Wal::append) will assign.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Count of staged (appended, not yet committed) records.
    pub fn staged_len(&self) -> usize {
        self.staged.len()
    }

    /// Stage one delta; returns its assigned sequence number. The
    /// record is NOT durable until [`commit`](Wal::commit) returns
    /// `Ok`.
    pub fn append(&mut self, delta: GraphDelta) -> io::Result<u64> {
        crate::fault::point("wal.append")?;
        let seq = self.next_seq;
        let payload = encode_payload(seq, delta);
        self.buf
            .extend_from_slice(&(payload.len() as u32).to_le_bytes());
        self.buf.extend_from_slice(&crc32(&payload).to_le_bytes());
        self.buf.extend_from_slice(&payload);
        self.staged.push(seq);
        self.next_seq = seq + 1;
        self.appended.inc();
        Ok(seq)
    }

    /// Group-commit every staged record: one write, one fsync. On
    /// `Ok`, all staged sequence numbers are durable and the caller
    /// may acknowledge them. On `Err`, NONE are durable — the live
    /// segment is rolled back to its previous committed length and
    /// the staged batch is dropped (the caller must nack).
    pub fn commit(&mut self) -> io::Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let result = self.commit_inner();
        if result.is_err() {
            // Roll back to the last durable point: a half-written
            // batch must not be replayable after restart.
            let _ = self.file.set_len(self.committed_len);
            let _ = self.file.seek(SeekFrom::End(0));
            self.buf.clear();
            self.staged.clear();
        }
        result
    }

    fn commit_inner(&mut self) -> io::Result<()> {
        self.file.write_all(&self.buf)?;
        crate::fault::point("wal.fsync")?;
        if let Err(first) = self.file.sync_data() {
            // One retry: transient sync failures (EINTR-class) are
            // worth a second attempt before declaring data loss.
            self.fsync_retries.inc();
            crate::obs_warn!("[wal] fsync failed, retrying: {first}");
            self.file.sync_data()?;
        }
        self.committed_len += self.buf.len() as u64;
        self.buf.clear();
        self.staged.clear();
        self.commits.inc();
        if self.committed_len > self.segment_bytes {
            self.rotate()?;
        }
        Ok(())
    }

    /// Start a new segment at the current `next_seq`. Called at
    /// commit boundaries only, so segments always begin on a record
    /// boundary. Rotation failure is non-fatal to durability: the
    /// committed data is already safe in the old segment, so the
    /// error is surfaced but the writer keeps appending there.
    fn rotate(&mut self) -> io::Result<()> {
        let seg_path = self.dir.join(segment_name(self.next_seq));
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&seg_path)?;
        file.write_all(MAGIC)?;
        file.sync_data()?;
        crate::obs_event!("wal.rotate");
        self.file = file;
        self.seg_path = seg_path;
        self.committed_len = MAGIC.len() as u64;
        Ok(())
    }
}

/// Read every valid record of one segment. Returns the decoded
/// records and the byte length of the valid prefix (magic included).
/// Never errors on corruption — a bad length, CRC, payload, or a
/// truncated tail simply ends the valid prefix. An unreadable file
/// or missing/wrong magic yields an empty prefix of length 0.
pub fn read_segment(path: &Path) -> (Vec<(u64, GraphDelta)>, u64) {
    let Ok(mut f) = File::open(path) else {
        return (Vec::new(), 0);
    };
    let mut bytes = Vec::new();
    if f.read_to_end(&mut bytes).is_err() {
        return (Vec::new(), 0);
    }
    if bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC {
        return (Vec::new(), 0);
    }
    let mut records = Vec::new();
    let mut off = MAGIC.len();
    loop {
        if off + 8 > bytes.len() {
            break;
        }
        let len = u32::from_le_bytes(
            bytes[off..off + 4].try_into().unwrap_or([0; 4]));
        if len == 0 || len > MAX_RECORD_LEN {
            break;
        }
        let len = len as usize;
        if off + 8 + len > bytes.len() {
            break;
        }
        let crc = u32::from_le_bytes(
            bytes[off + 4..off + 8].try_into().unwrap_or([0; 4]));
        let payload = &bytes[off + 8..off + 8 + len];
        if crc32(payload) != crc {
            break;
        }
        let Some(rec) = decode_payload(payload) else {
            break;
        };
        records.push(rec);
        off += 8 + len;
    }
    (records, off as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("repro-wal-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn crc32_known_vectors() {
        // IEEE 802.3 check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
                   0x414F_A339);
    }

    #[test]
    fn payload_round_trip() {
        for (seq, d) in [
            (1u64, GraphDelta::EdgeInsert { src: 3, dst: 9 }),
            (2, GraphDelta::EdgeDelete { src: 0, dst: u32::MAX }),
            (u64::MAX, GraphDelta::NodeAdd),
        ] {
            let p = encode_payload(seq, d);
            assert_eq!(decode_payload(&p), Some((seq, d)));
        }
        assert_eq!(decode_payload(&[]), None);
        assert_eq!(decode_payload(&[0; 9]), None, "tag 0 invalid");
        let mut long = encode_payload(1, GraphDelta::NodeAdd);
        long.push(0);
        assert_eq!(decode_payload(&long), None, "trailing bytes");
    }

    #[test]
    fn segment_names_round_trip() {
        assert_eq!(parse_segment_name(&segment_name(0)), Some(0));
        assert_eq!(parse_segment_name(&segment_name(12345)),
                   Some(12345));
        assert_eq!(parse_segment_name("wal-123.log"), None);
        assert_eq!(parse_segment_name("snap-00000000000000000001\
                                       .json"), None);
    }

    #[test]
    fn append_commit_read_back() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("rw");
        let mut w = Wal::open(&d, 1).unwrap();
        let deltas = [
            GraphDelta::EdgeInsert { src: 1, dst: 2 },
            GraphDelta::NodeAdd,
            GraphDelta::EdgeDelete { src: 1, dst: 2 },
        ];
        for &dl in &deltas {
            w.append(dl).unwrap();
        }
        assert_eq!(w.staged_len(), 3);
        w.commit().unwrap();
        assert_eq!(w.staged_len(), 0);
        let segs = list_segments(&d).unwrap();
        assert_eq!(segs.len(), 1);
        let (recs, _) = read_segment(&segs[0].1);
        assert_eq!(recs.len(), 3);
        for (i, &(seq, dl)) in recs.iter().enumerate() {
            assert_eq!(seq, i as u64 + 1);
            assert_eq!(dl, deltas[i]);
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn uncommitted_records_are_not_durable() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("stage");
        let mut w = Wal::open(&d, 1).unwrap();
        w.append(GraphDelta::NodeAdd).unwrap();
        // no commit — file holds only the magic
        let segs = list_segments(&d).unwrap();
        let (recs, len) = read_segment(&segs[0].1);
        assert!(recs.is_empty());
        assert_eq!(len, MAGIC.len() as u64);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn rotation_splits_segments_on_commit_boundaries() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("rot");
        let mut w = Wal::open(&d, 1).unwrap();
        w.set_segment_bytes(64); // tiny: rotate every couple commits
        for i in 0..40u32 {
            w.append(GraphDelta::EdgeInsert { src: i, dst: i + 1 })
                .unwrap();
            w.commit().unwrap();
        }
        let segs = list_segments(&d).unwrap();
        assert!(segs.len() > 1, "tiny budget must rotate");
        // Concatenated segments replay the full sequence in order.
        let mut all = Vec::new();
        for (_, p) in &segs {
            let (recs, _) = read_segment(p);
            all.extend(recs);
        }
        assert_eq!(all.len(), 40);
        for (i, &(seq, _)) in all.iter().enumerate() {
            assert_eq!(seq, i as u64 + 1);
        }
        // Segment start names match their first record seq.
        for (start, p) in &segs {
            let (recs, _) = read_segment(p);
            if let Some(&(seq, _)) = recs.first() {
                assert_eq!(seq, *start);
            }
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn injected_fsync_failure_rolls_back_batch() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("fsync");
        let mut w = Wal::open(&d, 1).unwrap();
        w.append(GraphDelta::EdgeInsert { src: 0, dst: 1 }).unwrap();
        w.commit().unwrap();
        let committed = w.committed_len;
        crate::fault::arm("wal.fsync", crate::fault::Trigger::Nth(1),
                          crate::fault::FaultAction::Error, 0);
        w.append(GraphDelta::EdgeInsert { src: 2, dst: 3 }).unwrap();
        assert!(w.commit().is_err(), "injected fsync fault surfaces");
        assert_eq!(w.staged_len(), 0, "failed batch dropped");
        assert_eq!(w.committed_len, committed, "rolled back");
        crate::fault::reset();
        // WAL remains usable at the previous durable point: the
        // sequence the failed batch consumed is simply a hole.
        w.append(GraphDelta::EdgeInsert { src: 4, dst: 5 }).unwrap();
        w.commit().unwrap();
        let segs = list_segments(&d).unwrap();
        let (recs, _) = read_segment(&segs[0].1);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].1,
                   GraphDelta::EdgeInsert { src: 4, dst: 5 });
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn corrupt_tail_ends_valid_prefix() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = tmpdir("tail");
        let mut w = Wal::open(&d, 1).unwrap();
        for i in 0..5u32 {
            w.append(GraphDelta::EdgeInsert { src: i, dst: i + 1 })
                .unwrap();
        }
        w.commit().unwrap();
        let seg = list_segments(&d).unwrap().remove(0).1;
        let (_, good_len) = read_segment(&seg);
        // Append garbage: prefix unchanged.
        let mut f = OpenOptions::new().append(true).open(&seg)
            .unwrap();
        f.write_all(&[0xDE, 0xAD, 0xBE, 0xEF, 0x01]).unwrap();
        drop(f);
        let (recs, len) = read_segment(&seg);
        assert_eq!(recs.len(), 5);
        assert_eq!(len, good_len);
        // Flip a byte inside record 3's payload: prefix shrinks.
        let mut bytes = std::fs::read(&seg).unwrap();
        let mid = MAGIC.len() + 2 * 25 + 12; // inside 3rd record
        bytes[mid] ^= 0xFF;
        std::fs::write(&seg, &bytes).unwrap();
        let (recs, _) = read_segment(&seg);
        assert_eq!(recs.len(), 2, "CRC stops the scan at record 3");
        std::fs::remove_dir_all(&d).ok();
    }
}
