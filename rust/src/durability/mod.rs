//! Crash-safe delta durability (DESIGN.md §14).
//!
//! Three layers, smallest surface on top:
//!
//! * [`wal`] — append-only delta log: length-prefixed CRC32 records,
//!   group-commit fsync batching, segment rotation.
//! * [`snapshot`] — periodic graph + HAG JSON snapshots at plan-epoch
//!   boundaries (atomic tmp+fsync+rename via `util::atomic_write`).
//! * [`recover`] — startup recovery: newest valid snapshot, torn-tail
//!   truncation, suffix replay into the resident engine/session pair.
//!
//! [`DurabilityState`] is the handle the serving path (and the
//! `serve`/`recover` CLI) holds: journal-then-ack on the update path,
//! best-effort snapshots after hot swaps. The ordering contract the
//! whole subsystem enforces: **no delta is acknowledged to a client
//! before its WAL commit fsync returns**, and conversely a WAL commit
//! failure nacks the whole batch (the clients' reply channels are
//! dropped) without applying any of it.

pub mod recover;
pub mod snapshot;
pub mod wal;

pub use recover::{recover, resume_pair, Recovered, ReplayReport};
pub use snapshot::Snapshot;
pub use wal::Wal;

use std::path::Path;

use crate::graph::Graph;
use crate::hag::Hag;
use crate::incremental::GraphDelta;

/// Durability handle carried by a serving resident (or the CLI).
pub struct DurabilityState {
    wal: Wal,
    /// Snapshot every N landed plan epochs (0 = never snapshot).
    snapshot_every: u64,
    /// Highest sequence number whose commit has returned `Ok`.
    last_durable_seq: u64,
    snapshots_written: u64,
    snapshot_failures: u64,
}

impl DurabilityState {
    /// Open (or create) durability state in `dir`, resuming sequence
    /// numbering after `tail_seq` (0 for a fresh log).
    pub fn open(dir: &Path, tail_seq: u64, snapshot_every: u64)
                -> std::io::Result<DurabilityState> {
        let wal = Wal::open(dir, tail_seq + 1)?;
        Ok(DurabilityState {
            wal,
            snapshot_every,
            last_durable_seq: tail_seq,
            snapshots_written: 0,
            snapshot_failures: 0,
        })
    }

    /// Journal a batch of deltas: stage all, fsync once. On `Ok`,
    /// every delta in the batch is durable and may be acknowledged
    /// and applied. On `Err`, NONE are durable — the caller must
    /// nack the whole batch and apply nothing.
    pub fn journal(&mut self, deltas: &[GraphDelta])
                   -> std::io::Result<u64> {
        for &d in deltas {
            self.wal.append(d)?;
        }
        self.wal.commit()?;
        self.last_durable_seq = self.wal.next_seq() - 1;
        Ok(self.last_durable_seq)
    }

    /// Cut a snapshot if this epoch is on the configured cadence.
    /// Best effort: failures are counted and logged, never fatal —
    /// the WAL alone is always sufficient for recovery.
    pub fn maybe_snapshot(&mut self, epoch: u64, graph: Graph,
                          hag: Hag) -> bool {
        if self.snapshot_every == 0
            || epoch % self.snapshot_every != 0
        {
            return false;
        }
        let s = Snapshot {
            seq: self.last_durable_seq,
            epoch,
            graph,
            hag,
        };
        match snapshot::write(self.wal.dir(), &s) {
            Ok(path) => {
                self.snapshots_written += 1;
                crate::obs_info!("[durability] snapshot {} (seq {})",
                                 path.display(), s.seq);
                true
            }
            Err(e) => {
                self.snapshot_failures += 1;
                crate::obs_warn!("[durability] snapshot failed \
                                  (serving continues): {e}");
                false
            }
        }
    }

    /// Highest acknowledged-durable sequence number.
    pub fn last_durable_seq(&self) -> u64 {
        self.last_durable_seq
    }

    /// Snapshots successfully written by this handle.
    pub fn snapshots_written(&self) -> u64 {
        self.snapshots_written
    }

    /// Snapshot attempts that failed (serving continued).
    pub fn snapshot_failures(&self) -> u64 {
        self.snapshot_failures
    }

    /// WAL directory.
    pub fn dir(&self) -> &Path {
        self.wal.dir()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn journal_then_recover_round_trip() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = std::env::temp_dir().join(
            format!("repro-dur-state-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        let mut st = DurabilityState::open(&d, 0, 0).unwrap();
        let batch = [
            GraphDelta::EdgeInsert { src: 0, dst: 1 },
            GraphDelta::NodeAdd,
        ];
        assert_eq!(st.journal(&batch).unwrap(), 2);
        assert_eq!(st.last_durable_seq(), 2);
        assert_eq!(st.journal(&[]).unwrap(), 2, "empty batch no-op");
        drop(st);
        let rec = recover(&d).unwrap();
        assert_eq!(rec.tail_seq, 2);
        assert_eq!(rec.deltas.len(), 2);
        // Reopen resumes numbering after the recovered tail.
        let mut st = DurabilityState::open(&d, rec.tail_seq, 0)
            .unwrap();
        assert_eq!(st.journal(&[GraphDelta::NodeAdd]).unwrap(), 3);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn failed_journal_batch_is_all_or_nothing() {
        let _g = crate::fault::exclusive();
        crate::fault::reset();
        let d = std::env::temp_dir().join(
            format!("repro-dur-nack-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        let mut st = DurabilityState::open(&d, 0, 0).unwrap();
        st.journal(&[GraphDelta::EdgeInsert { src: 0, dst: 1 }])
            .unwrap();
        crate::fault::arm("wal.fsync", crate::fault::Trigger::Nth(1),
                          crate::fault::FaultAction::Error, 0);
        let batch = [GraphDelta::NodeAdd, GraphDelta::NodeAdd];
        assert!(st.journal(&batch).is_err());
        assert_eq!(st.last_durable_seq(), 1, "nothing acked");
        crate::fault::reset();
        drop(st);
        let rec = recover(&d).unwrap();
        assert_eq!(rec.deltas.len(), 1, "failed batch not replayed");
        std::fs::remove_dir_all(&d).ok();
    }
}
