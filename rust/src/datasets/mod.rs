//! Dataset substrate: synthetic stand-ins for the paper's Table 2.
//!
//! The evaluation datasets (BZR, PPI, REDDIT, IMDB, COLLAB) live in
//! public archives this testbed cannot reach, so each is substituted by
//! a seeded synthetic generator matched to the statistics that drive HAG
//! benefit: node/edge counts (Table 2), degree skew, and — critically —
//! *neighbor overlap* (community/clique structure is exactly what
//! produces shared partial aggregates). Real data can be dropped in via
//! `graph::io` loaders. See DESIGN.md §3 for the substitution argument.
//!
//! `scale` linearly scales node/edge targets so CPU-scale benches finish
//! in minutes; metric *ratios* (Fig 3) are scale-checked in the bench
//! harness.

mod generators;

pub use generators::{community_graph, ego_clique_set, CommunityCfg,
                     EgoCliqueCfg};

use crate::graph::Graph;
use crate::util::Rng;

/// Node- or graph-level prediction (paper Table 2 split).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    NodeClassification,
    GraphClassification,
}

/// A fully materialized dataset: merged graph + features + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub graph: Graph,
    /// Row-major `[n, f_in]` node features.
    pub features: Vec<f32>,
    pub f_in: usize,
    pub classes: usize,
    /// Node labels (node classification) — `[n]`.
    pub labels: Vec<u32>,
    /// Train split mask — `[n]` (node classification).
    pub train_mask: Vec<bool>,
    pub task: Task,
    /// Graph id per node (graph classification; block-diagonal merge).
    pub graph_seg: Vec<u32>,
    /// Per-graph labels (graph classification).
    pub graph_labels: Vec<u32>,
    pub num_graphs: usize,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    pub fn e(&self) -> usize {
        self.graph.e()
    }
}

/// Paper Table 2 statistics: (name, nodes, edges, task).
pub const PAPER_TABLE2: &[(&str, usize, usize, Task)] = &[
    ("BZR", 6_519, 137_734, Task::NodeClassification),
    ("PPI", 56_944, 1_612_348, Task::NodeClassification),
    ("REDDIT", 232_965, 57_307_946, Task::NodeClassification),
    ("IMDB", 19_502, 197_806, Task::GraphClassification),
    ("COLLAB", 372_474, 12_288_900, Task::GraphClassification),
];

/// All dataset names, paper order.
pub fn names() -> Vec<&'static str> {
    PAPER_TABLE2.iter().map(|d| d.0).collect()
}

/// Load (generate) a dataset stand-in at `scale` in `(0, 1]`.
///
/// `f_in`/`classes` follow the paper's experimental setup (16 hidden
/// dims, small label spaces); deterministic in `seed`.
pub fn load(name: &str, scale: f64, seed: u64) -> Dataset {
    let &(_, n0, e0, task) = PAPER_TABLE2
        .iter()
        .find(|d| d.0.eq_ignore_ascii_case(name))
        .unwrap_or_else(|| panic!("unknown dataset {name:?} \
                                   (expected one of {:?})", names()));
    let n = ((n0 as f64 * scale) as usize).max(64);
    let e = ((e0 as f64 * scale) as usize).max(4 * n);
    let f_in = 16;
    match task {
        Task::NodeClassification => {
            let classes = match name.to_ascii_uppercase().as_str() {
                "PPI" => 8,
                "REDDIT" => 16,
                _ => 4,
            };
            // Community structure density differs per dataset: REDDIT
            // has hub-heavy overlap; BZR/PPI moderate communities.
            let cfg = CommunityCfg {
                n,
                e,
                communities: (n / 160).max(4),
                intra_frac: 0.9,
                zipf_exp: match name.to_ascii_uppercase().as_str() {
                    "REDDIT" => 1.1, // heavier hubs
                    _ => 0.8,
                },
                clone_frac: match name.to_ascii_uppercase().as_str() {
                    // posts in one subreddit share commenters heavily
                    "REDDIT" => 0.7,
                    _ => 0.5,
                },
            };
            let (graph, community) = community_graph(&cfg, seed);
            build_node_dataset(name, graph, community, f_in, classes,
                               seed)
        }
        Task::GraphClassification => {
            let num_graphs = match name.to_ascii_uppercase().as_str() {
                "IMDB" => ((1_500.0 * scale) as usize).max(8),
                _ => ((5_000.0 * scale) as usize).max(8),
            };
            let cfg = EgoCliqueCfg {
                num_graphs,
                total_nodes: n,
                total_edges: e,
                classes: 2,
            };
            let set = ego_clique_set(&cfg, seed);
            build_graph_dataset(name, set, f_in, seed)
        }
    }
}

fn build_node_dataset(name: &str, graph: Graph, community: Vec<u32>,
                      f_in: usize, classes: usize, seed: u64) -> Dataset {
    let n = graph.n();
    let mut rng = Rng::seed_from_u64(seed ^ 0xfea7);
    let labels: Vec<u32> =
        community.iter().map(|&c| c % classes as u32).collect();
    // Features: noisy label signal + noise dims -> learnable but not
    // trivial.
    let mut features = vec![0f32; n * f_in];
    for v in 0..n {
        for f in 0..f_in {
            features[v * f_in + f] = rng.range_f32(-0.5, 0.5);
        }
        let l = labels[v] as usize % f_in;
        features[v * f_in + l] += 1.0;
    }
    let train_mask: Vec<bool> = (0..n).map(|_| rng.bool(0.8)).collect();
    Dataset {
        name: name.to_string(),
        graph,
        features,
        f_in,
        classes,
        labels,
        train_mask,
        task: Task::NodeClassification,
        graph_seg: Vec::new(),
        graph_labels: Vec::new(),
        num_graphs: 1,
    }
}

fn build_graph_dataset(name: &str,
                       set: (Vec<Graph>, Vec<u32>),
                       f_in: usize, seed: u64) -> Dataset {
    let (graphs, graph_labels) = set;
    let num_graphs = graphs.len();
    let (graph, starts) = Graph::disjoint_union(&graphs);
    let n = graph.n();
    let mut graph_seg = vec![0u32; n];
    for (gi, w) in starts.windows(2).enumerate() {
        for v in w[0]..w[1] {
            graph_seg[v as usize] = gi as u32;
        }
    }
    if let Some(&last) = starts.last() {
        for v in last..n as u32 {
            graph_seg[v as usize] = (num_graphs - 1) as u32;
        }
    }
    let mut rng = Rng::seed_from_u64(seed ^ 0x9a7b);
    let mut features = vec![0f32; n * f_in];
    for v in 0..n {
        // features carry degree + label signal so the task is learnable
        let gl = graph_labels[graph_seg[v] as usize] as usize % f_in;
        for f in 0..f_in {
            features[v * f_in + f] = rng.range_f32(-0.5, 0.5);
        }
        features[v * f_in + gl] += 0.5;
        features[v * f_in + (f_in - 1)] =
            (graph.degree(v as u32) as f32).ln_1p() * 0.2;
    }
    let classes = (*graph_labels.iter().max().unwrap_or(&1) + 1) as usize;
    Dataset {
        name: name.to_string(),
        graph,
        features,
        f_in,
        classes: classes.max(2),
        labels: vec![0; n],
        train_mask: vec![false; n],
        task: Task::GraphClassification,
        graph_seg,
        graph_labels,
        num_graphs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_paper_datasets_generate_at_tiny_scale() {
        for &(name, _, _, task) in PAPER_TABLE2 {
            let d = load(name, 0.01, 7);
            assert!(d.n() >= 64, "{name}: n={}", d.n());
            assert!(d.e() > 0);
            assert_eq!(d.task, task);
            assert_eq!(d.features.len(), d.n() * d.f_in);
            if task == Task::GraphClassification {
                assert!(d.num_graphs >= 8);
                assert_eq!(d.graph_seg.len(), d.n());
                assert_eq!(d.graph_labels.len(), d.num_graphs);
            } else {
                assert_eq!(d.labels.len(), d.n());
                assert!(d.labels.iter().all(|&l| (l as usize) < d.classes));
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = load("BZR", 0.05, 3);
        let b = load("BZR", 0.05, 3);
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.features, b.features);
    }

    #[test]
    fn different_seeds_differ() {
        let a = load("BZR", 0.05, 3);
        let b = load("BZR", 0.05, 4);
        assert_ne!(a.graph, b.graph);
    }

    #[test]
    fn scale_scales_counts() {
        let small = load("PPI", 0.01, 1);
        let large = load("PPI", 0.04, 1);
        assert!(large.n() > 2 * small.n());
        assert!(large.e() > 2 * small.e());
    }

    #[test]
    fn edge_counts_near_target() {
        let d = load("BZR", 0.2, 5);
        let (_, n0, e0, _) = PAPER_TABLE2[0];
        let want_n = (n0 as f64 * 0.2) as usize;
        let want_e = (e0 as f64 * 0.2) as usize;
        assert!((d.n() as f64) > 0.8 * want_n as f64);
        // generators aim within ~25% of the edge target
        assert!((d.e() as f64) > 0.6 * want_e as f64,
                "e={} want~{want_e}", d.e());
        assert!((d.e() as f64) < 1.4 * want_e as f64,
                "e={} want~{want_e}", d.e());
    }
}
