//! Seeded synthetic graph generators.
//!
//! Two families cover the paper's dataset structures:
//! * [`community_graph`] — a community-structured graph with Zipf
//!   (power-law-ish) in-community popularity. Nodes in a community
//!   preferentially link to its popular members, so distinct nodes share
//!   many common neighbors — the redundancy HAGs exploit (webpage /
//!   social / PPI structure).
//! * [`ego_clique_set`] — many small graphs, each a union of overlapping
//!   cliques (IMDB/COLLAB ego-networks: all actors of a movie form a
//!   clique). Clique members share *all* other members as neighbors, the
//!   highest-overlap regime in the paper's eval.

use crate::graph::{Graph, GraphBuilder};
use crate::util::Rng;

/// Configuration for [`community_graph`].
#[derive(Debug, Clone)]
pub struct CommunityCfg {
    /// Target node count.
    pub n: usize,
    /// Target (directed aggregation-) edge count.
    pub e: usize,
    /// Community count.
    pub communities: usize,
    /// Fraction of edges that stay inside the community.
    pub intra_frac: f64,
    /// Zipf exponent for in-community popularity (higher = heavier
    /// hubs, more neighbor overlap).
    pub zipf_exp: f64,
    /// Fraction of nodes whose in-neighborhood is cloned from a shared
    /// community template (webpages under one domain share most links;
    /// users in one group follow the same accounts). This is the
    /// mechanism that gives real graphs their high pair-multiplicity —
    /// the redundancy Algorithm 3 harvests.
    pub clone_frac: f64,
}

/// Generate a community graph; returns `(graph, community_of_node)`.
///
/// Every undirected link is materialized in both directions (GNN
/// aggregation edges), so the directed edge count ~= `cfg.e`.
pub fn community_graph(cfg: &CommunityCfg, seed: u64) -> (Graph, Vec<u32>) {
    let mut rng = Rng::seed_from_u64(seed);
    let n = cfg.n;
    let nc = cfg.communities.max(1).min(n);
    // community assignment: node ids striped (v % nc)
    let mut community = vec![0u32; n];
    for (v, c) in community.iter_mut().enumerate() {
        *c = (v % nc) as u32;
    }
    let member = |c: usize, idx: usize| -> u32 { (idx * nc + c) as u32 };
    let csize = |c: usize| -> usize {
        if c < n % nc { n / nc + 1 } else { n / nc }
    };

    // Heavy-tailed popularity sampler over 0..k: index =
    // floor(k * u^(1+s)) — density ~ x^(-s/(1+s)), hub-concentrated at
    // low indices, heavier for larger s. Cheap, rejection-free, and
    // produces the shared-popular-neighbor structure HAGs exploit.
    let zipf = |rng: &mut Rng, k: usize, s: f64| -> usize {
        if k <= 1 {
            return 0;
        }
        let u: f64 = rng.range_f64(1e-12, 1.0);
        ((k as f64 * u.powf(1.0 + s)) as usize).min(k - 1)
    };

    let deg = (cfg.e as f64 / n as f64).max(1.0);
    // Community in-neighborhood templates (the "domain link set"):
    // clone adopters inherit ~80% of a template + private noise.
    let tpl_len = ((deg * 0.8) as usize).max(2);
    let mut b = GraphBuilder::new(n);
    let mut templates: Vec<Vec<Vec<u32>>> = Vec::with_capacity(nc);
    for c in 0..nc {
        let k = csize(c);
        let nt = (k / 40).clamp(1, 12); // templates per community
        let mut ts = Vec::with_capacity(nt);
        for _ in 0..nt {
            let mut t = Vec::with_capacity(tpl_len);
            for _ in 0..tpl_len.min(k.saturating_sub(1)).max(1) {
                t.push(member(c, zipf(&mut rng, k, cfg.zipf_exp)));
            }
            t.sort_unstable();
            t.dedup();
            ts.push(t);
        }
        templates.push(ts);
    }

    for v in 0..n as u32 {
        let c = community[v as usize] as usize;
        let k = csize(c);
        if k < 2 {
            continue;
        }
        let mut budget = deg * rng.range_f64(0.6, 1.4);
        if rng.bool(cfg.clone_frac) {
            // adopt a community template (shared in-neighborhood)
            let t = &templates[c][rng.range_usize(
                0, templates[c].len())];
            for &u in t {
                if u != v {
                    b.edge(u, v);
                }
            }
            budget -= t.len() as f64;
        }
        // private edges: zipf-popular within community, a slice
        // across. Heavy-tailed draws collide; draw until `private`
        // distinct in-neighbors are found (bounded attempts).
        let private = (budget.max(0.0) as usize).max(1);
        let mut got = std::collections::HashSet::new();
        let mut attempts = 0usize;
        while got.len() < private && attempts < private * 6 + 8 {
            attempts += 1;
            let u = if rng.bool(cfg.intra_frac) {
                member(c, zipf(&mut rng, k, cfg.zipf_exp))
            } else {
                let c2 = rng.range_usize(0, nc);
                member(c2, zipf(&mut rng, csize(c2), cfg.zipf_exp))
            };
            if u != v && got.insert(u) {
                b.edge(u, v);
            }
        }
    }
    (b.build(), community)
}

/// Configuration for [`ego_clique_set`].
#[derive(Debug, Clone)]
pub struct EgoCliqueCfg {
    pub num_graphs: usize,
    /// Total nodes across all graphs.
    pub total_nodes: usize,
    /// Total directed edges across all graphs.
    pub total_edges: usize,
    /// Label space (binary in IMDB-B/COLLAB fashion).
    pub classes: usize,
}

/// Generate a graph-classification set; returns `(graphs, labels)`.
///
/// Each graph is a union of 1-4 overlapping cliques. The label encodes
/// clique multiplicity (a structural, learnable property).
pub fn ego_clique_set(cfg: &EgoCliqueCfg, seed: u64)
                      -> (Vec<Graph>, Vec<u32>) {
    let mut rng = Rng::seed_from_u64(seed ^ 0xe90);
    let g = cfg.num_graphs.max(1);
    let avg_n = (cfg.total_nodes / g).max(4);
    let mut graphs = Vec::with_capacity(g);
    let mut labels = Vec::with_capacity(g);
    // Per-graph edge budget. Each clique over s of the graph's n_i
    // nodes contributes ~s*(s-1) directed edges (minus overlap); pick
    // the clique-size fraction so the expected total matches:
    //   cliques * (frac*n_i)^2 ~= edges_per_graph
    let edges_per_graph =
        (cfg.total_edges as f64 / g as f64).max(6.0);
    for _ in 0..g {
        let n_i = rng.range_usize((avg_n / 2).max(4),
                                  avg_n * 3 / 2 + 2);
        let cliques = rng.range_usize(1, 5);
        let label = if cliques <= 2 { 0u32 } else { 1u32 };
        // 1.25 compensates clique-overlap dedup losses (measured)
        let frac = (1.25 * (edges_per_graph / cliques as f64).sqrt()
            / n_i as f64).clamp(0.3, 1.0);
        let mut b = GraphBuilder::new(n_i);
        for _ in 0..cliques {
            // jitter the size +-25% around the calibrated fraction
            let s = ((n_i as f64 * frac
                      * rng.range_f64(0.75, 1.25)) as usize)
                .clamp(2, n_i);
            let start =
                rng.range_usize(0, n_i.saturating_sub(s).max(1));
            let members: Vec<u32> =
                (start..(start + s).min(n_i)).map(|x| x as u32).collect();
            for i in 0..members.len() {
                for j in (i + 1)..members.len() {
                    b.edge(members[i], members[j]);
                    b.edge(members[j], members[i]);
                }
            }
        }
        // ensure no fully isolated graph
        if b.edge_count() == 0 {
            b.edge(0, 1);
            b.edge(1, 0);
        }
        graphs.push(b.build());
        labels.push(label % cfg.classes.max(1) as u32);
    }
    (graphs, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn community_graph_hits_targets() {
        let cfg = CommunityCfg {
            n: 2000, e: 40_000, communities: 16,
            intra_frac: 0.9, zipf_exp: 0.9, clone_frac: 0.5,
        };
        let (g, com) = community_graph(&cfg, 42);
        assert_eq!(g.n(), 2000);
        assert_eq!(com.len(), 2000);
        let e = g.e() as f64;
        assert!(e > 0.6 * 40_000.0 && e < 1.4 * 40_000.0, "e={e}");
    }

    #[test]
    fn community_graph_has_neighbor_overlap() {
        // The whole point: shared neighbors must be plentiful.
        let cfg = CommunityCfg {
            n: 1000, e: 20_000, communities: 8,
            intra_frac: 0.95, zipf_exp: 1.0, clone_frac: 0.5,
        };
        let (g, _) = community_graph(&cfg, 1);
        // count pairs sharing >= 2 common neighbors among a sample
        let mut overlapping = 0;
        for v in 0..50u32 {
            for u in (v + 1)..50u32 {
                let nv = g.neighbors(v);
                let nu = g.neighbors(u);
                let common = nv.iter().filter(|x| nu.contains(x)).count();
                if common >= 2 {
                    overlapping += 1;
                }
            }
        }
        assert!(overlapping > 10, "too little overlap: {overlapping}");
    }

    #[test]
    fn ego_clique_set_shapes() {
        let cfg = EgoCliqueCfg {
            num_graphs: 50, total_nodes: 1000, total_edges: 10_000,
            classes: 2,
        };
        let (gs, ls) = ego_clique_set(&cfg, 7);
        assert_eq!(gs.len(), 50);
        assert_eq!(ls.len(), 50);
        assert!(ls.iter().all(|&l| l < 2));
        let total_n: usize = gs.iter().map(|g| g.n()).sum();
        assert!(total_n > 500 && total_n < 2000, "{total_n}");
        for g in &gs {
            assert!(g.e() > 0);
        }
    }

    #[test]
    fn generators_deterministic() {
        let cfg = CommunityCfg {
            n: 500, e: 5000, communities: 5, intra_frac: 0.9,
            zipf_exp: 0.9, clone_frac: 0.5,
        };
        let (a, _) = community_graph(&cfg, 5);
        let (b, _) = community_graph(&cfg, 5);
        assert_eq!(a, b);
    }
}
