//! Per-shard plan cache.
//!
//! Two tiers, both keyed off the spec fingerprint
//! ([`LowerSpec::fingerprint`](super::LowerSpec::fingerprint)):
//!
//! * **shard tier** — searched per-shard HAGs keyed by
//!   `(spec fingerprint, shard id, topology version)`, where the
//!   topology version is the shard's last-dirtying delta sequence
//!   number. A shard untouched since its last search is a cache hit;
//!   only dirty shards pay a re-search. Inserting a shard entry evicts
//!   that shard's stale versions (a shard can never be consistent at
//!   two versions at once), so the tier holds at most one entry per
//!   `(spec, shard)`.
//! * **plan tier** — the last stitched `(Hag, ExecutionPlan)` memoized
//!   at `(spec fingerprint, global version)`, so repeated
//!   [`Session::plan`](super::Session::plan) calls with no interleaved
//!   deltas are free.
//!
//! Invalidation rules (see DESIGN.md §7): any intra-shard edge delta
//! or node addition bumps its shard's version (shard-tier miss); any
//! applied delta — including cross-shard edges, which live only in the
//! stitch — bumps the global version (plan-tier miss).

use std::sync::Arc;

use crate::hag::{ExecutionPlan, Hag};
use crate::util::FxHashMap;

/// Shard-tier cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// [`LowerSpec::fingerprint`](super::LowerSpec::fingerprint),
    /// mixed with the session's base-graph fingerprint.
    pub spec: u64,
    pub shard: u32,
    /// Sequence number of the delta that last dirtied the shard
    /// (0 = the base graph).
    pub version: u64,
}

/// Hit/miss counters (also surfaced through
/// [`SessionStats`](super::SessionStats)).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub shard_hits: usize,
    pub shard_misses: usize,
    pub plan_hits: usize,
    pub plan_misses: usize,
}

/// The cache itself. Owned by one [`Session`](super::Session); shared
/// handles are `Arc`s so a hit never copies a HAG or an index tensor.
#[derive(Debug, Default)]
pub struct PlanCache {
    shards: FxHashMap<PlanKey, Arc<Hag>>,
    plan: Option<(u64, u64, Arc<Hag>, Arc<ExecutionPlan>)>,
    stats: CacheStats,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Shard-tier lookup; counts a hit or a miss.
    pub fn shard_hag(&mut self, key: PlanKey) -> Option<Arc<Hag>> {
        match self.shards.get(&key) {
            Some(h) => {
                self.stats.shard_hits += 1;
                Some(h.clone())
            }
            None => {
                self.stats.shard_misses += 1;
                None
            }
        }
    }

    /// Insert a freshly searched shard HAG, evicting stale versions of
    /// the same `(spec, shard)`.
    pub fn insert_shard(&mut self, key: PlanKey, hag: Arc<Hag>) {
        self.shards.retain(|k, _| {
            k.spec != key.spec || k.shard != key.shard
        });
        self.shards.insert(key, hag);
    }

    /// Plan-tier lookup at `(spec, global version)`.
    pub fn plan_at(&mut self, spec: u64, version: u64)
                   -> Option<(Arc<Hag>, Arc<ExecutionPlan>)> {
        match &self.plan {
            Some((s, v, hag, plan)) if *s == spec && *v == version => {
                self.stats.plan_hits += 1;
                Some((hag.clone(), plan.clone()))
            }
            _ => {
                self.stats.plan_misses += 1;
                None
            }
        }
    }

    pub fn insert_plan(&mut self, spec: u64, version: u64,
                       hag: Arc<Hag>, plan: Arc<ExecutionPlan>) {
        self.plan = Some((spec, version, hag, plan));
    }

    /// Does the shard tier hold `key` right now? (No hit/miss
    /// accounting — used to report dirty-shard counts.)
    pub fn contains_shard(&self, key: &PlanKey) -> bool {
        self.shards.contains_key(key)
    }

    /// Does the plan tier hold `(spec, version)` right now? (No
    /// hit/miss accounting — used by
    /// [`Session::plan_current`](super::Session::plan_current).)
    pub fn peek_plan(&self, spec: u64, version: u64) -> bool {
        matches!(&self.plan,
                 Some((s, v, _, _)) if *s == spec && *v == version)
    }

    /// Live shard-tier entries.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Drop everything (spec change, explicit reset).
    pub fn clear(&mut self) {
        self.shards.clear();
        self.plan = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hag::AggregateKind;

    fn dummy_hag(n: usize) -> Arc<Hag> {
        Arc::new(Hag {
            n,
            agg_nodes: Vec::new(),
            in_edges: vec![Vec::new(); n],
            kind: AggregateKind::Set,
        })
    }

    #[test]
    fn shard_tier_hits_and_evicts_stale_versions() {
        let mut c = PlanCache::new();
        let k0 = PlanKey { spec: 1, shard: 0, version: 0 };
        assert!(c.shard_hag(k0).is_none());
        c.insert_shard(k0, dummy_hag(3));
        assert!(c.shard_hag(k0).is_some());
        // same shard at a newer version evicts the old entry
        let k1 = PlanKey { spec: 1, shard: 0, version: 5 };
        c.insert_shard(k1, dummy_hag(3));
        assert!(!c.contains_shard(&k0));
        assert!(c.contains_shard(&k1));
        assert_eq!(c.len(), 1);
        // a different shard coexists
        let other = PlanKey { spec: 1, shard: 1, version: 5 };
        c.insert_shard(other, dummy_hag(4));
        assert_eq!(c.len(), 2);
        let s = c.stats();
        assert_eq!(s.shard_hits, 1);
        assert_eq!(s.shard_misses, 1);
    }

    #[test]
    fn different_specs_do_not_collide() {
        let mut c = PlanCache::new();
        let a = PlanKey { spec: 1, shard: 0, version: 0 };
        let b = PlanKey { spec: 2, shard: 0, version: 0 };
        c.insert_shard(a, dummy_hag(3));
        c.insert_shard(b, dummy_hag(3));
        assert_eq!(c.len(), 2, "spec is part of the key");
    }

    #[test]
    fn plan_tier_memoizes_one_version() {
        let mut c = PlanCache::new();
        assert!(c.plan_at(1, 0).is_none());
        let plan = Arc::new(crate::hag::build_plan(
            &crate::graph::Graph::from_edges(2, &[(0, 1)]),
            &dummy_hag(2).as_ref().clone(),
            &crate::hag::PlanConfig::default()));
        c.insert_plan(1, 0, dummy_hag(2), plan.clone());
        assert!(c.plan_at(1, 0).is_some());
        assert!(c.plan_at(1, 1).is_none(), "version mismatch");
        assert!(c.plan_at(2, 0).is_none(), "spec mismatch");
        let s = c.stats();
        assert_eq!(s.plan_hits, 1);
        assert_eq!(s.plan_misses, 3);
        // peek is side-effect free
        assert!(c.peek_plan(1, 0));
        assert!(!c.peek_plan(1, 1));
        assert!(!c.peek_plan(2, 0));
        assert_eq!(c.stats(), s, "peek_plan must not move the stats");
    }
}
