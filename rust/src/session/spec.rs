//! `LowerSpec` — the one canonical description of a lowering.
//!
//! The old entry points (`coordinator::lower_dataset`,
//! `coordinator::emit_buckets`) grew by positional accretion: five
//! knobs threaded through every call site, with `emit_buckets` pinning
//! `capacity = None` so the emitted bucket could silently disagree
//! with the plan a later train/infer run lowered. `LowerSpec` replaces
//! the knob thread: every parameter that influences the lowered
//! artifact — representation, AGGREGATE kind, capacity, sharding,
//! partition seed, plan layout, drift policy — lives in one struct
//! with builder setters, and a **deterministic fingerprint** over all
//! of them keys the per-shard plan cache
//! ([`PlanCache`](super::PlanCache)).
//!
//! Fingerprint contract: two specs hash equal iff every
//! lowering-relevant field is equal. The hash is the in-tree
//! [`FxHasher`](crate::util::fxhash::FxHasher) recurrence — fixed
//! seed, no per-process randomization — so fingerprints are stable
//! across runs and hosts (they may appear in logs and cache keys, but
//! are never persisted as a compatibility surface).

use std::hash::Hasher;

use crate::coordinator::Repr;
use crate::hag::{AggregateKind, PlanConfig, SearchConfig};
use crate::incremental::{DriftPolicy, StreamConfig};
use crate::partition::DEFAULT_PARTITION_SEED;
use crate::util::fxhash::FxHasher;

/// Canonical lowering spec: dataset-independent knobs. Resolved
/// against a concrete graph by [`Session::new`](super::Session::new)
/// (capacity defaults are per-`|V|`).
#[derive(Debug, Clone)]
pub struct LowerSpec {
    /// Representation to lower under (paper's central comparison).
    pub repr: Repr,
    /// Set or sequential AGGREGATE. Sequential does not shard (the
    /// session falls back to one whole-graph shard).
    pub kind: AggregateKind,
    /// Explicit `|V_A|` budget. `None` resolves to
    /// `capacity_frac * |V|` at session creation.
    pub capacity: Option<usize>,
    /// Capacity as a fraction of `|V|` when `capacity` is `None`
    /// (paper §5.2 default 0.25 — identical to the old
    /// `capacity.unwrap_or(n / 4)`).
    pub capacity_frac: f64,
    /// Shard count; `1` = single-threaded whole-graph search, `>= 2`
    /// routes through the partitioned per-shard pipeline. Values of 0
    /// are clamped to 1 (library callers may compute shard counts).
    pub shards: usize,
    /// Seed for the BFS partitioner's shard-seed selection.
    pub partition_seed: u64,
    /// Per-consumer candidate-pair window
    /// (see [`SearchConfig::pair_cap`]).
    pub pair_cap: usize,
    /// Plan-compiler layout knobs (must match the compiled bucket).
    pub plan: PlanConfig,
    /// Drift policy for streaming sessions (carried here so the
    /// serving and stream paths derive their re-search behavior from
    /// the same spec that lowered the plan).
    pub drift: DriftPolicy,
}

impl Default for LowerSpec {
    fn default() -> Self {
        LowerSpec {
            repr: Repr::Hag,
            kind: AggregateKind::Set,
            capacity: None,
            capacity_frac: 0.25,
            shards: 1,
            partition_seed: DEFAULT_PARTITION_SEED,
            pair_cap: 64,
            plan: PlanConfig::default(),
            drift: DriftPolicy::default(),
        }
    }
}

impl LowerSpec {
    pub fn with_repr(mut self, repr: Repr) -> Self {
        self.repr = repr;
        self
    }

    pub fn with_kind(mut self, kind: AggregateKind) -> Self {
        self.kind = kind;
        self
    }

    /// Pin an explicit `|V_A|` budget (overrides `capacity_frac`).
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = Some(capacity);
        self
    }

    pub fn with_capacity_frac(mut self, frac: f64) -> Self {
        self.capacity_frac = frac;
        self
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    pub fn with_partition_seed(mut self, seed: u64) -> Self {
        self.partition_seed = seed;
        self
    }

    pub fn with_pair_cap(mut self, pair_cap: usize) -> Self {
        self.pair_cap = pair_cap;
        self
    }

    pub fn with_plan(mut self, plan: PlanConfig) -> Self {
        self.plan = plan;
        self
    }

    pub fn with_drift(mut self, drift: DriftPolicy) -> Self {
        self.drift = drift;
        self
    }

    /// The `|V_A|` budget this spec grants a graph of `n` nodes.
    pub fn resolved_capacity(&self, n: usize) -> usize {
        match self.capacity {
            Some(c) => c,
            // n * 0.25 is exact in f64, so this floors to n / 4 —
            // bit-compatible with the pre-Session default.
            None => (n as f64 * self.capacity_frac) as usize,
        }
    }

    /// The [`SearchConfig`] this spec lowers a graph of `n` nodes
    /// under (per-shard budgets are split from this capacity).
    pub fn search_config(&self, n: usize) -> SearchConfig {
        SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: self.resolved_capacity(n),
            kind: self.kind,
            pair_cap: self.pair_cap,
        }
    }

    /// Shards the session actually runs: sequential AGGREGATE and the
    /// GNN-graph baseline do not shard.
    pub fn effective_shards(&self) -> usize {
        if self.repr == Repr::GnnGraph
            || self.kind == AggregateKind::Sequential
        {
            1
        } else {
            self.shards.max(1)
        }
    }

    /// Derive the streaming-maintenance config from this spec, so the
    /// engine repairing the graph and the session planning it agree on
    /// capacity fraction, pair window, sharding and drift policy.
    /// (An explicit `capacity` does not propagate — the engine's
    /// budget tracks the *current* `|V|` by design.)
    pub fn stream_config(&self) -> StreamConfig {
        let mut cfg = StreamConfig::default();
        cfg.capacity_frac = self.capacity_frac;
        cfg.pair_cap = self.pair_cap;
        cfg.shards = self.effective_shards();
        cfg.policy = self.drift.clone();
        cfg
    }

    /// Deterministic fingerprint over every lowering-relevant field.
    /// Stable across runs (fixed-seed FxHash, fixed field order).
    pub fn fingerprint(&self) -> u64 {
        let mut h = FxHasher::default();
        h.write_u64(match self.repr {
            Repr::GnnGraph => 0,
            Repr::Hag => 1,
        });
        h.write_u64(match self.kind {
            AggregateKind::Set => 0,
            AggregateKind::Sequential => 1,
        });
        match self.capacity {
            None => h.write_u64(0),
            Some(c) => {
                h.write_u64(1);
                h.write_u64(c as u64);
            }
        }
        h.write_u64(self.capacity_frac.to_bits());
        h.write_u64(self.shards.max(1) as u64);
        h.write_u64(self.partition_seed);
        h.write_u64(self.pair_cap as u64);
        h.write_u64(self.plan.br as u64);
        h.write_u64(self.plan.lvl_block as u64);
        h.write_u64(self.plan.max_bands as u64);
        h.write_u64(self.plan.nnzb_round as u64);
        h.write_u64(self.drift.fingerprint());
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_pre_session_defaults() {
        let s = LowerSpec::default();
        assert_eq!(s.resolved_capacity(1001), 1001 / 4);
        assert_eq!(s.resolved_capacity(7), 1);
        let sc = s.search_config(400);
        assert_eq!(sc.capacity, 100);
        assert_eq!(sc.pair_cap, 64);
        assert_eq!(sc.kind, AggregateKind::Set);
    }

    #[test]
    fn explicit_capacity_wins() {
        let s = LowerSpec::default().with_capacity(7);
        assert_eq!(s.resolved_capacity(10_000), 7);
    }

    #[test]
    fn fingerprint_is_stable_and_field_sensitive() {
        let a = LowerSpec::default();
        let b = LowerSpec::default();
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(),
                   a.clone().with_repr(Repr::GnnGraph).fingerprint());
        assert_ne!(a.fingerprint(),
                   a.clone().with_capacity(100).fingerprint());
        assert_ne!(a.fingerprint(),
                   a.clone().with_shards(4).fingerprint());
        assert_ne!(a.fingerprint(),
                   a.clone().with_partition_seed(1).fingerprint());
        assert_ne!(a.fingerprint(),
                   a.clone().with_pair_cap(32).fingerprint());
        let mut plan = PlanConfig::default();
        plan.max_bands = 2;
        assert_ne!(a.fingerprint(),
                   a.clone().with_plan(plan).fingerprint());
        let drift = DriftPolicy::default().with_threshold(0.5);
        assert_ne!(a.fingerprint(),
                   a.clone().with_drift(drift).fingerprint());
    }

    #[test]
    fn sequential_and_gnn_do_not_shard() {
        let s = LowerSpec::default().with_shards(4);
        assert_eq!(s.effective_shards(), 4);
        assert_eq!(s.clone().with_kind(AggregateKind::Sequential)
                       .effective_shards(), 1);
        assert_eq!(s.clone().with_repr(Repr::GnnGraph)
                       .effective_shards(), 1);
        assert_eq!(LowerSpec::default().with_shards(0)
                       .effective_shards(), 1);
    }

    #[test]
    fn stream_config_tracks_spec() {
        let s = LowerSpec::default()
            .with_shards(4)
            .with_capacity_frac(0.5)
            .with_drift(DriftPolicy::default().with_threshold(0.2));
        let c = s.stream_config();
        assert_eq!(c.shards, 4);
        assert!((c.capacity_frac - 0.5).abs() < 1e-12);
        assert!((c.policy.threshold - 0.2).abs() < 1e-12);
    }
}
