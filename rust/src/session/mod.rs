//! Unified lowering sessions: one owning object for
//! graph + partition + per-shard HAGs + plans.
//!
//! The paper's pipeline is search → plan → execute (Algorithm 3 + §4);
//! the old entry points re-ran the whole pipeline from scratch at
//! every lowering. A [`Session`] instead *owns* the moving parts:
//!
//! * the current topology (a copy-on-write [`OverlayGraph`] fed by
//!   [`GraphDelta`]s through [`Session::apply`]);
//! * the node [`Partition`] (BFS shards, maintained incrementally as
//!   nodes are added);
//! * pinned per-shard `|V_A|` budgets (split once at creation, so a
//!   clean shard's cached search can never be invalidated by another
//!   shard's growth);
//! * a two-tier [`PlanCache`] keyed by the
//!   [`LowerSpec::fingerprint`] — searched per-shard HAGs at
//!   `(spec, shard, topology version)` plus the last stitched plan.
//!
//! Deltas mark shards dirty through `Partition::shard_of`:
//! an intra-shard edge update bumps that shard's version, a node
//! addition bumps its assigned shard, and a cross-shard edge bumps
//! only the global version (cross edges live in the stitch, not in
//! any shard's subgraph). [`Session::plan`] then re-searches *only*
//! the dirty shards — in parallel, with the same worker pool shape as
//! [`search_partitioned`](crate::partition::search_partitioned) —
//! splices the cached clean shards back in with
//! [`stitch_hags`], and compiles the plan. This replaces the
//! whole-graph replan the old `coordinator::lower_dataset` paid on
//! every call (ROADMAP items 1 and 3).
//!
//! Correctness contract (asserted by `rust/tests/session.rs`): after
//! any applied delta sequence, the cached dirty-shard-only
//! [`Session::plan`] is **identical** — level/band structure and
//! every index tensor — to [`Session::plan_fresh`], which re-searches
//! every shard from scratch on the current graph. This holds because
//! a clean shard's subgraph is unchanged by construction (all
//! intra-shard mutations dirty it), budgets are pinned, and
//! `hag_search` / `build_plan` are deterministic.

pub mod cache;
pub mod spec;

pub use cache::{CacheStats, PlanCache, PlanKey};
pub use spec::LowerSpec;

use std::hash::Hasher;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::coordinator::{self, Lowered, Repr};
use crate::datasets::{Dataset, Task};
use crate::graph::Graph;
use crate::hag::{build_plan, hag_search_with_scratch, ExecutionPlan,
                 Hag, SearchConfig, SearchScratch};
use crate::incremental::{GraphDelta, OverlayGraph};
use crate::partition::{partition_bfs, split_capacity_by_edges,
                       stitch_hags, subgraph, worker_parallelism,
                       Partition, PartitionConfig};
use crate::runtime::BucketSpec;
use crate::util::fxhash::FxHasher;

/// What a session needs from a [`Dataset`] beyond the graph (bucket
/// naming and padding); graph-only sessions
/// ([`Session::from_graph`]) have none and cannot [`Session::lower`].
#[derive(Debug, Clone)]
struct DatasetMeta {
    name: String,
    f_in: usize,
    classes: usize,
    task: Task,
    num_graphs: usize,
}

/// Lifetime counters for one session.
#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    /// Deltas that changed the graph.
    pub deltas: usize,
    /// Deltas that were no-ops (duplicate insert, missing delete,
    /// out-of-range ids).
    pub noops: usize,
    /// Applied edge deltas whose endpoints live in different shards
    /// (no shard re-search needed — only the stitch changes).
    pub cross_shard_deltas: usize,
    /// [`Session::plan`] calls.
    pub plans: usize,
    /// Plans served entirely from the memoized plan tier.
    pub plan_cache_hits: usize,
    /// Per-shard searches actually run (the re-plan count the stream
    /// CLI reports; compare against `deltas`).
    pub shard_searches: usize,
    /// Per-shard searches avoided by the cache.
    pub shard_cache_hits: usize,
}

/// A lowering session: owns the graph, the partition, the per-shard
/// HAGs and the plan cache for one [`LowerSpec`].
pub struct Session {
    spec: LowerSpec,
    /// Spec fingerprint mixed with the base graph (and dataset name)
    /// fingerprint — the `spec` component of every [`PlanKey`].
    fp: u64,
    meta: Option<DatasetMeta>,
    graph: OverlayGraph,
    part: Partition,
    /// Pinned per-shard capacity budgets (creation-time split).
    budgets: Vec<usize>,
    /// Per shard: sequence number of the last dirtying delta.
    shard_version: Vec<u64>,
    /// Definition-2 weights shard searches price with — `(1, 1)`
    /// until [`Session::set_cost_weights`] installs a live (α̂, β̂)
    /// calibration. Positive weights provably never change the
    /// greedy result (see `SearchConfig::alpha`), so cached shard
    /// HAGs stay valid across weight updates and the weights are
    /// deliberately *not* part of the plan-cache key.
    cost_weights: (f64, f64),
    /// Global topology version (== applied-delta count).
    version: u64,
    cache: PlanCache,
    stats: SessionStats,
    /// Reusable search arena for the session's own (single-shard)
    /// re-searches; the sharded path gives each pool worker its own.
    scratch: SearchScratch,
    /// Definition-2 attribution terms
    /// `(aggregations, data_transfers)` per shard HAG, captured by
    /// the most recent [`Session::plan`] build (empty until one
    /// runs). Feeds `obs::cost::record_plan_terms` on the serving
    /// path; per-shard sums differ from the stitched totals by the
    /// cross-shard edges the stitch appends.
    shard_terms: Vec<(usize, usize)>,
}

impl Session {
    /// Session over a dataset (the usual entry: can emit buckets and
    /// [`Lowered`] workloads).
    pub fn new(ds: &Dataset, spec: LowerSpec) -> Session {
        let mut s = Session::from_graph(&ds.graph, spec);
        let mut h = FxHasher::default();
        h.write_u64(s.fp);
        h.write(ds.name.as_bytes());
        s.fp = h.finish();
        s.meta = Some(DatasetMeta {
            name: ds.name.clone(),
            f_in: ds.f_in,
            classes: ds.classes,
            task: ds.task,
            num_graphs: ds.num_graphs,
        });
        s
    }

    /// Graph-only session (tests, streaming drivers, library callers
    /// that pack their own workloads).
    pub fn from_graph(g: &Graph, spec: LowerSpec) -> Session {
        let n = g.n();
        let k = spec.effective_shards();
        let part = if k <= 1 {
            Partition::single(n)
        } else {
            partition_bfs(g, &PartitionConfig::new(k)
                .with_seed(spec.partition_seed))
        };
        let capacity = spec.resolved_capacity(n);
        let budgets = if spec.repr == Repr::GnnGraph {
            Vec::new()
        } else if part.n_shards <= 1 {
            vec![capacity]
        } else {
            // One O(n + e) counting pass — the split only needs
            // intra-edge counts, not materialized subgraphs (those
            // are extracted lazily, per dirty shard, at plan time).
            let mut intra = vec![0usize; part.n_shards];
            for (v, ns) in g.iter() {
                let sv = part.shard_of[v as usize];
                for &u in ns {
                    if part.shard_of[u as usize] == sv {
                        intra[sv as usize] += 1;
                    }
                }
            }
            split_capacity_by_edges(capacity, &intra)
        };
        let mut h = FxHasher::default();
        h.write_u64(spec.fingerprint());
        h.write_u64(n as u64);
        for (_, ns) in g.iter() {
            h.write_u64(ns.len() as u64);
            for &u in ns {
                h.write_u32(u);
            }
        }
        let shard_version = vec![0u64; part.n_shards];
        Session {
            spec,
            fp: h.finish(),
            meta: None,
            graph: OverlayGraph::new(g.clone()),
            part,
            budgets,
            shard_version,
            version: 0,
            cost_weights: (1.0, 1.0),
            cache: PlanCache::new(),
            stats: SessionStats::default(),
            scratch: SearchScratch::new(),
            shard_terms: Vec::new(),
        }
    }

    /// Per-shard `(aggregations, data_transfers)` from the most
    /// recent HAG build; empty before the first [`Session::plan`].
    pub fn shard_terms(&self) -> &[(usize, usize)] {
        &self.shard_terms
    }

    pub fn spec(&self) -> &LowerSpec {
        &self.spec
    }

    /// The cache-key fingerprint (spec ⊕ base graph ⊕ dataset name).
    pub fn fingerprint(&self) -> u64 {
        self.fp
    }

    pub fn partition(&self) -> &Partition {
        &self.part
    }

    pub fn n(&self) -> usize {
        self.graph.n()
    }

    pub fn e(&self) -> usize {
        self.graph.e()
    }

    /// Global topology version (applied-delta count).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Shard of a node (nodes added through [`Session::apply`]
    /// included).
    pub fn shard_of(&self, v: u32) -> u32 {
        self.part.shard_of[v as usize]
    }

    /// [`Self::shard_of`] that tolerates out-of-range ids (hostile
    /// serving input): `None` instead of a panic.
    pub fn shard_of_checked(&self, v: u32) -> Option<u32> {
        self.part.shard_of.get(v as usize).copied()
    }

    /// Does the plan tier already hold a plan for the current
    /// topology version? No cache-stat side effects — serving-path
    /// introspection (the batcher skips the drift re-plan when the
    /// plan it serves is still the memoized one).
    pub fn plan_current(&self) -> bool {
        self.cache.peek_plan(self.fp, self.version)
    }

    /// Materialize the current topology as a CSR graph.
    pub fn graph(&self) -> Graph {
        self.graph.to_graph()
    }

    fn key(&self, shard: usize) -> PlanKey {
        PlanKey {
            spec: self.fp,
            shard: shard as u32,
            version: self.shard_version[shard],
        }
    }

    /// Shards whose cached search is stale (would re-search on the
    /// next [`Session::plan`]). Always 0 for the GNN-graph baseline
    /// (nothing is searched).
    pub fn dirty_shards(&self) -> usize {
        if self.spec.repr == Repr::GnnGraph {
            return 0;
        }
        (0..self.part.n_shards)
            .filter(|&s| !self.cache.contains_shard(&self.key(s)))
            .count()
    }

    /// Apply one topology delta, marking the touched shard dirty.
    /// Returns `false` for no-ops (duplicate insert, missing delete,
    /// out-of-range ids — same semantics as the stream engine, so an
    /// engine and a session fed the same delta stream stay in
    /// lockstep).
    pub fn apply(&mut self, delta: GraphDelta) -> bool {
        let n = self.graph.n();
        let changed = match delta {
            GraphDelta::EdgeInsert { src, dst } => {
                if (src as usize) >= n || (dst as usize) >= n
                    || !self.graph.insert_edge(src, dst)
                {
                    false
                } else {
                    self.version += 1;
                    self.touch_edge(src, dst);
                    true
                }
            }
            GraphDelta::EdgeDelete { src, dst } => {
                if (src as usize) >= n || (dst as usize) >= n
                    || !self.graph.delete_edge(src, dst)
                {
                    false
                } else {
                    self.version += 1;
                    self.touch_edge(src, dst);
                    true
                }
            }
            GraphDelta::NodeAdd => {
                self.graph.add_node();
                self.version += 1;
                let s = self.part.lightest_shard();
                self.part.push_node(s);
                self.shard_version[s] = self.version;
                true
            }
        };
        if changed {
            self.stats.deltas += 1;
        } else {
            self.stats.noops += 1;
        }
        changed
    }

    fn touch_edge(&mut self, src: u32, dst: u32) {
        let a = self.part.shard_of[src as usize] as usize;
        let b = self.part.shard_of[dst as usize] as usize;
        if a == b {
            self.shard_version[a] = self.version;
        } else {
            // Cross-shard edges never enter a shard subgraph — they
            // are appended directly at stitch time from the current
            // graph — so neither shard's cached search goes stale.
            self.stats.cross_shard_deltas += 1;
        }
    }

    fn shard_config(&self, shard: usize) -> SearchConfig {
        SearchConfig {
            capacity: self.budgets[shard],
            kind: self.spec.kind,
            pair_cap: self.spec.pair_cap,
            alpha: 1.0,
            beta: 1.0,
        }
        .with_weights(self.cost_weights.0, self.cost_weights.1)
    }

    /// Install live Definition-2 weights (α̂, β̂) for every later
    /// shard search — the serving batcher feeds its
    /// [`CostModel`](crate::obs::CostModel) fit here before each
    /// re-plan. Clamping and the search-invariance argument live in
    /// [`SearchConfig::with_weights`]; because positive weights
    /// cannot change a search result, this never invalidates the
    /// plan cache.
    pub fn set_cost_weights(&mut self, alpha: f64, beta: f64) {
        self.cost_weights = (alpha, beta);
    }

    /// The weights shard searches currently price with.
    pub fn cost_weights(&self) -> (f64, f64) {
        self.cost_weights
    }

    /// Build the maintained HAG over `g` (the current graph),
    /// re-searching only cache misses when `use_cache` holds. With
    /// `use_cache == false` nothing is read from or written to the
    /// cache and no stats move (the from-scratch comparator).
    fn build_hag(&mut self, g: &Graph, use_cache: bool) -> Arc<Hag> {
        if self.spec.repr == Repr::GnnGraph {
            let hag = Arc::new(Hag::from_graph(g, self.spec.kind));
            self.shard_terms =
                vec![(hag.aggregations(), hag.data_transfers())];
            return hag;
        }
        let k = self.part.n_shards;
        if k <= 1 {
            let key = self.key(0);
            if use_cache {
                if let Some(h) = self.cache.shard_hag(key) {
                    self.stats.shard_cache_hits += 1;
                    crate::obs_event!("session.shard_cache_hit");
                    self.shard_terms = vec![(h.aggregations(),
                                             h.data_transfers())];
                    return h;
                }
            }
            let cfg = self.shard_config(0);
            let _sp = crate::obs_span!("session.shard_search",
                                       0u64, g.n());
            let (hag, _) =
                hag_search_with_scratch(g, &cfg, &mut self.scratch);
            let hag = Arc::new(hag);
            if use_cache {
                self.stats.shard_searches += 1;
                self.cache.insert_shard(key, hag.clone());
            }
            self.shard_terms = vec![(hag.aggregations(),
                                     hag.data_transfers())];
            return hag;
        }

        let mut locals: Vec<Option<Arc<Hag>>> = vec![None; k];
        let mut misses: Vec<usize> = Vec::new();
        for s in 0..k {
            if use_cache {
                let key = self.key(s);
                if let Some(h) = self.cache.shard_hag(key) {
                    self.stats.shard_cache_hits += 1;
                    crate::obs_event!("session.shard_cache_hit", s);
                    locals[s] = Some(h);
                    continue;
                }
            }
            misses.push(s);
        }

        if !misses.is_empty() {
            let local = self.part.local_ids();
            let subs: Vec<Graph> = misses.iter()
                .map(|&s| subgraph(g, &self.part, &local, s))
                .collect();
            let cfgs: Vec<SearchConfig> = misses.iter()
                .map(|&s| self.shard_config(s))
                .collect();
            let m = misses.len();
            let results: Vec<Mutex<Option<Hag>>> =
                (0..m).map(|_| Mutex::new(None)).collect();
            let threads = m.min(worker_parallelism()).max(1);
            let next = AtomicUsize::new(0);
            std::thread::scope(|sc| {
                for _ in 0..threads {
                    sc.spawn(|| {
                        // per-worker arena, reused across its misses
                        let mut scratch = SearchScratch::new();
                        loop {
                            let i =
                                next.fetch_add(1, Ordering::Relaxed);
                            if i >= m {
                                break;
                            }
                            let _sp = crate::obs_span!(
                                "session.shard_search",
                                misses[i], subs[i].n());
                            let (h, _) = hag_search_with_scratch(
                                &subs[i], &cfgs[i], &mut scratch);
                            *results[i].lock().unwrap() = Some(h);
                        }
                    });
                }
            });
            for (i, cell) in results.into_iter().enumerate() {
                let hag = Arc::new(cell.into_inner().unwrap()
                    .expect("worker completed every miss"));
                let s = misses[i];
                if use_cache {
                    self.stats.shard_searches += 1;
                    let key = self.key(s);
                    self.cache.insert_shard(key, hag.clone());
                }
                locals[s] = Some(hag);
            }
        }

        let locals: Vec<Arc<Hag>> = locals.into_iter()
            .map(|h| h.expect("every shard resolved"))
            .collect();
        self.shard_terms = locals.iter()
            .map(|h| (h.aggregations(), h.data_transfers()))
            .collect();
        let stitched = Arc::new(stitch_hags(g, &self.part, &locals));
        if crate::analysis::verify_enabled() {
            crate::analysis::gate_stitched(
                crate::obs::metrics::MetricsRegistry::global(),
                "session.stitch", g, &self.part, &locals, &stitched);
        }
        stitched
    }

    /// The spec's total `|V_A|` budget, when every shard budget is
    /// finite (what the `hag.capacity_fit` gate checks against).
    fn total_budget(&self) -> Option<usize> {
        if self.budgets.is_empty()
            || self.budgets.contains(&usize::MAX)
        {
            return None;
        }
        Some(self.budgets.iter()
            .fold(0usize, |a, &b| a.saturating_add(b)))
    }

    /// The maintained plan: re-searches dirty shards only, splices
    /// cached clean shards, compiles the plan. Idempotent between
    /// deltas (plan-tier memo).
    pub fn plan(&mut self) -> (Arc<Hag>, Arc<ExecutionPlan>) {
        self.stats.plans += 1;
        // args: a = 1 when the memoized plan tier answered
        let mut sp = crate::obs_span!("session.plan");
        if let Some(hit) = self.cache.plan_at(self.fp, self.version) {
            self.stats.plan_cache_hits += 1;
            sp.set_args(1, 0);
            return hit;
        }
        let g = self.graph.to_graph();
        let hag = self.build_hag(&g, true);
        let plan = Arc::new(build_plan(&g, &hag, &self.spec.plan));
        if crate::analysis::verify_enabled() {
            crate::analysis::gate_plan(
                crate::obs::metrics::MetricsRegistry::global(),
                "session.plan", &g, &hag, &plan,
                self.total_budget());
        }
        self.cache.insert_plan(self.fp, self.version, hag.clone(),
                               plan.clone());
        (hag, plan)
    }

    /// From-scratch comparator: re-search **every** shard on the
    /// current graph, bypassing the cache entirely. The correctness
    /// contract is `plan() == plan_fresh()` after any delta sequence
    /// (`rust/tests/session.rs`; `repro stream` re-checks it at the
    /// end of every run).
    pub fn plan_fresh(&mut self) -> (Hag, ExecutionPlan) {
        let g = self.graph.to_graph();
        let hag = self.build_hag(&g, false);
        let plan = build_plan(&g, &hag, &self.spec.plan);
        ((*hag).clone(), plan)
    }

    /// The maintained HAG alone (same cache path as
    /// [`Session::plan`]).
    pub fn hag(&mut self) -> Arc<Hag> {
        self.plan().0
    }

    /// Lower into a full workload descriptor (HAG + plan + bucket).
    /// Requires dataset metadata ([`Session::new`]); the bucket
    /// carries the spec's capacity end-to-end, so the emitted bucket
    /// and any later train/infer plan from the same spec can never
    /// disagree.
    pub fn lower(&mut self) -> Result<Lowered> {
        let meta = self.meta.clone().ok_or_else(|| {
            anyhow!("session was built from a bare graph; use \
                     Session::new(&dataset, spec) to lower buckets")
        })?;
        let (hag, plan) = self.plan();
        let bucket = coordinator::bucket_for_parts(
            &meta.name, meta.f_in, meta.classes, meta.task,
            meta.num_graphs, &plan, self.spec.repr);
        Ok(Lowered {
            repr: self.spec.repr,
            hag: (*hag).clone(),
            plan: (*plan).clone(),
            bucket,
        })
    }
}

/// Emit `artifacts/buckets.json` for a set of datasets (both
/// representations each) — phase 1 of the two-phase AOT build. Every
/// knob, including capacity, comes from `spec`, so the buckets written
/// here are exactly the buckets a later `Session` with the same spec
/// trains or serves against.
pub fn emit_buckets(datasets: &[Dataset], spec: &LowerSpec,
                    out: &Path) -> Result<Vec<BucketSpec>> {
    let mut buckets = Vec::new();
    for ds in datasets {
        for repr in [Repr::GnnGraph, Repr::Hag] {
            let mut session =
                Session::new(ds, spec.clone().with_repr(repr));
            buckets.push(session.lower()?.bucket);
        }
    }
    coordinator::write_buckets_json(&buckets, out)?;
    Ok(buckets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hag::{check_equivalence, hag_search};
    use crate::partition::search_partitioned;
    use crate::partition::test_graphs::clique_ring;

    #[test]
    fn single_shard_matches_direct_pipeline() {
        let g = clique_ring(4, 5);
        let mut s = Session::from_graph(&g, LowerSpec::default());
        let (hag, plan) = s.plan();
        let cfg = LowerSpec::default().search_config(g.n());
        let (want, _) = hag_search(&g, &cfg);
        assert_eq!(*hag, want);
        let want_plan = build_plan(&g, &want,
                                   &crate::hag::PlanConfig::default());
        assert_eq!(*plan, want_plan);
    }

    #[test]
    fn sharded_session_matches_search_partitioned() {
        let g = clique_ring(8, 6);
        let spec = LowerSpec::default().with_shards(4);
        let mut s = Session::from_graph(&g, spec.clone());
        let (hag, _) = s.plan();
        let part = partition_bfs(&g, &PartitionConfig::new(4)
            .with_seed(spec.partition_seed));
        let (want, _) = search_partitioned(
            &g, &part, &spec.search_config(g.n()));
        assert_eq!(*hag, want,
                   "session must reproduce the partitioned driver");
        check_equivalence(&g, &hag).unwrap();
    }

    #[test]
    fn plan_is_memoized_between_deltas() {
        let g = clique_ring(3, 5);
        let mut s = Session::from_graph(&g, LowerSpec::default());
        let (h1, p1) = s.plan();
        let (h2, p2) = s.plan();
        assert!(Arc::ptr_eq(&h1, &h2) && Arc::ptr_eq(&p1, &p2));
        assert_eq!(s.stats().plan_cache_hits, 1);
        // a delta invalidates the memo
        assert!(s.apply(GraphDelta::EdgeInsert { src: 0, dst: 7 }));
        let (h3, _) = s.plan();
        assert!(!Arc::ptr_eq(&h1, &h3));
        assert_eq!(s.stats().plan_cache_hits, 1);
    }

    #[test]
    fn dirty_shard_only_replan() {
        let g = clique_ring(8, 6);
        let spec = LowerSpec::default().with_shards(4);
        let mut s = Session::from_graph(&g, spec);
        s.plan();
        assert_eq!(s.stats().shard_searches, 4);
        assert_eq!(s.dirty_shards(), 0);
        // an intra-shard delta: delete an edge inside node 0's shard
        let shard0 = s.shard_of(0);
        let mates: Vec<u32> = (0..g.n() as u32)
            .filter(|&v| v != 0 && s.shard_of(v) == shard0)
            .collect();
        let u = *mates.iter()
            .find(|&&u| g.neighbors(0).contains(&u))
            .expect("clique mate in shard");
        assert!(s.apply(GraphDelta::EdgeDelete { src: u, dst: 0 }));
        assert_eq!(s.dirty_shards(), 1);
        let (hag, plan) = s.plan();
        assert_eq!(s.stats().shard_searches, 5,
                   "exactly one shard re-searched");
        assert_eq!(s.stats().shard_cache_hits, 3);
        // identical to the from-scratch pipeline
        let (fhag, fplan) = s.plan_fresh();
        assert_eq!(*hag, fhag);
        assert_eq!(*plan, fplan);
        check_equivalence(&s.graph(), &hag).unwrap();
    }

    #[test]
    fn cross_shard_delta_skips_every_search() {
        let g = clique_ring(8, 6);
        let spec = LowerSpec::default().with_shards(4);
        let mut s = Session::from_graph(&g, spec);
        s.plan();
        let base = s.stats().shard_searches;
        // find two nodes in different shards with no edge between them
        let (mut a, mut b) = (0u32, 0u32);
        'outer: for u in 0..g.n() as u32 {
            for v in 0..g.n() as u32 {
                if s.shard_of(u) != s.shard_of(v)
                    && !g.neighbors(v).contains(&u)
                {
                    a = u;
                    b = v;
                    break 'outer;
                }
            }
        }
        assert!(s.apply(GraphDelta::EdgeInsert { src: a, dst: b }));
        assert_eq!(s.stats().cross_shard_deltas, 1);
        assert_eq!(s.dirty_shards(), 0);
        let (hag, plan) = s.plan();
        assert_eq!(s.stats().shard_searches, base,
                   "cross-shard edges only re-stitch");
        // ... but the edge is in the plan (direct aggregation)
        assert!(hag.in_edges[b as usize].contains(&a));
        let (fhag, fplan) = s.plan_fresh();
        assert_eq!(*hag, fhag);
        assert_eq!(*plan, fplan);
    }

    #[test]
    fn node_add_dirties_exactly_one_shard() {
        let g = clique_ring(8, 6);
        let spec = LowerSpec::default().with_shards(4);
        let mut s = Session::from_graph(&g, spec);
        s.plan();
        assert!(s.apply(GraphDelta::NodeAdd));
        let v = (s.n() - 1) as u32;
        let shard = s.shard_of(v);
        assert_eq!(s.dirty_shards(), 1);
        // wire it in and re-plan
        assert!(s.apply(GraphDelta::EdgeInsert { src: 0, dst: v }));
        let (hag, plan) = s.plan();
        assert_eq!(hag.n, s.n());
        assert!(hag.in_edges[v as usize].contains(&0));
        let (fhag, fplan) = s.plan_fresh();
        assert_eq!(*hag, fhag);
        assert_eq!(*plan, fplan);
        assert!(shard < 4);
    }

    #[test]
    fn noop_deltas_do_not_invalidate() {
        let g = clique_ring(3, 5);
        let mut s = Session::from_graph(&g, LowerSpec::default());
        let (_, p1) = s.plan();
        // duplicate insert / missing delete / out-of-range
        let u = g.neighbors(0)[0];
        assert!(!s.apply(GraphDelta::EdgeInsert { src: u, dst: 0 }));
        assert!(!s.apply(GraphDelta::EdgeDelete { src: 0, dst: 0 }));
        assert!(!s.apply(GraphDelta::EdgeInsert { src: 999, dst: 0 }));
        assert_eq!(s.stats().noops, 3);
        let (_, p2) = s.plan();
        assert!(Arc::ptr_eq(&p1, &p2), "no-ops keep the memo");
    }

    #[test]
    fn shard_terms_track_the_latest_build() {
        let g = clique_ring(8, 6);
        let spec = LowerSpec::default().with_shards(4);
        let mut s = Session::from_graph(&g, spec);
        assert!(s.shard_terms().is_empty(), "nothing built yet");
        let (hag, _) = s.plan();
        let terms = s.shard_terms().to_vec();
        assert_eq!(terms.len(), 4);
        assert!(terms.iter().all(|&(a, t)| a > 0 && t >= a),
                "transfers dominate aggregations per Definition 2");
        // per-shard totals undercount the stitched HAG by exactly
        // the cross-shard edges appended at stitch time
        let (asum, tsum): (usize, usize) = terms.iter().fold(
            (0, 0), |(a, t), &(sa, st)| (a + sa, t + st));
        assert!(asum <= hag.aggregations());
        assert!(tsum <= hag.data_transfers());

        // single shard: terms are exactly the stitched totals
        let mut s1 =
            Session::from_graph(&g, LowerSpec::default());
        let (h1, _) = s1.plan();
        assert_eq!(s1.shard_terms(),
                   &[(h1.aggregations(), h1.data_transfers())]);
    }

    #[test]
    fn gnn_baseline_tracks_the_graph() {
        let g = clique_ring(3, 4);
        let spec = LowerSpec::default().with_repr(Repr::GnnGraph);
        let mut s = Session::from_graph(&g, spec);
        let (h1, p1) = s.plan();
        assert_eq!(h1.agg_nodes.len(), 0);
        assert_eq!(p1.levels, 0);
        assert!(s.apply(GraphDelta::NodeAdd));
        let v = (s.n() - 1) as u32;
        assert!(s.apply(GraphDelta::EdgeInsert { src: 1, dst: v }));
        let (h2, _) = s.plan();
        assert_eq!(h2.n, g.n() + 1);
        assert!(h2.in_edges[v as usize].contains(&1));
        assert_eq!(s.stats().shard_searches, 0,
                   "baseline never searches");
    }
}
