//! Edge-locality graph partitioner: BFS-grown, degree-balanced shards.
//!
//! The growth rule is locality-greedy BFS (a lightweight cousin of
//! Fennel/LDG streaming partitioners): shards are grown one at a time
//! from a high-degree seed, and the frontier is expanded in order of
//! *affinity* — the number of already-claimed neighbors a candidate
//! has — so tightly-knit regions (the communities whose shared
//! neighborhoods Algorithm 3 harvests) are swallowed whole before the
//! shard crosses into the next region. Balance is degree-weighted
//! (`w(v) = 1 + deg_total(v)`), since HAG-search work is edge-, not
//! node-, proportional.
//!
//! Guarantees (asserted by `rust/tests/partition.rs`):
//! * every node lands in **exactly one** shard;
//! * every shard's weight is `<= max(ideal * balance, ideal + w_max)`
//!   where `ideal = total_weight / n_shards` and `w_max` is the
//!   heaviest single node (one node can always overshoot by itself);
//! * deterministic in `(graph, config)` — the seed only perturbs seed-
//!   node choice, never introduces nondeterminism.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::graph::Graph;
use crate::util::{FxHashSet, Rng};

/// Default `--partition-seed` (any fixed value; exposed so the CLI,
/// coordinator and tests agree on it).
pub const DEFAULT_PARTITION_SEED: u64 = 0x9a61;

/// Partitioner knobs.
#[derive(Debug, Clone)]
pub struct PartitionConfig {
    /// Number of shards to grow.
    pub n_shards: usize,
    /// Seed-node selection seed (`--partition-seed`).
    pub seed: u64,
    /// Hard cap on shard weight relative to the ideal (`>= 1.0`);
    /// growth skips nodes that would push a shard past
    /// `ideal * balance`.
    pub balance: f64,
}

impl PartitionConfig {
    pub fn new(n_shards: usize) -> Self {
        PartitionConfig {
            n_shards: n_shards.max(1),
            seed: DEFAULT_PARTITION_SEED,
            balance: 1.25,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_balance(mut self, balance: f64) -> Self {
        self.balance = balance.max(1.0);
        self
    }
}

/// A disjoint, exhaustive node partition.
#[derive(Debug, Clone)]
pub struct Partition {
    pub n_shards: usize,
    /// `shard_of[v]` in `0..n_shards`.
    pub shard_of: Vec<u32>,
    /// Per shard: member node ids, ascending.
    pub members: Vec<Vec<u32>>,
}

/// Edge-cut / balance / halo accounting for a partition — the "is this
/// sharding any good" report behind `repro partition-stats`.
#[derive(Debug, Clone)]
pub struct PartitionReport {
    pub n_shards: usize,
    /// Nodes per shard.
    pub shard_nodes: Vec<usize>,
    /// Intra-shard aggregation edges per shard (both endpoints inside).
    pub shard_intra_edges: Vec<usize>,
    /// Distinct out-of-shard in-neighbors referenced per shard (the
    /// halo a distributed execution would have to replicate).
    pub shard_halo: Vec<usize>,
    /// Degree weight per shard (`sum of 1 + deg_total`).
    pub shard_weight: Vec<f64>,
    /// Edges whose endpoints live in different shards; these fall back
    /// to direct aggregation in the stitched HAG.
    pub cut_edges: usize,
    /// `cut_edges / |E|`.
    pub cut_frac: f64,
    /// `total_weight / n_shards`.
    pub ideal_weight: f64,
    /// `max(shard_weight) / ideal_weight` — the achieved imbalance.
    pub balance: f64,
}

impl Partition {
    /// The trivial one-shard partition (whole-graph fallback).
    pub fn single(n: usize) -> Partition {
        Partition {
            n_shards: 1,
            shard_of: vec![0; n],
            members: vec![(0..n as u32).collect()],
        }
    }

    /// Append a brand-new node (id = current node count) to `shard`.
    /// New ids are maximal, so the ascending-members invariant holds
    /// without a sort. Used by the session subsystem to keep the
    /// partition covering a growing graph.
    pub fn push_node(&mut self, shard: usize) -> u32 {
        assert!(shard < self.n_shards, "shard {shard} out of range");
        let v = self.shard_of.len() as u32;
        self.shard_of.push(shard as u32);
        self.members[shard].push(v);
        v
    }

    /// The shard with the fewest member nodes (ties: lowest id) — the
    /// deterministic destination for nodes added after partitioning.
    pub fn lightest_shard(&self) -> usize {
        (0..self.n_shards)
            .min_by_key(|&s| (self.members[s].len(), s))
            .unwrap_or(0)
    }

    /// Local (within-shard) index of every node; inverse of
    /// `members[shard_of[v]][local_id[v]] == v`.
    pub fn local_ids(&self) -> Vec<u32> {
        let n = self.shard_of.len();
        let mut local = vec![0u32; n];
        for mem in &self.members {
            for (i, &v) in mem.iter().enumerate() {
                local[v as usize] = i as u32;
            }
        }
        local
    }

    /// Compute the edge-cut / halo / balance report against `g`.
    pub fn report(&self, g: &Graph) -> PartitionReport {
        let k = self.n_shards;
        let mut intra = vec![0usize; k];
        let mut halo_sets: Vec<FxHashSet<u32>> =
            (0..k).map(|_| FxHashSet::default()).collect();
        let mut cut = 0usize;
        for (v, ns) in g.iter() {
            let sv = self.shard_of[v as usize] as usize;
            for &u in ns {
                if self.shard_of[u as usize] as usize == sv {
                    intra[sv] += 1;
                } else {
                    cut += 1;
                    halo_sets[sv].insert(u);
                }
            }
        }
        // Same weight metric the growth loop balances: 1 + total
        // (in + out) degree.
        let mut tdeg = vec![0u32; g.n()];
        for (v, ns) in g.iter() {
            tdeg[v as usize] += ns.len() as u32;
            for &u in ns {
                tdeg[u as usize] += 1;
            }
        }
        let weights: Vec<f64> = (0..k)
            .map(|s| {
                self.members[s]
                    .iter()
                    .map(|&v| 1.0 + tdeg[v as usize] as f64)
                    .sum()
            })
            .collect();
        let total: f64 = weights.iter().sum();
        let ideal = total / k as f64;
        let max_w = weights.iter().cloned().fold(0.0f64, f64::max);
        PartitionReport {
            n_shards: k,
            shard_nodes: self.members.iter().map(|m| m.len()).collect(),
            shard_intra_edges: intra,
            shard_halo: halo_sets.iter().map(|h| h.len()).collect(),
            shard_weight: weights,
            cut_edges: cut,
            cut_frac: if g.e() == 0 {
                0.0
            } else {
                cut as f64 / g.e() as f64
            },
            ideal_weight: ideal,
            balance: if ideal > 0.0 { max_w / ideal } else { 1.0 },
        }
    }
}

/// Symmetrized adjacency in flat CSR form: for every aggregation edge
/// `u -> v`, both `u in adj(v)` and `v in adj(u)`. May contain
/// duplicates when the input already has both directions — harmless
/// for BFS/affinity (a mutual edge simply counts double).
fn build_adjacency(g: &Graph) -> (Vec<u32>, Vec<u32>) {
    let n = g.n();
    let mut deg = vec![0u32; n];
    for (v, ns) in g.iter() {
        deg[v as usize] += ns.len() as u32;
        for &u in ns {
            deg[u as usize] += 1;
        }
    }
    let mut offsets = vec![0u32; n + 1];
    for v in 0..n {
        offsets[v + 1] = offsets[v] + deg[v];
    }
    let mut fill = offsets.clone();
    let mut flat = vec![0u32; offsets[n] as usize];
    for (v, ns) in g.iter() {
        for &u in ns {
            flat[fill[v as usize] as usize] = u;
            fill[v as usize] += 1;
            flat[fill[u as usize] as usize] = v;
            fill[u as usize] += 1;
        }
    }
    (offsets, flat)
}

/// Grow `cfg.n_shards` BFS shards over `g`. Every node is assigned to
/// exactly one shard; see the module docs for the balance guarantee.
pub fn partition_bfs(g: &Graph, cfg: &PartitionConfig) -> Partition {
    let n = g.n();
    let k = cfg.n_shards.max(1);
    let mut shard_of = vec![u32::MAX; n];
    if n == 0 {
        return Partition {
            n_shards: k,
            shard_of,
            members: vec![Vec::new(); k],
        };
    }

    let (adj_off, adj) = build_adjacency(g);
    let adj_of = |v: u32| -> &[u32] {
        &adj[adj_off[v as usize] as usize..adj_off[v as usize + 1] as usize]
    };
    let weight = |v: u32| -> f64 {
        1.0 + (adj_off[v as usize + 1] - adj_off[v as usize]) as f64
    };
    let total_weight: f64 = (n + adj.len()) as f64;
    let ideal = total_weight / k as f64;
    let cap = ideal * cfg.balance.max(1.0);

    // Seed candidates: nodes by adjacency degree descending (ties: id
    // ascending). The rng picks among the first few unassigned so
    // different `--partition-seed`s explore different growth orders.
    let mut by_degree: Vec<u32> = (0..n as u32).collect();
    by_degree.sort_by_key(|&v| {
        (Reverse(adj_off[v as usize + 1] - adj_off[v as usize]), v)
    });
    let mut rng = Rng::seed_from_u64(cfg.seed);

    let mut weights = vec![0f64; k];
    // Affinity of an unassigned node to the currently growing shard,
    // epoch-stamped so no per-shard reset pass is needed.
    let mut gain = vec![0u32; n];
    let mut stamp = vec![0u32; n];
    // Monotone cursor into `by_degree` for reseeding: assignment is
    // permanent, so skipped-assigned prefix entries never need a
    // rescan. Keeps many-component graphs (disjoint-union batching)
    // at amortized O(n) reseed cost instead of O(components * n).
    let mut seed_cursor = 0usize;

    for s in 0..k {
        let epoch = s as u32 + 1;
        let mut heap: BinaryHeap<(u32, Reverse<u32>)> = BinaryHeap::new();
        while weights[s] < ideal {
            // Pop the highest-affinity live frontier node; reseed from
            // the degree list when the frontier is exhausted
            // (disconnected graphs, or all frontier nodes claimed).
            let (v, reseeded) = loop {
                match heap.pop() {
                    Some((c, Reverse(v))) => {
                        if shard_of[v as usize] != u32::MAX {
                            continue; // claimed meanwhile
                        }
                        if stamp[v as usize] != epoch
                            || gain[v as usize] != c
                        {
                            continue; // stale entry
                        }
                        break (Some(v), false);
                    }
                    None => {
                        while seed_cursor < n
                            && shard_of[by_degree[seed_cursor] as usize]
                                != u32::MAX
                        {
                            seed_cursor += 1;
                        }
                        // Candidates: up to 8 unassigned nodes from a
                        // bounded window past the cursor (the window
                        // caps per-reseed cost; entry 0 is always
                        // unassigned when any node remains).
                        let cands: Vec<u32> = by_degree[seed_cursor..]
                            .iter()
                            .copied()
                            .take(64)
                            .filter(|&v| shard_of[v as usize] == u32::MAX)
                            .take(8)
                            .collect();
                        // A shard's *first* seed is deterministically
                        // the heaviest unassigned node (hubs anchor
                        // their community; an rng pick could start at
                        // a bridge and drag two regions into one
                        // shard). Later reseeds — the remainder is
                        // disconnected from everything claimed so far
                        // — are where `--partition-seed` explores
                        // different component orders.
                        let pick = if weights[s] == 0.0 {
                            cands.first().copied()
                        } else {
                            rng.choose(&cands).copied()
                        };
                        break (pick, true);
                    }
                }
            };
            let Some(v) = v else { break }; // no unassigned nodes left
            let w = weight(v);
            if weights[s] > 0.0 && weights[s] + w > cap {
                // Would blow the balance cap: leave the node for a
                // later shard (or the leftover pass). Frontier entries
                // are finite, so skipping them terminates; a *fresh
                // seed* failing the cap means nothing left fits this
                // shard — close it out rather than reseeding forever.
                if reseeded {
                    break;
                }
                continue;
            }
            shard_of[v as usize] = s as u32;
            weights[s] += w;
            for &u in adj_of(v) {
                if shard_of[u as usize] != u32::MAX {
                    continue;
                }
                if stamp[u as usize] != epoch {
                    stamp[u as usize] = epoch;
                    gain[u as usize] = 0;
                }
                gain[u as usize] += 1;
                heap.push((gain[u as usize], Reverse(u)));
            }
        }
    }

    // Leftover pass: nodes skipped by every cap (or unreachable after
    // all shards filled) go to the lightest shard. The lightest shard
    // is always <= ideal, so this keeps the balance bound.
    for v in 0..n as u32 {
        if shard_of[v as usize] == u32::MAX {
            let s = (0..k)
                .min_by(|&a, &b| {
                    weights[a].partial_cmp(&weights[b]).unwrap()
                })
                .unwrap();
            shard_of[v as usize] = s as u32;
            weights[s] += weight(v);
        }
    }

    let mut members = vec![Vec::new(); k];
    for v in 0..n as u32 {
        members[shard_of[v as usize] as usize].push(v);
    }
    Partition { n_shards: k, shard_of, members }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_cliques() -> Graph {
        // two K5s joined by a single bridge edge — the partitioner must
        // find the obvious 2-cut.
        let mut edges = Vec::new();
        for base in [0u32, 5] {
            for i in 0..5 {
                for j in 0..5 {
                    if i != j {
                        edges.push((base + i, base + j));
                    }
                }
            }
        }
        edges.push((4, 5));
        edges.push((5, 4));
        Graph::from_edges(10, &edges)
    }

    #[test]
    fn exhaustive_and_disjoint() {
        let g = two_cliques();
        let p = partition_bfs(&g, &PartitionConfig::new(2));
        assert!(p.shard_of.iter().all(|&s| s < 2));
        let total: usize = p.members.iter().map(|m| m.len()).sum();
        assert_eq!(total, g.n());
        for (s, mem) in p.members.iter().enumerate() {
            for &v in mem {
                assert_eq!(p.shard_of[v as usize], s as u32);
            }
            assert!(mem.windows(2).all(|w| w[0] < w[1]), "sorted");
        }
    }

    #[test]
    fn finds_the_obvious_cut() {
        let g = two_cliques();
        let p = partition_bfs(&g, &PartitionConfig::new(2));
        let r = p.report(&g);
        // only the bridge (2 directed edges) should be cut
        assert_eq!(r.cut_edges, 2, "{r:?}");
        assert_eq!(r.shard_nodes, vec![5, 5]);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = two_cliques();
        let a = partition_bfs(&g, &PartitionConfig::new(3).with_seed(9));
        let b = partition_bfs(&g, &PartitionConfig::new(3).with_seed(9));
        assert_eq!(a.shard_of, b.shard_of);
    }

    #[test]
    fn more_shards_than_nodes() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]);
        let p = partition_bfs(&g, &PartitionConfig::new(8));
        assert_eq!(p.members.iter().map(|m| m.len()).sum::<usize>(), 3);
        assert_eq!(p.members.len(), 8);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_edges(0, &[]);
        let p = partition_bfs(&g, &PartitionConfig::new(4));
        assert_eq!(p.n_shards, 4);
        let r = p.report(&g);
        assert_eq!(r.cut_edges, 0);
    }
}
