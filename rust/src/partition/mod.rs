//! Partitioned HAG search: graph sharding + parallel per-shard search.
//!
//! Algorithm 3 is a global greedy pass — single-threaded, whole-graph
//! state — which caps both search throughput and the graph sizes the
//! coordinator can lower. This subsystem trades a bounded amount of
//! search quality for near-linear parallel speedup:
//!
//! 1. [`partition_bfs`] grows degree-balanced, locality-greedy BFS
//!    shards and reports the edge cut ([`PartitionReport`]);
//! 2. [`search_sharded`] runs [`hag_search`] *independently* per shard
//!    on a `std::thread` worker pool (shard-local candidate sets — the
//!    restricted-candidate regime under which greedy hierarchical
//!    aggregation degrades gracefully);
//! 3. [`stitch_hags`] lifts the shard HAGs into one global [`Hag`]:
//!    local slots are remapped into the global slot space and every
//!    cross-shard edge falls back to direct aggregation.
//!
//! The stitched HAG is always valid and Theorem-1 equivalent, and its
//! `cost_core` is `sum_s cost_core(shard_s) + cut_edges <= |E|`:
//! sharding can only *miss* merges (those straddling the cut), never
//! add cost. The quality gap is therefore governed by the partitioner's
//! cut fraction, which `repro partition-stats` reports per shard.
//!
//! This module is also the seam future scale work plugs into:
//! per-shard plan caching, distributed per-shard training, and
//! multi-device execution all consume the same
//! `Partition -> [subgraph] -> stitch` contract.

pub mod partitioner;
pub mod stitch;

pub use partitioner::{partition_bfs, Partition, PartitionConfig,
                      PartitionReport, DEFAULT_PARTITION_SEED};
pub use stitch::{stitch_hags, subgraph};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::graph::Graph;
use crate::hag::{hag_search, hag_search_with_scratch, AggregateKind,
                 Hag, SearchConfig, SearchScratch, SearchStats};

/// Statistics for one sharded search run.
#[derive(Debug, Clone)]
pub struct ShardedStats {
    /// Per-shard search stats, shard order. A single entry when the
    /// driver fell back to whole-graph search (see [`search_sharded`]).
    pub per_shard: Vec<SearchStats>,
    /// Partition quality (edge cut, halo, balance).
    pub report: PartitionReport,
    /// Worker threads actually used.
    pub threads: usize,
    /// End-to-end wall time: per-shard searches + stitch (+ the
    /// partitioning itself when driven via [`search_sharded`] /
    /// [`search_sharded_seeded`]).
    pub wall_ms: f64,
    /// Whole-run totals in [`SearchStats`] shape (before/after counts
    /// are for the stitched HAG vs the input graph).
    pub total: SearchStats,
}

/// Partition `g` into `n_shards` BFS shards (default partition seed)
/// and search each in parallel. See [`search_partitioned`].
pub fn search_sharded(g: &Graph, n_shards: usize, cfg: &SearchConfig)
                      -> (Hag, ShardedStats) {
    search_sharded_seeded(g, n_shards, cfg, DEFAULT_PARTITION_SEED)
}

/// [`search_sharded`] with an explicit partition seed
/// (`--partition-seed`). Unlike calling [`search_partitioned`] with a
/// prebuilt partition, the reported `wall_ms` here *includes* the
/// partitioning step, so speedup-vs-single comparisons are honest
/// end-to-end numbers.
pub fn search_sharded_seeded(g: &Graph, n_shards: usize,
                             cfg: &SearchConfig, seed: u64)
                             -> (Hag, ShardedStats) {
    // Clamp here, not just at the CLI boundary: library callers (the
    // coordinator, the incremental engine's rebuild path) may compute
    // shard counts and 0 must mean "whole-graph", never a panic.
    let n_shards = n_shards.max(1);
    if n_shards <= 1 || cfg.kind == AggregateKind::Sequential {
        // Whole-graph fallback (see search_partitioned): don't pay
        // for a BFS partition that would be discarded.
        return search_partitioned(g, &Partition::single(g.n()), cfg);
    }
    let t0 = std::time::Instant::now();
    let part = partition_bfs(
        g, &PartitionConfig::new(n_shards).with_seed(seed));
    let (hag, mut stats) = search_partitioned(g, &part, cfg);
    stats.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    stats.total.elapsed_ms = stats.wall_ms;
    (hag, stats)
}

/// Run the per-shard searches over an existing partition and stitch.
///
/// Fallback: with a single shard, or under sequential AGGREGATE
/// (ordered-prefix covers do not decompose across a cut — cross-shard
/// operands would have to interleave back into the canonical order),
/// this degrades to one whole-graph [`hag_search`]; `stats.per_shard`
/// then has a single entry and `stats.threads == 1`.
pub fn search_partitioned(g: &Graph, part: &Partition,
                          cfg: &SearchConfig) -> (Hag, ShardedStats) {
    let t0 = std::time::Instant::now();
    let report = part.report(g);

    if part.n_shards <= 1 || cfg.kind == AggregateKind::Sequential {
        let (hag, stats) = hag_search(g, cfg);
        let mut total = stats.clone();
        total.elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
        let wall_ms = total.elapsed_ms;
        return (hag, ShardedStats {
            per_shard: vec![stats],
            report,
            threads: 1,
            wall_ms,
            total,
        });
    }

    let k = part.n_shards;
    let local = part.local_ids();
    let subs: Vec<Graph> =
        (0..k).map(|s| subgraph(g, part, &local, s)).collect();
    let caps = split_capacity(cfg.capacity, &subs);
    let cfgs: Vec<SearchConfig> = caps
        .into_iter()
        .map(|c| cfg.clone().with_capacity(c))
        .collect();

    let threads = k.min(worker_parallelism()).max(1);
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<(Hag, SearchStats)>>> =
        (0..k).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|sc| {
        for _ in 0..threads {
            sc.spawn(|| {
                // One arena per worker, reused across every shard the
                // worker drains: the kernel's tables and CSR buffers
                // are allocated once per pool, not once per shard.
                let mut scratch = SearchScratch::new();
                loop {
                    let s = next.fetch_add(1, Ordering::Relaxed);
                    if s >= k {
                        break;
                    }
                    let _sp = crate::obs_span!("partition.shard_search",
                                               s, subs[s].n());
                    let r = hag_search_with_scratch(&subs[s], &cfgs[s],
                                                    &mut scratch);
                    *results[s].lock().unwrap() = Some(r);
                }
            });
        }
    });

    let mut locals = Vec::with_capacity(k);
    let mut per_shard = Vec::with_capacity(k);
    for cell in results {
        let (h, s) = cell.into_inner().unwrap()
            .expect("worker completed every shard");
        locals.push(h);
        per_shard.push(s);
    }
    let hag = stitch_hags(g, part, &locals);
    if crate::analysis::verify_enabled() {
        crate::analysis::gate_stitched(
            crate::obs::metrics::MetricsRegistry::global(),
            "partition.stitch", g, part, &locals, &hag);
    }

    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let total = SearchStats {
        iterations: per_shard.iter().map(|s| s.iterations).sum(),
        agg_nodes: hag.agg_nodes.len(),
        aggregations_before: g
            .iter()
            .map(|(_, ns)| ns.len().saturating_sub(1))
            .sum(),
        aggregations_after: hag.aggregations(),
        transfers_before: g.e(),
        transfers_after: hag.data_transfers(),
        elapsed_ms: wall_ms,
        rounds: per_shard.iter().map(|s| s.rounds).sum(),
        heap_pops: per_shard.iter().map(|s| s.heap_pops).sum(),
        stale_pops: per_shard.iter().map(|s| s.stale_pops).sum(),
        // per-worker arenas: the max is the honest per-thread figure
        peak_scratch_bytes: per_shard
            .iter()
            .map(|s| s.peak_scratch_bytes)
            .max()
            .unwrap_or(0),
    };
    (hag, ShardedStats { per_shard, report, threads, wall_ms, total })
}

/// Worker-pool width when `available_parallelism()` errors (sandboxes
/// and some cgroup configurations return `Err`, not `1`): falling all
/// the way back to a single worker would silently serialize the whole
/// sharded path, so degrade to a modest fixed pool instead. Per-shard
/// searches are independent, so oversubscription only costs scheduling.
const FALLBACK_WORKERS: usize = 4;

/// `available_parallelism()` with the graceful
/// [`FALLBACK_WORKERS`] degradation. Shared with the session
/// subsystem's dirty-shard re-search pool.
pub(crate) fn worker_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(FALLBACK_WORKERS)
}

/// Split a global `|V_A|` budget across shards proportionally to their
/// intra-shard edge counts (search opportunity is edge-proportional);
/// the floored remainder goes to the edge-heaviest shards. The split
/// never exceeds the global budget.
pub fn split_capacity(capacity: usize, subs: &[Graph]) -> Vec<usize> {
    let edges: Vec<usize> = subs.iter().map(|g| g.e()).collect();
    split_capacity_by_edges(capacity, &edges)
}

/// [`split_capacity`] over bare intra-edge counts — for callers (the
/// session subsystem pinning its creation-time split) that know the
/// per-shard edge counts without materializing the subgraphs.
pub fn split_capacity_by_edges(capacity: usize,
                               intra_edges: &[usize]) -> Vec<usize> {
    let k = intra_edges.len();
    if capacity == usize::MAX {
        return vec![usize::MAX; k];
    }
    let e_tot: usize = intra_edges.iter().sum();
    if e_tot == 0 || k == 0 {
        return vec![capacity; k.max(1)];
    }
    let mut caps: Vec<usize> = intra_edges
        .iter()
        .map(|&e| {
            ((capacity as u128 * e as u128) / e_tot as u128) as usize
        })
        .collect();
    let mut rem = capacity - caps.iter().sum::<usize>();
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by_key(|&s| std::cmp::Reverse(intra_edges[s]));
    let mut i = 0;
    while rem > 0 {
        caps[order[i % k]] += 1;
        rem -= 1;
        i += 1;
    }
    caps
}

/// Shared test-graph generators for the partition submodule tests.
#[cfg(test)]
pub(crate) mod test_graphs {
    use crate::graph::Graph;

    /// `cliques` directed K_`size` blocks, consecutive blocks joined
    /// by one directed ring edge between their base nodes.
    pub(crate) fn clique_ring(cliques: usize, size: usize) -> Graph {
        let n = cliques * size;
        let mut edges = Vec::new();
        for c in 0..cliques {
            let b = (c * size) as u32;
            for i in 0..size as u32 {
                for j in 0..size as u32 {
                    if i != j {
                        edges.push((b + i, b + j));
                    }
                }
            }
            let nxt = (((c + 1) % cliques) * size) as u32;
            edges.push((b, nxt));
        }
        Graph::from_edges(n, &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::test_graphs::clique_ring;
    use super::*;
    use crate::hag::check_equivalence;

    #[test]
    fn sharded_search_valid_and_equivalent() {
        let g = clique_ring(8, 6);
        let cfg = SearchConfig::paper_default(g.n());
        let (hag, stats) = search_sharded(&g, 4, &cfg);
        hag.validate().unwrap();
        check_equivalence(&g, &hag).unwrap();
        assert_eq!(stats.per_shard.len(), 4);
        assert!(hag.cost_core() <= g.e());
        assert!(stats.total.aggregations_after
                <= stats.total.aggregations_before);
    }

    #[test]
    fn sharded_matches_single_on_disjoint_cliques() {
        // No ring edges -> zero cut -> sharded must find everything the
        // whole-graph search finds (clique HAGs are shard-local).
        let mut edges = Vec::new();
        for c in 0..4 {
            let b = (c * 5) as u32;
            for i in 0..5u32 {
                for j in 0..5u32 {
                    if i != j {
                        edges.push((b + i, b + j));
                    }
                }
            }
        }
        let g = Graph::from_edges(20, &edges);
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
            capacity: usize::MAX,
            kind: AggregateKind::Set,
            pair_cap: usize::MAX,
        };
        let (single, _) = hag_search(&g, &cfg);
        let (sharded, stats) = search_sharded(&g, 4, &cfg);
        assert_eq!(stats.report.cut_edges, 0);
        check_equivalence(&g, &sharded).unwrap();
        assert_eq!(sharded.cost_core(), single.cost_core());
    }

    #[test]
    fn one_shard_equals_plain_search() {
        let g = clique_ring(3, 5);
        let cfg = SearchConfig::paper_default(g.n());
        let (a, _) = hag_search(&g, &cfg);
        let (b, stats) = search_sharded(&g, 1, &cfg);
        assert_eq!(stats.threads, 1);
        assert_eq!(a.cost_core(), b.cost_core());
        assert_eq!(a.agg_nodes, b.agg_nodes);
    }

    #[test]
    fn zero_shards_clamps_to_whole_graph() {
        // Regression: library callers may pass 0; it must behave as 1
        // (whole-graph fallback), not panic or divide by zero.
        let g = clique_ring(3, 5);
        let cfg = SearchConfig::paper_default(g.n());
        let (a, _) = hag_search(&g, &cfg);
        let (b, stats) = search_sharded(&g, 0, &cfg);
        assert_eq!(stats.per_shard.len(), 1);
        assert_eq!(stats.threads, 1);
        assert_eq!(a.agg_nodes, b.agg_nodes);
        check_equivalence(&g, &b).unwrap();
    }

    #[test]
    fn sequential_falls_back_to_whole_graph() {
        let g = clique_ring(3, 4);
        let cfg = SearchConfig::paper_default(g.n())
            .with_kind(AggregateKind::Sequential);
        let (hag, stats) = search_sharded(&g, 4, &cfg);
        assert_eq!(stats.per_shard.len(), 1);
        assert_eq!(stats.threads, 1);
        check_equivalence(&g, &hag).unwrap();
    }

    #[test]
    fn capacity_split_respects_budget() {
        let g = clique_ring(6, 5);
        let cfg = SearchConfig::paper_default(g.n()).with_capacity(7);
        let (hag, _) = search_sharded(&g, 3, &cfg);
        assert!(hag.agg_nodes.len() <= 7,
                "global capacity violated: {}", hag.agg_nodes.len());
    }

    #[test]
    fn sharded_search_is_deterministic() {
        let g = clique_ring(5, 6);
        let cfg = SearchConfig::paper_default(g.n());
        let (a, _) = search_sharded(&g, 4, &cfg);
        let (b, _) = search_sharded(&g, 4, &cfg);
        assert_eq!(a.agg_nodes, b.agg_nodes);
        assert_eq!(a.in_edges, b.in_edges);
    }
}
