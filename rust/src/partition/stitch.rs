//! Shard extraction and HAG stitching.
//!
//! `subgraph` projects one shard's *intra-shard* edges into a local
//! node space; `stitch_hags` lifts the per-shard search results back
//! into one global [`Hag`]:
//!
//! * shard-local original ids map through `members[s]`;
//! * shard-local aggregation slots are remapped into a global slot
//!   space, shard blocks concatenated in shard order — creation order
//!   stays topological because a shard's agg nodes only ever reference
//!   that shard's earlier slots (or original nodes, which all precede
//!   every agg slot);
//! * cross-shard edges fall back to direct aggregation: each is
//!   appended verbatim to its consumer's in-list.
//!
//! Cost accounting: the stitched HAG's `cost_core` is exactly
//! `sum_s cost_core(shard_s) + cut_edges`. Since per-shard search never
//! increases a shard's cost above its trivial `|E_s|` (every merge pays
//! for itself), the stitched cost is never worse than the input
//! graph's `|E|` — partitioning can only *miss* merges, never add
//! aggregations. `rust/tests/partition.rs` asserts this property over
//! the seeded generator families.

use crate::graph::Graph;
use crate::hag::{AggNode, AggregateKind, Hag, Slot};

use super::partitioner::Partition;

/// Extract shard `s` of `part` as a standalone graph over local ids
/// `0..members[s].len()` (ascending-id order preserved), keeping only
/// intra-shard edges. `local_ids` must come from
/// [`Partition::local_ids`].
pub fn subgraph(g: &Graph, part: &Partition, local_ids: &[u32],
                s: usize) -> Graph {
    let mem = &part.members[s];
    let mut offsets = Vec::with_capacity(mem.len() + 1);
    let mut neighbors = Vec::new();
    offsets.push(0u32);
    for &v in mem {
        for &u in g.neighbors(v) {
            if part.shard_of[u as usize] == s as u32 {
                neighbors.push(local_ids[u as usize]);
            }
        }
        offsets.push(neighbors.len() as u32);
    }
    // Input lists are ascending and local ids are order-preserving
    // within a shard, so the CSR invariant holds without a sort.
    Graph::from_csr(offsets, neighbors)
}

/// Stitch per-shard HAGs (one per `part.members` entry, over the
/// corresponding [`subgraph`]) into a single HAG over `g`. Cross-shard
/// edges are appended as direct aggregation edges.
///
/// Only `AggregateKind::Set` decomposes this way — ordered (sequential)
/// covers cannot interleave cross-shard operands back into the
/// canonical order — so the caller must not pass sequential shard HAGs.
///
/// Generic over `Borrow<Hag>` so the session subsystem can splice
/// cache-shared `Arc<Hag>`s without cloning each shard's HAG.
pub fn stitch_hags<H: std::borrow::Borrow<Hag>>(
    g: &Graph, part: &Partition, locals: &[H]) -> Hag {
    assert_eq!(locals.len(), part.n_shards, "one HAG per shard");
    assert!(locals.iter()
                .all(|h| h.borrow().kind == AggregateKind::Set),
            "sharded stitching is Set-AGGREGATE only");
    let n = g.n();
    let total_agg: usize =
        locals.iter().map(|h| h.borrow().agg_nodes.len()).sum();
    let mut agg_nodes = Vec::with_capacity(total_agg);
    let mut in_edges: Vec<Vec<Slot>> = vec![Vec::new(); n];

    let mut base = n; // first global slot of the current shard's block
    for (s, lh) in locals.iter().enumerate() {
        let lh = lh.borrow();
        let mem = &part.members[s];
        assert_eq!(lh.n, mem.len(), "shard {s}: HAG/member mismatch");
        let remap = |slot: Slot| -> Slot {
            if (slot as usize) < lh.n {
                mem[slot as usize]
            } else {
                (base + (slot as usize - lh.n)) as Slot
            }
        };
        for a in &lh.agg_nodes {
            agg_nodes.push(AggNode {
                left: remap(a.left),
                right: remap(a.right),
            });
        }
        for (lv, list) in lh.in_edges.iter().enumerate() {
            let v = mem[lv] as usize;
            in_edges[v] = list.iter().map(|&x| remap(x)).collect();
        }
        base += lh.agg_nodes.len();
    }

    // Cross-shard edges: direct aggregation from the original node.
    for (v, ns) in g.iter() {
        let sv = part.shard_of[v as usize];
        for &u in ns {
            if part.shard_of[u as usize] != sv {
                in_edges[v as usize].push(u);
            }
        }
    }

    Hag { n, agg_nodes, in_edges, kind: AggregateKind::Set }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hag::{check_equivalence, hag_search, SearchConfig};
    use crate::partition::partitioner::{partition_bfs, PartitionConfig};
    use crate::partition::test_graphs::clique_ring as ring_of_cliques;

    #[test]
    fn subgraph_keeps_only_intra_edges() {
        let g = ring_of_cliques(4, 5);
        let p = partition_bfs(&g, &PartitionConfig::new(4));
        let local = p.local_ids();
        let mut total_local_edges = 0;
        for s in 0..4 {
            let sg = subgraph(&g, &p, &local, s);
            assert_eq!(sg.n(), p.members[s].len());
            total_local_edges += sg.e();
        }
        let r = p.report(&g);
        assert_eq!(total_local_edges + r.cut_edges, g.e());
    }

    #[test]
    fn stitched_trivial_hags_equal_graph() {
        // Stitching un-searched shard HAGs must reproduce the input
        // graph exactly (cover-wise).
        let g = ring_of_cliques(3, 4);
        let p = partition_bfs(&g, &PartitionConfig::new(3));
        let local = p.local_ids();
        let locals: Vec<Hag> = (0..3)
            .map(|s| Hag::from_graph(&subgraph(&g, &p, &local, s),
                                     AggregateKind::Set))
            .collect();
        let h = stitch_hags(&g, &p, &locals);
        assert_eq!(h.agg_nodes.len(), 0);
        assert_eq!(h.e_hat(), g.e());
        h.validate().unwrap();
        check_equivalence(&g, &h).unwrap();
    }

    #[test]
    fn stitched_searched_hags_are_equivalent() {
        let g = ring_of_cliques(4, 6);
        let p = partition_bfs(&g, &PartitionConfig::new(2));
        let local = p.local_ids();
        let locals: Vec<Hag> = (0..2)
            .map(|s| {
                let sg = subgraph(&g, &p, &local, s);
                hag_search(&sg, &SearchConfig { alpha: 1.0, beta: 1.0,
                    capacity: usize::MAX,
                    kind: AggregateKind::Set,
                    pair_cap: usize::MAX,
                }).0
            })
            .collect();
        let h = stitch_hags(&g, &p, &locals);
        h.validate().unwrap();
        check_equivalence(&g, &h).unwrap();
        assert!(h.cost_core() <= g.e(), "partitioning added cost");
    }
}
