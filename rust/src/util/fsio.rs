//! Crash-safe file output: the tmp + fsync + rename idiom, extracted
//! from the flight recorder so every artifact writer in the tree
//! (flight records, edge lists, buckets.json, durability snapshots)
//! shares one implementation and no output file can ever be observed
//! half-written.
//!
//! Contract: after [`atomic_write`] returns `Ok`, a reader opening
//! `path` sees either the previous complete contents or the new
//! complete contents — never a prefix. The data is fsync'd before the
//! rename, and the parent directory is fsync'd after it (best effort:
//! some filesystems refuse directory fsync; the rename itself is
//! still atomic there).

use std::io::{self, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Distinguishes concurrent writers targeting the same path from one
/// process (the pid distinguishes processes).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write `bytes` to `path` atomically: stage into a hidden sibling
/// tmp file, flush + fsync, rename over `path`, then fsync the parent
/// directory (best effort). On any error the tmp file is removed and
/// `path` is untouched.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty());
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("atomic_write: no file name in {}",
                    path.display())))?;
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp_name = format!(".{name}.{}.{seq}.tmp", std::process::id());
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => std::path::PathBuf::from(&tmp_name),
    };
    let staged = (|| -> io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)
    })();
    match staged {
        Ok(()) => {
            // Make the rename itself durable. Directory fsync is not
            // portable everywhere; failure here cannot un-rename, so
            // it is advisory.
            if let Some(d) = dir {
                if let Ok(df) = std::fs::File::open(d) {
                    let _ = df.sync_all();
                }
            }
            Ok(())
        }
        Err(e) => {
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("repro-fsio-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn writes_and_replaces() {
        let d = tmpdir("basic");
        let p = d.join("out.json");
        atomic_write(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second-longer").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second-longer");
        // no tmp droppings
        let names: Vec<String> = std::fs::read_dir(&d).unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(names, vec!["out.json".to_string()], "{names:?}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn failure_leaves_target_untouched_and_no_tmp() {
        let d = tmpdir("fail");
        let p = d.join("out.bin");
        atomic_write(&p, b"keep me").unwrap();
        // a directory in the way of the rename forces the error path
        let blocked = d.join("sub");
        std::fs::create_dir_all(blocked.join("x")).unwrap();
        assert!(atomic_write(&d.join("sub"), b"nope").is_err());
        assert_eq!(std::fs::read(&p).unwrap(), b"keep me");
        let tmps = std::fs::read_dir(&d).unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name()
                    .to_string_lossy().ends_with(".tmp")
            })
            .count();
        assert_eq!(tmps, 0, "tmp file cleaned up on failure");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn concurrent_writers_never_tear() {
        let d = tmpdir("race");
        let p = d.join("race.txt");
        let bodies: Vec<Vec<u8>> = (0..4u8)
            .map(|i| vec![b'a' + i; 512])
            .collect();
        std::thread::scope(|s| {
            for body in &bodies {
                let p = p.clone();
                s.spawn(move || {
                    for _ in 0..16 {
                        atomic_write(&p, body).unwrap();
                    }
                });
            }
        });
        let got = std::fs::read(&p).unwrap();
        assert_eq!(got.len(), 512);
        assert!(got.windows(2).all(|w| w[0] == w[1]),
                "file is one writer's body, never interleaved");
        std::fs::remove_dir_all(&d).ok();
    }
}
