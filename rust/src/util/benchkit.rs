//! Micro-benchmark harness (criterion is not vendored here): warmup +
//! repeated timing with median/mean/min reporting, matching the
//! `cargo bench` (harness = false) protocol. Results print in a
//! machine-greppable one-line format used by EXPERIMENTS.md, and can
//! additionally be collected into a machine-readable JSON document
//! ([`BenchJson`] — the `BENCH_*.json` files the perf log references)
//! so the repo's perf trajectory is diffable, not just greppable.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters {:>3}  median {:>12}  mean {:>12}  \
             min {:>12}",
            self.name, self.iters, fmt(self.median), fmt(self.mean),
            fmt(self.min));
    }
}

fn fmt(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Machine-readable result collector: every [`BenchStats`] pushed,
/// plus free-form derived metrics (speedups, cost gaps) keyed by
/// name. Written as one JSON document:
/// `{"schema": "benchkit-v1", "entries": [...], "derived": {...}}`.
#[derive(Debug, Default)]
pub struct BenchJson {
    entries: Vec<Value>,
    derived: BTreeMap<String, Value>,
}

impl BenchJson {
    pub fn new() -> BenchJson {
        BenchJson::default()
    }

    /// Record one harness result (times in seconds, f64).
    pub fn push(&mut self, s: &BenchStats) {
        self.push_entry(&s.name, s.iters as u64,
                        s.median.as_secs_f64(), s.mean.as_secs_f64(),
                        s.min.as_secs_f64(), s.max.as_secs_f64());
    }

    /// Record one entries row directly in the seconds-f64 domain —
    /// the single place the benchkit-v1 row shape is spelled out.
    /// Producers that are not [`Bencher`] runs (the telemetry
    /// snapshot's nanosecond histograms, the cost-audit sweep)
    /// serialize through here instead of hand-rolling the schema.
    pub fn push_entry(&mut self, name: &str, iters: u64,
                      median_s: f64, mean_s: f64, min_s: f64,
                      max_s: f64) {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Value::Str(name.to_string()));
        m.insert("iters".to_string(), Value::Num(iters as f64));
        m.insert("median_s".to_string(), Value::Num(median_s));
        m.insert("mean_s".to_string(), Value::Num(mean_s));
        m.insert("min_s".to_string(), Value::Num(min_s));
        m.insert("max_s".to_string(), Value::Num(max_s));
        self.entries.push(Value::Obj(m));
    }

    /// Record a derived metric next to the raw entries (later writes
    /// to the same key win).
    pub fn derived(&mut self, key: &str, v: Value) {
        self.derived.insert(key.to_string(), v);
    }

    /// Convenience for scalar derived metrics.
    pub fn derived_num(&mut self, key: &str, v: f64) {
        self.derived(key, Value::Num(v));
    }

    pub fn to_value(&self) -> Value {
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(),
                 Value::Str("benchkit-v1".to_string()));
        m.insert("entries".to_string(),
                 Value::Arr(self.entries.clone()));
        m.insert("derived".to_string(),
                 Value::Obj(self.derived.clone()));
        Value::Obj(m)
    }

    /// Write pretty-printed JSON to `path` (parent directories must
    /// exist).
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_value().to_string_pretty())
    }
}

/// Configurable runner.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 1,
            iters: 10,
            max_total: Duration::from_secs(60),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 1, iters: 5,
                  max_total: Duration::from_secs(30) }
    }

    /// Time `f`, discarding its output (use `std::hint::black_box`
    /// inside if needed).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            f();
        }
        let start = Instant::now();
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
            if start.elapsed() > self.max_total && times.len() >= 3 {
                break;
            }
        }
        times.sort();
        let sum: Duration = times.iter().sum();
        let stats = BenchStats {
            name: name.to_string(),
            iters: times.len(),
            mean: sum / times.len() as u32,
            median: times[times.len() / 2],
            min: times[0],
            max: *times.last().unwrap(),
        };
        stats.report();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_orders() {
        let b = Bencher { warmup: 0, iters: 5,
                          max_total: Duration::from_secs(5) };
        let mut n = 0u64;
        let s = b.run("spin", || {
            for i in 0..10_000u64 {
                n = n.wrapping_add(std::hint::black_box(i));
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn bench_json_roundtrips() {
        let b = Bencher { warmup: 0, iters: 3,
                          max_total: Duration::from_secs(5) };
        let s = b.run("noop", || {
            std::hint::black_box(1 + 1);
        });
        let mut j = BenchJson::new();
        j.push(&s);
        j.derived_num("speedup", 2.5);
        let v = crate::util::json::parse(&j.to_value().to_string())
            .unwrap();
        assert_eq!(v.req_str("schema").unwrap(), "benchkit-v1");
        let entries = v.req_arr("entries").unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].req_str("name").unwrap(), "noop");
        assert_eq!(entries[0].req_usize("iters").unwrap(), 3);
        assert!(entries[0].req_f64("median_s").unwrap() >= 0.0);
        let d = v.req("derived").unwrap();
        assert_eq!(d.req_f64("speedup").unwrap(), 2.5);
    }

    #[test]
    fn respects_time_cap() {
        let b = Bencher { warmup: 0, iters: 1000,
                          max_total: Duration::from_millis(50) };
        let s = b.run("sleepy", || {
            std::thread::sleep(Duration::from_millis(20));
        });
        assert!(s.iters < 1000);
        assert!(s.iters >= 3);
    }
}
