//! Micro-benchmark harness (criterion is not vendored here): warmup +
//! repeated timing with median/mean/min reporting, matching the
//! `cargo bench` (harness = false) protocol. Results print in a
//! machine-greppable one-line format used by EXPERIMENTS.md.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters {:>3}  median {:>12}  mean {:>12}  \
             min {:>12}",
            self.name, self.iters, fmt(self.median), fmt(self.mean),
            fmt(self.min));
    }
}

fn fmt(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

/// Configurable runner.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 1,
            iters: 10,
            max_total: Duration::from_secs(60),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 1, iters: 5,
                  max_total: Duration::from_secs(30) }
    }

    /// Time `f`, discarding its output (use `std::hint::black_box`
    /// inside if needed).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            f();
        }
        let start = Instant::now();
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
            if start.elapsed() > self.max_total && times.len() >= 3 {
                break;
            }
        }
        times.sort();
        let sum: Duration = times.iter().sum();
        let stats = BenchStats {
            name: name.to_string(),
            iters: times.len(),
            mean: sum / times.len() as u32,
            median: times[times.len() / 2],
            min: times[0],
            max: *times.last().unwrap(),
        };
        stats.report();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_orders() {
        let b = Bencher { warmup: 0, iters: 5,
                          max_total: Duration::from_secs(5) };
        let mut n = 0u64;
        let s = b.run("spin", || {
            for i in 0..10_000u64 {
                n = n.wrapping_add(std::hint::black_box(i));
            }
        });
        assert_eq!(s.iters, 5);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn respects_time_cap() {
        let b = Bencher { warmup: 0, iters: 1000,
                          max_total: Duration::from_millis(50) };
        let s = b.run("sleepy", || {
            std::thread::sleep(Duration::from_millis(20));
        });
        assert!(s.iters < 1000);
        assert!(s.iters >= 3);
    }
}
