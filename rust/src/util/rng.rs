//! Deterministic PRNG substrate (no `rand` crate in the vendored
//! environment): xoshiro256** seeded via SplitMix64, plus the sampling
//! helpers the generators and tests need. Statistical quality is more
//! than sufficient for synthetic-graph generation and property tests;
//! nothing here is security-sensitive.

/// xoshiro256** (Blackman & Vigna), SplitMix64-seeded.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 to fill the state (never all-zero).
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.range_f64(lo as f64, hi as f64) as f32
    }

    /// Uniform usize in [lo, hi) (hi > lo). Lemire-style rejection-free
    /// multiply-shift; bias is negligible for the ranges used here.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        let span = (hi - lo) as u64;
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
            as usize
    }

    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        self.range_usize(lo as usize, hi as usize) as u32
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt()
            * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniformly chosen element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.range_usize(0, xs.len())])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.range_usize(3, 17);
            assert!((3..17).contains(&x));
            let f = r.range_f64(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&f));
        }
    }

    #[test]
    fn uniformish() {
        let mut r = Rng::seed_from_u64(2);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.range_usize(0, 10)] += 1;
        }
        for &c in &counts {
            let expect = n / 10;
            assert!((c as i64 - expect as i64).unsigned_abs()
                    < (expect / 10) as u64,
                    "bucket count {c} too far from {expect}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let xs: Vec<f64> =
            (0..n).map(|_| r.normal_f32() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean))
            .sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(4);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::seed_from_u64(5);
        let hits = (0..100_000).filter(|_| r.bool(0.3)).count();
        assert!((hits as f64 / 100_000.0 - 0.3).abs() < 0.01);
    }
}
