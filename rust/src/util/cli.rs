//! Minimal CLI argument parser (clap is not vendored here).
//!
//! Grammar: `prog <subcommand> [--key value | --key=value | --flag]...`
//! Values that begin with `-` (e.g. negative numbers) must use the
//! `--key=value` form. Unknown keys are surfaced as errors by
//! [`Args::finish`] so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, Vec<String>>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(it: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut args = Args::default();
        let mut it = it.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument {tok:?}");
            };
            if let Some((k, v)) = key.split_once('=') {
                args.opts.entry(k.to_string()).or_default()
                    .push(v.to_string());
            } else if it.peek().is_some_and(|n| !n.starts_with('-')) {
                args.opts.entry(key.to_string()).or_default()
                    .push(it.next().unwrap());
            } else {
                args.opts.entry(key.to_string()).or_default()
                    .push("true".to_string());
            }
        }
        Ok(args)
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// Last occurrence of `--key`, parsed.
    pub fn get<T: std::str::FromStr>(&self, key: &str)
                                     -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.opts.get(key).and_then(|v| v.last()) {
            None => Ok(None),
            Some(s) => s.parse::<T>().map(Some).map_err(|e| {
                anyhow::anyhow!("--{key} {s:?}: {e}")
            }),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, key: &str,
                                        default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get(key)?.unwrap_or(default))
    }

    /// All occurrences of `--key` (repeatable options).
    pub fn get_all(&self, key: &str) -> Vec<String> {
        self.mark(key);
        self.opts.get(key).cloned().unwrap_or_default()
    }

    /// Boolean flag (`--flag` or `--flag true/false`).
    pub fn flag(&self, key: &str) -> Result<bool> {
        Ok(self.get::<bool>(key)?.unwrap_or(false))
    }

    /// Error on any option that no handler consumed.
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        let unknown: Vec<&String> = self.opts.keys()
            .filter(|k| !seen.contains(k)).collect();
        if !unknown.is_empty() {
            bail!("unknown option(s): {unknown:?}");
        }
        Ok(())
    }
}

/// Shared partition flags (`search`, `partition-stats`):
/// * `--shards N` — route HAG search through the partitioned parallel
///   driver ([`crate::partition::search_sharded`]); `N >= 2` shards,
///   `1` (or absent) keeps the single-threaded whole-graph search;
/// * `--partition-seed S` — seed for the BFS partitioner's shard-seed
///   selection (defaults to
///   [`crate::partition::DEFAULT_PARTITION_SEED`]).
///
/// Subcommands that only lower through the coordinator (`train`,
/// `infer`, `serve`, `emit-buckets`) take `--shards` alone: their
/// sharded path pins the default partition seed so bucket shapes stay
/// reproducible across runs.
pub fn partition_opts(args: &Args) -> Result<(Option<usize>, u64)> {
    let shards = shards_opt(args)?;
    let seed = args.get_or("partition-seed",
                           crate::partition::DEFAULT_PARTITION_SEED)?;
    Ok((shards, seed))
}

/// Just the validated `--shards` flag — the subcommands that lower
/// through the coordinator (`train`, `infer`, `serve`, `emit-buckets`)
/// take it without `--partition-seed` (see [`partition_opts`]).
pub fn shards_opt(args: &Args) -> Result<Option<usize>> {
    let shards = args.get::<usize>("shards")?;
    if shards == Some(0) {
        bail!("--shards must be >= 1");
    }
    Ok(shards)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --dataset BZR --epochs 20 --scale=0.05");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get::<String>("dataset").unwrap().unwrap(), "BZR");
        assert_eq!(a.get_or::<usize>("epochs", 1).unwrap(), 20);
        assert_eq!(a.get_or::<f64>("scale", 1.0).unwrap(), 0.05);
        assert_eq!(a.get_or::<u64>("seed", 7).unwrap(), 7);
        a.finish().unwrap();
    }

    #[test]
    fn flags_and_repeats() {
        let a = parse("x --verbose --datasets BZR --datasets PPI");
        assert!(a.flag("verbose").unwrap());
        assert!(!a.flag("quiet").unwrap());
        assert_eq!(a.get_all("datasets"), vec!["BZR", "PPI"]);
        a.finish().unwrap();
    }

    #[test]
    fn equals_form_for_negatives() {
        let a = parse("x --offset=-3");
        assert_eq!(a.get::<i32>("offset").unwrap().unwrap(), -3);
    }

    #[test]
    fn unknown_options_fail_finish() {
        let a = parse("x --oops 1");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("x --epochs banana");
        assert!(a.get::<usize>("epochs").is_err());
    }

    #[test]
    fn partition_opts_parse_and_default() {
        let a = parse("search --shards 4 --partition-seed 11");
        assert_eq!(partition_opts(&a).unwrap(), (Some(4), 11));
        let b = parse("search");
        assert_eq!(
            partition_opts(&b).unwrap(),
            (None, crate::partition::DEFAULT_PARTITION_SEED));
        let c = parse("search --shards 0");
        assert!(partition_opts(&c).is_err());
    }

    #[test]
    fn shards_boundary_values() {
        // Regression for the `--shards 0` / `--shards 1` boundary:
        // 0 is a loud CLI error, 1 is the explicit single-shard path
        // (the library side additionally clamps 0 to 1 — see
        // `partition::search_sharded_seeded`).
        let one = parse("search --shards 1");
        assert_eq!(shards_opt(&one).unwrap(), Some(1));
        let zero = parse("train --shards 0");
        assert!(shards_opt(&zero).is_err());
        let none = parse("train");
        assert_eq!(shards_opt(&none).unwrap(), None);
    }
}
