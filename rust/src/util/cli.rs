//! Minimal CLI argument parser (clap is not vendored here).
//!
//! Grammar: `prog <subcommand> [--key value | --key=value | --flag]...`
//! Values that begin with `-` (e.g. negative numbers) must use the
//! `--key=value` form. Unknown keys are surfaced as errors by
//! [`Args::finish`] so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, Vec<String>>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse(it: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut args = Args::default();
        let mut it = it.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument {tok:?}");
            };
            if let Some((k, v)) = key.split_once('=') {
                args.opts.entry(k.to_string()).or_default()
                    .push(v.to_string());
            } else if it.peek().is_some_and(|n| !n.starts_with('-')) {
                args.opts.entry(key.to_string()).or_default()
                    .push(it.next().unwrap());
            } else {
                args.opts.entry(key.to_string()).or_default()
                    .push("true".to_string());
            }
        }
        Ok(args)
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// Last occurrence of `--key`, parsed.
    pub fn get<T: std::str::FromStr>(&self, key: &str)
                                     -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.opts.get(key).and_then(|v| v.last()) {
            None => Ok(None),
            Some(s) => s.parse::<T>().map(Some).map_err(|e| {
                anyhow::anyhow!("--{key} {s:?}: {e}")
            }),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, key: &str,
                                        default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get(key)?.unwrap_or(default))
    }

    /// All occurrences of `--key` (repeatable options).
    pub fn get_all(&self, key: &str) -> Vec<String> {
        self.mark(key);
        self.opts.get(key).cloned().unwrap_or_default()
    }

    /// Boolean flag (`--flag` or `--flag true/false`).
    pub fn flag(&self, key: &str) -> Result<bool> {
        Ok(self.get::<bool>(key)?.unwrap_or(false))
    }

    /// Error on any option that no handler consumed.
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        let unknown: Vec<&String> = self.opts.keys()
            .filter(|k| !seen.contains(k)).collect();
        if !unknown.is_empty() {
            bail!("unknown option(s): {unknown:?}");
        }
        Ok(())
    }
}

/// The one spec-flag parser every lowering subcommand shares
/// (`search`, `emit-buckets`, `train`, `infer`, `serve`, `stream`,
/// `stream-stats`, `partition-stats`), so
/// `--capacity` / `--shards` / `--partition-seed` and friends are
/// accepted uniformly instead of per-subcommand:
///
/// * `--repr gnn|hag` — representation                       \[hag\]
/// * `--kind set|seq` — AGGREGATE class                      \[set\]
/// * `--capacity N` — explicit `|V_A|` budget (overrides the
///   fraction; carried through buckets end-to-end)
/// * `--capacity-frac F` — budget as a fraction of `|V|`     \[0.25\]
/// * `--shards N` — partitioned parallel search; `N >= 2` shards, `1`
///   (or absent) is the whole-graph search; `0` is a loud error
/// * `--partition-seed S` — BFS partitioner seed (defaults to
///   [`crate::partition::DEFAULT_PARTITION_SEED`], so bucket shapes
///   stay reproducible across runs)
/// * `--drift-threshold F` — streaming re-plan trigger       \[0.08\]
/// * `--background` — background (snapshot + replay) rebuilds
///
/// All flags are consumed whether or not the subcommand acts on them,
/// so moving a flag between subcommands never trips
/// [`Args::finish`].
pub struct SpecArgs {
    pub spec: crate::session::LowerSpec,
}

impl SpecArgs {
    pub fn parse(args: &Args) -> Result<SpecArgs> {
        use crate::coordinator::Repr;
        use crate::hag::AggregateKind;

        let mut spec = crate::session::LowerSpec::default();
        spec.repr =
            match args.get_or::<String>("repr", "hag".into())?.as_str()
        {
            "gnn" | "gnn-graph" => Repr::GnnGraph,
            "hag" => Repr::Hag,
            other => bail!("--repr must be gnn|hag, got {other:?}"),
        };
        spec.kind =
            match args.get_or::<String>("kind", "set".into())?.as_str()
        {
            "set" => AggregateKind::Set,
            "seq" | "sequential" => AggregateKind::Sequential,
            other => bail!("--kind must be set|seq, got {other:?}"),
        };
        spec.capacity = args.get::<usize>("capacity")?;
        spec.capacity_frac = args.get_or("capacity-frac", 0.25)?;
        match args.get::<usize>("shards")? {
            Some(0) => bail!("--shards must be >= 1"),
            Some(k) => spec.shards = k,
            None => {}
        }
        spec.partition_seed =
            args.get_or("partition-seed",
                        crate::partition::DEFAULT_PARTITION_SEED)?;
        spec.drift.threshold = args.get_or("drift-threshold", 0.08)?;
        spec.drift.background = args.flag("background")?;
        Ok(SpecArgs { spec })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --dataset BZR --epochs 20 --scale=0.05");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get::<String>("dataset").unwrap().unwrap(), "BZR");
        assert_eq!(a.get_or::<usize>("epochs", 1).unwrap(), 20);
        assert_eq!(a.get_or::<f64>("scale", 1.0).unwrap(), 0.05);
        assert_eq!(a.get_or::<u64>("seed", 7).unwrap(), 7);
        a.finish().unwrap();
    }

    #[test]
    fn flags_and_repeats() {
        let a = parse("x --verbose --datasets BZR --datasets PPI");
        assert!(a.flag("verbose").unwrap());
        assert!(!a.flag("quiet").unwrap());
        assert_eq!(a.get_all("datasets"), vec!["BZR", "PPI"]);
        a.finish().unwrap();
    }

    #[test]
    fn equals_form_for_negatives() {
        let a = parse("x --offset=-3");
        assert_eq!(a.get::<i32>("offset").unwrap().unwrap(), -3);
    }

    #[test]
    fn unknown_options_fail_finish() {
        let a = parse("x --oops 1");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("x --epochs banana");
        assert!(a.get::<usize>("epochs").is_err());
    }

    #[test]
    fn spec_args_parse_and_default() {
        let a = parse("search --shards 4 --partition-seed 11 \
                       --capacity 500 --repr gnn --kind seq \
                       --drift-threshold 0.2 --background");
        let s = SpecArgs::parse(&a).unwrap().spec;
        assert_eq!(s.shards, 4);
        assert_eq!(s.partition_seed, 11);
        assert_eq!(s.capacity, Some(500));
        assert_eq!(s.repr, crate::coordinator::Repr::GnnGraph);
        assert_eq!(s.kind, crate::hag::AggregateKind::Sequential);
        assert!((s.drift.threshold - 0.2).abs() < 1e-12);
        assert!(s.drift.background);
        a.finish().unwrap();

        let b = parse("train");
        let d = SpecArgs::parse(&b).unwrap().spec;
        assert_eq!(d.shards, 1);
        assert_eq!(d.partition_seed,
                   crate::partition::DEFAULT_PARTITION_SEED);
        assert_eq!(d.capacity, None);
        assert!((d.capacity_frac - 0.25).abs() < 1e-12);
        // parsing consumes every spec flag uniformly
        b.finish().unwrap();
    }

    #[test]
    fn spec_args_shards_boundary_values() {
        // Regression for the `--shards 0` / `--shards 1` boundary:
        // 0 is a loud CLI error, 1 is the explicit single-shard path
        // (the library side additionally clamps 0 to 1 — see
        // `partition::search_sharded_seeded` and
        // `LowerSpec::with_shards`).
        let one = parse("search --shards 1");
        assert_eq!(SpecArgs::parse(&one).unwrap().spec.shards, 1);
        let zero = parse("train --shards 0");
        assert!(SpecArgs::parse(&zero).is_err());
    }

    #[test]
    fn spec_args_reject_bad_enums() {
        assert!(SpecArgs::parse(&parse("x --repr banana")).is_err());
        assert!(SpecArgs::parse(&parse("x --kind banana")).is_err());
    }

    #[test]
    fn spec_flags_accepted_on_every_subcommand() {
        // The historical foot-gun: `--partition-seed` on `train` (or
        // `--capacity` on `emit-buckets`) was an unknown-option error.
        // SpecArgs consumes the full flag set everywhere.
        for sub in ["search", "emit-buckets", "train", "infer",
                    "serve", "stream", "stream-stats",
                    "partition-stats"] {
            let a = parse(&format!(
                "{sub} --capacity 9 --shards 2 --partition-seed 3"));
            let s = SpecArgs::parse(&a).unwrap().spec;
            assert_eq!((s.capacity, s.shards, s.partition_seed),
                       (Some(9), 2, 3), "{sub}");
            a.finish().unwrap();
        }
    }
}
