//! Self-contained substrates for facilities that would normally come
//! from crates.io (only the `xla` dependency closure is vendored in
//! this environment): JSON, PRNG, CLI parsing, and a micro-benchmark
//! harness.

pub mod benchkit;
pub mod cli;
pub mod fsio;
pub mod fxhash;
pub mod json;
pub mod rng;

pub use fsio::atomic_write;
pub use fxhash::{FxHashMap, FxHashSet};
pub use rng::Rng;
