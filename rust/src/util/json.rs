//! Minimal JSON substrate (no external crates are vendored in this
//! environment beyond the `xla` closure, so the interchange layer is
//! built from scratch): a recursive-descent parser + serializer
//! sufficient for `manifest.json` / `buckets.json` and the metric dumps
//! this repo writes. RFC 8259 subset: full string escapes (incl.
//! `\uXXXX` with surrogate pairs), numbers as f64, no depth limit
//! beyond recursion.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve no insertion order (BTreeMap) —
/// deterministic output matters more here than order fidelity.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Typed field access with a path-aware error message.
    pub fn req(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key).ok_or_else(|| {
            JsonError(format!("missing field {key:?}"))
        })
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?.as_str().ok_or_else(|| {
            JsonError(format!("field {key:?} is not a string"))
        })
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.req(key)?.as_usize().ok_or_else(|| {
            JsonError(format!("field {key:?} is not a non-negative \
                               integer"))
        })
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?.as_f64().ok_or_else(|| {
            JsonError(format!("field {key:?} is not a number"))
        })
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Value], JsonError> {
        self.req(key)?.as_arr().ok_or_else(|| {
            JsonError(format!("field {key:?} is not an array"))
        })
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 1-space indentation (matches aot.py's output
    /// style closely enough for diffing).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>,
             depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else {
                "false"
            }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

impl From<JsonError> for String {
    fn from(e: JsonError) -> String {
        e.to_string()
    }
}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let b = input.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i)
                .ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i)
                        .ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1)
                                        == Some(&b'u')
                                {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| {
                                self.err("invalid \\u escape")
                            })?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: find the char at i-1
                    let start = self.i - 1;
                    let tail = &self.b[start..];
                    let st = std::str::from_utf8(
                        &tail[..tail.len().min(4)])
                        .map_or_else(
                            |e| if e.valid_up_to() > 0 {
                                std::str::from_utf8(
                                    &tail[..e.valid_up_to()]).ok()
                            } else {
                                None
                            },
                            Some)
                        .ok_or_else(|| self.err("invalid utf8"))?;
                    let ch = st.chars().next()
                        .ok_or_else(|| self.err("invalid utf8"))?;
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hx = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hx, 16)
            .map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(),
                       Some(b'0'..=b'9') | Some(b'.') | Some(b'e')
                       | Some(b'E') | Some(b'+') | Some(b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v))
        .collect())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

pub fn num(n: impl Into<f64>) -> Value {
    Value::Num(n.into())
}

pub fn str_(s: impl Into<String>) -> Value {
    Value::Str(s.into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = parse(r#"{"a": [1, 2.5, -3], "b": "x\ny",
                          "c": true, "d": null}"#).unwrap();
        assert_eq!(v.req_arr("a").unwrap().len(), 3);
        assert_eq!(v.req_str("b").unwrap(), "x\ny");
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("d"), Some(&Value::Null));
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn parses_nested_and_pretty_roundtrips() {
        let v = obj(vec![
            ("buckets", arr(vec![obj(vec![
                ("name", str_("bzr_hag")),
                ("n_pad", num(6528u32)),
                ("bands", arr(vec![arr(vec![num(816u32),
                                            num(64u32)])])),
            ])])),
        ]);
        let text = v.to_string_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(back, v);
        let b0 = &back.req_arr("buckets").unwrap()[0];
        assert_eq!(b0.req_usize("n_pad").unwrap(), 6528);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""aéb😀c""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\u{e9}b\u{1f600}c");
        // raw multi-byte utf8 too
        let v2 = parse("\"héllo 😀\"").unwrap();
        assert_eq!(v2.as_str().unwrap(), "héllo 😀");
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(num(5u32).to_string(), "5");
        assert_eq!(num(2.5f64).to_string(), "2.5");
    }

    #[test]
    fn errors_are_positioned() {
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("[1, 2").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(),
                   Value::Obj(BTreeMap::new()));
        assert_eq!(Value::Arr(vec![]).to_string_pretty(), "[]");
    }

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1,
          "artifacts": [
            {"name": "gcn_train_tiny0", "file": "gcn_train_tiny0.hlo.txt",
             "inputs": [{"name": "w1", "shape": [8, 16],
                         "dtype": "f32"}],
             "lr": 0.01}
          ]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.req_usize("version").unwrap(), 1);
        let a = &v.req_arr("artifacts").unwrap()[0];
        assert_eq!(a.req_str("name").unwrap(), "gcn_train_tiny0");
        assert_eq!(a.req_f64("lr").unwrap(), 0.01);
        let inp = &a.req_arr("inputs").unwrap()[0];
        let shape: Vec<usize> = inp.req_arr("shape").unwrap().iter()
            .map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![8, 16]);
    }
}
