//! FxHash-style multiply-xor hasher (rustc's FxHasher recurrence) —
//! std's SipHash is DoS-resistant but ~4x slower on the small integer
//! keys the HAG search hammers (pair-count maps keyed by `(u32, u32)`).
//! Perf-pass measurement in EXPERIMENTS.md §Perf.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word)
            .wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// HashMap with the fast hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

/// HashSet with the fast hasher.
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works() {
        let mut m: FxHashMap<(u32, u32), u32> = FxHashMap::default();
        for i in 0..1000u32 {
            m.insert((i, i + 1), i);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u32 {
            assert_eq!(m.get(&(i, i + 1)), Some(&i));
        }
        assert_eq!(m.get(&(5, 5)), None);
    }

    #[test]
    fn distributes_pairs() {
        // sanity: no catastrophic collisions over a realistic key set
        let mut seen = std::collections::HashSet::new();
        for a in 0..200u32 {
            for b in 0..200u32 {
                let mut h = FxHasher::default();
                h.write_u32(a);
                h.write_u32(b);
                seen.insert(h.finish());
            }
        }
        assert!(seen.len() > 39_000, "collisions: {}", 40_000 - seen.len());
    }
}
