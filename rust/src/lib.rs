//! # repro — Redundancy-Free Computation Graphs for GNNs (HAG)
//!
//! A rust + JAX + Pallas reproduction of *"Redundancy-Free Computation
//! Graphs for Graph Neural Networks"* (Jia et al., 2019): GNN neighbor
//! aggregation de-duplicated through **Hierarchically Aggregated
//! computation Graphs**.
//!
//! Architecture (three layers, Python never on the hot path):
//! * **L3 (this crate)** — graph substrate, the HAG search algorithm
//!   (paper Algorithm 3), the partitioned/parallel search subsystem
//!   ([`partition`]), the streaming incremental-maintenance subsystem
//!   ([`incremental`]), crash-safe delta durability (WAL + snapshots
//!   + recovery, [`durability`]) with a deterministic fault-injection
//!   plane ([`fault`]), the unified lowering [`session`] (spec +
//!   per-shard plan cache), plan compiler, PJRT runtime, training
//!   coordinator and inference server, dataset generators, benches,
//!   and the [`obs`] telemetry substrate (metrics registry, event
//!   tracer, flight recorder) threaded through all of the above.
//! * **L2 (python/compile/model.py)** — GCN / GraphSAGE-P fwd+bwd in
//!   JAX, AOT-lowered to HLO text per shape bucket.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the
//!   aggregation hot-spots, lowered inside the L2 HLO.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod analysis;
pub mod bench;
pub mod coordinator;
pub mod datasets;
pub mod durability;
pub mod fault;
pub mod graph;
pub mod hag;
pub mod incremental;
pub mod net;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod session;
pub mod util;
