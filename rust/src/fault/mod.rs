//! Deterministic fault injection: named points on the durability and
//! serving paths that can be armed to fail on demand.
//!
//! Every risky effect the crash-safety story depends on is guarded by
//! a call to [`point`] with a stable dotted name — the inventory
//! (DESIGN.md §14):
//!
//! | point            | guarded effect                               |
//! |------------------|----------------------------------------------|
//! | `wal.append`     | staging a delta record into the WAL          |
//! | `wal.fsync`      | the group-commit fsync                       |
//! | `snapshot.write` | writing a graph+HAG snapshot                 |
//! | `serve.swap`     | installing a re-planned HAG into the worker  |
//! | `batcher.exec`   | executing a score batch (panic-capable)      |
//! | `net.write`      | writing a reply frame to a client socket     |
//!
//! Disarmed cost is **one relaxed atomic load** — the plane is
//! compiled in everywhere, always, so production binaries exercise
//! the exact code paths the chaos suite proves out
//! (`benches/recovery.rs` measures the disarmed ns/call).
//!
//! Arming is deterministic and seeded: via the `REPRO_FAULTS` env var
//! (read once, at the first [`point`] hit) or the [`arm_spec`] /
//! [`arm`] API. Spec grammar (also in DESIGN.md §14):
//!
//! ```text
//! spec    := clause (';' clause)*
//! clause  := name '=' trigger (',' opt)*
//! trigger := 'nth:' K       fire on the K-th hit only (1-based)
//!          | 'first:' K     fire on hits 1..=K
//!          | 'prob:' P      fire each hit with probability P
//!          | 'always'       fire on every hit
//! opt     := 'panic'        fire by panicking instead of erroring
//!          | 'seed:' S      per-point RNG seed for 'prob' (default 0)
//! ```
//!
//! e.g. `REPRO_FAULTS="serve.swap=nth:2;wal.fsync=first:1"`. Every
//! fired fault is traced (`fault.fired` event + counter on the global
//! registry and an `obs_warn!` line), so a chaos run's injections are
//! attributable after the fact.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::util::Rng;

/// How an armed point fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// [`point`] returns `Err(FaultError)`.
    Error,
    /// [`point`] panics (exercises `catch_unwind` supervision).
    Panic,
}

/// When an armed point fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Trigger {
    /// Fire on the k-th hit only (1-based).
    Nth(u64),
    /// Fire on every hit up to and including the k-th.
    First(u64),
    /// Fire each hit independently with probability `p`, from a
    /// seeded per-point RNG (deterministic per hit sequence).
    Prob(f64),
    /// Fire on every hit.
    Always,
}

/// The error an injected (non-panic) fault surfaces.
#[derive(Debug, Clone)]
pub struct FaultError {
    /// The point that fired.
    pub point: String,
    /// This point's lifetime hit number that fired (1-based).
    pub hit: u64,
}

impl std::fmt::Display for FaultError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
           -> std::fmt::Result {
        write!(f, "injected fault at {} (hit {})", self.point,
               self.hit)
    }
}

impl std::error::Error for FaultError {}

impl From<FaultError> for std::io::Error {
    fn from(e: FaultError) -> std::io::Error {
        std::io::Error::other(e)
    }
}

struct PointState {
    trigger: Trigger,
    action: FaultAction,
    rng: Rng,
    hits: u64,
    fired: u64,
}

struct Plane {
    points: HashMap<String, PointState>,
}

/// Number of armed points. Zero is the disarmed fast path; the
/// sentinel [`UNINIT`] forces exactly one slow-path pass to parse
/// `REPRO_FAULTS` before the steady state is reached.
static ARMED: AtomicUsize = AtomicUsize::new(UNINIT);
const UNINIT: usize = usize::MAX;

fn plane() -> MutexGuard<'static, Plane> {
    static PLANE: OnceLock<Mutex<Plane>> = OnceLock::new();
    PLANE
        .get_or_init(|| Mutex::new(Plane { points: HashMap::new() }))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

fn sync_armed(p: &Plane) {
    ARMED.store(p.points.len(), Ordering::Release);
}

fn init_from_env() {
    let mut p = plane();
    if ARMED.load(Ordering::Acquire) != UNINIT {
        return; // raced: another thread initialized first
    }
    if let Ok(spec) = std::env::var("REPRO_FAULTS") {
        if let Err(e) = arm_spec_locked(&mut p, &spec) {
            crate::obs_error!("[fault] bad REPRO_FAULTS spec: {e}");
        }
    }
    sync_armed(&p);
}

/// One fault point. The disarmed steady state costs a single relaxed
/// atomic load; an armed plane takes the registry lock on every hit
/// of any point (armed planes are test/chaos configurations, never
/// the production default).
pub fn point(name: &str) -> Result<(), FaultError> {
    let armed = ARMED.load(Ordering::Relaxed);
    if armed == 0 {
        return Ok(());
    }
    if armed == UNINIT {
        init_from_env();
        if ARMED.load(Ordering::Acquire) == 0 {
            return Ok(());
        }
    }
    let fired = {
        let mut p = plane();
        let Some(st) = p.points.get_mut(name) else {
            return Ok(());
        };
        st.hits += 1;
        let fire = match st.trigger {
            Trigger::Nth(k) => st.hits == k,
            Trigger::First(k) => st.hits <= k,
            Trigger::Prob(pr) => st.rng.bool(pr),
            Trigger::Always => true,
        };
        if !fire {
            return Ok(());
        }
        st.fired += 1;
        (st.hits, st.action)
    };
    let (hit, action) = fired;
    crate::obs::metrics::MetricsRegistry::global()
        .counter("fault.fired")
        .inc();
    crate::obs_event!("fault.fired", hit);
    crate::obs_warn!("[fault] {name} fired (hit {hit}, {action:?})");
    match action {
        FaultAction::Error => Err(FaultError {
            point: name.to_string(),
            hit,
        }),
        // The one justified panic outside test code in this module:
        // panic-action faults exist to prove the supervision story.
        FaultAction::Panic => panic!("injected fault: {name}"),
    }
}

/// Arm one point programmatically (tests, chaos drivers).
pub fn arm(name: &str, trigger: Trigger, action: FaultAction,
           seed: u64) {
    let mut p = plane();
    p.points.insert(name.to_string(), PointState {
        trigger,
        action,
        rng: Rng::seed_from_u64(seed),
        hits: 0,
        fired: 0,
    });
    sync_armed(&p);
}

/// Disarm everything (including env-armed points) and reset hit
/// counters. Tests call this before and after arming their own
/// points.
pub fn reset() {
    let mut p = plane();
    p.points.clear();
    sync_armed(&p);
}

/// Lifetime fire count of a point (0 if never armed).
pub fn fired(name: &str) -> u64 {
    plane().points.get(name).map_or(0, |s| s.fired)
}

/// Lifetime hit count of a point while armed (0 if never armed).
pub fn hits(name: &str) -> u64 {
    plane().points.get(name).map_or(0, |s| s.hits)
}

/// Parse and arm a `REPRO_FAULTS`-grammar spec. Returns the number
/// of points armed.
pub fn arm_spec(spec: &str) -> Result<usize, String> {
    let mut p = plane();
    let n = arm_spec_locked(&mut p, spec)?;
    sync_armed(&p);
    Ok(n)
}

fn arm_spec_locked(p: &mut Plane, spec: &str)
                   -> Result<usize, String> {
    let mut n = 0usize;
    for clause in spec.split(';') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        let (name, rest) = clause.split_once('=').ok_or_else(|| {
            format!("clause {clause:?} is missing '='")
        })?;
        let name = name.trim();
        if name.is_empty() {
            return Err(format!("clause {clause:?} has no point name"));
        }
        let mut trigger: Option<Trigger> = None;
        let mut action = FaultAction::Error;
        let mut seed = 0u64;
        for part in rest.split(',') {
            let part = part.trim();
            if part == "always" {
                trigger = Some(Trigger::Always);
            } else if part == "panic" {
                action = FaultAction::Panic;
            } else if let Some(k) = part.strip_prefix("nth:") {
                let k: u64 = k.trim().parse().map_err(|_| {
                    format!("bad nth count in {clause:?}")
                })?;
                trigger = Some(Trigger::Nth(k.max(1)));
            } else if let Some(k) = part.strip_prefix("first:") {
                let k: u64 = k.trim().parse().map_err(|_| {
                    format!("bad first count in {clause:?}")
                })?;
                trigger = Some(Trigger::First(k));
            } else if let Some(pr) = part.strip_prefix("prob:") {
                let pr: f64 = pr.trim().parse().map_err(|_| {
                    format!("bad probability in {clause:?}")
                })?;
                if !(0.0..=1.0).contains(&pr) {
                    return Err(format!(
                        "probability out of [0,1] in {clause:?}"));
                }
                trigger = Some(Trigger::Prob(pr));
            } else if let Some(s) = part.strip_prefix("seed:") {
                seed = s.trim().parse().map_err(|_| {
                    format!("bad seed in {clause:?}")
                })?;
            } else {
                return Err(format!(
                    "unknown spec part {part:?} in {clause:?}"));
            }
        }
        let trigger = trigger.ok_or_else(|| {
            format!("clause {clause:?} has no trigger \
                     (nth:/first:/prob:/always)")
        })?;
        p.points.insert(name.to_string(), PointState {
            trigger,
            action,
            rng: Rng::seed_from_u64(seed),
            hits: 0,
            fired: 0,
        });
        n += 1;
    }
    Ok(n)
}

/// Serializes tests (and chaos-sensitive live-serving tests) that
/// touch the process-global fault plane: hold this guard for the
/// duration of any test that arms points or would misbehave if a
/// concurrent test armed them.
pub fn exclusive() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_points_pass() {
        let _g = exclusive();
        reset();
        for _ in 0..1000 {
            point("test.nowhere").unwrap();
        }
    }

    #[test]
    fn nth_fires_exactly_once() {
        let _g = exclusive();
        reset();
        arm("test.nth", Trigger::Nth(3), FaultAction::Error, 0);
        let mut fails = Vec::new();
        for i in 1..=6u64 {
            if point("test.nth").is_err() {
                fails.push(i);
            }
        }
        assert_eq!(fails, vec![3]);
        assert_eq!(fired("test.nth"), 1);
        assert_eq!(hits("test.nth"), 6);
        reset();
    }

    #[test]
    fn first_fires_leading_hits() {
        let _g = exclusive();
        reset();
        arm("test.first", Trigger::First(2), FaultAction::Error, 0);
        let fails: Vec<bool> =
            (0..4).map(|_| point("test.first").is_err()).collect();
        assert_eq!(fails, vec![true, true, false, false]);
        reset();
    }

    #[test]
    fn prob_is_deterministic_per_seed() {
        let _g = exclusive();
        reset();
        arm("test.prob", Trigger::Prob(0.5), FaultAction::Error, 42);
        let a: Vec<bool> =
            (0..64).map(|_| point("test.prob").is_err()).collect();
        reset();
        arm("test.prob", Trigger::Prob(0.5), FaultAction::Error, 42);
        let b: Vec<bool> =
            (0..64).map(|_| point("test.prob").is_err()).collect();
        assert_eq!(a, b, "same seed, same fire pattern");
        assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f),
                "p=0.5 over 64 hits fires some and passes some");
        reset();
    }

    #[test]
    fn spec_grammar_round_trips() {
        let _g = exclusive();
        reset();
        let n = arm_spec(
            "a.x=nth:2; b.y=prob:0.25,seed:7; c.z=always,panic; \
             d.w=first:3")
            .unwrap();
        assert_eq!(n, 4);
        assert!(point("a.x").is_ok());
        assert!(point("a.x").is_err());
        assert!(point("a.x").is_ok());
        assert!(point("d.w").is_err());
        let err =
            std::panic::catch_unwind(|| point("c.z")).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned()
            .unwrap_or_default();
        assert!(msg.contains("injected fault: c.z"), "{msg}");
        reset();
    }

    #[test]
    fn spec_errors_are_descriptive() {
        let _g = exclusive();
        reset();
        assert!(arm_spec("nodots").unwrap_err().contains("'='"));
        assert!(arm_spec("a.x=nth:zero").unwrap_err()
            .contains("nth"));
        assert!(arm_spec("a.x=prob:1.5").unwrap_err()
            .contains("[0,1]"));
        assert!(arm_spec("a.x=wiggle:3").unwrap_err()
            .contains("unknown"));
        assert!(arm_spec("a.x=seed:5").unwrap_err()
            .contains("no trigger"));
        reset();
    }

    #[test]
    fn fault_error_converts_to_io_error() {
        let e = FaultError { point: "wal.fsync".into(), hit: 4 };
        let io: std::io::Error = e.into();
        assert!(io.to_string().contains("wal.fsync"));
    }
}
