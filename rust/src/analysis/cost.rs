//! Cost-consistency passes: Definition-2 terms recomputed from raw
//! structure must agree with everything downstream that claims them —
//! the `Hag` cost methods, the producer's per-shard term claims, and
//! the `cost.pred_*` gauges the serving path records (obs/cost.rs,
//! DESIGN.md §11).

use crate::hag::Hag;
use crate::obs::metrics::StatsSnapshot;

use super::{HagCtx, Report};

/// Definition-2 terms counted directly off the raw field vectors —
/// deliberately *not* via the `Hag` methods, so a broken method (or a
/// claim derived from a different HAG) cannot agree by construction.
fn recount(hag: &Hag) -> (usize, usize, usize) {
    let na = hag.agg_nodes.len();
    let final_edges: usize =
        hag.in_edges.iter().map(|l| l.len()).sum();
    let e_hat = 2 * na + final_edges;
    let aggregations = na
        + hag.in_edges.iter()
            .map(|l| l.len().saturating_sub(1)).sum::<usize>();
    (aggregations, e_hat, e_hat - na)
}

/// `cost.term_consistency`: recomputed terms vs `Hag::cost*` methods,
/// the α/β cost identity, and (when the producer supplied them) the
/// claimed `(aggregations, data_transfers)` pair.
pub fn term_consistency(ctx: &HagCtx, r: &mut Report) {
    const ID: &str = "cost.term_consistency";
    r.ran(ID);
    let hag = ctx.hag;
    let (aggs, transfers, core) = recount(hag);
    let mut err = |entity: &str, msg: String, hint: &'static str,
                   r: &mut Report| {
        r.error(ID, entity.to_string(), msg, hint);
    };
    if hag.aggregations() != aggs {
        err("aggregations",
            format!("Hag::aggregations() = {} but the structure \
                     counts {aggs}", hag.aggregations()),
            "Definition-2 term drift between method and structure",
            r);
    }
    if hag.data_transfers() != transfers {
        err("data_transfers",
            format!("Hag::data_transfers() = {} but the structure \
                     counts {transfers}", hag.data_transfers()),
            "Definition-2 term drift between method and structure",
            r);
    }
    if hag.cost_core() != core {
        err("cost_core",
            format!("Hag::cost_core() = {} but e_hat - |V_A| = \
                     {core}", hag.cost_core()),
            "cost_core is the quantity Algorithm 3 minimizes; the \
             method and the structure disagree", r);
    }
    // The calibration identity DriftPolicy prices swaps with
    // (obs/cost.rs::calibrated_cost): cost(α,β) = α·core + (β−α)·n.
    for (alpha, beta) in [(1.0f64, 1.0f64), (2.5, 0.8)] {
        let want = alpha * core as f64
            + (beta - alpha) * hag.n as f64;
        let got = hag.cost(alpha, beta);
        if (got - want).abs() > 1e-6 * want.abs().max(1.0) {
            err("cost(alpha,beta)",
                format!("cost({alpha},{beta}) = {got} but the \
                         identity gives {want}"),
                "Hag::cost must satisfy cost = alpha*cost_core + \
                 (beta-alpha)*n; the drift policy prices swaps \
                 through this identity", r);
            break;
        }
    }
    if let Some((claimed_aggs, claimed_transfers)) =
        ctx.claimed_terms
    {
        if claimed_aggs != aggs || claimed_transfers != transfers {
            err("claimed terms",
                format!("producer claims (aggregations, transfers) \
                         = ({claimed_aggs}, {claimed_transfers}), \
                         structure counts ({aggs}, {transfers})"),
                "the claimed Definition-2 terms (e.g. summed shard \
                 terms) describe a different HAG than the one being \
                 served", r);
        }
    }
}

/// `cost.gauges_match`: the `cost.pred_*` gauges
/// (`record_plan_terms`) against the served HAG's recomputed terms
/// and the session's per-shard term claims. Run right after the
/// gauges are recorded on a swap.
pub fn gauges_match(snap: &StatsSnapshot, hag: &Hag,
                    shard_terms: &[(usize, usize)],
                    r: &mut Report) {
    const ID: &str = "cost.gauges_match";
    r.ran(ID);
    let (aggs, transfers, _) = recount(hag);
    let check = |name: String, want: i64, r: &mut Report| {
        let got = snap.gauge(&name);
        if got != want {
            r.error(ID, name,
                    format!("gauge reads {got}, recomputed \
                             Definition-2 term is {want}"),
                    "cost.pred_* gauges are set-to-absolute from the \
                     HAG at swap time (record_plan_terms); a \
                     mismatch means the gauges describe a stale or \
                     different plan");
        }
    };
    check("cost.pred_aggregations".to_string(), aggs as i64, r);
    check("cost.pred_transfers".to_string(), transfers as i64, r);
    let mut sum_a = 0usize;
    let mut sum_t = 0usize;
    for (i, &(a, t)) in shard_terms.iter().enumerate() {
        check(format!("cost.shard{i}.pred_aggregations"), a as i64,
              r);
        check(format!("cost.shard{i}.pred_transfers"), t as i64, r);
        sum_a += a;
        sum_t += t;
    }
    // Stitching only adds cross-shard work on top of shard-local
    // terms, so the shard sums can never exceed the stitched totals.
    if !shard_terms.is_empty() && (sum_a > aggs || sum_t > transfers)
    {
        r.error(ID, "shard term sums".to_string(),
                format!("per-shard sums ({sum_a}, {sum_t}) exceed \
                         stitched totals ({aggs}, {transfers})"),
                "shard-local Definition-2 terms are a lower bound on \
                 the stitched plan's; the shard claims are stale");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::hag::AggregateKind;
    use crate::obs::cost::record_plan_terms;
    use crate::obs::metrics::MetricsRegistry;

    fn star() -> (Graph, Hag) {
        let g = Graph::from_edges(
            5, &[(1, 0), (2, 0), (3, 0), (4, 0)]);
        let h = Hag::from_graph(&g, AggregateKind::Set);
        (g, h)
    }

    #[test]
    fn claimed_term_skew_is_caught() {
        let (g, h) = star();
        let ctx = crate::analysis::HagCtx::new(&g, &h)
            .with_claimed_terms(h.aggregations() + 1,
                                h.data_transfers());
        let mut r = Report::new();
        term_consistency(&ctx, &mut r);
        assert!(r.flagged("cost.term_consistency"), "{}", r.format());
        // and the honest claim is clean
        let ctx = crate::analysis::HagCtx::new(&g, &h)
            .with_claimed_terms(h.aggregations(),
                                h.data_transfers());
        let mut r = Report::new();
        term_consistency(&ctx, &mut r);
        assert!(r.is_clean(), "{}", r.format());
    }

    #[test]
    fn gauge_skew_is_caught() {
        let (_, h) = star();
        let reg = MetricsRegistry::new();
        let shards = [(h.aggregations(), h.data_transfers())];
        record_plan_terms(&reg, &h, &shards);
        let mut r = Report::new();
        gauges_match(&reg.snapshot(), &h, &shards, &mut r);
        assert!(r.is_clean(), "{}", r.format());
        // desync one gauge: the audit must notice
        reg.gauge("cost.pred_transfers").add(1);
        let mut r = Report::new();
        gauges_match(&reg.snapshot(), &h, &shards, &mut r);
        assert!(r.flagged("cost.gauges_match"), "{}", r.format());
    }
}
