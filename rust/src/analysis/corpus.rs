//! The shared verification corpus: deterministic seeded artifacts —
//! generator graphs × {exact, windowed, capacity-capped} search
//! configs × {single, sharded/stitched, repaired} lowering paths —
//! that `repro verify --corpus` (hard CI gate),
//! `rust/tests/analysis.rs` (clean-pass property + mutation-kill
//! matrix) and `benches/verify_overhead.rs` all run over.

use crate::datasets::{community_graph, ego_clique_set, CommunityCfg,
                      EgoCliqueCfg};
use crate::graph::Graph;
use crate::hag::{build_plan, hag_search, AggregateKind,
                 ExecutionPlan, Hag, PlanConfig, SearchConfig};
use crate::incremental::IncrementalHag;
use crate::partition::{partition_bfs, stitch_hags, subgraph,
                       Partition, PartitionConfig};

use super::{verify, verify_stitched, HagCtx, Report};

/// One verifiable artifact: a HAG over its graph, optionally the
/// compiled plan, the capacity it was searched under, the producer's
/// claimed Definition-2 terms, and (for stitched artifacts) the
/// partition plus per-shard HAGs.
#[derive(Clone)]
pub struct Artifact {
    pub name: String,
    pub graph: Graph,
    pub hag: Hag,
    pub plan: Option<ExecutionPlan>,
    pub capacity: Option<usize>,
    pub claimed_terms: Option<(usize, usize)>,
    pub part: Option<Partition>,
    pub locals: Option<Vec<Hag>>,
}

impl Artifact {
    /// Run every applicable pass: the hag/plan/cost pipeline, plus
    /// the cross-shard passes when the artifact was stitched.
    pub fn verify(&self) -> Report {
        let mut ctx = HagCtx::new(&self.graph, &self.hag);
        if let Some(p) = &self.plan {
            ctx.plan = Some(p);
        }
        ctx.capacity = self.capacity;
        ctx.claimed_terms = self.claimed_terms;
        let mut r = verify(&ctx);
        if let (Some(part), Some(locals)) = (&self.part, &self.locals)
        {
            r.merge(verify_stitched(&self.graph, part, locals,
                                    &self.hag));
        }
        r
    }
}

fn exact(kind: AggregateKind) -> SearchConfig {
    SearchConfig { alpha: 1.0, beta: 1.0, capacity: usize::MAX,
                   kind, pair_cap: usize::MAX }
}

/// The three search regimes the satellite test matrix names.
fn configs(n: usize) -> Vec<(&'static str, SearchConfig)> {
    vec![
        ("exact", exact(AggregateKind::Set)),
        ("windowed",
         SearchConfig { pair_cap: 8, ..exact(AggregateKind::Set) }),
        ("capped",
         SearchConfig { capacity: (n / 8).max(1),
                        ..exact(AggregateKind::Set) }),
    ]
}

fn community() -> Graph {
    community_graph(&CommunityCfg { n: 160, e: 1600, communities: 4,
                                    intra_frac: 0.9, zipf_exp: 0.9,
                                    clone_frac: 0.5 }, 11).0
}

fn ego_union() -> Graph {
    let (graphs, _) = ego_clique_set(
        &EgoCliqueCfg { num_graphs: 5, total_nodes: 100,
                        total_edges: 700, classes: 2 }, 7);
    Graph::disjoint_union(&graphs).0
}

/// Hub + chain + a clique of shared consumers: tiny, but exercises
/// every plan shape (hub band skew, a level hierarchy, empty rows).
fn star_chain() -> Graph {
    let mut edges = Vec::new();
    for u in 1..33u32 {
        edges.push((u, 0)); // hub
    }
    for v in 33..64u32 {
        edges.push((v - 1, v)); // chain
    }
    for v in 64..72u32 {
        for u in 1..5u32 {
            edges.push((u, v)); // shared {1,2,3,4} consumers
        }
    }
    Graph::from_edges(72, &edges)
}

fn single(name: &str, g: Graph, cfg: &SearchConfig) -> Artifact {
    let (hag, _) = hag_search(&g, cfg);
    let plan = build_plan(&g, &hag, &PlanConfig::default());
    let claimed = (hag.aggregations(), hag.data_transfers());
    Artifact { name: name.to_string(), graph: g, hag,
               plan: Some(plan), capacity: Some(cfg.capacity),
               claimed_terms: Some(claimed), part: None,
               locals: None }
}

fn sharded(name: &str, g: Graph, shards: usize,
           cfg: &SearchConfig) -> Artifact {
    let part = partition_bfs(&g, &PartitionConfig::new(shards));
    let local_ids = part.local_ids();
    let locals: Vec<Hag> = (0..part.n_shards)
        .map(|s| hag_search(&subgraph(&g, &part, &local_ids, s),
                            cfg).0)
        .collect();
    let hag = stitch_hags(&g, &part, &locals);
    let plan = build_plan(&g, &hag, &PlanConfig::default());
    let claimed = (hag.aggregations(), hag.data_transfers());
    Artifact { name: name.to_string(), graph: g, hag,
               plan: Some(plan), capacity: None,
               claimed_terms: Some(claimed), part: Some(part),
               locals: Some(locals) }
}

/// Drive a seeded delta stream (deletes with fallback, inserts, node
/// adds, then a windowed re-merge) through an [`IncrementalHag`];
/// returns the post-delta graph and the repaired incremental HAG.
pub fn repaired_stream() -> (Graph, IncrementalHag) {
    let g = community();
    let (h, _) = hag_search(&g, &exact(AggregateKind::Set));
    let mut ih = IncrementalHag::from_hag(&h);
    // adjacency mirror (in-neighbor lists), maintained alongside
    let mut adj: Vec<Vec<u32>> =
        g.iter().map(|(_, ns)| ns.to_vec()).collect();
    let mut rng = crate::util::Rng::seed_from_u64(23);
    let mut dirty: Vec<u32> = Vec::new();
    for step in 0..160usize {
        let v = rng.range_u32(0, adj.len() as u32);
        if step % 3 == 0 && !adj[v as usize].is_empty() {
            // delete a random existing in-edge of v
            let k = rng.range_usize(0, adj[v as usize].len());
            let u = adj[v as usize].remove(k);
            let nn = adj[v as usize].clone();
            ih.delete_edge(u, v, &nn);
            dirty.push(v);
        } else {
            // insert a fresh in-edge u -> v
            let u = rng.range_u32(0, adj.len() as u32);
            if u != v && !adj[v as usize].contains(&u) {
                adj[v as usize].push(u);
                ih.insert_edge(u, v);
                dirty.push(v);
            }
        }
    }
    ih.add_node();
    adj.push(Vec::new());
    let w = (adj.len() - 1) as u32;
    adj[w as usize].push(0);
    ih.insert_edge(0, w);
    dirty.push(w);
    dirty.sort_unstable();
    dirty.dedup();
    ih.local_remerge(&dirty, 16, 64, usize::MAX);
    // rebuild the post-delta graph from the adjacency mirror
    let mut edges = Vec::new();
    for (v, ns) in adj.iter().enumerate() {
        for &u in ns {
            edges.push((u, v as u32));
        }
    }
    (Graph::from_edges(adj.len(), &edges), ih)
}

fn repaired(name: &str) -> Artifact {
    let (g, ih) = repaired_stream();
    let hag = ih.to_hag();
    let plan = build_plan(&g, &hag, &PlanConfig::default());
    let claimed = (hag.aggregations(), hag.data_transfers());
    Artifact { name: name.to_string(), graph: g, hag,
               plan: Some(plan), capacity: None,
               claimed_terms: Some(claimed), part: None,
               locals: None }
}

/// Build the full corpus. Deterministic: seeded generators, no
/// wall-clock or randomness outside the fixed seeds.
pub fn corpus() -> Vec<Artifact> {
    let mut arts = Vec::new();
    for (label, build) in [
        ("community", community as fn() -> Graph),
        ("ego-union", ego_union as fn() -> Graph),
        ("star-chain", star_chain as fn() -> Graph),
    ] {
        for (cname, cfg) in configs(build().n()) {
            arts.push(single(&format!("{label}/{cname}"), build(),
                             &cfg));
        }
    }
    // order-sensitive covers (no stitching: Set-only)
    {
        let g = star_chain();
        let cfg = exact(AggregateKind::Sequential);
        arts.push(single("star-chain/sequential", g, &cfg));
    }
    arts.push(sharded("community/sharded4", community(), 4,
                      &exact(AggregateKind::Set)));
    arts.push(sharded("ego-union/sharded3", ego_union(), 3,
                      &SearchConfig { pair_cap: 8,
                                      ..exact(AggregateKind::Set) }));
    arts.push(repaired("community/repaired"));
    arts
}

/// Verify every corpus artifact plus the incremental-IR stream case;
/// returns `(name, report)` pairs for the `haglint-v1` envelope.
pub fn verify_corpus() -> Vec<(String, Report)> {
    let mut out: Vec<(String, Report)> = corpus()
        .iter()
        .map(|a| (a.name.clone(), a.verify()))
        .collect();
    let (_, ih) = repaired_stream();
    out.push(("community/repaired-incr".to_string(),
              super::check_incremental(&ih)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_covers_all_lowering_paths() {
        let arts = corpus();
        assert!(arts.iter().any(|a| a.part.is_some()),
                "corpus needs a stitched artifact");
        assert!(arts.iter().any(
                    |a| a.hag.kind == AggregateKind::Sequential),
                "corpus needs a sequential artifact");
        assert!(arts.iter().any(|a| !a.hag.agg_nodes.is_empty()),
                "corpus needs hierarchical HAGs");
        assert!(arts.iter().any(|a| a.plan.as_ref()
                    .map_or(false, |p| p.levels >= 1)),
                "corpus needs a leveled plan");
    }
}
