//! Semantic exactness: the static Theorem-1 check.
//!
//! `hag.cover_exact` symbolically expands every aggregation node's
//! cover (paper Eq. 2/3) with one memoized pass in creation order —
//! creation order is topological, so each operand's cover is already
//! available — then checks, per original node, that the concatenated
//! covers of its final in-list reproduce its input-graph neighborhood
//! exactly: as a multiset for `Set` aggregation (catching both missed
//! and double-counted neighbors), verbatim in order for `Sequential`.
//! This subsumes the probabilistic oracle
//! (`hag/equivalence.rs::check_equivalence_probabilistic`) on swap
//! paths: no execution, no false negatives.
//!
//! Only runs on a structurally clean HAG (gated by
//! [`super::structural::hag_passes`]) so cover expansion can index
//! operands unchecked.

use crate::hag::AggregateKind;

use super::{HagCtx, Report};

/// `hag.cover_exact`.
pub fn cover_exact(ctx: &HagCtx, r: &mut Report) {
    const ID: &str = "hag.cover_exact";
    r.ran(ID);
    let hag = ctx.hag;
    let g = ctx.graph;
    if g.n() != hag.n {
        r.error(ID, "n".to_string(),
                format!("HAG has {} original nodes, graph has {}",
                        hag.n, g.n()),
                "a HAG is only equivalent to the graph it was built \
                 from");
        return;
    }
    let n = hag.n;
    let set = hag.kind == AggregateKind::Set;

    // Memoized cover expansion, creation order (topological).
    let mut covers: Vec<Vec<u32>> = Vec::with_capacity(
        hag.agg_nodes.len());
    for a in &hag.agg_nodes {
        let mut c = Vec::new();
        for op in [a.left, a.right] {
            if (op as usize) < n {
                c.push(op);
            } else {
                c.extend_from_slice(&covers[op as usize - n]);
            }
        }
        if set {
            c.sort_unstable();
        }
        covers.push(c);
    }

    let mut got = Vec::new();
    let mut want = Vec::new();
    for v in 0..n {
        got.clear();
        for &s in &hag.in_edges[v] {
            if (s as usize) < n {
                got.push(s);
            } else {
                got.extend_from_slice(&covers[s as usize - n]);
            }
        }
        want.clear();
        want.extend_from_slice(g.neighbors(v as u32));
        if set {
            got.sort_unstable();
            want.sort_unstable();
        }
        if got != want {
            // classify the first divergence for the diagnostic
            let detail = if got.len() != want.len() {
                format!("cover has {} element(s), N(v) has {}",
                        got.len(), want.len())
            } else {
                let i = got.iter().zip(want.iter())
                    .position(|(a, b)| a != b).unwrap_or(0);
                format!("first divergence at position {i}: cover \
                         yields {}, N(v) has {}", got[i], want[i])
            };
            r.error(ID, format!("node {v}"), detail,
                    "the final in-list's expanded covers must \
                     reproduce the node's neighborhood exactly \
                     (Theorem 1); the producing search/stitch/repair \
                     step dropped, duplicated or reordered a \
                     contribution");
            return; // one witness is enough; avoid diagnostic floods
        }
    }
}
