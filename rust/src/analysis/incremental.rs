//! Incremental-IR passes over [`IncrementalHag`]'s raw state
//! (incremental/repair.rs): the bit-31 agg-id-space discipline,
//! liveness/ordering of references, refcount exactness, and the
//! maintained counters. `IncrementalHag::check` is a thin wrapper
//! over [`incr_passes`], so the engine's self-check and the verifier
//! can never disagree.
//!
//! The mutation-kill tests for these passes live here (not in
//! `rust/tests/analysis.rs`): corrupting an `IncrementalHag` needs
//! the crate-internal `raw_parts_mut` window.

use crate::incremental::repair::{agg_id, is_agg};
use crate::incremental::IncrementalHag;

use super::Report;

/// Run the four incremental passes in dependency order (reference
/// decoding gates everything that indexes through agg ids).
pub fn incr_passes(ih: &IncrementalHag) -> Report {
    let mut r = Report::new();
    id_space(ih, &mut r);
    if !r.is_clean() {
        return r;
    }
    topo_order(ih, &mut r);
    refcounts(ih, &mut r);
    counters(ih, &mut r);
    r
}

/// `incr.id_space`: every internal slot — live agg operands and final
/// in-slots — decodes to a real node (`< n`) or an allocated agg id.
fn id_space(ih: &IncrementalHag, r: &mut Report) {
    const ID: &str = "incr.id_space";
    r.ran(ID);
    let (n, aggs, _, in_edges, _, _) = ih.raw_parts();
    let mut check = |entity: String, s: u32, r: &mut Report| {
        if is_agg(s) {
            if agg_id(s) >= aggs.len() {
                r.error(ID, entity,
                        format!("agg id {} >= allocated id space {}",
                                agg_id(s), aggs.len()),
                        "bit-31 slots must decode to an allocated \
                         aggregation id; ids are append-only");
            }
        } else if (s as usize) >= n {
            r.error(ID, entity,
                    format!("node slot {s} >= n = {n}"),
                    "untagged slots are original node ids");
        }
    };
    for (i, a) in aggs.iter().enumerate() {
        if let Some(a) = a {
            check(format!("agg {i}"), a.left, r);
            check(format!("agg {i}"), a.right, r);
        }
    }
    for (v, l) in in_edges.iter().enumerate() {
        for &s in l {
            check(format!("node {v}"), s, r);
        }
    }
}

/// `incr.topo_order`: live-reference discipline — a live agg's
/// operands reference *live*, *earlier* aggs (id order is creation
/// order, hence topological), and finals never consume GC'd nodes.
fn topo_order(ih: &IncrementalHag, r: &mut Report) {
    const ID: &str = "incr.topo_order";
    r.ran(ID);
    let (_, aggs, _, in_edges, _, _) = ih.raw_parts();
    for (i, a) in aggs.iter().enumerate() {
        if let Some(a) = a {
            for op in [a.left, a.right] {
                if !is_agg(op) {
                    continue;
                }
                if aggs[agg_id(op)].is_none() {
                    r.error(ID, format!("agg {i}"),
                            format!("references garbage-collected \
                                     agg {}", agg_id(op)),
                            "the refcount cascade must keep every \
                             referenced node alive");
                } else if agg_id(op) >= i {
                    r.error(ID, format!("agg {i}"),
                            format!("references non-earlier agg {}",
                                    agg_id(op)),
                            "ids are append-only, so a merge may \
                             only consume already-created nodes");
                }
            }
        }
    }
    for (v, l) in in_edges.iter().enumerate() {
        for &s in l {
            if is_agg(s) && aggs[agg_id(s)].is_none() {
                r.error(ID, format!("node {v}"),
                        format!("in-list references \
                                 garbage-collected agg {}",
                                agg_id(s)),
                        "finals hold a reference; collection of a \
                         still-consumed node is a refcount bug");
            }
        }
    }
}

/// `incr.refcounts`: stored refcounts equal the recomputed live
/// reference counts (finals + live agg operands).
fn refcounts(ih: &IncrementalHag, r: &mut Report) {
    const ID: &str = "incr.refcounts";
    r.ran(ID);
    let (_, aggs, refs, in_edges, _, _) = ih.raw_parts();
    let mut want = vec![0u32; aggs.len()];
    for a in aggs.iter().flatten() {
        for op in [a.left, a.right] {
            if is_agg(op) {
                want[agg_id(op)] += 1;
            }
        }
    }
    for l in in_edges {
        for &s in l {
            if is_agg(s) {
                want[agg_id(s)] += 1;
            }
        }
    }
    for (i, (&got, &want)) in
        refs.iter().zip(want.iter()).enumerate()
    {
        if aggs[i].is_some() && got != want {
            r.error(ID, format!("agg {i}"),
                    format!("stored refcount {got} != recomputed \
                             {want}"),
                    "acquire/release must bracket every rewire; a \
                     desynced refcount GCs live nodes or leaks dead \
                     ones");
        }
    }
}

/// `incr.counters`: the maintained `live` / `final_edges` counters
/// are exact and in-lists are duplicate-free (set AGGREGATE).
fn counters(ih: &IncrementalHag, r: &mut Report) {
    const ID: &str = "incr.counters";
    r.ran(ID);
    let (_, aggs, _, in_edges, live, final_edges) = ih.raw_parts();
    let actual_live = aggs.iter().filter(|a| a.is_some()).count();
    if actual_live != live {
        r.error(ID, "live".to_string(),
                format!("maintained live count {live} != actual \
                         {actual_live}"),
                "live is the cost-model input (cost_core = live + \
                 final_edges); every take()/push must adjust it");
    }
    let actual_edges: usize =
        in_edges.iter().map(|l| l.len()).sum();
    if actual_edges != final_edges {
        r.error(ID, "final_edges".to_string(),
                format!("maintained edge count {final_edges} != \
                         actual {actual_edges}"),
                "final_edges is the cost-model input; every in-list \
                 edit must adjust it");
    }
    let mut scratch = Vec::new();
    for (v, l) in in_edges.iter().enumerate() {
        scratch.clear();
        scratch.extend_from_slice(l);
        scratch.sort_unstable();
        let before = scratch.len();
        scratch.dedup();
        if scratch.len() != before {
            r.error(ID, format!("node {v}"),
                    format!("in-list of {before} slots has \
                             duplicates"),
                    "set-AGGREGATE in-lists are duplicate-free; a \
                     repeated slot double-counts its cover");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::hag::{hag_search, AggregateKind, SearchConfig};
    use crate::incremental::repair::agg_slot;

    /// finals 3,4,5 share {0,1,2}: the exact search chains two merges
    /// (agg0 = (0,1), agg1 = (agg0, 2)), giving a deterministic
    /// two-agg incremental HAG to corrupt.
    fn chained() -> IncrementalHag {
        let mut edges = Vec::new();
        for v in 3..6u32 {
            for u in 0..3u32 {
                edges.push((u, v));
            }
        }
        let g = Graph::from_edges(6, &edges);
        let (h, _) = hag_search(&g, &SearchConfig {
            alpha: 1.0, beta: 1.0, capacity: usize::MAX,
            kind: AggregateKind::Set, pair_cap: usize::MAX });
        let ih = IncrementalHag::from_hag(&h);
        assert_eq!(ih.live_aggs(), 2, "fixture needs a chain");
        assert!(incr_passes(&ih).is_clean());
        ih
    }

    #[test]
    fn kill_id_space_on_unallocated_agg_id() {
        let mut ih = chained();
        {
            let (aggs, _, in_edges, _, final_edges) =
                ih.raw_parts_mut();
            let bogus = agg_slot(aggs.len() + 7);
            in_edges[0].push(bogus);
            *final_edges += 1; // keep incr.counters honest
        }
        let r = incr_passes(&ih);
        assert!(r.flagged("incr.id_space"), "{}", r.format());
        assert!(ih.check().is_err());
    }

    #[test]
    fn kill_topo_order_on_forward_reference() {
        let mut ih = chained();
        {
            // agg0's left operand (an original) now points forward at
            // agg1; bump agg1's refcount so only the ordering pass,
            // not incr.refcounts, can catch it.
            let (aggs, refs, _, _, _) = ih.raw_parts_mut();
            let a0 = aggs[0].as_mut().expect("agg0 live");
            assert!(!crate::incremental::repair::is_agg(a0.left));
            a0.left = agg_slot(1);
            refs[1] += 1;
        }
        let r = incr_passes(&ih);
        assert!(r.flagged("incr.topo_order"), "{}", r.format());
        assert!(!r.flagged("incr.refcounts"),
                "mutation must be invisible to the refcount pass: {}",
                r.format());
    }

    #[test]
    fn kill_refcounts_on_desync() {
        let mut ih = chained();
        {
            let (_, refs, _, _, _) = ih.raw_parts_mut();
            refs[0] += 1;
        }
        let r = incr_passes(&ih);
        assert!(r.flagged("incr.refcounts"), "{}", r.format());
        assert!(!r.flagged("incr.topo_order"), "{}", r.format());
    }

    #[test]
    fn kill_counters_on_live_skew() {
        let mut ih = chained();
        {
            let (_, _, _, live, _) = ih.raw_parts_mut();
            *live += 1;
        }
        let r = incr_passes(&ih);
        assert!(r.flagged("incr.counters"), "{}", r.format());
    }

    #[test]
    fn kill_counters_on_duplicate_inslot() {
        let mut ih = chained();
        {
            // repeat an original (untagged) slot so refcounts stay
            // untouched and only the duplicate check can fire
            let (_, _, in_edges, _, final_edges) =
                ih.raw_parts_mut();
            in_edges[0].push(2);
            in_edges[0].push(2);
            *final_edges += 2;
        }
        let r = incr_passes(&ih);
        assert!(r.flagged("incr.counters"), "{}", r.format());
        assert!(!r.flagged("incr.refcounts"), "{}", r.format());
    }
}
