//! Source-convention lint (`repro lint-src`): a std-only line scanner
//! over `rust/src/` enforcing three repo conventions that rustc cannot
//! see:
//!
//! - **R1 `no-panic-path`** — no `.unwrap()` / `.expect("...")` /
//!   `panic!(` in the request path (`net/`, `durability/`, `fault/`,
//!   and `coordinator/server.rs`): a poisoned lock, malformed frame,
//!   or failed fsync must degrade to a protocol error or a nack,
//!   never take the serving thread down. (The fault plane's Panic
//!   action is the one allowlisted exception — it panics by
//!   contract.)
//! - **R2 `metric-name`** — literal metric names registered via
//!   `.counter("...")` / `.gauge("...")` / `.histogram("...")` follow
//!   the `subsystem.noun_verb` shape (`[a-z][a-z0-9_]*` segments, >= 2,
//!   dot-separated) that `repro obs` checkers and the dashboards key
//!   on.
//! - **R3 `no-deprecated`** — the deprecated one-shot wrappers
//!   (`coordinator::lower_dataset`, `coordinator::emit_buckets`) are
//!   not referenced outside `coordinator/` itself; everything else
//!   goes through sessions. (The `-D deprecated` CI job catches typed
//!   uses; this catches path strings in macros and generated dispatch
//!   the attribute misses.)
//!
//! Known-good exceptions live in `tools/srclint-allow.txt`
//! (`<path-suffix>|<line-substring>` per line); trailing
//! `#[cfg(test)] mod tests` regions are skipped, since tests *should*
//! unwrap. Needles are assembled at runtime so the linter's own
//! source never matches them.

use std::fs;
use std::path::{Path, PathBuf};

/// One lint hit: file (repo-relative, `/`-separated), 1-based line,
/// rule id, and the offending line.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub excerpt: String,
}

impl Finding {
    pub fn format(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule,
                self.excerpt.trim())
    }
}

/// Parse `tools/srclint-allow.txt`: `path-suffix|line-substring`
/// entries, `#` comments and blank lines ignored. A missing file is
/// an empty allowlist, not an error.
pub fn load_allowlist(path: &Path) -> Vec<(String, String)> {
    let Ok(text) = fs::read_to_string(path) else {
        return Vec::new();
    };
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            l.split_once('|')
                .map(|(p, n)| (p.trim().to_string(),
                               n.trim().to_string()))
        })
        .collect()
}

fn allowed(allow: &[(String, String)], file: &str,
           line: &str) -> bool {
    allow.iter().any(|(p, n)| {
        (file == p || file.ends_with(p)) && line.contains(n)
    })
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = fs::read_dir(dir)
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Index of the first line of the trailing `#[cfg(test)]` + `mod ...`
/// region, or `len` when the file has none. Inline `#[cfg(test)]`
/// attributes on items other than modules do not end the scan.
fn test_region_start(lines: &[&str]) -> usize {
    for (i, l) in lines.iter().enumerate() {
        if l.trim() != format!("#[cfg({})]", "test") {
            continue;
        }
        let next = lines[i + 1..].iter()
            .map(|l| l.trim())
            .find(|l| !l.is_empty());
        if next.is_some_and(
            |l| l.starts_with("mod ") || l.starts_with("pub mod "))
        {
            return i;
        }
    }
    lines.len()
}

/// `subsystem.noun_verb`: >= 2 dot-separated `[a-z0-9_]+` segments,
/// first segment starting with a letter.
fn metric_name_ok(name: &str) -> bool {
    let segs: Vec<&str> = name.split('.').collect();
    segs.len() >= 2
        && name.starts_with(|c: char| c.is_ascii_lowercase())
        && segs.iter().all(|s| {
            !s.is_empty()
                && s.bytes().all(
                    |b| b.is_ascii_lowercase()
                        || b.is_ascii_digit() || b == b'_')
        })
}

/// Lint every `.rs` file under `src_root`. Deterministic order;
/// returns findings not covered by `allow`.
pub fn run(src_root: &Path, allow: &[(String, String)])
           -> Result<Vec<Finding>, String> {
    // runtime-assembled needles: this file must not lint itself
    let panic_needles: Vec<String> = vec![
        format!(".{}()", "unwrap"),
        format!(".{}(\"", "expect"),
        format!("{}!(", "panic"),
    ];
    let metric_needles: Vec<String> =
        ["counter", "gauge", "histogram"]
            .iter().map(|k| format!(".{k}(\"")).collect();
    let deprecated_needles: Vec<String> =
        ["lower_dataset", "emit_buckets"]
            .iter().map(|f| format!("{}::{f}", "coordinator"))
            .collect();

    let mut files = Vec::new();
    rs_files(src_root, &mut files)?;
    let mut findings = Vec::new();
    for path in files {
        let rel = path.strip_prefix(src_root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let text = fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let lines: Vec<&str> = text.lines().collect();
        let end = test_region_start(&lines);
        let in_request_path = rel.starts_with("net/")
            || rel.starts_with("durability/")
            || rel.starts_with("fault/")
            || rel == "coordinator/server.rs";
        let in_coordinator = rel.starts_with("coordinator/");
        for (i, &line) in lines[..end].iter().enumerate() {
            if line.trim_start().starts_with("//") {
                continue;
            }
            let mut hit = |rule: &'static str| {
                if !allowed(allow, &rel, line) {
                    findings.push(Finding {
                        file: rel.clone(), line: i + 1, rule,
                        excerpt: line.to_string(),
                    });
                }
            };
            if in_request_path
                && panic_needles.iter().any(|n| line.contains(n))
            {
                hit("no-panic-path");
            }
            if !in_coordinator
                && deprecated_needles.iter()
                    .any(|n| line.contains(n))
            {
                hit("no-deprecated");
            }
            for needle in &metric_needles {
                let mut rest = line;
                while let Some(pos) = rest.find(needle.as_str()) {
                    rest = &rest[pos + needle.len()..];
                    if let Some(q) = rest.find('"') {
                        if !metric_name_ok(&rest[..q]) {
                            hit("metric-name");
                        }
                        rest = &rest[q + 1..];
                    } else {
                        break;
                    }
                }
            }
        }
    }
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct TempTree(PathBuf);

    impl TempTree {
        fn new(tag: &str) -> Self {
            let dir = std::env::temp_dir().join(format!(
                "srclint-{tag}-{}", std::process::id()));
            let _ = fs::remove_dir_all(&dir);
            fs::create_dir_all(&dir).unwrap();
            TempTree(dir)
        }

        fn write(&self, rel: &str, body: &str) {
            let p = self.0.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(p, body).unwrap();
        }
    }

    impl Drop for TempTree {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.0);
        }
    }

    #[test]
    fn flags_panic_in_request_path_only() {
        let t = TempTree::new("panic");
        let body = format!("fn f() {{ x.{}(); }}\n", "unwrap");
        t.write("net/a.rs", &body);
        t.write("util/b.rs", &body);
        let f = run(&t.0, &[]).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].file, "net/a.rs");
        assert_eq!(f[0].rule, "no-panic-path");
    }

    #[test]
    fn skips_trailing_test_module() {
        let t = TempTree::new("testmod");
        let body = format!(
            "fn f() {{}}\n#[cfg({})]\nmod tests {{\n    fn g() {{ \
             x.{}(); }}\n}}\n", "test", "unwrap");
        t.write("net/a.rs", &body);
        assert!(run(&t.0, &[]).unwrap().is_empty());
    }

    #[test]
    fn allowlist_suppresses_by_suffix_and_substring() {
        let t = TempTree::new("allow");
        let body = format!("fn f() {{ lock.{}(); }}\n", "unwrap");
        t.write("net/a.rs", &body);
        let needle = format!("lock.{}()", "unwrap");
        let allow = vec![("net/a.rs".to_string(), needle)];
        assert!(run(&t.0, &allow).unwrap().is_empty());
        // a different line in the same file still fires
        let other = format!("fn f() {{ other.{}(); }}\n", "unwrap");
        t.write("net/a.rs", &other);
        assert_eq!(run(&t.0, &allow).unwrap().len(), 1);
    }

    #[test]
    fn flags_malformed_metric_names_anywhere() {
        let t = TempTree::new("metric");
        let body = format!(
            "fn f(r: &R) {{\n    r.{}(\"serve.requests\");\n    \
             r.{}(\"BadName\");\n    r.{}(\"noseparator\");\n}}\n",
            "counter", "gauge", "histogram");
        t.write("util/m.rs", &body);
        let f = run(&t.0, &[]).unwrap();
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "metric-name"));
    }

    #[test]
    fn flags_deprecated_wrappers_outside_coordinator() {
        let t = TempTree::new("deprecated");
        let call = format!("    {}::{}(x);\n",
                           "coordinator", "lower_dataset");
        let body = format!("fn f() {{\n{call}}}\n");
        t.write("session/a.rs", &body);
        t.write("coordinator/a.rs", &body);
        // doc comments are exempt: migration notes may name them
        t.write("util/doc.rs", &format!("//! uses {}::{}\n",
                                        "coordinator",
                                        "lower_dataset"));
        let f = run(&t.0, &[]).unwrap();
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].file, "session/a.rs");
        assert_eq!(f[0].rule, "no-deprecated");
    }
}
