//! The corruption harness: one targeted, minimal defect per pass
//! class, applied to a corpus [`Artifact`]. Each mutant is designed
//! so its owning pass *must* fire — the mutation-kill matrix in
//! `rust/tests/analysis.rs` asserts exactly that, which is the proof
//! that no analysis pass is vacuous. (The incremental-IR mutants
//! need crate-private state and live in `analysis/incremental.rs`;
//! the `cost.gauges_match` kill drives a real registry and lives in
//! the test crate.)
//!
//! Mutations are deliberately *surgical*: they corrupt exactly one
//! invariant, keeping everything upstream of the owning pass clean so
//! dependency gating cannot hide the kill. Passes downstream of the
//! defect may fire too — the kill assertion is membership of the
//! expected pass id, not exclusivity.

use crate::hag::AggregateKind;

use super::corpus::Artifact;

/// Every public mutant, one (or more) per analysis pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutant {
    /// Agg operand points at its own slot -> `hag.topo_order`.
    HagForwardRef,
    /// Final in-edge past the slot space -> `hag.slot_range`.
    HagSlotOob,
    /// Repeated slot in a set in-list -> `hag.dup_inslots`.
    HagDupInSlot,
    /// Unconsumed aggregation node appended -> `hag.orphan_agg`.
    HagOrphanAgg,
    /// Declared capacity below `|V_A|` -> `hag.capacity_fit`.
    HagCapacityBust,
    /// Original slot dropped from an in-list -> `hag.cover_exact`.
    HagCoverDrop,
    /// Claimed Definition-2 terms skewed -> `cost.term_consistency`.
    CostClaimSkew,
    /// `n_pad` inflated without repadding -> `plan.shape`.
    PlanShapePad,
    /// `perm` swapped without fixing `inv_perm` ->
    /// `plan.perm_bijection`.
    PlanPermSwap,
    /// Band gather index past the buffer -> `plan.index_range`.
    PlanIndexOob,
    /// Level operand reads its own level -> `plan.level_order`.
    PlanLevelOrder,
    /// Two band entries' destination rows swapped ->
    /// `plan.encodes_hag`.
    PlanBandRowSwap,
    /// Level operand retargeted to a different original ->
    /// `plan.encodes_hag`.
    PlanLvlSkew,
    /// Stitched agg operand leaks into another shard ->
    /// `stitch.shard_blocks`.
    StitchBlockLeak,
    /// Cross-shard fallback edge dropped -> `stitch.cross_edges`.
    StitchCrossDrop,
    /// Shard-local HAG edited after stitching ->
    /// `stitch.term_sums`.
    StitchLocalSkew,
}

/// All public mutants, matrix order.
pub const ALL_MUTANTS: &[Mutant] = &[
    Mutant::HagForwardRef,
    Mutant::HagSlotOob,
    Mutant::HagDupInSlot,
    Mutant::HagOrphanAgg,
    Mutant::HagCapacityBust,
    Mutant::HagCoverDrop,
    Mutant::CostClaimSkew,
    Mutant::PlanShapePad,
    Mutant::PlanPermSwap,
    Mutant::PlanIndexOob,
    Mutant::PlanLevelOrder,
    Mutant::PlanBandRowSwap,
    Mutant::PlanLvlSkew,
    Mutant::StitchBlockLeak,
    Mutant::StitchCrossDrop,
    Mutant::StitchLocalSkew,
];

impl Mutant {
    /// The pass that owns this corruption class and must catch it.
    pub fn expected_pass(self) -> &'static str {
        match self {
            Mutant::HagForwardRef => "hag.topo_order",
            Mutant::HagSlotOob => "hag.slot_range",
            Mutant::HagDupInSlot => "hag.dup_inslots",
            Mutant::HagOrphanAgg => "hag.orphan_agg",
            Mutant::HagCapacityBust => "hag.capacity_fit",
            Mutant::HagCoverDrop => "hag.cover_exact",
            Mutant::CostClaimSkew => "cost.term_consistency",
            Mutant::PlanShapePad => "plan.shape",
            Mutant::PlanPermSwap => "plan.perm_bijection",
            Mutant::PlanIndexOob => "plan.index_range",
            Mutant::PlanLevelOrder => "plan.level_order",
            Mutant::PlanBandRowSwap => "plan.encodes_hag",
            Mutant::PlanLvlSkew => "plan.encodes_hag",
            Mutant::StitchBlockLeak => "stitch.shard_blocks",
            Mutant::StitchCrossDrop => "stitch.cross_edges",
            Mutant::StitchLocalSkew => "stitch.term_sums",
        }
    }
}

/// Apply `m` to `art` in place. Returns `false` when the artifact
/// cannot host this mutant (e.g. no aggregation nodes, no levels, no
/// cut edges) — the kill matrix requires each mutant to land on at
/// least one corpus artifact, not on all of them.
pub fn apply(m: Mutant, art: &mut Artifact) -> bool {
    match m {
        Mutant::HagForwardRef => {
            if art.hag.agg_nodes.is_empty() {
                return false;
            }
            // self-reference: the minimal non-earlier operand
            art.hag.agg_nodes[0].left = art.hag.n as u32;
            true
        }
        Mutant::HagSlotOob => {
            let oob = art.hag.slots() as u32 + 3;
            art.hag.in_edges[0].push(oob);
            true
        }
        Mutant::HagDupInSlot => {
            if art.hag.kind != AggregateKind::Set {
                return false;
            }
            let Some(list) = art.hag.in_edges.iter_mut()
                .find(|l| !l.is_empty())
            else {
                return false;
            };
            let s = list[0];
            list.push(s);
            true
        }
        Mutant::HagOrphanAgg => {
            if art.hag.n < 2 {
                return false;
            }
            art.hag.agg_nodes.push(
                crate::hag::AggNode { left: 0, right: 1 });
            true
        }
        Mutant::HagCapacityBust => {
            if art.hag.agg_nodes.is_empty() {
                return false;
            }
            art.capacity = Some(art.hag.agg_nodes.len() - 1);
            true
        }
        Mutant::HagCoverDrop => {
            // Drop an *original* slot so no agg is orphaned and the
            // structural passes stay clean — only the Theorem-1
            // check can see the missing contribution.
            let n = art.hag.n as u32;
            for list in art.hag.in_edges.iter_mut() {
                if let Some(pos) =
                    list.iter().position(|&s| s < n)
                {
                    list.remove(pos);
                    return true;
                }
            }
            false
        }
        Mutant::CostClaimSkew => {
            let (a, t) = art.claimed_terms.unwrap_or((
                art.hag.aggregations(), art.hag.data_transfers()));
            art.claimed_terms = Some((a + 1, t));
            true
        }
        Mutant::PlanShapePad => {
            let Some(plan) = art.plan.as_mut() else {
                return false;
            };
            plan.n_pad += plan.br.max(1);
            true
        }
        Mutant::PlanPermSwap => {
            let Some(plan) = art.plan.as_mut() else {
                return false;
            };
            if plan.n < 2 {
                return false;
            }
            plan.perm.swap(0, 1); // inv_perm left stale
            true
        }
        Mutant::PlanIndexOob => {
            let Some(plan) = art.plan.as_mut() else {
                return false;
            };
            let m_pad = plan.m_pad() as i32;
            let Some(cols) = plan.band_cols.first_mut() else {
                return false;
            };
            if cols.is_empty() {
                return false;
            }
            cols[0] = m_pad;
            true
        }
        Mutant::PlanLevelOrder => {
            let Some(plan) = art.plan.as_mut() else {
                return false;
            };
            if plan.levels == 0 {
                return false;
            }
            // first level-1 entry is always real; point its operand
            // at its own level's base
            plan.lvl_left[0] = plan.n_pad as i32;
            true
        }
        Mutant::PlanBandRowSwap => {
            let Some(plan) = art.plan.as_mut() else {
                return false;
            };
            let zero = plan.zero_slot();
            for (bi, &(nb, nnzb)) in
                plan.bands.clone().iter().enumerate()
            {
                for b in 0..nb {
                    // two real entries in one block with different
                    // destination rows and different columns
                    let idx = |j: usize| b * nnzb + j;
                    for j1 in 0..nnzb {
                        if plan.band_cols[bi][idx(j1)] == zero {
                            continue;
                        }
                        for j2 in (j1 + 1)..nnzb {
                            if plan.band_cols[bi][idx(j2)] == zero {
                                continue;
                            }
                            if plan.band_rows[bi][idx(j1)]
                                != plan.band_rows[bi][idx(j2)]
                                && plan.band_cols[bi][idx(j1)]
                                    != plan.band_cols[bi][idx(j2)]
                            {
                                plan.band_rows[bi]
                                    .swap(idx(j1), idx(j2));
                                return true;
                            }
                        }
                    }
                }
            }
            false
        }
        Mutant::PlanLvlSkew => {
            let Some(plan) = art.plan.as_mut() else {
                return false;
            };
            if plan.levels == 0 {
                return false;
            }
            // Level-1 operands are originals (< n_pad), so a +-1
            // retarget stays a valid, well-ordered buffer index —
            // only the encoding check can see it.
            let v = plan.lvl_left[0];
            plan.lvl_left[0] = if (v as usize) + 1
                < plan.n_pad { v + 1 } else { v - 1 };
            true
        }
        Mutant::StitchBlockLeak => {
            let (Some(part), Some(_)) = (&art.part, &art.locals)
            else {
                return false;
            };
            if art.hag.agg_nodes.is_empty() {
                return false;
            }
            // retarget agg 0's operand at a node of a different
            // shard than its old operand's
            let a = art.hag.agg_nodes[0];
            let owner = |s: u32| -> u32 {
                if (s as usize) < art.hag.n {
                    part.shard_of[s as usize]
                } else {
                    u32::MAX
                }
            };
            let old = owner(a.left);
            let Some(w) = (0..art.hag.n as u32).find(
                |&w| part.shard_of[w as usize] != old
                    && w != a.left)
            else {
                return false;
            };
            art.hag.agg_nodes[0].left = w;
            true
        }
        Mutant::StitchCrossDrop => {
            let (Some(part), Some(locals)) =
                (&art.part, &art.locals)
            else {
                return false;
            };
            // find a node with a non-empty cross-shard tail and
            // drop the tail's last (direct fallback) slot
            let mut local_len = vec![0usize; art.hag.n];
            for (s, lh) in locals.iter().enumerate() {
                for (lv, list) in lh.in_edges.iter().enumerate() {
                    local_len[part.members[s][lv] as usize] =
                        list.len();
                }
            }
            for (v, list) in art.hag.in_edges.iter_mut().enumerate()
            {
                if list.len() > local_len[v] {
                    list.pop();
                    return true;
                }
            }
            false
        }
        Mutant::StitchLocalSkew => {
            let Some(locals) = art.locals.as_mut() else {
                return false;
            };
            for lh in locals.iter_mut() {
                if let Some(list) = lh.in_edges.iter_mut()
                    .find(|l| !l.is_empty())
                {
                    list.pop();
                    return true;
                }
            }
            false
        }
    }
}
