//! Structural well-formedness passes over [`Hag`] and
//! [`ExecutionPlan`].
//!
//! These run first and gate everything else: the exactness / cost /
//! plan passes index through agg operands and permutations, so they
//! are only attempted once the structure they index through is known
//! sound (a corrupt artifact must yield diagnostics, never a panic).

use crate::hag::{AggregateKind, ExecutionPlan, Hag};

use super::{HagCtx, Report};

fn round_up(x: usize, q: usize) -> usize {
    if q == 0 { x } else { x.div_ceil(q) * q }
}

/// Run the five HAG structural passes.
pub fn hag_passes(ctx: &HagCtx, r: &mut Report) {
    topo_order(ctx.hag, r);
    slot_range(ctx.hag, r);
    dup_inslots(ctx.hag, r);
    // orphan/capacity only make sense once references are in-range
    if r.is_clean() {
        orphan_agg(ctx.hag, r);
    }
    capacity_fit(ctx.hag, ctx.capacity, r);
}

/// `hag.topo_order`: each aggregation node's operands reference
/// strictly earlier slots. Creation order is topological by
/// construction (hag/mod.rs module docs), so this is also the
/// acyclicity check: a forward reference is the only way a cycle
/// could be encoded.
fn topo_order(hag: &Hag, r: &mut Report) {
    const ID: &str = "hag.topo_order";
    r.ran(ID);
    for (i, a) in hag.agg_nodes.iter().enumerate() {
        let self_slot = (hag.n + i) as u32;
        if a.left >= self_slot || a.right >= self_slot {
            r.error(ID, format!("agg {i}"),
                    format!("operands ({}, {}) must be < own slot \
                             {self_slot}", a.left, a.right),
                    "merges may only reference already-created slots; \
                     re-emit aggregation nodes in creation order");
        }
    }
}

/// `hag.slot_range`: every final in-edge names an existing slot.
fn slot_range(hag: &Hag, r: &mut Report) {
    const ID: &str = "hag.slot_range";
    r.ran(ID);
    let max_slot = hag.slots() as u32;
    for (v, l) in hag.in_edges.iter().enumerate() {
        for &s in l {
            if s >= max_slot {
                r.error(ID, format!("node {v}"),
                        format!("in-edge slot {s} >= slot count \
                                 {max_slot}"),
                        "final in-edges must point at an original \
                         node or a materialized aggregation node");
            }
        }
    }
    if hag.in_edges.len() != hag.n {
        r.error(ID, "in_edges".to_string(),
                format!("{} final lists for {} original nodes",
                        hag.in_edges.len(), hag.n),
                "in_edges must carry exactly one list per original \
                 node");
    }
}

/// `hag.dup_inslots`: for `Set` aggregation, a node's in-list is a
/// set — a duplicate slot would double-count its cover.
fn dup_inslots(hag: &Hag, r: &mut Report) {
    const ID: &str = "hag.dup_inslots";
    r.ran(ID);
    if hag.kind != AggregateKind::Set {
        return;
    }
    let mut scratch = Vec::new();
    for (v, l) in hag.in_edges.iter().enumerate() {
        scratch.clear();
        scratch.extend_from_slice(l);
        scratch.sort_unstable();
        let before = scratch.len();
        scratch.dedup();
        if scratch.len() != before {
            r.error(ID, format!("node {v}"),
                    format!("in-list of {} slots has {} duplicate(s)",
                            before, before - scratch.len()),
                    "deduplicate the in-list; a repeated slot \
                     double-counts its cover under set aggregation");
        }
    }
}

/// `hag.orphan_agg`: every aggregation node is consumed by at least
/// one final in-list or later aggregation node. An orphan is never
/// produced by the search/stitch/repair pipeline and silently skews
/// every Definition-2 term (`e_hat` counts 2 operand edges per agg).
fn orphan_agg(hag: &Hag, r: &mut Report) {
    const ID: &str = "hag.orphan_agg";
    r.ran(ID);
    let na = hag.agg_nodes.len();
    if na == 0 {
        return;
    }
    let mut referenced = vec![false; na];
    let mut mark = |s: u32, referenced: &mut Vec<bool>| {
        if let Some(i) = (s as usize).checked_sub(hag.n) {
            referenced[i] = true;
        }
    };
    for a in &hag.agg_nodes {
        mark(a.left, &mut referenced);
        mark(a.right, &mut referenced);
    }
    for l in &hag.in_edges {
        for &s in l {
            mark(s, &mut referenced);
        }
    }
    for (i, refd) in referenced.iter().enumerate() {
        if !refd {
            r.error(ID, format!("agg {i}"),
                    "aggregation node is consumed by no final list \
                     or later merge".to_string(),
                    "garbage-collect unconsumed merges before \
                     exporting a HAG");
        }
    }
}

/// `hag.capacity_fit`: `|V_A|` within the producer's declared budget
/// (the paper §3.2 a-hat memory bound the search was run under).
fn capacity_fit(hag: &Hag, capacity: Option<usize>, r: &mut Report) {
    const ID: &str = "hag.capacity_fit";
    let Some(cap) = capacity else { return };
    r.ran(ID);
    if hag.agg_nodes.len() > cap {
        r.error(ID, "agg_nodes".to_string(),
                format!("|V_A| = {} exceeds capacity budget {cap}",
                        hag.agg_nodes.len()),
                "the search/remerge must stop materializing merges \
                 at the capacity bound; rebuild with the declared \
                 budget");
    }
}

/// Run the plan passes in dependency order: `shape` ->
/// `perm_bijection` -> `index_range` -> `level_order` ->
/// `encodes_hag`; each later pass only runs once everything it
/// indexes through has been proven sound.
pub fn plan_passes(ctx: &HagCtx, plan: &ExecutionPlan,
                   r: &mut Report) {
    let before = r.errors();
    plan_shape(ctx.hag, plan, r);
    if r.errors() > before {
        return;
    }
    plan_perm_bijection(plan, r);
    if r.errors() > before {
        return;
    }
    plan_index_range(plan, r);
    if r.errors() > before {
        return;
    }
    plan_level_order(plan, r);
    if r.errors() > before {
        return;
    }
    plan_encodes_hag(ctx, plan, r);
}

/// `plan.shape`: padded dims and tensor lengths obey the layout
/// contract in schedule.rs (and python/compile/buckets.py).
fn plan_shape(hag: &Hag, plan: &ExecutionPlan, r: &mut Report) {
    const ID: &str = "plan.shape";
    r.ran(ID);
    let mut err = |entity: &str, msg: String, hint: &'static str| {
        r.error(ID, entity.to_string(), msg, hint);
    };
    if plan.n != hag.n {
        err("n", format!("plan.n = {} but hag.n = {}", plan.n, hag.n),
            "a plan is only valid for the HAG it was compiled from");
    }
    if plan.br == 0 || plan.lvl_block == 0 {
        err("br/lvl_block",
            format!("br = {}, lvl_block = {} must be positive",
                    plan.br, plan.lvl_block),
            "layout quanta come from PlanConfig and are never zero");
        return; // everything below divides by these
    }
    let want_n_pad = round_up(plan.n.max(1), 128_usize.max(plan.br));
    if plan.n_pad != want_n_pad {
        err("n_pad",
            format!("n_pad = {} but round_up(max(n,1), max(128,br)) \
                     = {want_n_pad}", plan.n_pad),
            "n_pad is fully determined by n and br; recompile the \
             plan");
    }
    if plan.levels == 0 {
        if plan.l_pad != 0 {
            err("l_pad",
                format!("l_pad = {} with zero levels", plan.l_pad),
                "a level-free plan has no level tensors; l_pad must \
                 be 0");
        }
    } else if plan.l_pad == 0 || plan.l_pad % plan.lvl_block != 0 {
        err("l_pad",
            format!("l_pad = {} is not a positive multiple of \
                     lvl_block {}", plan.l_pad, plan.lvl_block),
            "l_pad is the max level size rounded up to lvl_block");
    }
    let rows: usize =
        plan.bands.iter().map(|&(nb, _)| nb * plan.br).sum();
    if rows != plan.n_pad {
        err("bands",
            format!("band row extents sum to {rows}, n_pad = {}",
                    plan.n_pad),
            "bands partition the padded row space exactly");
    }
    for (bi, &(nb, nnzb)) in plan.bands.iter().enumerate() {
        if nb == 0 || nnzb == 0 {
            err("bands",
                format!("band {bi} has nb = {nb}, nnzb = {nnzb}"),
                "every band spans at least one block and one entry");
        }
    }
    if plan.band_cols.len() != plan.bands.len()
        || plan.band_rows.len() != plan.bands.len()
    {
        err("band tensors",
            format!("{} col / {} row tensors for {} bands",
                    plan.band_cols.len(), plan.band_rows.len(),
                    plan.bands.len()),
            "one (cols, rows) tensor pair per band");
        return;
    }
    for (bi, &(nb, nnzb)) in plan.bands.iter().enumerate() {
        if plan.band_cols[bi].len() != nb * nnzb
            || plan.band_rows[bi].len() != nb * nnzb
        {
            err("band tensors",
                format!("band {bi}: cols/rows lengths ({}, {}) != \
                         nb * nnzb = {}", plan.band_cols[bi].len(),
                        plan.band_rows[bi].len(), nb * nnzb),
                "band tensors are dense [nb * nnzb] row-major");
        }
    }
    let want_lvl = plan.levels * plan.l_pad;
    if plan.lvl_left.len() != want_lvl
        || plan.lvl_right.len() != want_lvl
    {
        err("level tensors",
            format!("lvl_left/right lengths ({}, {}) != levels * \
                     l_pad = {want_lvl}", plan.lvl_left.len(),
                    plan.lvl_right.len()),
            "level tensors are dense [levels * l_pad] row-major");
    }
    if plan.deg.len() != plan.n_pad {
        err("deg",
            format!("deg length {} != n_pad {}", plan.deg.len(),
                    plan.n_pad),
            "deg carries one entry per padded row");
    }
    if plan.perm.len() != plan.n || plan.inv_perm.len() != plan.n {
        err("perm",
            format!("perm/inv_perm lengths ({}, {}) != n = {}",
                    plan.perm.len(), plan.inv_perm.len(), plan.n),
            "the degree-sort permutation covers exactly the real \
             nodes");
    }
}

/// `plan.perm_bijection`: `perm` and `inv_perm` are mutually inverse
/// bijections over `0..n`.
fn plan_perm_bijection(plan: &ExecutionPlan, r: &mut Report) {
    const ID: &str = "plan.perm_bijection";
    r.ran(ID);
    let n = plan.n;
    let mut seen = vec![false; n];
    for (new, &old) in plan.perm.iter().enumerate() {
        let old = old as usize;
        if old >= n {
            r.error(ID, format!("perm[{new}]"),
                    format!("maps to {old} >= n = {n}"),
                    "perm entries are original node ids");
            return;
        }
        if seen[old] {
            r.error(ID, format!("perm[{new}]"),
                    format!("original node {old} appears twice"),
                    "the degree sort is a permutation; repack the \
                     plan");
            return;
        }
        seen[old] = true;
        if plan.inv_perm[old] as usize != new {
            r.error(ID, format!("inv_perm[{old}]"),
                    format!("= {} but perm[{new}] = {old}",
                            plan.inv_perm[old]),
                    "inv_perm must invert perm exactly; data packers \
                     and score lookups both rely on it");
            return;
        }
    }
}

/// `plan.index_range`: every level/band index lands inside the value
/// buffer `[0, m_pad)`; band-local rows inside `[0, br)`.
fn plan_index_range(plan: &ExecutionPlan, r: &mut Report) {
    const ID: &str = "plan.index_range";
    r.ran(ID);
    let m_pad = plan.m_pad() as i64;
    let check = |name: &str, idx: usize, v: i32, r: &mut Report| {
        if (v as i64) < 0 || (v as i64) >= m_pad {
            r.error(ID, format!("{name}[{idx}]"),
                    format!("buffer index {v} outside [0, {m_pad})"),
                    "all gather/combine operands index the padded \
                     value buffer; padding points at the zero slot");
        }
    };
    for (i, &v) in plan.lvl_left.iter().enumerate() {
        check("lvl_left", i, v, r);
    }
    for (i, &v) in plan.lvl_right.iter().enumerate() {
        check("lvl_right", i, v, r);
    }
    for (bi, cols) in plan.band_cols.iter().enumerate() {
        for (i, &v) in cols.iter().enumerate() {
            check(&format!("band_cols[{bi}]"), i, v, r);
        }
    }
    for (bi, rows) in plan.band_rows.iter().enumerate() {
        for (i, &v) in rows.iter().enumerate() {
            if v < 0 || v as usize >= plan.br {
                r.error(ID, format!("band_rows[{bi}][{i}]"),
                        format!("local row {v} outside [0, {})",
                                plan.br),
                        "band rows are block-local destinations");
            }
        }
    }
}

/// `plan.level_order`: level-`l` combine operands read originals or
/// strictly earlier levels — never their own or a later level (the
/// level kernel executes one dense slice at a time).
fn plan_level_order(plan: &ExecutionPlan, r: &mut Report) {
    const ID: &str = "plan.level_order";
    r.ran(ID);
    let zero = plan.zero_slot();
    for l in 0..plan.levels {
        let level_base = (plan.n_pad + l * plan.l_pad) as i32;
        for j in 0..plan.l_pad {
            let li = plan.lvl_left[l * plan.l_pad + j];
            let ri = plan.lvl_right[l * plan.l_pad + j];
            if li == zero && ri == zero {
                continue; // padding entry
            }
            for v in [li, ri] {
                if v >= level_base && v != zero {
                    r.error(ID, format!("level {l} entry {j}"),
                            format!("operand {v} reads its own or a \
                                     later level (level base \
                                     {level_base})"),
                            "a combine may only read originals or \
                             already-computed levels; re-level the \
                             HAG");
                }
            }
        }
    }
}

/// `plan.encodes_hag`: the plan's tensors encode exactly the HAG they
/// were compiled from. The leveling and slot map are recomputed
/// independently from the HAG; then
/// * every real level entry must carry `slot_of(left/right)` of its
///   agg node and every padding entry the zero slot;
/// * per permuted row, the multiset of band gather columns must equal
///   the multiset of `slot_of(final in-edges)`;
/// * `deg[new]` must be the true graph degree of `perm[new]`.
fn plan_encodes_hag(ctx: &HagCtx, plan: &ExecutionPlan,
                    r: &mut Report) {
    const ID: &str = "plan.encodes_hag";
    r.ran(ID);
    let hag = ctx.hag;
    let n = hag.n;
    let na = hag.agg_nodes.len();

    // Recompute the leveling (schedule.rs step 1) from the HAG.
    let mut level = vec![0u32; na];
    let mut max_level = 0u32;
    for (i, a) in hag.agg_nodes.iter().enumerate() {
        let lv = |s: u32| -> u32 {
            if (s as usize) < n { 0 } else { level[s as usize - n] }
        };
        level[i] = 1 + lv(a.left).max(lv(a.right));
        max_level = max_level.max(level[i]);
    }
    if plan.levels != max_level as usize {
        r.error(ID, "levels".to_string(),
                format!("plan.levels = {} but the HAG levels to {}",
                        plan.levels, max_level),
                "recompile: the plan was built from a different HAG");
        return;
    }
    let mut level_sizes = vec![0usize; plan.levels + 1];
    let mut idx_in_level = vec![0usize; na];
    for i in 0..na {
        let l = level[i] as usize;
        idx_in_level[i] = level_sizes[l];
        level_sizes[l] += 1;
    }
    if plan.levels > 0 {
        let want_l_pad = round_up(
            level_sizes[1..].iter().copied().max().unwrap_or(0)
                .max(1),
            plan.lvl_block);
        if plan.l_pad != want_l_pad {
            r.error(ID, "l_pad".to_string(),
                    format!("l_pad = {} but the HAG's max level size \
                             rounds to {want_l_pad}", plan.l_pad),
                    "recompile: level occupancy changed");
            return;
        }
        if let Some(&max_sz) = level_sizes[1..].iter().max() {
            if max_sz > plan.l_pad {
                // unreachable given the l_pad check, but keeps the
                // slot map below in-bounds under all edits
                return;
            }
        }
    } else if na > 0 {
        return; // inconsistent; levels check above already fired
    }

    let zero = plan.zero_slot();
    let slot_of = |s: u32| -> i32 {
        if (s as usize) < n {
            plan.inv_perm[s as usize] as i32
        } else {
            let i = s as usize - n;
            (plan.n_pad + (level[i] as usize - 1) * plan.l_pad
                + idx_in_level[i]) as i32
        }
    };

    // Level tensors, entry by entry.
    let mut expect_left = vec![zero; plan.levels * plan.l_pad];
    let mut expect_right = vec![zero; plan.levels * plan.l_pad];
    for (i, a) in hag.agg_nodes.iter().enumerate() {
        let l = level[i] as usize - 1;
        let j = idx_in_level[i];
        expect_left[l * plan.l_pad + j] = slot_of(a.left);
        expect_right[l * plan.l_pad + j] = slot_of(a.right);
    }
    for (idx, (&got, &want)) in plan.lvl_left.iter()
        .zip(expect_left.iter()).enumerate()
    {
        if got != want {
            r.error(ID, format!("lvl_left[{idx}]"),
                    format!("= {got}, HAG encodes {want}"),
                    "level tensors must encode each merge's operand \
                     slots; recompile the plan");
            return;
        }
    }
    for (idx, (&got, &want)) in plan.lvl_right.iter()
        .zip(expect_right.iter()).enumerate()
    {
        if got != want {
            r.error(ID, format!("lvl_right[{idx}]"),
                    format!("= {got}, HAG encodes {want}"),
                    "level tensors must encode each merge's operand \
                     slots; recompile the plan");
            return;
        }
    }

    // Band tensors: per permuted row, multiset of real gather
    // columns == multiset of slot_of(final in-edges). Real entries
    // never carry the zero slot (all real slots are < m_pad - 1), so
    // zero-col entries are padding and must carry row 0.
    let mut row0 = 0usize;
    for (bi, &(nb, nnzb)) in plan.bands.iter().enumerate() {
        for b in 0..nb {
            let mut per_row: Vec<Vec<i32>> = vec![Vec::new(); plan.br];
            for j in 0..nnzb {
                let col = plan.band_cols[bi][b * nnzb + j];
                let row = plan.band_rows[bi][b * nnzb + j] as usize;
                if col == zero {
                    if row != 0 {
                        r.error(ID,
                                format!("band {bi} block {b} entry \
                                         {j}"),
                                format!("padding entry targets row \
                                         {row}, not 0"),
                                "padding gathers the zero slot into \
                                 row 0 so padded contributions \
                                 vanish");
                        return;
                    }
                    continue;
                }
                per_row[row].push(col);
            }
            for lr in 0..plan.br {
                let new = row0 + b * plan.br + lr;
                let mut want: Vec<i32> = if new < n {
                    hag.in_edges[plan.perm[new] as usize].iter()
                        .map(|&s| slot_of(s)).collect()
                } else {
                    Vec::new()
                };
                let mut got = per_row[lr].clone();
                // padding entries into row 0 contribute zero and are
                // skipped above; compare as multisets (execution is
                // a sum — order within a row is not semantic)
                got.sort_unstable();
                want.sort_unstable();
                if got != want {
                    r.error(ID,
                            format!("band {bi} block {b} row {lr}"),
                            format!("row gathers {} column(s), HAG \
                                     in-list encodes {} (first \
                                     mismatch after sorting)",
                                    got.len(), want.len()),
                            "band gather lists must enumerate \
                             exactly each row's final in-edges; \
                             recompile the plan");
                    return;
                }
            }
        }
        row0 += nb * plan.br;
    }

    // True degrees, permuted.
    for new in 0..plan.n_pad {
        let want = if new < n {
            ctx.graph.degree(plan.perm[new]) as f32
        } else {
            0.0
        };
        if plan.deg[new] != want {
            r.error(ID, format!("deg[{new}]"),
                    format!("= {}, true graph degree is {want}",
                            plan.deg[new]),
                    "deg is the unpermuted input-graph in-degree \
                     (GCN normalizer), not the HAG in-list length");
            return;
        }
    }
}
