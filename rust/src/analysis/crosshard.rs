//! Cross-shard passes: a stitched HAG against the per-shard HAGs and
//! partition it was stitched from (partition/stitch.rs).
//!
//! The stitcher's contract is fully deterministic — shard agg blocks
//! concatenate in shard order with originals remapped through
//! `members[s]`, then every cross-shard edge is appended verbatim as
//! a direct slot — so these passes verify it by independent
//! reconstruction: recompute each remap and compare entity by entity,
//! with graceful diagnostics where the stitcher itself would assert.

use std::borrow::Borrow;

use crate::graph::Graph;
use crate::hag::{Hag, Slot};
use crate::partition::Partition;

use super::Report;

/// Run the three stitch passes.
pub fn stitch_passes<H: Borrow<Hag>>(g: &Graph, part: &Partition,
                                     locals: &[H],
                                     stitched: &Hag) -> Report {
    let mut r = Report::new();
    if !preconditions(g, part, locals, stitched, &mut r) {
        return r;
    }
    shard_blocks(part, locals, stitched, &mut r);
    cross_edges(g, part, locals, stitched, &mut r);
    term_sums(g, part, locals, stitched, &mut r);
    r
}

/// Shared shape preconditions; reported under `stitch.shard_blocks`
/// (the pass that owns block layout).
fn preconditions<H: Borrow<Hag>>(g: &Graph, part: &Partition,
                                 locals: &[H], stitched: &Hag,
                                 r: &mut Report) -> bool {
    const ID: &str = "stitch.shard_blocks";
    let mut ok = true;
    if locals.len() != part.n_shards {
        r.error(ID, "locals".to_string(),
                format!("{} shard HAGs for {} shards", locals.len(),
                        part.n_shards),
                "stitching takes exactly one HAG per shard");
        ok = false;
    }
    if stitched.n != g.n() || part.shard_of.len() != g.n() {
        r.error(ID, "n".to_string(),
                format!("stitched.n = {}, |shard_of| = {}, graph n \
                         = {}", stitched.n, part.shard_of.len(),
                        g.n()),
                "the stitched HAG and partition must cover the \
                 input graph's node set");
        ok = false;
    }
    for (s, lh) in locals.iter().enumerate() {
        if s < part.members.len()
            && lh.borrow().n != part.members[s].len()
        {
            r.error(ID, format!("shard {s}"),
                    format!("shard HAG has {} nodes, member list has \
                             {}", lh.borrow().n,
                            part.members[s].len()),
                    "each shard HAG is searched over exactly its \
                     member subgraph");
            ok = false;
        }
    }
    ok
}

/// `stitch.shard_blocks`: shard agg blocks are contiguous in shard
/// order, every operand remapped through the shard's own member list
/// or its own earlier block — never another shard's slots — and each
/// member node's in-list prefix is its remapped local list.
fn shard_blocks<H: Borrow<Hag>>(part: &Partition, locals: &[H],
                                stitched: &Hag, r: &mut Report) {
    const ID: &str = "stitch.shard_blocks";
    r.ran(ID);
    let n = stitched.n;
    let total_agg: usize =
        locals.iter().map(|h| h.borrow().agg_nodes.len()).sum();
    if stitched.agg_nodes.len() != total_agg {
        r.error(ID, "agg_nodes".to_string(),
                format!("stitched carries {} agg nodes, shard blocks \
                         sum to {total_agg}",
                        stitched.agg_nodes.len()),
                "stitching concatenates shard agg blocks exactly; \
                 no merges appear or vanish");
        return;
    }
    let mut base = n;
    for (s, lh) in locals.iter().enumerate() {
        let lh = lh.borrow();
        let mem = &part.members[s];
        let remap = |slot: Slot| -> Slot {
            if (slot as usize) < lh.n {
                mem[slot as usize]
            } else {
                (base + (slot as usize - lh.n)) as Slot
            }
        };
        for (i, a) in lh.agg_nodes.iter().enumerate() {
            let got = stitched.agg_nodes[base - n + i];
            let (wl, wr) = (remap(a.left), remap(a.right));
            if got.left != wl || got.right != wr {
                r.error(ID, format!("shard {s} agg {i}"),
                        format!("stitched operands ({}, {}) != \
                                 remapped local operands ({wl}, \
                                 {wr})", got.left, got.right),
                        "a shard's merges may only reference its own \
                         members and its own earlier block slots; \
                         re-stitch from the shard HAGs");
                return;
            }
        }
        for (lv, list) in lh.in_edges.iter().enumerate() {
            let v = mem[lv] as usize;
            let got = &stitched.in_edges[v];
            if got.len() < list.len()
                || got[..list.len()].iter().zip(list.iter())
                    .any(|(&gs, &ls)| gs != remap(ls))
            {
                r.error(ID, format!("node {v} (shard {s})"),
                        format!("in-list prefix does not match the \
                                 remapped shard-local list of {} \
                                 slot(s)", list.len()),
                        "a member node's in-list is its shard-local \
                         list (remapped) followed by cross-shard \
                         fallback edges only");
                return;
            }
        }
        base += lh.agg_nodes.len();
    }
}

/// `stitch.cross_edges`: after the shard-local prefix, each node's
/// in-list carries exactly its cross-shard neighbors, verbatim as
/// direct original slots (the direct-aggregation fallback), and
/// nothing else.
fn cross_edges<H: Borrow<Hag>>(g: &Graph, part: &Partition,
                               locals: &[H], stitched: &Hag,
                               r: &mut Report) {
    const ID: &str = "stitch.cross_edges";
    r.ran(ID);
    // local in-list length per node (the prefix the shard owns)
    let mut local_len = vec![0usize; stitched.n];
    for (s, lh) in locals.iter().enumerate() {
        let lh = lh.borrow();
        for (lv, list) in lh.in_edges.iter().enumerate() {
            local_len[part.members[s][lv] as usize] = list.len();
        }
    }
    for (v, ns) in g.iter() {
        let sv = part.shard_of[v as usize];
        let want: Vec<Slot> = ns.iter().copied()
            .filter(|&u| part.shard_of[u as usize] != sv)
            .collect();
        let list = &stitched.in_edges[v as usize];
        let ll = local_len[v as usize].min(list.len());
        let got = &list[ll..];
        if got != want.as_slice() {
            r.error(ID, format!("node {v}"),
                    format!("cross-shard tail has {} slot(s), the \
                             graph cuts {} edge(s) at this node",
                            got.len(), want.len()),
                    "every cut edge falls back to one direct \
                     aggregation slot, appended in neighbor order; \
                     a dropped or reordered tail breaks Theorem-1 \
                     equivalence");
            return;
        }
    }
}

/// `stitch.term_sums`: the stitch cost identity
/// `cost_core(stitched) = sum_s cost_core(shard_s) + cut_edges`
/// (partition/stitch.rs module docs), and per-shard Definition-2
/// term sums never exceed the stitched totals.
fn term_sums<H: Borrow<Hag>>(g: &Graph, part: &Partition,
                             locals: &[H], stitched: &Hag,
                             r: &mut Report) {
    const ID: &str = "stitch.term_sums";
    r.ran(ID);
    let cut_edges: usize = g.iter()
        .map(|(v, ns)| {
            let sv = part.shard_of[v as usize];
            ns.iter()
                .filter(|&&u| part.shard_of[u as usize] != sv)
                .count()
        })
        .sum();
    let local_core: usize =
        locals.iter().map(|h| h.borrow().cost_core()).sum();
    if stitched.cost_core() != local_core + cut_edges {
        r.error(ID, "cost_core".to_string(),
                format!("stitched cost_core = {} but shard sum {} + \
                         cut edges {cut_edges} = {}",
                        stitched.cost_core(), local_core,
                        local_core + cut_edges),
                "the stitch identity (shard cores plus cut edges) \
                 must hold exactly; a shard HAG or the stitched \
                 in-lists were modified after stitching");
    }
    let sum_aggs: usize =
        locals.iter().map(|h| h.borrow().aggregations()).sum();
    let sum_transfers: usize =
        locals.iter().map(|h| h.borrow().data_transfers()).sum();
    if sum_aggs > stitched.aggregations()
        || sum_transfers > stitched.data_transfers()
    {
        r.error(ID, "shard terms".to_string(),
                format!("per-shard sums ({sum_aggs}, \
                         {sum_transfers}) exceed stitched totals \
                         ({}, {})", stitched.aggregations(),
                        stitched.data_transfers()),
                "shard-local terms lower-bound the stitched plan's; \
                 the shard HAGs are stale relative to the stitched \
                 one");
    }
}
