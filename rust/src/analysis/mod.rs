//! `haglint` — multi-pass static verification of HAGs and plans.
//!
//! Every correctness claim the paper makes about a lowered artifact —
//! Theorem-1 equivalence of a HAG to its GNN-graph, Definition-2 cost
//! terms, the plan tensors' encoding contract — is checked here
//! *statically*: by inspecting the artifact's structure, never by
//! executing it. The dynamic oracles (`hag/equivalence.rs`'s
//! probabilistic check, `plan() == plan_fresh()` tensor identity)
//! stay as test-time ground truth; this module is the cheap,
//! execution-free gate the serving path can afford to run on every
//! stitch / repair / hot swap.
//!
//! Structure:
//! * a pass inventory ([`PASSES`]) over three IRs —
//!   [`Hag`](crate::hag::Hag) vs its [`Graph`](crate::graph::Graph),
//!   the compiled [`ExecutionPlan`](crate::hag::ExecutionPlan), and
//!   the [`IncrementalHag`](crate::incremental::IncrementalHag) — in
//!   five classes: structural, exactness, cost, cross-shard,
//!   incremental;
//! * typed diagnostics ([`Diagnostic`]: pass id, severity, offending
//!   entity, fix hint) collected into a [`Report`] with a
//!   machine-readable `haglint-v1` JSON form;
//! * hot-path gates ([`gate_plan`] / [`gate_hag`] /
//!   [`gate_stitched`] / [`gate_cost_gauges`]) wired into
//!   `Session::plan`, the stitcher, `StreamEngine::install_hag` and
//!   the serving swap path — always on in debug builds, opt-in via
//!   `REPRO_VERIFY=1` in release, with `verify.*` metrics so the
//!   gate's own cost is observable;
//! * a mutation harness ([`mutate`]) proving no pass is vacuous: one
//!   targeted corruption per pass, each killed by exactly the pass
//!   that owns it (`rust/tests/analysis.rs` and the in-crate
//!   incremental kill tests);
//! * a shared verification [`corpus`] (generator graphs × search
//!   configs × single/stitched/repaired artifacts) behind the
//!   `repro verify --corpus` CI gate and `benches/verify_overhead.rs`.
//!
//! Pass ordering is dependency-gated: exactness / cost / plan passes
//! only run once the structural passes they index through are clean,
//! so a corrupt artifact produces diagnostics, never a panic.

pub mod corpus;
pub mod cost;
pub mod crosshard;
pub mod exactness;
pub mod incremental;
pub mod mutate;
pub mod srclint;
pub mod structural;

use std::borrow::Borrow;
use std::sync::OnceLock;

use crate::graph::Graph;
use crate::hag::{ExecutionPlan, Hag};
use crate::incremental::IncrementalHag;
use crate::obs::metrics::{MetricsRegistry, StatsSnapshot};
use crate::partition::Partition;
use crate::util::json::{arr, num, obj, str_, Value};

/// Diagnostic severity. `Error` fails gates and CI; `Warning` is
/// surfaced but never fails a verification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One finding: which pass, how bad, where, what, and how to fix it.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Pass id from [`PASSES`] (e.g. `"hag.cover_exact"`).
    pub pass: &'static str,
    pub severity: Severity,
    /// The offending entity (`"agg 7"`, `"node 12"`, `"band 2"`, …).
    pub entity: String,
    pub message: String,
    /// Actionable fix hint.
    pub hint: &'static str,
}

/// The result of running a set of passes over one artifact.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
    /// Pass ids that ran to completion (skipped dependents absent).
    pub passes_run: Vec<&'static str>,
}

impl Report {
    pub fn new() -> Report {
        Report::default()
    }

    pub(crate) fn ran(&mut self, pass: &'static str) {
        if !self.passes_run.contains(&pass) {
            self.passes_run.push(pass);
        }
    }

    pub(crate) fn error(&mut self, pass: &'static str, entity: String,
                        message: String, hint: &'static str) {
        self.diagnostics.push(Diagnostic {
            pass,
            severity: Severity::Error,
            entity,
            message,
            hint,
        });
    }

    /// Errors only (warnings never fail a gate).
    pub fn errors(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    pub fn is_clean(&self) -> bool {
        self.errors() == 0
    }

    /// Did `pass` emit at least one error? (The mutation-kill
    /// assertion.)
    pub fn flagged(&self, pass: &str) -> bool {
        self.diagnostics
            .iter()
            .any(|d| d.pass == pass && d.severity == Severity::Error)
    }

    pub fn merge(&mut self, other: Report) {
        for p in other.passes_run {
            self.ran(p);
        }
        self.diagnostics.extend(other.diagnostics);
    }

    /// Human-readable listing, one line per diagnostic.
    pub fn format(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&format!("{:7} [{}] {}: {} (fix: {})\n",
                                  d.severity.as_str(), d.pass,
                                  d.entity, d.message, d.hint));
        }
        out
    }

    /// JSON form of this report's body (the `haglint-v1` envelope is
    /// assembled per-run by [`corpus_report_json`]).
    pub fn to_value(&self) -> Value {
        obj(vec![
            ("passes_run",
             arr(self.passes_run.iter().map(|p| str_(*p)).collect())),
            ("errors", num(self.errors() as f64)),
            ("diagnostics",
             arr(self.diagnostics.iter().map(|d| {
                 obj(vec![
                     ("pass", str_(d.pass)),
                     ("severity", str_(d.severity.as_str())),
                     ("entity", str_(d.entity.clone())),
                     ("message", str_(d.message.clone())),
                     ("hint", str_(d.hint)),
                 ])
             }).collect())),
        ])
    }
}

/// Pass classes (ISSUE taxonomy; DESIGN.md §13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassClass {
    Structural,
    Exactness,
    Cost,
    CrossShard,
    Incremental,
}

impl PassClass {
    pub fn as_str(self) -> &'static str {
        match self {
            PassClass::Structural => "structural",
            PassClass::Exactness => "exactness",
            PassClass::Cost => "cost",
            PassClass::CrossShard => "cross-shard",
            PassClass::Incremental => "incremental",
        }
    }
}

/// Static metadata for one pass.
#[derive(Debug, Clone, Copy)]
pub struct PassInfo {
    pub id: &'static str,
    pub class: PassClass,
    pub desc: &'static str,
}

/// The full pass inventory. Every id a [`Diagnostic`] can carry is
/// listed here; `repro verify --list` prints it and DESIGN.md §13
/// documents it.
pub const PASSES: &[PassInfo] = &[
    PassInfo { id: "hag.topo_order", class: PassClass::Structural,
               desc: "aggregation nodes reference earlier slots only \
                      (creation order is topological; acyclicity)" },
    PassInfo { id: "hag.slot_range", class: PassClass::Structural,
               desc: "final in-edges reference existing slots" },
    PassInfo { id: "hag.dup_inslots", class: PassClass::Structural,
               desc: "set-AGGREGATE in-lists are duplicate-free" },
    PassInfo { id: "hag.orphan_agg", class: PassClass::Structural,
               desc: "every aggregation node is consumed by a final \
                      or another aggregation node" },
    PassInfo { id: "hag.capacity_fit", class: PassClass::Structural,
               desc: "|V_A| fits the declared capacity budget \
                      (paper §3.2 a-hat memory bound)" },
    PassInfo { id: "plan.shape", class: PassClass::Structural,
               desc: "padded dims and tensor lengths are mutually \
                      consistent (n_pad/l_pad quanta, band extents)" },
    PassInfo { id: "plan.perm_bijection", class: PassClass::Structural,
               desc: "degree-sort perm and inv_perm are mutually \
                      inverse bijections over 0..n" },
    PassInfo { id: "plan.index_range", class: PassClass::Structural,
               desc: "level/band indices stay inside the value \
                      buffer; band rows inside the block height" },
    PassInfo { id: "plan.level_order", class: PassClass::Structural,
               desc: "level-combine operands come from originals or \
                      strictly earlier levels" },
    PassInfo { id: "plan.encodes_hag", class: PassClass::Structural,
               desc: "level tensors, band gather lists and degrees \
                      encode exactly the HAG they were compiled from" },
    PassInfo { id: "hag.cover_exact", class: PassClass::Exactness,
               desc: "symbolic cover expansion: every node's multiset \
                      neighborhood is reproduced exactly (static \
                      Theorem-1 check)" },
    PassInfo { id: "cost.term_consistency", class: PassClass::Cost,
               desc: "Definition-2 terms recomputed from structure \
                      match Hag::cost and the producer's claimed \
                      terms" },
    PassInfo { id: "cost.gauges_match", class: PassClass::Cost,
               desc: "cost.pred_* registry gauges match the served \
                      HAG's recomputed Definition-2 terms" },
    PassInfo { id: "stitch.shard_blocks", class: PassClass::CrossShard,
               desc: "shard agg blocks are remapped contiguously and \
                      never reference another shard's slots" },
    PassInfo { id: "stitch.cross_edges", class: PassClass::CrossShard,
               desc: "every cross-shard edge falls back to a direct \
                      aggregation slot, and nothing else is appended" },
    PassInfo { id: "stitch.term_sums", class: PassClass::CrossShard,
               desc: "sum of shard cost_core plus cut edges equals \
                      the stitched cost_core; per-shard terms never \
                      exceed stitched totals" },
    PassInfo { id: "incr.id_space", class: PassClass::Incremental,
               desc: "bit-31 agg-id-space discipline: every internal \
                      slot decodes to a real node or agg id" },
    PassInfo { id: "incr.topo_order", class: PassClass::Incremental,
               desc: "references point at live, earlier aggregation \
                      nodes (GC'd nodes are never consumed)" },
    PassInfo { id: "incr.refcounts", class: PassClass::Incremental,
               desc: "stored refcounts equal recomputed live \
                      reference counts" },
    PassInfo { id: "incr.counters", class: PassClass::Incremental,
               desc: "maintained live/final-edge counters are exact \
                      and in-lists are duplicate-free" },
];

/// Everything the core hag/plan pipeline verifies against.
pub struct HagCtx<'a> {
    pub graph: &'a Graph,
    pub hag: &'a Hag,
    pub plan: Option<&'a ExecutionPlan>,
    /// `|V_A|` budget the producer searched under, if known.
    pub capacity: Option<usize>,
    /// Producer-claimed `(aggregations, data_transfers)` — e.g. a
    /// session's summed shard terms — cross-checked by
    /// `cost.term_consistency`.
    pub claimed_terms: Option<(usize, usize)>,
}

impl<'a> HagCtx<'a> {
    pub fn new(graph: &'a Graph, hag: &'a Hag) -> HagCtx<'a> {
        HagCtx { graph, hag, plan: None, capacity: None,
                 claimed_terms: None }
    }

    pub fn with_plan(mut self, plan: &'a ExecutionPlan) -> Self {
        self.plan = Some(plan);
        self
    }

    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = Some(capacity);
        self
    }

    pub fn with_claimed_terms(mut self, aggs: usize,
                              transfers: usize) -> Self {
        self.claimed_terms = Some((aggs, transfers));
        self
    }
}

/// Run the hag/plan pipeline: structural passes first, then (only on
/// a structurally clean HAG, so cover expansion cannot index out of
/// bounds) exactness and cost, then the plan passes in dependency
/// order. The single entry every gate and CLI path funnels through.
pub fn verify(ctx: &HagCtx) -> Report {
    let mut r = Report::new();
    structural::hag_passes(ctx, &mut r);
    let hag_clean = r.is_clean();
    if hag_clean {
        exactness::cover_exact(ctx, &mut r);
        cost::term_consistency(ctx, &mut r);
    }
    if let Some(plan) = ctx.plan {
        if hag_clean {
            structural::plan_passes(ctx, plan, &mut r);
        }
    }
    r
}

/// HAG-only verification (structural + exactness + cost).
pub fn verify_hag(g: &Graph, hag: &Hag) -> Report {
    verify(&HagCtx::new(g, hag))
}

/// HAG + plan verification.
pub fn verify_plan(g: &Graph, hag: &Hag,
                   plan: &ExecutionPlan) -> Report {
    verify(&HagCtx::new(g, hag).with_plan(plan))
}

/// Cross-shard verification of a stitched HAG against its per-shard
/// inputs (see [`crosshard`]).
pub fn verify_stitched<H: Borrow<Hag>>(g: &Graph, part: &Partition,
                                       locals: &[H],
                                       stitched: &Hag) -> Report {
    crosshard::stitch_passes(g, part, locals, stitched)
}

/// Incremental-IR verification (see [`incremental`]); the engine's
/// `IncrementalHag::check` is a thin wrapper over this.
pub fn check_incremental(ih: &IncrementalHag) -> Report {
    incremental::incr_passes(ih)
}

/// Registry-gauge cost audit (see [`cost::gauges_match`]).
pub fn check_cost_gauges(snap: &StatsSnapshot, hag: &Hag,
                         shard_terms: &[(usize, usize)]) -> Report {
    let mut r = Report::new();
    cost::gauges_match(snap, hag, shard_terms, &mut r);
    r
}

/// `Hag::validate`, reimplemented over the analysis structural
/// passes so the two can never disagree: first structural error
/// message, or `Ok`.
pub fn validate_hag(hag: &Hag) -> Result<(), String> {
    // Validation is graph-independent; an empty graph placeholder
    // keeps the ctx honest (no structural pass reads it).
    let g = Graph::from_edges(hag.n, &[]);
    let mut r = Report::new();
    structural::hag_passes(&HagCtx::new(&g, hag), &mut r);
    match r.diagnostics.iter()
        .find(|d| d.severity == Severity::Error)
    {
        None => Ok(()),
        Some(d) => Err(format!("[{}] {}: {}", d.pass, d.entity,
                               d.message)),
    }
}

// ---------------------------------------------------------------
// Hot-path gates
// ---------------------------------------------------------------

/// Is the verify gate live? Debug builds: always (the ISSUE's
/// "swap-path verify gate enabled in debug test runs"). Release:
/// opt-in via `REPRO_VERIFY=1`/`on` (and explicitly disableable in
/// debug with `REPRO_VERIFY=0`/`off`). Read once per process.
pub fn verify_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        match std::env::var("REPRO_VERIFY") {
            Ok(v) => {
                let v = v.trim().to_ascii_lowercase();
                !(v == "0" || v == "off" || v == "false" || v.is_empty())
            }
            Err(_) => cfg!(debug_assertions),
        }
    })
}

/// Shared gate tail: record `verify.runs`/`verify.ns` (and
/// `verify.failures` + a flight dump on a dirty report), then either
/// pass, panic (debug — a corrupt artifact on a hot path is a bug,
/// not an operational condition), or refuse (release).
fn finish_gate(reg: &MetricsRegistry, site: &str, report: &Report,
               t0: std::time::Instant) -> bool {
    reg.counter("verify.runs").inc();
    reg.histogram("verify.ns")
        .record_ns(t0.elapsed().as_nanos() as u64);
    if report.is_clean() {
        return true;
    }
    reg.counter("verify.failures").inc();
    for d in report.diagnostics.iter()
        .filter(|d| d.severity == Severity::Error).take(8)
    {
        crate::obs_error!("[haglint] {site}: [{}] {}: {}", d.pass,
                          d.entity, d.message);
    }
    crate::obs::flight::dump("verify-failed", reg);
    if cfg!(debug_assertions) {
        panic!("haglint gate failed at {site}: {} error(s)\n{}",
               report.errors(), report.format());
    }
    false
}

/// Gate a freshly compiled (hag, plan) pair before it is served or
/// cached. Returns `true` to proceed.
pub fn gate_plan(reg: &MetricsRegistry, site: &str, g: &Graph,
                 hag: &Hag, plan: &ExecutionPlan,
                 capacity: Option<usize>) -> bool {
    let t0 = std::time::Instant::now();
    let mut ctx = HagCtx::new(g, hag).with_plan(plan);
    ctx.capacity = capacity;
    let report = verify(&ctx);
    finish_gate(reg, site, &report, t0)
}

/// Gate a HAG about to be adopted (e.g. `StreamEngine::install_hag`).
pub fn gate_hag(reg: &MetricsRegistry, site: &str, g: &Graph,
                hag: &Hag) -> bool {
    let t0 = std::time::Instant::now();
    let report = verify_hag(g, hag);
    finish_gate(reg, site, &report, t0)
}

/// Gate a stitched HAG against its per-shard inputs.
pub fn gate_stitched<H: Borrow<Hag>>(reg: &MetricsRegistry,
                                     site: &str, g: &Graph,
                                     part: &Partition, locals: &[H],
                                     stitched: &Hag) -> bool {
    let t0 = std::time::Instant::now();
    let report = verify_stitched(g, part, locals, stitched);
    finish_gate(reg, site, &report, t0)
}

/// Gate the `cost.pred_*` gauges right after they were recorded for
/// a newly served plan.
pub fn gate_cost_gauges(reg: &MetricsRegistry, site: &str, hag: &Hag,
                        shard_terms: &[(usize, usize)]) -> bool {
    let t0 = std::time::Instant::now();
    let snap = reg.snapshot();
    let report = check_cost_gauges(&snap, hag, shard_terms);
    finish_gate(reg, site, &report, t0)
}

/// Assemble the `haglint-v1` JSON envelope for a verification run
/// (the `repro verify --json` artifact `repro obs --check-verify`
/// validates).
pub fn corpus_report_json(cases: &[(String, Report)]) -> Value {
    let total: usize = cases.iter().map(|(_, r)| r.errors()).sum();
    obj(vec![
        ("schema", str_("haglint-v1")),
        ("clean", Value::Bool(total == 0)),
        ("total_errors", num(total as f64)),
        ("passes",
         arr(PASSES.iter().map(|p| {
             obj(vec![
                 ("id", str_(p.id)),
                 ("class", str_(p.class.as_str())),
                 ("desc", str_(p.desc)),
             ])
         }).collect())),
        ("cases",
         arr(cases.iter().map(|(name, r)| {
             let mut body = r.to_value();
             if let Value::Obj(fields) = &mut body {
                 fields.insert("name".to_string(), str_(name.clone()));
             }
             body
         }).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hag::{hag_search, AggregateKind, SearchConfig};

    fn k6() -> Graph {
        let mut edges = Vec::new();
        for u in 0..6u32 {
            for v in 0..6u32 {
                if u != v {
                    edges.push((u, v));
                }
            }
        }
        Graph::from_edges(6, &edges)
    }

    #[test]
    fn searched_hag_and_plan_verify_clean() {
        let g = k6();
        let cfg = SearchConfig { alpha: 1.0, beta: 1.0,
                                 capacity: usize::MAX,
                                 kind: AggregateKind::Set,
                                 pair_cap: usize::MAX };
        let (hag, _) = hag_search(&g, &cfg);
        let plan = crate::hag::build_plan(
            &g, &hag, &crate::hag::PlanConfig::default());
        let r = verify(&HagCtx::new(&g, &hag).with_plan(&plan)
                           .with_capacity(usize::MAX)
                           .with_claimed_terms(hag.aggregations(),
                                               hag.data_transfers()));
        assert!(r.is_clean(), "{}", r.format());
        // every hag/plan/cost pass actually ran
        for id in ["hag.topo_order", "hag.cover_exact", "plan.shape",
                   "plan.encodes_hag", "cost.term_consistency"] {
            assert!(r.passes_run.contains(&id), "{id} did not run");
        }
    }

    #[test]
    fn pass_inventory_ids_are_unique() {
        for (i, a) in PASSES.iter().enumerate() {
            for b in &PASSES[i + 1..] {
                assert_ne!(a.id, b.id);
            }
        }
    }

    #[test]
    fn validate_hag_reports_first_structural_error() {
        let mut h = Hag::from_graph(&k6(), AggregateKind::Set);
        h.in_edges[0].push(99);
        let err = validate_hag(&h).unwrap_err();
        assert!(err.contains("hag.slot_range"), "{err}");
    }

    #[test]
    fn report_json_envelope_is_haglint_v1() {
        let g = k6();
        let hag = Hag::from_graph(&g, AggregateKind::Set);
        let r = verify_hag(&g, &hag);
        let doc = corpus_report_json(&[("k6".into(), r)]);
        assert_eq!(doc.req_str("schema").unwrap(), "haglint-v1");
        assert_eq!(doc.get("clean").and_then(|v| v.as_bool()),
                   Some(true));
        assert!(!doc.req_arr("cases").unwrap().is_empty());
        assert_eq!(doc.req_arr("passes").unwrap().len(), PASSES.len());
    }
}
