//! Graph file I/O: whitespace edge lists and a JSON container format.
//!
//! The synthetic dataset generators are the default data source (this
//! testbed has no network access to the public archives), but real data
//! drops in through these loaders: an edge-list file per graph, or the
//! JSON container for graph-classification sets.

use std::fmt::Write;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

use super::{Graph, GraphBuilder};

/// Load a whitespace-separated edge list (`src dst` per line, `#`
/// comments). Node count resolution, in priority order: the `n`
/// argument, a `# n=<count>` header on the **first line only** (what
/// [`save_edge_list`] writes — this is what lets graphs with trailing
/// isolated nodes round-trip, which the incremental overlay depends
/// on; later comments are never interpreted, so external files with
/// incidental `n=` tokens in annotations load untouched), else
/// `max id + 1`. Duplicate lines are deduped by the CSR builder,
/// matching [`GraphSet`] JSON loading. `undirected` mirrors every
/// edge.
pub fn load_edge_list(path: &Path, n: Option<usize>,
                      undirected: bool) -> Result<Graph> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut edges = Vec::new();
    let mut max_id = 0u32;
    let mut header_n: Option<usize> = None;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            // Header convention: first line, first token is `n=<N>`.
            if lineno == 0 {
                if let Some(rest) = t.strip_prefix('#') {
                    header_n = rest
                        .split_whitespace()
                        .next()
                        .and_then(|tok| tok.strip_prefix("n="))
                        .and_then(|v| v.parse::<usize>().ok());
                }
            }
            continue;
        }
        let mut it = t.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("{}:{}: expected `src dst`", path.display(),
                       lineno + 1),
        };
        let (u, v): (u32, u32) = (a.parse()?, b.parse()?);
        max_id = max_id.max(u).max(v);
        edges.push((u, v));
    }
    let inferred = if edges.is_empty() { 0 } else { max_id as usize + 1 };
    // An explicit count (argument or header) must still cover every
    // edge endpoint; take the max so malformed headers fail soft.
    let n = n.or(header_n).map_or(inferred, |c| c.max(inferred));
    Ok(if undirected {
        Graph::from_undirected_edges(n, &edges)
    } else {
        Graph::from_edges(n, &edges)
    })
}

/// Write a graph as a directed edge list (one `src dst` line per
/// edge), atomically — a crash mid-save leaves the previous file
/// intact rather than a truncated list that would load as a smaller
/// graph.
pub fn save_edge_list(g: &Graph, path: &Path) -> Result<()> {
    let mut out = String::new();
    writeln!(out, "# n={} e={}", g.n(), g.e())?;
    for (v, ns) in g.iter() {
        for &u in ns {
            writeln!(out, "{u} {v}")?;
        }
    }
    crate::util::atomic_write(path, out.as_bytes())?;
    Ok(())
}

/// A labeled multi-graph container (graph-classification datasets).
pub struct GraphSet {
    pub name: String,
    /// Per graph: node count, directed edge list, class label.
    pub graphs: Vec<GraphRecord>,
}

pub struct GraphRecord {
    pub n: usize,
    pub edges: Vec<(u32, u32)>,
    pub label: u32,
}

impl GraphSet {
    pub fn load(path: &Path) -> Result<GraphSet> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        let name = v.req_str("name")?.to_string();
        let mut graphs = Vec::new();
        for g in v.req_arr("graphs")? {
            let n = g.req_usize("n")?;
            let mut edges = Vec::new();
            for e in g.req_arr("edges")? {
                let pair = e.as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| anyhow::anyhow!("bad edge entry"))?;
                let s = pair[0].as_usize()
                    .ok_or_else(|| anyhow::anyhow!("bad edge src"))?;
                let d = pair[1].as_usize()
                    .ok_or_else(|| anyhow::anyhow!("bad edge dst"))?;
                edges.push((s as u32, d as u32));
            }
            let label = g.req_usize("label")? as u32;
            graphs.push(GraphRecord { n, edges, label });
        }
        Ok(GraphSet { name, graphs })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let graphs: Vec<Value> = self
            .graphs
            .iter()
            .map(|g| {
                json::obj(vec![
                    ("n", json::num(g.n as f64)),
                    ("edges", Value::Arr(
                        g.edges.iter()
                            .map(|&(s, d)| Value::Arr(vec![
                                json::num(s), json::num(d)]))
                            .collect())),
                    ("label", json::num(g.label)),
                ])
            })
            .collect();
        let doc = json::obj(vec![
            ("name", json::str_(self.name.clone())),
            ("graphs", Value::Arr(graphs)),
        ]);
        crate::util::atomic_write(path, doc.to_string().as_bytes())?;
        Ok(())
    }

    pub fn to_graphs(&self) -> Vec<Graph> {
        self.graphs
            .iter()
            .map(|r| {
                GraphBuilder::new(r.n)
                    .edges(r.edges.iter().copied())
                    .build()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_list_roundtrip() {
        let dir = std::env::temp_dir().join("repro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.edges");
        let g = Graph::from_edges(5, &[(0, 1), (2, 1), (3, 4)]);
        save_edge_list(&g, &p).unwrap();
        let g2 = load_edge_list(&p, Some(5), false).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn edge_list_rejects_garbage() {
        let dir = std::env::temp_dir().join("repro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.edges");
        std::fs::write(&p, "0\n").unwrap();
        assert!(load_edge_list(&p, None, false).is_err());
    }

    #[test]
    fn edge_list_roundtrips_isolated_nodes_via_header() {
        // Node 4 is isolated and node 0 has no in-edges; without the
        // `# n=` header a reload would shrink the graph to max id + 1.
        let dir = std::env::temp_dir().join("repro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("isolated.edges");
        let g = Graph::from_edges(5, &[(0, 1), (2, 1), (0, 3)]);
        save_edge_list(&g, &p).unwrap();
        let g2 = load_edge_list(&p, None, false).unwrap();
        assert_eq!(g, g2, "header `# n=` must preserve node count");
        // an explicit argument still wins over the header
        let g3 = load_edge_list(&p, Some(7), false).unwrap();
        assert_eq!(g3.n(), 7);
        assert_eq!(g3.neighbors(1), &[0, 2]);
    }

    #[test]
    fn edge_list_duplicate_edges_dedup_consistently() {
        let dir = std::env::temp_dir().join("repro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("dups.edges");
        std::fs::write(&p, "# n=4\n0 1\n0 1\n2 1\n0 1\n").unwrap();
        let g = load_edge_list(&p, None, false).unwrap();
        assert_eq!(g.n(), 4);
        assert_eq!(g.e(), 2, "duplicates collapse like from_edges");
        assert_eq!(g.neighbors(1), &[0, 2]);
        // and the same edges through the builder agree exactly
        assert_eq!(g, Graph::from_edges(
            4, &[(0, 1), (0, 1), (2, 1), (0, 1)]));
    }

    #[test]
    fn edge_list_ignores_non_header_comments() {
        // `n=` tokens outside the first-line header position must not
        // change the node count (external files annotate freely).
        let dir = std::env::temp_dir().join("repro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("annotated.edges");
        std::fs::write(
            &p, "# sample n=500 of 7000\n0 1\n# subset n=900\n2 1\n")
            .unwrap();
        let g = load_edge_list(&p, None, false).unwrap();
        // first-line comment's first token is "sample", not "n=..."
        assert_eq!(g.n(), 3, "annotation comments must not set n");
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn edge_list_header_smaller_than_ids_fails_soft() {
        let dir = std::env::temp_dir().join("repro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("small_header.edges");
        std::fs::write(&p, "# n=2\n0 5\n").unwrap();
        let g = load_edge_list(&p, None, false).unwrap();
        assert_eq!(g.n(), 6, "edge endpoints extend a short header");
        assert_eq!(g.neighbors(5), &[0]);
    }

    #[test]
    fn graphset_roundtrip() {
        let dir = std::env::temp_dir().join("repro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("set.json");
        let set = GraphSet {
            name: "t".into(),
            graphs: vec![GraphRecord { n: 3, edges: vec![(0, 1), (1, 2)],
                                       label: 1 }],
        };
        set.save(&p).unwrap();
        let set2 = GraphSet::load(&p).unwrap();
        assert_eq!(set2.graphs.len(), 1);
        assert_eq!(set2.graphs[0].label, 1);
        let gs = set2.to_graphs();
        assert_eq!(gs[0].neighbors(1), &[0]);
    }

    #[test]
    fn graphset_roundtrips_isolated_nodes_and_dups() {
        // The JSON container carries `n` explicitly, so isolated nodes
        // survive; duplicate edges must collapse exactly like the
        // edge-list loader (both feed the same CSR builder).
        let dir = std::env::temp_dir().join("repro_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("iso_dup.json");
        let set = GraphSet {
            name: "iso".into(),
            graphs: vec![GraphRecord {
                n: 6,
                edges: vec![(0, 1), (0, 1), (2, 1), (0, 3)],
                label: 0,
            }],
        };
        set.save(&p).unwrap();
        let gs = GraphSet::load(&p).unwrap().to_graphs();
        assert_eq!(gs[0].n(), 6, "isolated nodes 4, 5 kept");
        assert_eq!(gs[0].e(), 3, "duplicate edge collapsed");
        assert_eq!(gs[0],
                   Graph::from_edges(6, &[(0, 1), (2, 1), (0, 3)]));
        assert_eq!(gs[0].neighbors(5), &[] as &[u32]);
    }
}
