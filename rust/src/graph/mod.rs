//! Input-graph substrate: CSR graphs, builders, statistics, batching.
//!
//! The paper's GNN abstraction (Algorithm 1) aggregates over each node's
//! in-neighborhood `N(v)`. We store directed aggregation edges `u -> v`
//! ("u's activations are aggregated into v") in CSR-of-in-neighbors form
//! with deterministic (sorted) neighbor order — determinism matters both
//! for reproducible HAG search and for the sequential-AGGREGATE variant,
//! where neighbor order is semantic.

mod builder;
pub mod io;

pub use builder::GraphBuilder;

/// A directed graph in CSR (in-neighbor) layout.
///
/// `offsets.len() == n + 1`; the in-neighbors of `v` are
/// `neighbors[offsets[v]..offsets[v+1]]`, sorted ascending and
/// duplicate-free.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    offsets: Vec<u32>,
    neighbors: Vec<u32>,
}

impl Graph {
    /// Build from an edge list of `(src, dst)` aggregation edges.
    /// Duplicates are removed; `n` is the node count (ids `0..n`).
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        GraphBuilder::new(n).edges(edges.iter().copied()).build()
    }

    /// Treat an undirected edge list as bidirectional aggregation.
    pub fn from_undirected_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let mut b = GraphBuilder::new(n);
        for &(u, v) in edges {
            b.edge(u, v);
            b.edge(v, u);
        }
        b.build()
    }

    pub(crate) fn from_csr(offsets: Vec<u32>, neighbors: Vec<u32>) -> Self {
        debug_assert!(!offsets.is_empty());
        debug_assert_eq!(*offsets.last().unwrap() as usize, neighbors.len());
        Graph { offsets, neighbors }
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total aggregation-edge count `|E|`.
    pub fn e(&self) -> usize {
        self.neighbors.len()
    }

    /// In-neighbors of `v`, sorted ascending.
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.neighbors[s..e]
    }

    /// In-degree of `v`.
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Iterate `(v, neighbors)` for all nodes.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &[u32])> {
        (0..self.n() as u32).map(move |v| (v, self.neighbors(v)))
    }

    /// Edge density `|E| / |V|^2` (as the paper reports for COLLAB).
    pub fn density(&self) -> f64 {
        let n = self.n() as f64;
        if n == 0.0 {
            0.0
        } else {
            self.e() as f64 / (n * n)
        }
    }

    /// Degree distribution summary (min, mean, max).
    pub fn degree_stats(&self) -> (usize, f64, usize) {
        if self.n() == 0 {
            return (0, 0.0, 0);
        }
        let mut min = usize::MAX;
        let mut max = 0usize;
        for v in 0..self.n() as u32 {
            let d = self.degree(v);
            min = min.min(d);
            max = max.max(d);
        }
        (min, self.e() as f64 / self.n() as f64, max)
    }

    /// Disjoint union (block-diagonal batching for graph classification).
    /// Returns the merged graph plus, for each input graph, its node-id
    /// offset in the merged graph.
    pub fn disjoint_union(graphs: &[Graph]) -> (Graph, Vec<u32>) {
        let total_n: usize = graphs.iter().map(|g| g.n()).sum();
        let total_e: usize = graphs.iter().map(|g| g.e()).sum();
        let mut offsets = Vec::with_capacity(total_n + 1);
        let mut neighbors = Vec::with_capacity(total_e);
        let mut starts = Vec::with_capacity(graphs.len());
        offsets.push(0u32);
        let mut base = 0u32;
        for g in graphs {
            starts.push(base);
            for v in 0..g.n() as u32 {
                neighbors.extend(g.neighbors(v).iter().map(|&u| u + base));
                offsets.push(neighbors.len() as u32);
            }
            base += g.n() as u32;
        }
        (Graph { offsets, neighbors }, starts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_roundtrip() {
        let g = Graph::from_edges(4, &[(1, 0), (2, 0), (3, 2), (1, 2)]);
        assert_eq!(g.n(), 4);
        assert_eq!(g.e(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[] as &[u32]);
        assert_eq!(g.neighbors(2), &[1, 3]);
        assert_eq!(g.degree(0), 2);
    }

    #[test]
    fn dedup_and_sort() {
        let g = Graph::from_edges(3, &[(2, 0), (1, 0), (2, 0), (1, 0)]);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.e(), 2);
    }

    #[test]
    fn undirected_doubles_edges() {
        let g = Graph::from_undirected_edges(3, &[(0, 1), (1, 2)]);
        assert_eq!(g.e(), 4);
        assert_eq!(g.neighbors(1), &[0, 2]);
    }

    #[test]
    fn disjoint_union_offsets() {
        let g1 = Graph::from_undirected_edges(3, &[(0, 1), (1, 2)]);
        let g2 = Graph::from_undirected_edges(2, &[(0, 1)]);
        let (m, starts) = Graph::disjoint_union(&[g1, g2]);
        assert_eq!(m.n(), 5);
        assert_eq!(m.e(), 6);
        assert_eq!(starts, vec![0, 3]);
        assert_eq!(m.neighbors(3), &[4]);
        assert_eq!(m.neighbors(4), &[3]);
    }

    #[test]
    fn degree_stats_and_density() {
        let g = Graph::from_edges(4, &[(1, 0), (2, 0), (3, 0)]);
        let (min, mean, max) = g.degree_stats();
        assert_eq!((min, max), (0, 3));
        assert!((mean - 0.75).abs() < 1e-9);
        assert!((g.density() - 3.0 / 16.0).abs() < 1e-12);
    }
}
