//! Incremental graph builder: collects edges, sorts, dedups, emits CSR.

use super::Graph;

/// Accumulates `(src, dst)` aggregation edges and builds a [`Graph`].
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(u32, u32)>,
}

impl GraphBuilder {
    pub fn new(n: usize) -> Self {
        GraphBuilder { n, edges: Vec::new() }
    }

    /// Add an aggregation edge `src -> dst` (src aggregated into dst).
    pub fn edge(&mut self, src: u32, dst: u32) -> &mut Self {
        debug_assert!((src as usize) < self.n && (dst as usize) < self.n,
                      "edge ({src},{dst}) out of range n={}", self.n);
        self.edges.push((src, dst));
        self
    }

    pub fn edges(mut self, it: impl IntoIterator<Item = (u32, u32)>) -> Self {
        for (s, d) in it {
            self.edge(s, d);
        }
        self
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Sort by (dst, src), dedup, emit CSR-of-in-neighbors.
    pub fn build(mut self) -> Graph {
        self.edges.sort_unstable_by_key(|&(s, d)| (d, s));
        self.edges.dedup();
        let mut offsets = Vec::with_capacity(self.n + 1);
        let mut neighbors = Vec::with_capacity(self.edges.len());
        offsets.push(0u32);
        let mut cur = 0u32;
        for (s, d) in self.edges {
            while cur < d {
                offsets.push(neighbors.len() as u32);
                cur += 1;
            }
            neighbors.push(s);
        }
        while (offsets.len() as usize) < self.n + 1 {
            offsets.push(neighbors.len() as u32);
        }
        Graph::from_csr(offsets, neighbors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(3).build();
        assert_eq!(g.n(), 3);
        assert_eq!(g.e(), 0);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
    }

    #[test]
    fn trailing_isolated_nodes() {
        let g = GraphBuilder::new(5).edges([(0u32, 1u32)]).build();
        assert_eq!(g.n(), 5);
        assert_eq!(g.neighbors(1), &[0]);
        assert_eq!(g.neighbors(4), &[] as &[u32]);
    }
}
