//! Data packer: dataset + execution plan -> named host tensors matching
//! the artifact manifest layout.
//!
//! The plan compiler degree-sorts (permutes) nodes; every per-node
//! tensor crossing the boundary is permuted here, and logits coming back
//! are un-permuted by [`unpermute_rows`]. Padding rows are zero (masked
//! out of the loss), and graph-classification padding nodes point at the
//! sink graph `g_pad - 1`.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::datasets::{Dataset, Task};
use crate::hag::ExecutionPlan;
use crate::runtime::{BucketSpec, HostTensor};

/// Named tensors for the data + plan section of an artifact's inputs.
pub struct PackedWorkload {
    tensors: HashMap<String, HostTensor>,
}

impl PackedWorkload {
    pub fn get(&self, name: &str) -> Option<&HostTensor> {
        self.tensors.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    /// Replace the feature matrix (serving path: batched feature
    /// updates re-pack only `h0`).
    pub fn set_h0(&mut self, h0: HostTensor) {
        self.tensors.insert("h0".into(), h0);
    }
}

/// The plan-derived static tensors — `deg`, the level operand tensors
/// and the per-band gather tensors — exactly as [`pack_workload`] lays
/// them out. Shared with the serving hot-swap path
/// ([`InferenceServer`](super::InferenceServer)), which re-derives
/// them from a freshly spliced plan without re-packing the dataset.
pub fn plan_tensors(plan: &ExecutionPlan) -> Vec<(String, HostTensor)> {
    let mut t = Vec::new();
    t.push(("deg".to_string(),
            HostTensor::f32(plan.deg.clone(), &[plan.n_pad])));
    if plan.levels > 0 {
        t.push(("lvl_left".to_string(),
                HostTensor::i32(plan.lvl_left.clone(),
                                &[plan.levels, plan.l_pad])));
        t.push(("lvl_right".to_string(),
                HostTensor::i32(plan.lvl_right.clone(),
                                &[plan.levels, plan.l_pad])));
    }
    for (i, (&(nb, nnzb), (cols, rows))) in plan
        .bands
        .iter()
        .zip(plan.band_cols.iter().zip(plan.band_rows.iter()))
        .enumerate()
    {
        t.push((format!("band{i}_col"),
                HostTensor::i32(cols.clone(), &[nb, nnzb])));
        t.push((format!("band{i}_row"),
                HostTensor::i32(rows.clone(), &[nb, nnzb])));
    }
    t
}

/// Pack `ds` lowered through `plan` for `bucket`.
pub fn pack_workload(ds: &Dataset, plan: &ExecutionPlan,
                     bucket: &BucketSpec) -> Result<PackedWorkload> {
    if !bucket.fits(plan) {
        bail!("plan does not fit bucket {:?}: plan n_pad={} levels={} \
               l_pad={} bands={:?} vs bucket n_pad={} levels={} l_pad={} \
               bands={:?}",
              bucket.name, plan.n_pad, plan.levels, plan.l_pad,
              plan.bands, bucket.n_pad, bucket.levels, bucket.l_pad,
              bucket.bands);
    }
    if ds.f_in != bucket.f_in {
        bail!("dataset f_in={} != bucket f_in={}", ds.f_in, bucket.f_in);
    }
    let n = ds.n();
    let n_pad = plan.n_pad;
    let f = ds.f_in;
    let mut t = HashMap::new();

    // ---- h0: permuted features, zero padding ----
    let mut h0 = vec![0f32; n_pad * f];
    for new in 0..n {
        let old = plan.perm[new] as usize;
        h0[new * f..(new + 1) * f]
            .copy_from_slice(&ds.features[old * f..(old + 1) * f]);
    }
    t.insert("h0".into(), HostTensor::f32(h0, &[n_pad, f]));

    // ---- plan-derived statics (deg + level + band tensors; shared
    // with the serving hot-swap path) ----
    for (name, tensor) in plan_tensors(plan) {
        t.insert(name, tensor);
    }

    // ---- task-specific tensors ----
    match ds.task {
        Task::NodeClassification => {
            let mut labels = vec![0i32; n_pad];
            let mut mask = vec![0f32; n_pad];
            for new in 0..n {
                let old = plan.perm[new] as usize;
                labels[new] = ds.labels[old] as i32;
                mask[new] = if ds.train_mask[old] { 1.0 } else { 0.0 };
            }
            t.insert("labels".into(), HostTensor::i32(labels, &[n_pad]));
            t.insert("mask".into(), HostTensor::f32(mask, &[n_pad]));
        }
        Task::GraphClassification => {
            let g_pad = bucket.g_pad;
            if ds.num_graphs + 1 > g_pad {
                bail!("{} graphs (+ sink) exceed g_pad={}",
                      ds.num_graphs, g_pad);
            }
            let sink = (g_pad - 1) as i32;
            let mut seg = vec![sink; n_pad];
            let mut sizes = vec![1f32; g_pad];
            let mut counts = vec![0usize; g_pad];
            for new in 0..n {
                let old = plan.perm[new] as usize;
                let gi = ds.graph_seg[old] as usize;
                seg[new] = gi as i32;
                counts[gi] += 1;
            }
            for gi in 0..ds.num_graphs {
                sizes[gi] = counts[gi].max(1) as f32;
            }
            let mut glabels = vec![0i32; g_pad];
            let mut gmask = vec![0f32; g_pad];
            for gi in 0..ds.num_graphs {
                glabels[gi] = ds.graph_labels[gi] as i32;
                gmask[gi] = 1.0;
            }
            t.insert("graph_seg".into(), HostTensor::i32(seg, &[n_pad]));
            t.insert("graph_sizes".into(),
                     HostTensor::f32(sizes, &[g_pad]));
            t.insert("graph_labels".into(),
                     HostTensor::i32(glabels, &[g_pad]));
            t.insert("graph_mask".into(),
                     HostTensor::f32(gmask, &[g_pad]));
        }
    }
    Ok(PackedWorkload { tensors: t })
}

/// Un-permute per-node output rows (e.g. logits) back to original node
/// order. `rows` is `[n_pad, width]`; output is `[plan.n, width]`.
pub fn unpermute_rows(plan: &ExecutionPlan, rows: &[f32],
                      width: usize) -> Vec<f32> {
    let mut out = vec![0f32; plan.n * width];
    for new in 0..plan.n {
        let old = plan.perm[new] as usize;
        out[old * width..(old + 1) * width]
            .copy_from_slice(&rows[new * width..(new + 1) * width]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::hag::{build_plan, AggregateKind, Hag, PlanConfig};

    fn bucket_for(plan: &ExecutionPlan, ds: &Dataset,
                  g_pad: usize) -> BucketSpec {
        BucketSpec {
            name: "test".into(),
            n_pad: plan.n_pad,
            f_in: ds.f_in,
            hidden: 16,
            classes: ds.classes,
            levels: plan.levels,
            l_pad: plan.l_pad,
            bands: plan.bands.clone(),
            br: plan.br,
            lvl_block: plan.lvl_block,
            g_pad,
            impl_: "scatter".into(),
        }
    }

    #[test]
    fn node_pack_permutes_consistently() {
        let ds = datasets::load("BZR", 0.02, 11);
        let hag = Hag::from_graph(&ds.graph, AggregateKind::Set);
        let plan = build_plan(&ds.graph, &hag, &PlanConfig::default());
        let bucket = bucket_for(&plan, &ds, 0);
        let w = pack_workload(&ds, &plan, &bucket).unwrap();
        let h0 = w.get("h0").unwrap().as_f32().unwrap();
        let labels = w.get("labels").unwrap().as_i32().unwrap();
        // row `new` must hold features/label of node perm[new]
        for new in [0usize, 1, ds.n() / 2, ds.n() - 1] {
            let old = plan.perm[new] as usize;
            assert_eq!(h0[new * ds.f_in],
                       ds.features[old * ds.f_in]);
            assert_eq!(labels[new], ds.labels[old] as i32);
        }
        // padding region zero
        for pad in ds.n()..plan.n_pad {
            assert_eq!(labels[pad], 0);
            assert!(h0[pad * ds.f_in..(pad + 1) * ds.f_in]
                .iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn graph_pack_builds_segments() {
        let ds = datasets::load("IMDB", 0.01, 13);
        let hag = Hag::from_graph(&ds.graph, AggregateKind::Set);
        let plan = build_plan(&ds.graph, &hag, &PlanConfig::default());
        let g_pad = (ds.num_graphs + 1).next_multiple_of(16);
        let bucket = bucket_for(&plan, &ds, g_pad);
        let w = pack_workload(&ds, &plan, &bucket).unwrap();
        let seg = w.get("graph_seg").unwrap().as_i32().unwrap();
        let sizes = w.get("graph_sizes").unwrap().as_f32().unwrap();
        let gmask = w.get("graph_mask").unwrap().as_f32().unwrap();
        // all real nodes point at real graphs; padding at sink
        for new in 0..ds.n() {
            assert!((seg[new] as usize) < ds.num_graphs);
        }
        for pad in ds.n()..plan.n_pad {
            assert_eq!(seg[pad] as usize, g_pad - 1);
        }
        // sizes add up to n over real graphs
        let total: f32 = sizes[..ds.num_graphs].iter().sum();
        assert_eq!(total as usize, ds.n());
        assert_eq!(gmask[..ds.num_graphs].iter()
            .filter(|&&m| m == 1.0).count(), ds.num_graphs);
    }

    #[test]
    fn unpermute_roundtrip() {
        let ds = datasets::load("BZR", 0.02, 17);
        let hag = Hag::from_graph(&ds.graph, AggregateKind::Set);
        let plan = build_plan(&ds.graph, &hag, &PlanConfig::default());
        // permuted "logits" = new index; unpermute must place new index
        // at old position
        let rows: Vec<f32> = (0..plan.n_pad).map(|i| i as f32).collect();
        let out = unpermute_rows(&plan, &rows, 1);
        for old in 0..plan.n {
            assert_eq!(out[old], plan.inv_perm[old] as f32);
        }
    }

    #[test]
    fn plan_tensors_match_packed_workload() {
        let ds = datasets::load("BZR", 0.02, 11);
        let (hag, _) = crate::hag::hag_search(
            &ds.graph,
            &crate::hag::SearchConfig::paper_default(ds.graph.n()));
        let plan = build_plan(&ds.graph, &hag, &PlanConfig::default());
        let bucket = bucket_for(&plan, &ds, 0);
        let w = pack_workload(&ds, &plan, &bucket).unwrap();
        let tensors = plan_tensors(&plan);
        // every plan tensor appears in the workload, same shape + data
        assert!(tensors.iter().any(|(n, _)| n == "deg"));
        if plan.levels > 0 {
            assert!(tensors.iter().any(|(n, _)| n == "lvl_left"));
        }
        for (name, t) in &tensors {
            let packed = w.get(name)
                .unwrap_or_else(|| panic!("workload missing {name}"));
            assert_eq!(packed.shape(), t.shape(), "{name}");
            match (packed, t) {
                (HostTensor::F32 { data: a, .. },
                 HostTensor::F32 { data: b, .. }) => assert_eq!(a, b),
                (HostTensor::I32 { data: a, .. },
                 HostTensor::I32 { data: b, .. }) => assert_eq!(a, b),
                _ => panic!("{name}: dtype mismatch"),
            }
        }
    }

    #[test]
    fn bucket_mismatch_rejected() {
        let ds = datasets::load("BZR", 0.02, 19);
        let hag = Hag::from_graph(&ds.graph, AggregateKind::Set);
        let plan = build_plan(&ds.graph, &hag, &PlanConfig::default());
        let mut bucket = bucket_for(&plan, &ds, 0);
        bucket.n_pad += 128;
        assert!(pack_workload(&ds, &plan, &bucket).is_err());
    }
}
