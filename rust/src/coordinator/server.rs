//! Inference serving path: request router + dynamic batcher.
//!
//! Serving model: the graph (and its HAG plan) is resident; clients
//! submit *feature-update scoring requests* — "these node feature rows
//! changed, give me fresh logits for them" (the transductive GNN serving
//! pattern: user/post features refresh continuously, topology changes
//! slowly). The batcher coalesces concurrent requests into one XLA
//! execution over the shared graph, amortizing the full-graph
//! aggregation across the batch — exactly where HAG's reduced
//! aggregation count pays off in serving latency.
//!
//! Flow: client threads -> bounded mpsc queue -> batcher thread
//! (size- or deadline-triggered) -> XLA execute -> per-request oneshot
//! replies. The `xla` crate's handles are not `Send` (Rc + raw
//! pointers), so the batcher thread owns its *own* PJRT client,
//! executable and device buffers end to end; only plain host tensors
//! cross the thread boundary. Built on std::sync primitives (tokio is
//! not vendored here; a blocking XLA worker gains nothing from an async
//! runtime anyway).
//!
//! Online topology updates: the queue carries [`ServerMsg`], either a
//! scoring request or an [`UpdateRequest`] (a
//! [`GraphDelta`](crate::incremental::GraphDelta) for the optional
//! resident [`StreamEngine`]). Updates are repaired inline between
//! batches — local repair is microseconds, and drift-triggered
//! re-searches run on the engine's background thread — so scoring
//! traffic keeps flowing while the HAG is maintained. The *compiled*
//! artifact stays pinned to its bucket; the maintained HAG is what the
//! next emit-buckets/compile cycle lowers, i.e. the serving plan
//! trails the live topology by one plan swap (DESIGN.md §6).

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError,
                      SyncSender};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::hag::ExecutionPlan;
use crate::incremental::{ApplyOutcome, GraphDelta, RebuildEvent,
                         StreamEngine};
use crate::runtime::xla;
use crate::runtime::{Executable, HostTensor, Runtime};

use super::packing::PackedWorkload;
use super::trainer::init_params;

/// One scoring request: overwrite node features, return its logits.
pub struct ScoreRequest {
    /// Original (un-permuted) node id.
    pub node: u32,
    /// Replacement feature row (`f_in` long), or empty to keep current.
    pub features: Vec<f32>,
    /// Single-use reply channel.
    pub reply: SyncSender<ScoreResponse>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct ScoreResponse {
    pub node: u32,
    pub logits: Vec<f32>,
    /// Queue + batch + execute time.
    pub latency: Duration,
}

/// Create a reply channel pair for a [`ScoreRequest`].
pub fn oneshot() -> (SyncSender<ScoreResponse>,
                     Receiver<ScoreResponse>) {
    sync_channel(1)
}

/// Everything the serving queue carries.
pub enum ServerMsg {
    Score(ScoreRequest),
    Update(UpdateRequest),
}

/// One topology update for the resident [`StreamEngine`].
pub struct UpdateRequest {
    pub delta: GraphDelta,
    /// Optional reply channel (fire-and-forget updates pass `None`).
    pub reply: Option<SyncSender<UpdateResponse>>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct UpdateResponse {
    /// Engine sequence number; `0` when the server has no stream
    /// engine (the update was dropped).
    pub seq: u64,
    pub outcome: ApplyOutcome,
    pub rebuild: RebuildEvent,
    /// `cost_core` of the maintained HAG after this update.
    pub cost_core: usize,
    /// Queue + repair time.
    pub latency: Duration,
}

/// Create a reply channel pair for an [`UpdateRequest`].
pub fn update_oneshot() -> (SyncSender<UpdateResponse>,
                            Receiver<UpdateResponse>) {
    sync_channel(1)
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_exec_ms: f64,
    pub throughput_rps: f64,
    /// Topology updates repaired while serving.
    pub updates: usize,
    /// Drift-triggered HAG rebuilds swapped in while serving.
    pub rebuild_swaps: usize,
}

/// The inference server over one prepared (graph, plan, artifact).
pub struct InferenceServer {
    tx: SyncSender<ServerMsg>,
    handle: std::thread::JoinHandle<ServeStats>,
}

impl InferenceServer {
    /// Spawn straight from a lowered session workload: derives the
    /// infer-artifact name from the bucket and packs the dataset
    /// against the plan. `lowered` should come from
    /// [`Session::lower`](crate::session::Session::lower) on the same
    /// dataset.
    pub fn for_lowered(artifacts_dir: impl Into<PathBuf>, model: &str,
                       ds: &crate::datasets::Dataset,
                       lowered: &super::Lowered, policy: BatchPolicy,
                       seed: u64, stream: Option<StreamEngine>)
                       -> Result<InferenceServer> {
        let artifact =
            super::artifact_name(model, "infer", &lowered.bucket);
        let workload = super::pack_workload(ds, &lowered.plan,
                                            &lowered.bucket)?;
        Self::spawn(artifacts_dir, &artifact, &workload, &lowered.plan,
                    policy, seed, stream)
    }

    /// Spawn the batcher thread and block until its PJRT state is
    /// ready. `workload` supplies the resident graph tensors; params
    /// are initialized (a full deployment would load a checkpoint).
    /// `stream` (optional) is the incremental-maintenance engine that
    /// [`UpdateRequest`]s feed; pass
    /// `StreamEngine::new(&ds.graph, ..)` with a background drift
    /// policy so re-searches never stall the batcher.
    pub fn spawn(artifacts_dir: impl Into<PathBuf>, artifact: &str,
                 workload: &PackedWorkload, plan: &ExecutionPlan,
                 policy: BatchPolicy, seed: u64,
                 stream: Option<StreamEngine>)
                 -> Result<InferenceServer> {
        let dir = artifacts_dir.into();
        let artifact = artifact.to_string();
        // Host-side state crossing into the worker thread (all Send).
        let h0 = workload
            .get("h0")
            .ok_or_else(|| anyhow!("workload missing h0"))?
            .as_f32()?
            .to_vec();
        let statics: Vec<(String, HostTensor)> = workload
            .names()
            .filter(|n| *n != "h0")
            .map(|n| (n.to_string(), workload.get(n).unwrap().clone()))
            .collect();
        let inv_perm = plan.inv_perm.clone();

        let (tx, rx) = sync_channel::<ServerMsg>(4096);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let handle = std::thread::spawn(move || {
            let setup = Worker::setup(&dir, &artifact, statics, h0,
                                      seed);
            match setup {
                Ok(mut w) => {
                    let _ = ready_tx.send(Ok(()));
                    w.batcher_loop(rx, &inv_perm, policy, stream)
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    ServeStats::default()
                }
            }
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(InferenceServer { tx, handle }),
            Ok(Err(e)) => {
                let _ = handle.join();
                Err(e)
            }
            Err(_) => {
                let _ = handle.join();
                Err(anyhow!("server thread died during setup"))
            }
        }
    }

    /// Queue handle: send [`ServerMsg::Score`] to score,
    /// [`ServerMsg::Update`] to stream a topology delta.
    pub fn client(&self) -> SyncSender<ServerMsg> {
        self.tx.clone()
    }

    /// Close the queue and collect final stats.
    pub fn shutdown(self) -> ServeStats {
        drop(self.tx);
        self.handle.join().unwrap_or_default()
    }
}

/// Thread-confined XLA state.
struct Worker {
    runtime: Runtime,
    exe: std::sync::Arc<Executable>,
    static_slots: Vec<(usize, xla::PjRtBuffer)>,
    h0_index: usize,
    h0: Vec<f32>,
    n_pad: usize,
    f_in: usize,
    classes: usize,
}

impl Worker {
    fn setup(dir: &PathBuf, artifact: &str,
             statics: Vec<(String, HostTensor)>, h0: Vec<f32>,
             seed: u64) -> Result<Worker> {
        let runtime = Runtime::open(dir)?;
        let exe = runtime.compile(artifact)?;
        if exe.spec.kind != "infer" {
            return Err(anyhow!("{artifact} is not an infer artifact"));
        }
        let bucket = &exe.spec.bucket;
        let (n_pad, f_in, classes) =
            (bucket.n_pad, bucket.f_in, bucket.classes);

        let param_specs: Vec<_> = exe.spec.inputs.iter()
            .filter(|s| !matches!(s.name.as_str(), "h0" | "deg")
                    && !s.name.starts_with("lvl_")
                    && !s.name.starts_with("band"))
            .cloned().collect();
        let params = init_params(&param_specs, seed);

        let mut static_slots = Vec::new();
        let mut h0_index = None;
        let mut pi = 0usize;
        for (i, s) in exe.spec.inputs.iter().enumerate() {
            if s.name == "h0" {
                h0_index = Some(i);
            } else if s.name == "deg" || s.name.starts_with("lvl_")
                || s.name.starts_with("band")
            {
                let t = statics.iter().find(|(n, _)| *n == s.name)
                    .map(|(_, t)| t)
                    .ok_or_else(|| anyhow!("workload missing {:?}",
                                           s.name))?;
                static_slots.push((i, runtime.upload(t)?));
            } else {
                static_slots.push((i, runtime.upload(&params[pi])?));
                pi += 1;
            }
        }
        let h0_index =
            h0_index.ok_or_else(|| anyhow!("artifact lacks h0 input"))?;
        Ok(Worker { runtime, exe, static_slots, h0_index, h0, n_pad,
                    f_in, classes })
    }

    /// Repair one topology update against the resident engine (local
    /// repair is microseconds; rebuilds go to the engine's background
    /// thread), replying if the client asked for one.
    fn handle_update(stream: &mut Option<StreamEngine>,
                     req: UpdateRequest) {
        let resp = match stream.as_mut() {
            Some(eng) => {
                let rep = eng.apply(req.delta);
                UpdateResponse {
                    seq: rep.seq,
                    outcome: rep.outcome,
                    rebuild: rep.rebuild,
                    cost_core: rep.cost_core,
                    latency: req.submitted.elapsed(),
                }
            }
            None => UpdateResponse {
                seq: 0,
                outcome: ApplyOutcome::NoOp,
                rebuild: RebuildEvent::None,
                cost_core: 0,
                latency: req.submitted.elapsed(),
            },
        };
        if let Some(tx) = req.reply {
            let _ = tx.send(resp);
        }
    }

    fn batcher_loop(&mut self, rx: Receiver<ServerMsg>,
                    inv_perm: &[u32], policy: BatchPolicy,
                    mut stream: Option<StreamEngine>) -> ServeStats {
        let mut stats_lat: Vec<f64> = Vec::new();
        let mut stats_exec: Vec<f64> = Vec::new();
        let mut batches = 0usize;
        let mut requests = 0usize;
        let mut updates = 0usize;
        let t_start = Instant::now();
        'serve: loop {
            // Collect a batch: first scoring request blocks, the rest
            // race the deadline. Updates are repaired inline as they
            // arrive — they never block scoring and never count
            // toward the batch.
            let first;
            loop {
                match rx.recv() {
                    Ok(ServerMsg::Score(r)) => {
                        first = r;
                        break;
                    }
                    Ok(ServerMsg::Update(u)) => {
                        updates += 1;
                        Self::handle_update(&mut stream, u);
                    }
                    Err(_) => break 'serve,
                }
            }
            let mut batch = vec![first];
            let deadline = Instant::now() + policy.max_wait;
            while batch.len() < policy.max_batch {
                let left =
                    deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(ServerMsg::Score(r)) => batch.push(r),
                    Ok(ServerMsg::Update(u)) => {
                        updates += 1;
                        Self::handle_update(&mut stream, u);
                    }
                    Err(RecvTimeoutError::Timeout)
                    | Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            // Land any finished background re-search between batches.
            if let Some(eng) = stream.as_mut() {
                eng.poll_rebuild();
            }
            // Apply feature updates to the resident (permuted) h0.
            for r in &batch {
                if !r.features.is_empty() {
                    let new = inv_perm[r.node as usize] as usize;
                    self.h0[new * self.f_in..(new + 1) * self.f_in]
                        .copy_from_slice(&r.features);
                }
            }
            let te = Instant::now();
            let result = self.run_batch();
            let exec_ms = te.elapsed().as_secs_f64() * 1e3;
            stats_exec.push(exec_ms);
            batches += 1;
            match result {
                Ok(logits) => {
                    for r in batch {
                        requests += 1;
                        let new = inv_perm[r.node as usize] as usize;
                        let row = logits[new * self.classes
                            ..(new + 1) * self.classes].to_vec();
                        let latency = r.submitted.elapsed();
                        stats_lat.push(latency.as_secs_f64() * 1e3);
                        let _ = r.reply.send(ScoreResponse {
                            node: r.node,
                            logits: row,
                            latency,
                        });
                    }
                }
                Err(e) => {
                    eprintln!("[serve] batch failed: {e:#}");
                    // drop replies; clients observe a closed channel
                }
            }
        }
        let rebuild_swaps =
            stream.as_ref().map_or(0, |e| e.stats().rebuild_swaps);
        finalize_stats(stats_lat, stats_exec, batches, requests,
                       updates, rebuild_swaps, t_start.elapsed())
    }

    fn run_batch(&self) -> Result<Vec<f32>> {
        let h0_buf = self.runtime.upload(&HostTensor::f32(
            self.h0.clone(), &[self.n_pad, self.f_in]))?;
        let n_inputs = self.exe.spec.inputs.len();
        let mut slots: Vec<Option<&xla::PjRtBuffer>> =
            vec![None; n_inputs];
        for (i, b) in &self.static_slots {
            slots[*i] = Some(b);
        }
        slots[self.h0_index] = Some(&h0_buf);
        let args: Vec<&xla::PjRtBuffer> = slots
            .into_iter()
            .enumerate()
            .map(|(i, o)| o.ok_or_else(|| anyhow!("input {i} unbound")))
            .collect::<Result<_>>()?;
        let outs = self.runtime.execute(&self.exe, &args)?;
        Ok(outs[0].as_f32()?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::incremental::StreamConfig;

    // The scoring path needs compiled artifacts (tests/integration.rs
    // covers it, self-skipping without them); the update path is pure
    // engine work and is testable here without XLA.

    #[test]
    fn handle_update_replies_with_engine_state() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let mut stream =
            Some(StreamEngine::new(&g, StreamConfig::default()));
        let (tx, rx) = update_oneshot();
        Worker::handle_update(&mut stream, UpdateRequest {
            delta: GraphDelta::EdgeInsert { src: 0, dst: 2 },
            reply: Some(tx),
            submitted: Instant::now(),
        });
        let resp = rx.recv().unwrap();
        assert_eq!(resp.seq, 1);
        assert_eq!(resp.outcome, ApplyOutcome::Inserted);
        assert_eq!(resp.rebuild, RebuildEvent::None);
        let eng = stream.as_ref().unwrap();
        assert_eq!(resp.cost_core, eng.cost_core());
        assert_eq!(eng.e(), g.e() + 1);
    }

    #[test]
    fn handle_update_without_engine_replies_sentinel() {
        let mut stream: Option<StreamEngine> = None;
        let (tx, rx) = update_oneshot();
        Worker::handle_update(&mut stream, UpdateRequest {
            delta: GraphDelta::NodeAdd,
            reply: Some(tx),
            submitted: Instant::now(),
        });
        let resp = rx.recv().unwrap();
        assert_eq!(resp.seq, 0, "no-engine sentinel");
        assert_eq!(resp.outcome, ApplyOutcome::NoOp);
    }

    #[test]
    fn handle_update_fire_and_forget_does_not_block() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]);
        let mut stream =
            Some(StreamEngine::new(&g, StreamConfig::default()));
        Worker::handle_update(&mut stream, UpdateRequest {
            delta: GraphDelta::EdgeDelete { src: 0, dst: 1 },
            reply: None,
            submitted: Instant::now(),
        });
        assert_eq!(stream.as_ref().unwrap().e(), g.e() - 1);
    }
}

fn finalize_stats(mut lat: Vec<f64>, exec: Vec<f64>, batches: usize,
                  requests: usize, updates: usize,
                  rebuild_swaps: usize,
                  elapsed: Duration) -> ServeStats {
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        if lat.is_empty() {
            f64::NAN
        } else {
            lat[((lat.len() as f64 - 1.0) * p) as usize]
        }
    };
    ServeStats {
        requests,
        batches,
        mean_batch: if batches == 0 {
            0.0
        } else {
            requests as f64 / batches as f64
        },
        p50_ms: pct(0.5),
        p99_ms: pct(0.99),
        mean_exec_ms: if exec.is_empty() {
            f64::NAN
        } else {
            exec.iter().sum::<f64>() / exec.len() as f64
        },
        throughput_rps: requests as f64 / elapsed.as_secs_f64().max(1e-9),
        updates,
        rebuild_swaps,
    }
}
