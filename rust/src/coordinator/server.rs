//! Inference serving path: request router + dynamic batcher, with an
//! optional resident maintenance pair (engine + session) and a hot
//! plan-swap protocol.
//!
//! Serving model: the graph (and its HAG plan) is resident; clients
//! submit *feature-update scoring requests* — "these node feature rows
//! changed, give me fresh logits for them" (the transductive GNN serving
//! pattern: user/post features refresh continuously, topology changes
//! slowly). The batcher coalesces concurrent requests into one
//! execution over the shared graph, amortizing the full-graph
//! aggregation across the batch — exactly where HAG's reduced
//! aggregation count pays off in serving latency.
//!
//! Flow: client threads -> bounded mpsc queue -> batcher thread
//! (size- or deadline-triggered) -> execute -> per-request oneshot
//! replies. The `xla` crate's handles are not `Send` (Rc + raw
//! pointers), so the batcher thread owns its *own* PJRT client,
//! executable and device buffers end to end; only plain host tensors
//! cross the thread boundary. When the PJRT backend is unavailable
//! (default CPU-stub builds, or no compiled artifacts) the worker falls
//! back to a host **reference executor** that runs the same 2-layer GCN
//! through the plan's level/band tensors — slower, but the full serving
//! path (validation, batching, update coalescing, plan swap) is
//! exercised end to end without any artifacts.
//!
//! Hardened request path: every [`ScoreRequest`] is validated on
//! receipt — an out-of-range node id or a wrong-length feature row is
//! answered with [`ScoreResponse::Err`] instead of indexing out of
//! bounds inside the batcher, and a failed batch execute replies
//! [`ScoreReject::ExecFailed`] to every request in the batch rather
//! than silently dropping the reply channels. The batcher thread
//! survives all three.
//!
//! Online topology maintenance ([`Resident`]): the queue carries
//! [`ServerMsg::Update`] deltas which the batcher **buffers** and
//! flushes between scoring batches, coalesced by
//! `Partition::shard_of` of the touched node (locality-aware update
//! batching: a skewed stream dirties few shards between re-plans, so
//! the session's per-shard plan cache hits on the rest). Each flushed
//! delta flows to *both* the [`StreamEngine`] (per-delta local repair)
//! and the [`Session`] (dirty-shard bookkeeping). When drift crosses
//! the spec's threshold, the next serving plan comes from
//! [`Session::plan`] — a spliced dirty-shard re-plan served from the
//! per-shard cache — and is **hot-swapped** into the worker: the
//! resident `h0` is re-derived under the new permutation, the static
//! `lvl_*`/`band*`/`deg` tensors are rebuilt from the new
//! [`ExecutionPlan`], and (on the XLA path) the executable is reused
//! when the plan still fits its bucket or recompiled against a
//! matching bucket artifact when one is present — all without
//! restarting the batcher thread. Scoring a node added by `NodeAdd`
//! returns [`ScoreReject::NodeOutOfRange`] until a swap publishes a
//! plan that covers it (the serving plan trails the live topology by
//! one swap, not by a whole emit-buckets/compile cycle; DESIGN.md §8).
//!
//! Telemetry (DESIGN.md §10): each server owns a
//! [`MetricsRegistry`] — counters and bounded log-scale histograms
//! (`serve.latency`, `serve.exec`) replace the historical unbounded
//! per-request `Vec<f64>` accumulators, so memory is O(1) per metric
//! and percentiles are readable *live*: [`ServerMsg::Stats`] returns
//! a [`StatsSnapshot`] over the same queue the scoring traffic uses.
//! The batcher marks its lifecycle in the trace ring
//! (`serve.batch`/`serve.flush` spans, `serve.drift_check` instants,
//! a `serve.plan_swap` span per landed swap), and failures — batch
//! execute, plan swap — write a flight-recorder artifact
//! ([`crate::obs::flight`]) carrying the failing span.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError,
                      SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::durability::DurabilityState;
use crate::graph::Graph;
use crate::hag::{AggregateKind, ExecutionPlan, Hag};
use crate::incremental::{ApplyOutcome, GraphDelta, RebuildEvent,
                         StreamEngine};
use crate::obs::{self, CostModel, Counter, Histogram,
                 MetricsRegistry, StatsSnapshot};
use crate::runtime::xla;
use crate::runtime::{BucketSpec, Executable, HostTensor, Runtime,
                     TensorSpec};
use crate::session::Session;

use super::packing::{plan_tensors, PackedWorkload};
use super::trainer::init_params;
use super::Repr;

/// One scoring request: overwrite node features, return its logits.
/// Validated on receipt: `node` must be below the *serving plan's*
/// real node count and `features` must be empty (keep current) or
/// exactly `f_in` long — violations are answered with
/// [`ScoreResponse::Err`], never a panic.
pub struct ScoreRequest {
    /// Original (un-permuted) node id.
    pub node: u32,
    /// Replacement feature row (`f_in` long), or empty to keep current.
    pub features: Vec<f32>,
    /// Single-use reply channel.
    pub reply: SyncSender<ScoreResponse>,
    pub submitted: Instant,
    /// Pin this read to a plan epoch: `Some(e)` answers only while
    /// the serving plan's epoch is exactly `e`, else the request is
    /// rejected with [`ScoreReject::EpochMismatch`] — a client that
    /// observed epoch `e` is told a hot swap landed instead of
    /// silently reading under a different plan. `None` (the default)
    /// reads whatever plan is current. Checked on the batcher
    /// thread, so the check is race-free against swaps.
    pub pin_epoch: Option<u64>,
}

/// Successful scoring reply.
#[derive(Debug, Clone)]
pub struct ScoreOk {
    pub node: u32,
    pub logits: Vec<f32>,
    /// Queue + batch + execute time.
    pub latency: Duration,
    /// Plan epoch this answer was computed under. Starts at 1 for
    /// the spawn-time plan and is bumped by exactly 1 per landed
    /// hot swap, so values are strictly monotone over a server's
    /// lifetime — the serving analogue of the paper's Theorem-1
    /// guarantee: any two reads with equal epochs were computed
    /// under the identical (equivalence-checked) plan.
    pub epoch: u64,
}

/// Why a scoring request was answered with an error outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum ScoreReject {
    /// `node >= n` for the currently *served* plan — hostile input, or
    /// a `NodeAdd` the next plan swap has not yet published.
    NodeOutOfRange { node: u32, n: usize },
    /// Feature row length does not match the model's `f_in`.
    FeatureLen { got: usize, want: usize },
    /// The batch execute failed; the server is still alive (clients
    /// can distinguish "server rejected this batch" from a closed
    /// channel, i.e. "server died").
    ExecFailed { message: String },
    /// The request pinned a plan epoch the server no longer (or not
    /// yet) serves — a hot swap landed between the client observing
    /// `pinned` and this read. Carries the serving epoch so the
    /// client can re-pin without a second round trip.
    EpochMismatch { pinned: u64, current: u64 },
}

/// Error scoring reply (request-level or batch-level failure).
#[derive(Debug, Clone)]
pub struct ScoreError {
    pub node: u32,
    pub reject: ScoreReject,
    pub latency: Duration,
    /// Plan epoch at rejection time (see [`ScoreOk::epoch`]).
    pub epoch: u64,
}

/// Scoring reply: logits, or an explicit error outcome.
#[derive(Debug, Clone)]
pub enum ScoreResponse {
    Ok(ScoreOk),
    Err(ScoreError),
}

impl ScoreResponse {
    pub fn node(&self) -> u32 {
        match self {
            ScoreResponse::Ok(r) => r.node,
            ScoreResponse::Err(e) => e.node,
        }
    }

    pub fn latency(&self) -> Duration {
        match self {
            ScoreResponse::Ok(r) => r.latency,
            ScoreResponse::Err(e) => e.latency,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, ScoreResponse::Ok(_))
    }

    /// Plan epoch the response was produced under.
    pub fn epoch(&self) -> u64 {
        match self {
            ScoreResponse::Ok(r) => r.epoch,
            ScoreResponse::Err(e) => e.epoch,
        }
    }

    pub fn into_result(self) -> std::result::Result<ScoreOk, ScoreError> {
        match self {
            ScoreResponse::Ok(r) => Ok(r),
            ScoreResponse::Err(e) => Err(e),
        }
    }
}

/// Create a reply channel pair for a [`ScoreRequest`].
pub fn oneshot() -> (SyncSender<ScoreResponse>,
                     Receiver<ScoreResponse>) {
    sync_channel(1)
}

/// Everything the serving queue carries.
pub enum ServerMsg {
    Score(ScoreRequest),
    Update(UpdateRequest),
    /// Live stats: the worker publishes the resident pair's own
    /// counters into its registry and replies with a point-in-time
    /// [`StatsSnapshot`]. Never blocks behind a batch window —
    /// answered from whichever receive loop sees it.
    Stats(StatsRequest),
}

/// A live-stats request (see [`ServerMsg::Stats`]).
pub struct StatsRequest {
    pub reply: SyncSender<StatsSnapshot>,
}

/// Create a reply channel pair for a [`StatsRequest`].
pub fn stats_oneshot() -> (SyncSender<StatsSnapshot>,
                           Receiver<StatsSnapshot>) {
    sync_channel(1)
}

/// One topology update for the resident maintenance pair. Buffered on
/// receipt and applied at the next coalesced flush (between scoring
/// batches, when the pending buffer fills, or after `max_wait` of
/// queue idleness), so the reply latency is bounded even on an idle
/// server.
pub struct UpdateRequest {
    pub delta: GraphDelta,
    /// Optional reply channel (fire-and-forget updates pass `None`).
    pub reply: Option<SyncSender<UpdateResponse>>,
    pub submitted: Instant,
}

#[derive(Debug, Clone)]
pub struct UpdateResponse {
    /// Engine sequence number; `0` when the server has no resident
    /// maintenance pair (the update was dropped).
    pub seq: u64,
    pub outcome: ApplyOutcome,
    pub rebuild: RebuildEvent,
    /// `cost_core` of the maintained HAG after this update.
    pub cost_core: usize,
    /// Queue + coalesce + repair time.
    pub latency: Duration,
}

/// Create a reply channel pair for an [`UpdateRequest`].
pub fn update_oneshot() -> (SyncSender<UpdateResponse>,
                            Receiver<UpdateResponse>) {
    sync_channel(1)
}

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// Plan-swap / update-batching policy (`serve --plan-swap`,
/// `--update-batch`).
#[derive(Debug, Clone)]
pub struct SwapPolicy {
    /// Hot-swap the serving plan from the session's per-shard cache
    /// when drift crosses the spec threshold. Forced off for the
    /// GNN-graph baseline and sequential AGGREGATE (no point repair).
    pub swap_plans: bool,
    /// Pending-update count that forces a coalesced flush outside the
    /// batch-collection window (clamped to >= 1).
    pub max_pending: usize,
}

impl Default for SwapPolicy {
    fn default() -> Self {
        SwapPolicy { swap_plans: false, max_pending: 64 }
    }
}

/// The resident maintenance pair the batcher owns: a [`StreamEngine`]
/// repairing the HAG per delta and a [`Session`] whose per-shard plan
/// cache supplies the next serving plan. Built with [`Resident::new`]
/// from the *same* session that lowered the serving workload, so the
/// first drift re-plan hits the cache for every clean shard.
pub struct Resident {
    pub engine: StreamEngine,
    pub session: Session,
    pub swap: SwapPolicy,
    /// Crash-safe journaling (DESIGN.md §14): when present, every
    /// coalesced update batch is fsync'd into the WAL *before* it is
    /// applied or acknowledged, and a snapshot is cut on the
    /// configured epoch cadence after each landed swap.
    pub durability: Option<DurabilityState>,
    /// Run one forced swap check before the first batch (recovery
    /// resumes serving the recovered session plan immediately
    /// instead of waiting for the next due drift check).
    pub force_initial_swap: bool,
    /// Serving-side drift threshold, from the session spec. Negative
    /// values trigger a swap check at every flush (CI/test forcing
    /// knob — see `DriftPolicy::threshold`).
    threshold: f64,
}

impl Resident {
    /// Wire a session into serving. `session` must be the session that
    /// lowered the serving workload (its cache is already warm at the
    /// current topology version), `g` its base graph, and `hag` the
    /// lowered HAG (`lowered.hag`) — the engine adopts it instead of
    /// paying a second initial search.
    ///
    /// Exactly one party owns re-planning: with `swap.swap_plans` (and
    /// a Set-AGGREGATE HAG spec) the engine's own whole-graph drift
    /// rebuild is disabled and drift installs the session's spliced
    /// dirty-shard re-plan; otherwise the engine keeps its policy with
    /// rebuilds forced onto the background thread so the batcher never
    /// stalls on a search.
    pub fn new(session: Session, g: &Graph, hag: &Hag,
               swap: SwapPolicy) -> Resident {
        let spec = session.spec().clone();
        let swappable = spec.repr == Repr::Hag
            && spec.kind == AggregateKind::Set;
        let swap = SwapPolicy {
            swap_plans: swap.swap_plans && swappable,
            max_pending: swap.max_pending.max(1),
        };
        let cfg = Self::engine_config(&spec, swap.swap_plans);
        let engine = if swappable {
            StreamEngine::from_hag(g, cfg, hag)
        } else {
            StreamEngine::new(g, cfg)
        };
        Resident { engine, session, swap, durability: None,
                   force_initial_swap: false,
                   threshold: spec.drift.threshold }
    }

    /// The engine config a resident runs under: with plan swapping
    /// the engine's own drift rebuild is disabled (the session owns
    /// re-planning), otherwise rebuilds go to the background thread.
    fn engine_config(spec: &crate::session::LowerSpec,
                     swap_plans: bool)
                     -> crate::incremental::StreamConfig {
        let mut cfg = spec.stream_config();
        if swap_plans {
            cfg.policy.threshold = f64::INFINITY;
        } else {
            cfg.policy.background = true;
        }
        cfg
    }

    /// Replay recovered durability state into this resident pair:
    /// snapshot adoption plus WAL suffix for the engine, full history
    /// for the session (see [`crate::durability::resume_pair`]).
    /// Combine with [`Resident::with_initial_swap`] so the recovered
    /// topology's plan is served from the first batch.
    pub fn resume(&mut self, rec: &crate::durability::Recovered)
                  -> Result<crate::durability::ReplayReport, String> {
        let cfg = Self::engine_config(self.session.spec(),
                                      self.swap.swap_plans);
        crate::durability::resume_pair(rec, &mut self.engine,
                                       &mut self.session, &cfg)
    }

    /// Attach crash-safe journaling: the update path becomes
    /// journal-then-ack against this WAL.
    pub fn with_durability(mut self, dur: DurabilityState)
                           -> Resident {
        self.durability = Some(dur);
        self
    }

    /// Serve the session's current plan from the first batch onward
    /// (recovery resume: the recovered topology is ahead of the
    /// lowered plan, so waiting for drift would serve stale state).
    pub fn with_initial_swap(mut self) -> Resident {
        self.force_initial_swap = true;
        self
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Scoring requests admitted to a batch and answered `Ok`.
    pub requests: usize,
    /// Malformed requests refused with an error reply on receipt.
    pub rejected: usize,
    /// Requests answered [`ScoreReject::ExecFailed`].
    pub failed: usize,
    pub batches: usize,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_exec_ms: f64,
    pub throughput_rps: f64,
    /// Topology updates applied while serving.
    pub updates: usize,
    /// Coalesced update flushes.
    pub update_batches: usize,
    /// Engine-side HAG rebuild/install swaps (maintenance state).
    pub rebuild_swaps: usize,
    /// Session-fed plans hot-swapped into the serving state.
    pub plan_swaps: usize,
    /// Drift asked for a swap but no compatible artifact existed
    /// (XLA path) or the swap errored.
    pub swaps_skipped: usize,
    /// Batch executes that failed (each answers its whole batch with
    /// `ExecFailed`; the worker stays alive).
    pub exec_failures: usize,
    /// Per-shard searches the resident session ran.
    pub shard_searches: usize,
    /// Per-shard searches the session's plan cache absorbed.
    pub shard_cache_hits: usize,
    /// Batcher rounds that panicked and were restarted by the
    /// supervision loop (bounded; see `MAX_WORKER_RESTARTS`).
    pub worker_restarts: usize,
    /// Update batches nacked because their WAL commit failed (every
    /// delta in the batch was refused; none were applied).
    pub wal_nacked_batches: usize,
    /// Snapshots cut at epoch boundaries by the durability handle.
    pub snapshots_written: usize,
    /// Shutdown contract check (swap-enabled residents only):
    /// session `plan()` == `plan_fresh()` with full tensor equality.
    pub plan_matches_fresh: Option<bool>,
}

/// Final server state: stats plus the resident pair handed back for
/// inspection (tests assert the serving-path cache contract on it).
pub struct ServeOutcome {
    pub stats: ServeStats,
    pub resident: Option<Resident>,
}

/// The inference server over one prepared (graph, plan, artifact).
pub struct InferenceServer {
    tx: SyncSender<ServerMsg>,
    handle: std::thread::JoinHandle<ServeOutcome>,
    /// Shared plan-epoch cell (see [`ScoreOk::epoch`]): written by
    /// the batcher, read by the wire front end for diagnostics.
    epoch: Arc<AtomicU64>,
}

impl InferenceServer {
    /// Spawn straight from a lowered session workload: derives the
    /// infer-artifact name from the bucket and packs the dataset
    /// against the plan. `lowered` should come from
    /// [`Session::lower`](crate::session::Session::lower) on the same
    /// dataset — and `resident`, when present, from [`Resident::new`]
    /// over that same session.
    pub fn for_lowered(artifacts_dir: impl Into<PathBuf>, model: &str,
                       ds: &crate::datasets::Dataset,
                       lowered: &super::Lowered, policy: BatchPolicy,
                       seed: u64, resident: Option<Resident>)
                       -> Result<InferenceServer> {
        let artifact =
            super::artifact_name(model, "infer", &lowered.bucket);
        let workload = super::pack_workload(ds, &lowered.plan,
                                            &lowered.bucket)?;
        Self::spawn(artifacts_dir, &artifact, &workload, &lowered.plan,
                    &lowered.bucket, policy, seed, resident)
    }

    /// Spawn the batcher thread and block until its backend is ready.
    /// `workload` supplies the resident graph tensors; params are
    /// initialized from `seed` (a full deployment would load a
    /// checkpoint). When the PJRT runtime or the artifact is
    /// unavailable, the worker serves on the host reference executor
    /// instead of failing.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(artifacts_dir: impl Into<PathBuf>, artifact: &str,
                 workload: &PackedWorkload, plan: &ExecutionPlan,
                 bucket: &BucketSpec, policy: BatchPolicy, seed: u64,
                 resident: Option<Resident>) -> Result<InferenceServer> {
        let dir = artifacts_dir.into();
        let artifact = artifact.to_string();
        // Host-side state crossing into the worker thread (all Send).
        let h0 = workload
            .get("h0")
            .ok_or_else(|| anyhow!("workload missing h0"))?
            .as_f32()?
            .to_vec();
        let statics: Vec<(String, HostTensor)> = workload
            .names()
            .filter(|n| *n != "h0")
            .map(|n| (n.to_string(), workload.get(n).unwrap().clone()))
            .collect();
        let plan = Arc::new(plan.clone());
        let bucket = bucket.clone();

        let (tx, rx) = sync_channel::<ServerMsg>(4096);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let epoch = Arc::new(AtomicU64::new(0));
        let epoch_worker = epoch.clone();
        let handle = std::thread::spawn(move || {
            let setup = Worker::setup(&dir, &artifact, statics, h0,
                                      plan, &bucket, seed,
                                      epoch_worker);
            match setup {
                Ok(mut w) => {
                    let _ = ready_tx.send(Ok(()));
                    w.batcher_loop(rx, policy, resident)
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    ServeOutcome { stats: ServeStats::default(),
                                   resident: None }
                }
            }
        });
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(InferenceServer { tx, handle, epoch }),
            Ok(Err(e)) => {
                let _ = handle.join();
                Err(e)
            }
            Err(_) => {
                let _ = handle.join();
                Err(anyhow!("server thread died during setup"))
            }
        }
    }

    /// Queue handle: send [`ServerMsg::Score`] to score,
    /// [`ServerMsg::Update`] to stream a topology delta.
    pub fn client(&self) -> SyncSender<ServerMsg> {
        self.tx.clone()
    }

    /// The live plan-epoch cell (1 after spawn, +1 per landed hot
    /// swap). Share it with [`crate::net::NetServer`] so the wire
    /// layer can report the serving epoch without queueing.
    pub fn epoch_cell(&self) -> Arc<AtomicU64> {
        self.epoch.clone()
    }

    /// Current plan epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Close the queue and collect final stats.
    pub fn shutdown(self) -> ServeStats {
        self.shutdown_outcome().stats
    }

    /// Close the queue and collect stats plus the resident pair (the
    /// serving-path cache contract is asserted against its session).
    pub fn shutdown_outcome(self) -> ServeOutcome {
        drop(self.tx);
        self.handle.join().unwrap_or_else(|_| ServeOutcome {
            stats: ServeStats::default(),
            resident: None,
        })
    }
}

/// Application order for a pending update batch: edge deltas are
/// grouped by the destination node's shard within each
/// `NodeAdd`-delimited segment, preserving arrival order inside every
/// group (stable). `NodeAdd`s are barriers — an edge delta referencing
/// a node id minted by an earlier `NodeAdd` must stay on its side —
/// and two deltas on the same edge share a destination, hence a group,
/// so the reorder can never change delta semantics. Returns a
/// permutation of indices into `deltas`.
pub fn coalesce_order(deltas: &[GraphDelta],
                      shard_of: impl Fn(u32) -> u32) -> Vec<usize> {
    let mut keys: Vec<(u32, u32, usize)> =
        Vec::with_capacity(deltas.len());
    let mut seg = 0u32;
    for (i, d) in deltas.iter().enumerate() {
        match d {
            GraphDelta::NodeAdd => {
                keys.push((seg, u32::MAX, i));
                seg += 1;
            }
            GraphDelta::EdgeInsert { dst, .. }
            | GraphDelta::EdgeDelete { dst, .. } => {
                keys.push((seg, shard_of(*dst), i));
            }
        }
    }
    keys.sort_unstable(); // arrival index breaks ties => stable
    keys.into_iter().map(|(_, _, i)| i).collect()
}

// Nearest-rank percentile semantics moved to
// `obs::metrics::percentile_exact` (the exact reference) and
// `obs::metrics::Histogram::percentile_ns` (the bounded serving-path
// estimator, within a documented ≤ 2% relative bucket error).

/// Re-derive the resident permuted `h0` under a new plan's
/// permutation: row `old.inv_perm[v]` moves to `new.inv_perm[v]`.
/// Nodes the old plan did not cover (post-`NodeAdd`) start as zero
/// rows until a client scores or updates them.
fn repermute_h0(old: &ExecutionPlan, new: &ExecutionPlan, h0: &[f32],
                f: usize) -> Vec<f32> {
    let mut out = vec![0f32; new.n_pad * f];
    for v in 0..old.n.min(new.n) {
        let o = old.inv_perm[v] as usize;
        let n = new.inv_perm[v] as usize;
        out[n * f..(n + 1) * f].copy_from_slice(&h0[o * f..(o + 1) * f]);
    }
    out
}

fn is_plan_tensor(name: &str) -> bool {
    name == "deg" || name.starts_with("lvl_") || name.starts_with("band")
}

/// Thread-confined XLA state (handles are not `Send`; built and used
/// only on the batcher thread).
struct XlaState {
    runtime: Runtime,
    exe: Arc<Executable>,
    static_slots: Vec<(usize, xla::PjRtBuffer)>,
    h0_index: usize,
    /// Host copies of the params, artifact order — re-uploaded when a
    /// swap recompiles against a different bucket artifact.
    params: Vec<HostTensor>,
    /// `"<model>_infer_"` prefix for matching-artifact lookup on swap
    /// (empty when the artifact name has no such form).
    prefix: String,
}

/// Host reference executor: the same 2-layer GCN the `gcn_infer_*`
/// artifacts compute (model.py `gcn_forward`), run through the plan's
/// level/band tensors in f32 on the batcher thread.
struct RefState {
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
}

enum Backend {
    Xla(XlaState),
    Reference(RefState),
    /// Test-only: every execute fails (exercises the error-reply path).
    #[cfg(test)]
    Broken,
}

impl Backend {
    fn reference(f_in: usize, hidden: usize, classes: usize,
                 seed: u64) -> Backend {
        let spec = |name: &str, shape: Vec<usize>| TensorSpec {
            name: name.to_string(),
            shape,
            dtype: "f32".to_string(),
        };
        let specs = [
            spec("w1", vec![f_in, hidden]),
            spec("b1", vec![hidden]),
            spec("w2", vec![hidden, classes]),
            spec("b2", vec![classes]),
        ];
        let mut params = init_params(&specs, seed).into_iter();
        let mut take = || -> Vec<f32> {
            params.next().expect("four params")
                .as_f32().expect("f32 param").to_vec()
        };
        Backend::Reference(RefState {
            w1: take(),
            b1: take(),
            w2: take(),
            b2: take(),
        })
    }
}

/// Restart budget for the batcher supervision loop: a worker that
/// panics this many times shuts down instead of spinning (each
/// restart already flight-recorded its panic for diagnosis).
const MAX_WORKER_RESTARTS: usize = 3;

/// Outcome of one supervised serving round.
enum Round {
    Continue,
    Shutdown,
}

/// Best-effort text of a caught panic payload (panics carry `&str`
/// or `String` in practice; anything else is reported opaquely).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The batcher thread's serving state.
struct Worker {
    backend: Backend,
    /// The plan currently being served (validation bound, permutation,
    /// level/band tensors). Replaced by a hot swap.
    plan: Arc<ExecutionPlan>,
    /// Resident features, permuted by `plan`, `[n_pad * f_in]`.
    h0: Vec<f32>,
    f_in: usize,
    classes: usize,
    hidden: usize,
    /// The served plan is the session's memoized plan (skip re-plan
    /// checks until a delta bumps the topology version).
    served_session_plan: bool,
    /// Plan epoch (see [`ScoreOk::epoch`]): written only by this
    /// worker (+1 per landed swap), shared so the wire front end
    /// can stamp diagnostics without a queue round trip.
    epoch: Arc<AtomicU64>,
}

impl Worker {
    fn setup(dir: &PathBuf, artifact: &str,
             statics: Vec<(String, HostTensor)>, h0: Vec<f32>,
             plan: Arc<ExecutionPlan>, bucket: &BucketSpec,
             seed: u64, epoch: Arc<AtomicU64>) -> Result<Worker> {
        // Fall back to the reference executor only when the runtime
        // itself is unavailable (no manifest / stubbed PJRT client).
        // Once a runtime opens, artifact problems — wrong kind,
        // missing tensors, corrupt spec — are configuration errors
        // and must fail spawn loudly, not silently serve a
        // random-parameter reference model.
        let backend = match Runtime::open(dir) {
            Ok(runtime) => {
                Self::xla_setup(runtime, artifact, &statics, seed)?
            }
            Err(e) => {
                crate::obs_warn!("[serve] PJRT backend unavailable \
                                  ({e:#}); serving on the host \
                                  reference executor");
                Backend::reference(bucket.f_in, bucket.hidden,
                                   bucket.classes, seed)
            }
        };
        epoch.store(1, Ordering::Release);
        Ok(Worker {
            backend,
            plan,
            h0,
            f_in: bucket.f_in,
            classes: bucket.classes,
            hidden: bucket.hidden,
            served_session_plan: false,
            epoch,
        })
    }

    fn xla_setup(runtime: Runtime, artifact: &str,
                 statics: &[(String, HostTensor)],
                 seed: u64) -> Result<Backend> {
        let exe = runtime.compile(artifact)?;
        if exe.spec.kind != "infer" {
            bail!("{artifact} is not an infer artifact");
        }
        let param_specs: Vec<TensorSpec> = exe.spec.inputs.iter()
            .filter(|s| s.name != "h0" && !is_plan_tensor(&s.name))
            .cloned().collect();
        let params = init_params(&param_specs, seed);

        let mut static_slots = Vec::new();
        let mut h0_index = None;
        let mut pi = 0usize;
        for (i, s) in exe.spec.inputs.iter().enumerate() {
            if s.name == "h0" {
                h0_index = Some(i);
            } else if is_plan_tensor(&s.name) {
                let t = statics.iter().find(|(n, _)| *n == s.name)
                    .map(|(_, t)| t)
                    .ok_or_else(|| anyhow!("workload missing {:?}",
                                           s.name))?;
                static_slots.push((i, runtime.upload(t)?));
            } else {
                static_slots.push((i, runtime.upload(&params[pi])?));
                pi += 1;
            }
        }
        let h0_index =
            h0_index.ok_or_else(|| anyhow!("artifact lacks h0 input"))?;
        let prefix = artifact.find("_infer_")
            .map(|p| artifact[..p + "_infer_".len()].to_string())
            .unwrap_or_default();
        Ok(Backend::Xla(XlaState { runtime, exe, static_slots,
                                   h0_index, params, prefix }))
    }

    /// Receipt-time validation against the *served* plan. The epoch
    /// pin is checked first: a stale-pinned request learns about the
    /// swap even when its other fields would also have been invalid
    /// under the plan it thinks it is reading.
    fn validate(&self, r: &ScoreRequest) -> Option<ScoreReject> {
        if let Some(pinned) = r.pin_epoch {
            let current = self.epoch.load(Ordering::Acquire);
            if pinned != current {
                return Some(ScoreReject::EpochMismatch {
                    pinned,
                    current,
                });
            }
        }
        if (r.node as usize) >= self.plan.n {
            return Some(ScoreReject::NodeOutOfRange {
                node: r.node,
                n: self.plan.n,
            });
        }
        if !r.features.is_empty() && r.features.len() != self.f_in {
            return Some(ScoreReject::FeatureLen {
                got: r.features.len(),
                want: self.f_in,
            });
        }
        None
    }

    fn reject(&self, r: ScoreRequest, reject: ScoreReject,
              c: &mut Counters) {
        c.rejected.inc();
        let _ = r.reply.send(ScoreResponse::Err(ScoreError {
            node: r.node,
            reject,
            latency: r.submitted.elapsed(),
            epoch: self.epoch.load(Ordering::Acquire),
        }));
    }

    /// Apply the buffered updates, coalesced by shard (see
    /// [`coalesce_order`]), to both engine and session, replying to
    /// each; then run the drift/swap check.
    fn flush_updates(&mut self, resident: &mut Option<Resident>,
                     pending: &mut Vec<UpdateRequest>,
                     c: &mut Counters) {
        if pending.is_empty() {
            return;
        }
        let _sp = crate::obs_span!("serve.flush", pending.len());
        let tr = Instant::now();
        let deltas: Vec<GraphDelta> =
            pending.iter().map(|u| u.delta).collect();
        // Journal-then-ack (DESIGN.md §14): the whole coalesced
        // batch must be durable before any of it is applied or
        // acknowledged. A failed WAL commit nacks the batch by
        // dropping every reply sender — the wire front end surfaces
        // the closed channel as an Internal error — and applies
        // nothing, so the graph and the WAL stay at the same durable
        // point together.
        if let Some(dur) = resident.as_mut()
            .and_then(|r| r.durability.as_mut())
        {
            if let Err(e) = dur.journal(&deltas) {
                crate::obs_error!("[serve] WAL commit failed; \
                                   nacking {} update(s): {e}",
                                  deltas.len());
                c.wal_nacks.inc();
                obs::flight::dump("wal-commit-failed", &c.registry);
                pending.clear();
                c.t_repair.record(tr.elapsed());
                return;
            }
        }
        let order = match resident.as_ref() {
            Some(res) => coalesce_order(&deltas, |v| {
                res.session.shard_of_checked(v).unwrap_or(u32::MAX)
            }),
            None => (0..deltas.len()).collect(),
        };
        let mut reqs: Vec<Option<UpdateRequest>> =
            pending.drain(..).map(Some).collect();
        for i in order {
            let req = reqs[i].take().expect("order is a permutation");
            let resp = match resident.as_mut() {
                Some(res) => {
                    let rep = res.engine.apply(req.delta);
                    res.session.apply(req.delta);
                    UpdateResponse {
                        seq: rep.seq,
                        outcome: rep.outcome,
                        rebuild: rep.rebuild,
                        cost_core: rep.cost_core,
                        latency: req.submitted.elapsed(),
                    }
                }
                None => UpdateResponse {
                    seq: 0,
                    outcome: ApplyOutcome::NoOp,
                    rebuild: RebuildEvent::None,
                    cost_core: 0,
                    latency: req.submitted.elapsed(),
                },
            };
            c.updates.inc();
            if let Some(tx) = req.reply {
                let _ = tx.send(resp);
            }
        }
        c.update_batches.inc();
        // Repair bucket = the coalesced apply loop (per-delta local
        // repair inside `engine.apply`); the swap check accounts to
        // the plan bucket separately.
        c.t_repair.record(tr.elapsed());
        self.maybe_swap(resident, c, false);
    }

    /// Drift check + session-fed hot swap. The dirty-shard re-plan
    /// runs synchronously here — it is the cheap per-shard unit of
    /// work the cache was built for, not a whole-graph search.
    /// `force` bypasses the drift-due check (recovery resume serves
    /// the recovered plan before the first batch); the verify gate
    /// and the swap protocol itself are never bypassed.
    fn maybe_swap(&mut self, resident: &mut Option<Resident>,
                  c: &mut Counters, force: bool) {
        let Some(res) = resident.as_mut() else { return };
        if !res.swap.swap_plans || res.engine.rebuild_in_flight() {
            return;
        }
        let due = force || res.engine.drift() > res.threshold;
        crate::obs_event!("serve.drift_check", due as u64);
        if !due {
            return;
        }
        // Nothing changed since the plan we already serve: skip.
        if !force && self.served_session_plan
            && res.session.plan_current()
        {
            return;
        }
        // Span the whole swap attempt; cancelled on every path that
        // leaves the serving plan untouched, so a `serve.plan_swap`
        // span in a trace means a swap actually landed (and is always
        // preceded by a due `serve.drift_check` instant).
        let mut sp = crate::obs_span!("serve.plan_swap");
        let tq = Instant::now();
        // Price the re-plan's shard searches with the live
        // calibration (a positive (alpha, beta) provably cannot
        // change the search result — see `SearchConfig::alpha` —
        // so the session's plan cache stays valid across updates).
        let (alpha, beta) = c.cost.alpha_beta();
        res.session.set_cost_weights(alpha, beta);
        let (hag, plan) = res.session.plan();
        if Arc::ptr_eq(&plan, &self.plan) {
            self.served_session_plan = true;
            sp.cancel();
            return;
        }
        if *plan == *self.plan {
            // Tensor-identical (e.g. the initial lower's plan under a
            // different Arc): adopt the handle, no serving-state churn.
            self.plan = plan;
            self.served_session_plan = true;
            sp.cancel();
            return;
        }
        // haglint gate: a corrupt re-plan must never become the
        // serving state (debug: always; release: REPRO_VERIFY=1).
        if crate::analysis::verify_enabled() {
            let g = res.session.graph();
            if !crate::analysis::gate_plan(&c.registry,
                                           "serve.swap_verify", &g,
                                           &hag, &plan, None)
            {
                c.swaps_skipped.inc();
                sp.cancel();
                return;
            }
        }
        // Install into the engine only once the serving state actually
        // swapped: an install resets the drift tracker, and resetting
        // it while still serving the old plan would stop tracking that
        // plan's (unbounded) staleness. The `serve.swap` fault point
        // models the whole protocol failing (upload error, torn
        // rebind): it must roll back to the old plan cleanly.
        let attempt = match crate::fault::point("serve.swap") {
            Ok(()) => self.swap_to(plan),
            Err(e) => Err(anyhow::Error::new(e)),
        };
        match attempt {
            Ok(true) => {
                res.engine.install_hag(&hag);
                c.plan_swaps.inc();
                self.served_session_plan = true;
                // Publish the new epoch only after the serving state
                // swapped: every response computed from here on
                // carries it, and no earlier response could have.
                let e = self.epoch.fetch_add(1, Ordering::AcqRel) + 1;
                c.registry.gauge("serve.epoch").set(e as i64);
                crate::obs_event!("serve.epoch", e);
                // The served plan changed: refresh the predicted
                // attribution terms it will be audited against, and
                // re-apportion the measured tallies to the new
                // shard shares.
                obs::cost::record_plan_terms(
                    &c.registry, &hag, res.session.shard_terms());
                obs::cost::record_shard_meas_terms(
                    &c.registry, c.meas_aggs.get(),
                    c.meas_transfers.get(),
                    res.session.shard_terms());
                // Audit the pred_* gauges the attribution report
                // will divide by, right after they were recorded.
                if crate::analysis::verify_enabled() {
                    crate::analysis::gate_cost_gauges(
                        &c.registry, "serve.cost_gauges", &hag,
                        res.session.shard_terms());
                }
                // Plan-epoch boundary: cut a snapshot on the
                // configured cadence. Best effort — the WAL alone is
                // always sufficient; a failure is counted and
                // serving continues (conformance e19).
                if let Some(dur) = res.durability.as_mut() {
                    if dur.maybe_snapshot(e, res.session.graph(),
                                          (*hag).clone())
                    {
                        c.registry
                            .counter("durability.snapshots")
                            .inc();
                    }
                }
            }
            Ok(false) => {
                c.swaps_skipped.inc();
                sp.cancel();
            }
            Err(e) => {
                crate::obs_warn!("[serve] plan swap failed: {e:#}");
                c.swaps_skipped.inc();
                sp.cancel();
                obs::flight::dump("plan-swap-failed", &c.registry);
            }
        }
        // Plan bucket = re-plan + swap protocol, attributed only when
        // a due drift check actually did the work.
        c.t_plan.record(tq.elapsed());
    }

    /// The swap protocol: re-derive `h0` under the new permutation and
    /// the plan-derived statics from the new plan, without restarting
    /// the thread. Reference backend: tensors only. XLA backend: reuse
    /// the executable when the plan still fits its bucket (re-upload
    /// `deg`/`lvl_*`/`band*`), else recompile against a matching
    /// bucket artifact when the manifest has one; `Ok(false)` = no
    /// compatible artifact, keep serving the old plan.
    fn swap_to(&mut self, plan: Arc<ExecutionPlan>) -> Result<bool> {
        let h0_new = repermute_h0(&self.plan, &plan, &self.h0,
                                  self.f_in);
        match &mut self.backend {
            Backend::Reference(_) => {}
            Backend::Xla(state) => {
                if state.exe.spec.bucket.fits(&plan) {
                    // Upload every replacement before touching
                    // static_slots: a mid-loop failure must not leave
                    // the executable bound to a mix of old- and
                    // new-plan tensors.
                    let tensors = plan_tensors(&plan);
                    let mut fresh = Vec::new();
                    for (pos, (i, _)) in
                        state.static_slots.iter().enumerate()
                    {
                        let spec = &state.exe.spec.inputs[*i];
                        if !is_plan_tensor(&spec.name) {
                            continue;
                        }
                        let t = tensors.iter()
                            .find(|(n, _)| *n == spec.name)
                            .map(|(_, t)| t)
                            .ok_or_else(|| anyhow!(
                                "swapped plan lacks tensor {:?}",
                                spec.name))?;
                        if t.shape() != spec.shape.as_slice() {
                            bail!("tensor {:?}: plan shape {:?} != \
                                   artifact shape {:?}",
                                  spec.name, t.shape(), spec.shape);
                        }
                        fresh.push((pos, state.runtime.upload(t)?));
                    }
                    for (pos, buf) in fresh {
                        state.static_slots[pos].1 = buf;
                    }
                } else {
                    let name = find_matching_artifact(
                        &state.runtime, &state.prefix, &plan,
                        &state.exe.spec.name);
                    let Some(name) = name else {
                        return Ok(false);
                    };
                    rebind_artifact(state, &name, &plan)?;
                }
            }
            #[cfg(test)]
            Backend::Broken => return Ok(false),
        }
        self.h0 = h0_new;
        self.plan = plan;
        Ok(true)
    }

    fn batcher_loop(&mut self, rx: Receiver<ServerMsg>,
                    policy: BatchPolicy,
                    mut resident: Option<Resident>) -> ServeOutcome {
        let mut c = Counters::default();
        c.registry.gauge("serve.epoch")
            .set(self.epoch.load(Ordering::Acquire) as i64);
        let mut pending: Vec<UpdateRequest> = Vec::new();
        let max_pending = resident.as_ref()
            .map_or(64, |r| r.swap.max_pending).max(1);
        // Attribution at serve start: record the resident plan's
        // Definition-2 terms, and hand the engine the live
        // calibration so its drift checks price in measured units as
        // soon as the model warms up.
        if let Some(res) = resident.as_mut() {
            res.engine.set_cost_model(c.cost.clone());
            obs::cost::record_plan_terms(&c.registry,
                                         &res.engine.to_hag(),
                                         res.session.shard_terms());
        }
        // Recovery resume: serve the recovered session plan from the
        // first batch onward instead of waiting for the next due
        // drift check (the lowered plan predates the replayed WAL).
        if resident.as_ref().is_some_and(|r| r.force_initial_swap) {
            self.maybe_swap(&mut resident, &mut c, true);
        }
        let t_start = Instant::now();
        // Bounded-restart supervision (DESIGN.md §14): each serving
        // round runs under `catch_unwind`. A panic drops that
        // round's in-flight reply channels (clients observe them as
        // closed — an explicit failure, not a hang), flight-records
        // the payload, and the next round resumes from the last good
        // serving plan. The restart budget keeps a deterministically
        // crashing worker from spinning; exhausting it exits the
        // loop cleanly, which closes the queue and turns all
        // subsequent traffic into "batcher is gone" errors at the
        // front end.
        let mut restarts = 0usize;
        loop {
            let round = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    self.serve_round(&rx, &policy, max_pending,
                                     &mut resident, &mut pending,
                                     &mut c)
                }));
            match round {
                Ok(Round::Continue) => {}
                Ok(Round::Shutdown) => break,
                Err(payload) => {
                    restarts += 1;
                    c.worker_restarts.inc();
                    crate::obs_error!(
                        "[serve] worker panicked ({}); restart \
                         {restarts}/{MAX_WORKER_RESTARTS}",
                        panic_message(payload.as_ref()));
                    obs::flight::dump("worker-panic", &c.registry);
                    if restarts >= MAX_WORKER_RESTARTS {
                        crate::obs_error!(
                            "[serve] restart budget exhausted; \
                             shutting down");
                        break;
                    }
                }
            }
        }
        // Drain leftovers, land in-flight rebuilds, and run the
        // serving-path plan contract check.
        self.flush_updates(&mut resident, &mut pending, &mut c);
        let mut plan_matches_fresh = None;
        if let Some(res) = resident.as_mut() {
            res.engine.finish_rebuild();
            if res.swap.swap_plans {
                let (hag_c, plan_c) = res.session.plan();
                let (hag_f, plan_f) = res.session.plan_fresh();
                plan_matches_fresh =
                    Some(*hag_c == hag_f && *plan_c == plan_f);
            }
        }
        let stats = c.finalize(t_start.elapsed(), resident.as_ref(),
                               plan_matches_fresh);
        ServeOutcome { stats, resident }
    }

    /// One serving round: collect a batch, flush coalesced updates,
    /// execute, reply. Extracted from the serve loop so the
    /// supervisor can `catch_unwind` each round independently.
    fn serve_round(&mut self, rx: &Receiver<ServerMsg>,
                   policy: &BatchPolicy, max_pending: usize,
                   resident: &mut Option<Resident>,
                   pending: &mut Vec<UpdateRequest>,
                   c: &mut Counters) -> Round {
        {
            // Collect a batch: wait for the first valid scoring
            // request. With updates pending, wait at most max_wait so
            // their coalesced flush (and replies) stay bounded; with
            // nothing buffered, block — an idle server must not
            // busy-poll.
            let first = loop {
                let msg = if pending.is_empty() {
                    rx.recv()
                        .map_err(|_| RecvTimeoutError::Disconnected)
                } else {
                    rx.recv_timeout(policy.max_wait)
                };
                match msg {
                    Ok(ServerMsg::Score(r)) => {
                        match self.validate(&r) {
                            Some(why) => self.reject(r, why, c),
                            None => break r,
                        }
                    }
                    Ok(ServerMsg::Update(u)) => {
                        pending.push(u);
                        if pending.len() >= max_pending {
                            self.flush_updates(resident, pending, c);
                        }
                    }
                    Ok(ServerMsg::Stats(s)) => {
                        publish_resident_stats(resident, c);
                        let _ = s.reply.send(c.registry.snapshot());
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        self.flush_updates(resident, pending, c);
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        return Round::Shutdown;
                    }
                }
            };
            let mut batch = vec![first];
            let deadline = Instant::now() + policy.max_wait;
            while batch.len() < policy.max_batch {
                let left =
                    deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(ServerMsg::Score(r)) => match self.validate(&r) {
                        Some(why) => self.reject(r, why, c),
                        None => batch.push(r),
                    },
                    // Buffer only — updates never stretch the
                    // latency-critical batch window; they flush next.
                    Ok(ServerMsg::Update(u)) => pending.push(u),
                    Ok(ServerMsg::Stats(s)) => {
                        publish_resident_stats(resident, c);
                        let _ = s.reply.send(c.registry.snapshot());
                    }
                    Err(RecvTimeoutError::Timeout)
                    | Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            // Between batches: land any finished background
            // re-search, then the coalesced flush (+ swap check).
            if let Some(res) = resident.as_mut() {
                res.engine.poll_rebuild();
            }
            self.flush_updates(resident, pending, c);
            // Apply feature updates to the resident (permuted) h0.
            // Safe: nodes were validated and n only ever grows.
            let tp = Instant::now();
            for r in &batch {
                if !r.features.is_empty() {
                    let new = self.plan.inv_perm[r.node as usize]
                        as usize;
                    self.h0[new * self.f_in..(new + 1) * self.f_in]
                        .copy_from_slice(&r.features);
                }
            }
            c.t_pack.record(tp.elapsed());
            let sp = crate::obs_span!("serve.batch", batch.len());
            let te = Instant::now();
            // `batcher.exec` models the execute itself failing (or,
            // with the panic action, the worker dying mid-batch —
            // which the supervision loop above must absorb).
            let result = match crate::fault::point("batcher.exec") {
                Ok(()) => self.run_batch(c),
                Err(e) => Err(anyhow::Error::new(e)),
            };
            // Land the span before handling the result: a failing
            // batch's flight record must already carry it.
            drop(sp);
            let exec_wall = te.elapsed();
            c.exec.record(exec_wall);
            c.t_exec.record(exec_wall);
            c.batches.inc();
            match result {
                Ok(logits) => {
                    let epoch = self.epoch.load(Ordering::Acquire);
                    for r in batch {
                        c.requests.inc();
                        let new = self.plan.inv_perm[r.node as usize]
                            as usize;
                        let row = logits[new * self.classes
                            ..(new + 1) * self.classes].to_vec();
                        let latency = r.submitted.elapsed();
                        c.lat.record(latency);
                        let _ = r.reply.send(ScoreResponse::Ok(
                            ScoreOk { node: r.node, logits: row,
                                      latency, epoch }));
                    }
                }
                Err(e) => {
                    // Explicit error outcome per request: clients can
                    // tell "server rejected" from "server died".
                    crate::obs_error!("[serve] batch failed: {e:#}");
                    crate::obs_event!("serve.exec_failed");
                    c.exec_failures.inc();
                    obs::flight::dump("batch-exec-failed", &c.registry);
                    let message = format!("{e:#}");
                    for r in batch {
                        self.reject_failed(r, &message, c);
                    }
                }
            }
        }
        Round::Continue
    }

    fn reject_failed(&self, r: ScoreRequest, message: &str,
                     c: &mut Counters) {
        c.failed.inc();
        let _ = r.reply.send(ScoreResponse::Err(ScoreError {
            node: r.node,
            reject: ScoreReject::ExecFailed {
                message: message.to_string(),
            },
            latency: r.submitted.elapsed(),
            epoch: self.epoch.load(Ordering::Acquire),
        }));
    }

    fn run_batch(&self, c: &Counters) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Xla(state) => self.run_xla(state),
            Backend::Reference(state) => {
                Ok(self.run_reference(state, c))
            }
            #[cfg(test)]
            Backend::Broken => Err(anyhow!("broken test backend")),
        }
    }

    fn run_xla(&self, state: &XlaState) -> Result<Vec<f32>> {
        let h0_buf = state.runtime.upload(&HostTensor::f32(
            self.h0.clone(), &[self.plan.n_pad, self.f_in]))?;
        let n_inputs = state.exe.spec.inputs.len();
        let mut slots: Vec<Option<&xla::PjRtBuffer>> =
            vec![None; n_inputs];
        for (i, b) in &state.static_slots {
            slots[*i] = Some(b);
        }
        slots[state.h0_index] = Some(&h0_buf);
        let args: Vec<&xla::PjRtBuffer> = slots
            .into_iter()
            .enumerate()
            .map(|(i, o)| o.ok_or_else(|| anyhow!("input {i} unbound")))
            .collect::<Result<_>>()?;
        let outs = state.runtime.execute(&state.exe, &args)?;
        Ok(outs[0].as_f32()?.to_vec())
    }

    /// model.py `gcn_forward` on the host, entirely in permuted space:
    /// `z = (agg(h) + h) / (deg + 1)`, two layers, logits last.
    ///
    /// Cost-model metering (DESIGN.md §11): only the two
    /// `reference_aggregate` passes are timed — the matmuls scale
    /// with weight shapes, not with the plan's aggregation
    /// structure, and folding them in would poison the α̂/β̂ fit.
    /// One `(aggregations, transfers, ns)` sample per batch; on a
    /// fixed plan the samples are collinear and the model's
    /// shared-rate fallback (α̂ == β̂) applies by design.
    fn run_reference(&self, state: &RefState, c: &Counters)
                     -> Vec<f32> {
        let p = &*self.plan;
        let n_pad = p.n_pad;
        let norm: Vec<f32> =
            p.deg.iter().map(|&d| 1.0 / (d + 1.0)).collect();
        let mut agg_ns = 0u64;
        let mut layer_in = |h: &[f32], f: usize| -> Vec<f32> {
            let t0 = Instant::now();
            let a = reference_aggregate(p, h, f);
            agg_ns += t0.elapsed().as_nanos() as u64;
            let mut z = vec![0f32; n_pad * f];
            for v in 0..n_pad {
                for k in 0..f {
                    z[v * f + k] = (a[v * f + k] + h[v * f + k])
                        * norm[v];
                }
            }
            z
        };
        let z1 = layer_in(&self.h0, self.f_in);
        let mut h1 = matmul_bias(&z1, &state.w1, &state.b1, n_pad,
                                 self.f_in, self.hidden);
        for x in h1.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
        let z2 = layer_in(&h1, self.hidden);
        let out = matmul_bias(&z2, &state.w2, &state.b2, n_pad,
                              self.hidden, self.classes);
        let (combine, scatter) = plan_op_counts(p);
        let width = (self.f_in + self.hidden) as u64;
        let aggs = (combine + scatter) * width;
        let transfers = (2 * combine + scatter) * width;
        c.meas_aggs.add(aggs);
        c.meas_transfers.add(transfers);
        c.cost.record_sample(aggs, transfers, agg_ns);
        out
    }
}

/// Element-scaled op counts of one `reference_aggregate` pass:
/// `(combine_rows, scatter_rows)`. Combine rows are the padded level
/// slots (`levels * l_pad` — each does one binary add over two
/// operand reads: the measured counterpart of an aggregation node's
/// `+1` aggregation / `+2` transfers in Definition 2); scatter rows
/// are the padded band entries (`Σ nb * nnzb` — one add over one
/// operand read, the counterpart of a final in-edge). Both include
/// the padding the predicted terms exclude, which is exactly the gap
/// the audit attributes. Width-independent; multiply by the feature
/// width for element counts.
pub fn plan_op_counts(plan: &ExecutionPlan) -> (u64, u64) {
    let combine = (plan.levels * plan.l_pad) as u64;
    let scatter = plan.bands.iter()
        .map(|&(nb, nnzb)| (nb * nnzb) as u64)
        .sum();
    (combine, scatter)
}

/// One dataset's measured-vs-predicted cost audit (`repro
/// cost-audit`, `benches/cost_model.rs`).
#[derive(Debug, Clone)]
pub struct CostProbe {
    pub name: String,
    pub n: usize,
    pub e: usize,
    /// Definition-2 terms of the served HAG (padding-free).
    pub pred_aggregations: usize,
    pub pred_transfers: usize,
    /// Width-independent executed rows per aggregate pass
    /// (padding included): `combine + scatter` aggregation rows,
    /// `2*combine + scatter` transfer rows.
    pub plan_agg_rows: u64,
    pub plan_transfer_rows: u64,
    /// Element-scaled tallies over all `batches` executions.
    pub meas_aggregations: u64,
    pub meas_transfers: u64,
    pub batches: usize,
    /// Batch execute wall time (the whole reference forward).
    pub exec: crate::obs::HistSummary,
}

impl CostProbe {
    /// Padding overhead the audit attributes: executed aggregation
    /// rows over the predicted (ideal) Definition-2 count.
    pub fn agg_overhead(&self) -> f64 {
        self.plan_agg_rows as f64
            / (self.pred_aggregations as f64).max(1.0)
    }

    pub fn transfer_overhead(&self) -> f64 {
        self.plan_transfer_rows as f64
            / (self.pred_transfers as f64).max(1.0)
    }
}

/// Run `batches` reference-executor forwards over `g` under the
/// default lowering spec, metering every batch into `model` (shared
/// across probes so one calibration spans the sweep), and report the
/// predicted terms next to the measured tallies. This is the
/// host-side audit loop behind `repro cost-audit` and the
/// `cost_model` bench — the same executor and metering path the
/// serving batcher uses, without threads or queues.
pub fn cost_probe(name: &str, g: &Graph, f_in: usize, hidden: usize,
                  classes: usize, batches: usize,
                  model: &Arc<CostModel>) -> CostProbe {
    let mut session = Session::from_graph(
        g, crate::session::LowerSpec::default());
    let (hag, plan) = session.plan();
    obs::cost::record_plan_terms(MetricsRegistry::global(), &hag,
                                 session.shard_terms());
    let mut h0 = vec![0f32; plan.n_pad * f_in];
    for (i, x) in h0.iter_mut().enumerate() {
        // deterministic non-zero features; values are irrelevant to
        // the metering, but all-zero rows would let `matmul_bias`
        // short-circuit and understate the (untimed) matmul share
        *x = ((i % 13) as f32 - 6.0) * 0.1;
    }
    let worker = Worker {
        backend: Backend::reference(f_in, hidden, classes, 7),
        plan: plan.clone(),
        h0,
        f_in,
        classes,
        hidden,
        served_session_plan: false,
        epoch: Arc::new(AtomicU64::new(1)),
    };
    let c = Counters::with_model(Arc::new(MetricsRegistry::new()),
                                 model.clone());
    for _ in 0..batches {
        let t0 = Instant::now();
        let _ = worker.run_batch(&c);
        c.t_exec.record(t0.elapsed());
    }
    let (combine, scatter) = plan_op_counts(&plan);
    CostProbe {
        name: name.to_string(),
        n: g.n(),
        e: g.e(),
        pred_aggregations: hag.aggregations(),
        pred_transfers: hag.data_transfers(),
        plan_agg_rows: combine + scatter,
        plan_transfer_rows: 2 * combine + scatter,
        meas_aggregations: c.meas_aggs.get(),
        meas_transfers: c.meas_transfers.get(),
        batches,
        exec: c.t_exec.summary(),
    }
}

/// Execute the plan's sum-aggregation (levels then bands) over
/// `[n_pad, f]` row-major activations — the host mirror of
/// model.py `hag_aggregate_sum`.
fn reference_aggregate(plan: &ExecutionPlan, h: &[f32],
                       f: usize) -> Vec<f32> {
    let m = plan.m_pad();
    let mut buf = vec![0f32; m * f];
    buf[..plan.n_pad * f].copy_from_slice(&h[..plan.n_pad * f]);
    for l in 0..plan.levels {
        let base = plan.n_pad + l * plan.l_pad;
        for j in 0..plan.l_pad {
            let li = plan.lvl_left[l * plan.l_pad + j] as usize;
            let ri = plan.lvl_right[l * plan.l_pad + j] as usize;
            for k in 0..f {
                buf[(base + j) * f + k] =
                    buf[li * f + k] + buf[ri * f + k];
            }
        }
    }
    let mut out = vec![0f32; plan.n_pad * f];
    let mut row0 = 0usize;
    for (bi, &(nb, nnzb)) in plan.bands.iter().enumerate() {
        for b in 0..nb {
            for j in 0..nnzb {
                let col =
                    plan.band_cols[bi][b * nnzb + j] as usize;
                let r = plan.band_rows[bi][b * nnzb + j] as usize;
                let dst = (row0 + b * plan.br + r) * f;
                for k in 0..f {
                    out[dst + k] += buf[col * f + k];
                }
            }
        }
        row0 += nb * plan.br;
    }
    out
}

/// `out[n, m] = x[n, k] @ w[k, m] + b[m]`, row-major f32.
fn matmul_bias(x: &[f32], w: &[f32], b: &[f32], n: usize, k: usize,
               m: usize) -> Vec<f32> {
    let mut out = vec![0f32; n * m];
    for i in 0..n {
        let row = &x[i * k..(i + 1) * k];
        let dst = &mut out[i * m..(i + 1) * m];
        dst.copy_from_slice(b);
        for (t, &xv) in row.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[t * m..(t + 1) * m];
            for (d, &wv) in dst.iter_mut().zip(wrow) {
                *d += xv * wv;
            }
        }
    }
    out
}

/// A manifest infer artifact (same model prefix) whose bucket fits the
/// swapped plan — the recompile target when the pinned bucket no
/// longer matches.
fn find_matching_artifact(runtime: &Runtime, prefix: &str,
                          plan: &ExecutionPlan,
                          current: &str) -> Option<String> {
    if prefix.is_empty() {
        return None;
    }
    runtime
        .artifact_names()
        .into_iter()
        .filter(|n| *n != current && n.starts_with(prefix))
        .filter_map(|n| runtime.spec(n).ok())
        .find(|s| s.kind == "infer" && s.bucket.fits(plan))
        .map(|s| s.name.clone())
}

/// Recompile + rebind the XLA state against `artifact` for `plan`:
/// params re-uploaded from their host copies, plan tensors re-derived.
fn rebind_artifact(state: &mut XlaState, artifact: &str,
                   plan: &ExecutionPlan) -> Result<()> {
    let exe = state.runtime.compile(artifact)?;
    if exe.spec.kind != "infer" {
        bail!("{artifact} is not an infer artifact");
    }
    let tensors = plan_tensors(plan);
    let mut static_slots = Vec::new();
    let mut h0_index = None;
    let mut pi = 0usize;
    for (i, s) in exe.spec.inputs.iter().enumerate() {
        if s.name == "h0" {
            h0_index = Some(i);
        } else if is_plan_tensor(&s.name) {
            let t = tensors.iter().find(|(n, _)| *n == s.name)
                .map(|(_, t)| t)
                .ok_or_else(|| anyhow!("swapped plan lacks tensor \
                                        {:?}", s.name))?;
            if t.shape() != s.shape.as_slice() {
                bail!("tensor {:?}: plan shape {:?} != artifact shape \
                       {:?}", s.name, t.shape(), s.shape);
            }
            static_slots.push((i, state.runtime.upload(t)?));
        } else {
            let t = state.params.get(pi).ok_or_else(|| {
                anyhow!("artifact {artifact} wants more params than \
                         {:?} had", state.exe.spec.name)
            })?;
            if t.shape() != s.shape.as_slice() {
                bail!("param {:?} shape {:?} != {:?} across buckets",
                      s.name, t.shape(), s.shape);
            }
            static_slots.push((i, state.runtime.upload(t)?));
            pi += 1;
        }
    }
    state.h0_index =
        h0_index.ok_or_else(|| anyhow!("artifact lacks h0 input"))?;
    state.static_slots = static_slots;
    state.exe = exe;
    Ok(())
}

/// Batcher-loop metrics: registry-backed handles (one relaxed atomic
/// op per event), folded into [`ServeStats`] at shutdown. The
/// latency/exec histograms are bounded — a long-running server no
/// longer grows per-request memory — and every value here is
/// readable live through [`ServerMsg::Stats`].
struct Counters {
    registry: Arc<MetricsRegistry>,
    requests: Counter,
    rejected: Counter,
    failed: Counter,
    batches: Counter,
    updates: Counter,
    update_batches: Counter,
    plan_swaps: Counter,
    swaps_skipped: Counter,
    exec_failures: Counter,
    worker_restarts: Counter,
    wal_nacks: Counter,
    /// Queue + batch + execute latency per answered request.
    lat: Histogram,
    /// Batch execute wall time.
    exec: Histogram,
    /// Cost-model audit (DESIGN.md §11): per-batch wall-time buckets
    /// (`cost.pack`/`cost.exec`/`cost.repair`/`cost.plan`), measured
    /// Definition-2 tallies from the reference executor, and the
    /// online α̂/β̂ calibration the resident engine prices drift
    /// with.
    t_pack: Histogram,
    t_exec: Histogram,
    t_repair: Histogram,
    t_plan: Histogram,
    meas_aggs: Counter,
    meas_transfers: Counter,
    cost: Arc<CostModel>,
}

impl Default for Counters {
    fn default() -> Counters {
        Counters::new(Arc::new(MetricsRegistry::new()))
    }
}

impl Counters {
    fn new(registry: Arc<MetricsRegistry>) -> Counters {
        Counters::with_model(registry, Arc::new(CostModel::new()))
    }

    /// Share an externally owned model (the cost-audit CLI probe
    /// meters several sweeps into one calibration).
    fn with_model(registry: Arc<MetricsRegistry>,
                  cost: Arc<CostModel>) -> Counters {
        Counters {
            requests: registry.counter("serve.requests"),
            rejected: registry.counter("serve.rejected"),
            failed: registry.counter("serve.failed"),
            batches: registry.counter("serve.batches"),
            updates: registry.counter("serve.updates"),
            update_batches: registry.counter("serve.update_batches"),
            plan_swaps: registry.counter("serve.plan_swaps"),
            swaps_skipped: registry.counter("serve.swaps_skipped"),
            exec_failures: registry.counter("serve.exec_failures"),
            worker_restarts:
                registry.counter("serve.worker_restarts"),
            wal_nacks: registry.counter("durability.wal_nacks"),
            lat: registry.histogram("serve.latency"),
            exec: registry.histogram("serve.exec"),
            t_pack: registry.histogram("cost.pack"),
            t_exec: registry.histogram("cost.exec"),
            t_repair: registry.histogram("cost.repair"),
            t_plan: registry.histogram("cost.plan"),
            meas_aggs: registry.counter("cost.meas_aggregations"),
            meas_transfers: registry.counter("cost.meas_transfers"),
            cost,
            registry,
        }
    }

    fn finalize(&self, elapsed: Duration, resident: Option<&Resident>,
                plan_matches_fresh: Option<bool>) -> ServeStats {
        // Final snapshots must carry the calibration gauges even if
        // no Stats request ever landed.
        self.cost.publish(&self.registry);
        let (shard_searches, shard_cache_hits, rebuild_swaps) =
            resident.map_or((0, 0, 0), |r| {
                (r.session.stats().shard_searches,
                 r.session.stats().shard_cache_hits,
                 r.engine.stats().rebuild_swaps)
            });
        let requests = self.requests.get() as usize;
        let failed = self.failed.get() as usize;
        let batches = self.batches.get() as usize;
        let exec = self.exec.summary();
        ServeStats {
            requests,
            rejected: self.rejected.get() as usize,
            failed,
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                (requests + failed) as f64 / batches as f64
            },
            p50_ms: self.lat.percentile_ms(0.5),
            p99_ms: self.lat.percentile_ms(0.99),
            mean_exec_ms: if exec.count == 0 {
                f64::NAN
            } else {
                exec.mean_ns / 1.0e6
            },
            throughput_rps: requests as f64
                / elapsed.as_secs_f64().max(1e-9),
            updates: self.updates.get() as usize,
            update_batches: self.update_batches.get() as usize,
            rebuild_swaps,
            plan_swaps: self.plan_swaps.get() as usize,
            swaps_skipped: self.swaps_skipped.get() as usize,
            exec_failures: self.exec_failures.get() as usize,
            shard_searches,
            shard_cache_hits,
            worker_restarts: self.worker_restarts.get() as usize,
            wal_nacked_batches: self.wal_nacks.get() as usize,
            snapshots_written: resident
                .and_then(|r| r.durability.as_ref())
                .map_or(0, |d| d.snapshots_written() as usize),
            plan_matches_fresh,
        }
    }
}

/// Fold the resident pair's own counters into the server registry as
/// absolute gauges (`session.*`, `incr.*`), so one [`StatsSnapshot`]
/// is a coherent cross-subsystem view. Called on every
/// [`ServerMsg::Stats`]; gauges are set-to-absolute, so republishing
/// is idempotent.
fn publish_resident_stats(resident: &Option<Resident>, c: &Counters) {
    // Calibration gauges first — they exist with or without a
    // resident pair (the reference executor meters every batch).
    c.cost.publish(&c.registry);
    let Some(res) = resident.as_ref() else { return };
    let reg = &c.registry;
    obs::cost::record_shard_meas_terms(reg, c.meas_aggs.get(),
                                       c.meas_transfers.get(),
                                       res.session.shard_terms());
    let s = res.session.stats();
    reg.gauge("session.deltas").set(s.deltas as i64);
    reg.gauge("session.noops").set(s.noops as i64);
    reg.gauge("session.cross_shard_deltas")
        .set(s.cross_shard_deltas as i64);
    reg.gauge("session.plans").set(s.plans as i64);
    reg.gauge("session.plan_cache_hits").set(s.plan_cache_hits as i64);
    reg.gauge("session.shard_searches").set(s.shard_searches as i64);
    reg.gauge("session.shard_cache_hits")
        .set(s.shard_cache_hits as i64);
    let e = res.engine.stats();
    reg.gauge("incr.applied").set(e.applied as i64);
    reg.gauge("incr.noops").set(e.noops as i64);
    reg.gauge("incr.fallbacks").set(e.fallbacks as i64);
    reg.gauge("incr.remerge_passes").set(e.remerge_passes as i64);
    reg.gauge("incr.remerge_merges").set(e.remerge_merges as i64);
    reg.gauge("incr.rebuild_swaps").set(e.rebuild_swaps as i64);
    reg.gauge("incr.installs").set(e.installs as i64);
    if let Some(d) = res.durability.as_ref() {
        reg.gauge("durability.last_seq")
            .set(d.last_durable_seq() as i64);
        reg.gauge("durability.snapshots_written")
            .set(d.snapshots_written() as i64);
        reg.gauge("durability.snapshot_failures")
            .set(d.snapshot_failures() as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::test_graphs::clique_ring;
    use crate::session::LowerSpec;

    fn reference_worker(g: &Graph, f_in: usize, hidden: usize,
                        classes: usize) -> (Worker, Session) {
        let mut s = Session::from_graph(g, LowerSpec::default());
        let (_, plan) = s.plan();
        let h0 = vec![0f32; plan.n_pad * f_in];
        (Worker {
            backend: Backend::reference(f_in, hidden, classes, 7),
            plan,
            h0,
            f_in,
            classes,
            hidden,
            served_session_plan: false,
            epoch: Arc::new(AtomicU64::new(1)),
        }, s)
    }

    fn score(node: u32, features: Vec<f32>)
             -> (ScoreRequest, Receiver<ScoreResponse>) {
        let (tx, rx) = oneshot();
        (ScoreRequest { node, features, reply: tx,
                        submitted: Instant::now(),
                        pin_epoch: None }, rx)
    }

    // Nearest-rank percentile unit tests live with the moved code:
    // `obs::metrics::tests::percentile_exact_is_nearest_rank`.

    #[test]
    fn coalesce_groups_by_shard_with_node_add_barriers() {
        use GraphDelta::*;
        // shard(v) = v % 2
        let deltas = vec![
            EdgeInsert { src: 9, dst: 1 }, // shard 1
            EdgeInsert { src: 9, dst: 2 }, // shard 0
            EdgeDelete { src: 9, dst: 3 }, // shard 1
            NodeAdd,                       // barrier
            EdgeInsert { src: 9, dst: 4 }, // shard 0
            EdgeInsert { src: 9, dst: 5 }, // shard 1
        ];
        let order = coalesce_order(&deltas, |v| v % 2);
        assert_eq!(order, vec![1, 0, 2, 3, 4, 5]);
        // same-dst deltas keep arrival order (same group, stable)
        let same = vec![
            EdgeInsert { src: 0, dst: 7 },
            EdgeDelete { src: 0, dst: 7 },
            EdgeInsert { src: 1, dst: 7 },
        ];
        let order = coalesce_order(&same, |_| 3);
        assert_eq!(order, vec![0, 1, 2]);
        // empty input
        assert!(coalesce_order(&[], |v| v).is_empty());
    }

    #[test]
    fn flush_applies_to_engine_and_session_and_replies() {
        let g = clique_ring(4, 5);
        let mut sess = Session::from_graph(&g, LowerSpec::default());
        let (hag, _) = sess.plan();
        let resident = Resident::new(sess, &g, &hag,
                                     SwapPolicy::default());
        let mut resident = Some(resident);
        let (mut w, _) = reference_worker(&g, 4, 8, 3);
        let (tx, rx) = update_oneshot();
        let mut pending = vec![UpdateRequest {
            delta: GraphDelta::EdgeInsert { src: 0, dst: 7 },
            reply: Some(tx),
            submitted: Instant::now(),
        }];
        let mut c = Counters::default();
        w.flush_updates(&mut resident, &mut pending, &mut c);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.seq, 1);
        assert_eq!(resp.outcome, ApplyOutcome::Inserted);
        let res = resident.as_ref().unwrap();
        assert_eq!(res.engine.e(), g.e() + 1);
        assert_eq!(res.session.e(), g.e() + 1);
        assert_eq!(resp.cost_core, res.engine.cost_core());
        assert_eq!(c.updates.get(), 1);
        assert_eq!(c.update_batches.get(), 1);
        assert!(pending.is_empty());
    }

    #[test]
    fn flush_without_resident_replies_sentinel() {
        let g = clique_ring(3, 4);
        let (mut w, _) = reference_worker(&g, 4, 8, 3);
        let (tx, rx) = update_oneshot();
        let mut pending = vec![UpdateRequest {
            delta: GraphDelta::NodeAdd,
            reply: Some(tx),
            submitted: Instant::now(),
        }];
        let mut c = Counters::default();
        w.flush_updates(&mut None, &mut pending, &mut c);
        let resp = rx.recv().unwrap();
        assert_eq!(resp.seq, 0, "no-resident sentinel");
        assert_eq!(resp.outcome, ApplyOutcome::NoOp);
    }

    #[test]
    fn flush_fire_and_forget_does_not_block() {
        let g = clique_ring(3, 4);
        let mut sess = Session::from_graph(&g, LowerSpec::default());
        let (hag, _) = sess.plan();
        let mut resident = Some(Resident::new(sess, &g, &hag,
                                              SwapPolicy::default()));
        let (mut w, _) = reference_worker(&g, 4, 8, 3);
        let u = g.neighbors(0)[0];
        let mut pending = vec![UpdateRequest {
            delta: GraphDelta::EdgeDelete { src: u, dst: 0 },
            reply: None,
            submitted: Instant::now(),
        }];
        let mut c = Counters::default();
        w.flush_updates(&mut resident, &mut pending, &mut c);
        assert_eq!(resident.as_ref().unwrap().engine.e(), g.e() - 1);
    }

    #[test]
    fn hostile_requests_rejected_and_worker_survives() {
        let g = clique_ring(4, 5);
        let (mut w, _) = reference_worker(&g, 4, 8, 3);
        let n = g.n();
        let (tx, rx) = sync_channel::<ServerMsg>(16);
        let (r1, rx1) = score(n as u32 + 100, vec![]);
        let (r2, rx2) = score(0, vec![1.0; 3]); // f_in is 4
        let (r3, rx3) = score(1, vec![0.5; 4]); // valid
        tx.send(ServerMsg::Score(r1)).unwrap();
        tx.send(ServerMsg::Score(r2)).unwrap();
        tx.send(ServerMsg::Score(r3)).unwrap();
        drop(tx);
        let out = w.batcher_loop(rx, BatchPolicy::default(), None);
        match rx1.recv().unwrap() {
            ScoreResponse::Err(e) => assert_eq!(
                e.reject,
                ScoreReject::NodeOutOfRange { node: n as u32 + 100, n }),
            r => panic!("expected rejection, got {r:?}"),
        }
        match rx2.recv().unwrap() {
            ScoreResponse::Err(e) => assert_eq!(
                e.reject, ScoreReject::FeatureLen { got: 3, want: 4 }),
            r => panic!("expected rejection, got {r:?}"),
        }
        let ok = rx3.recv().unwrap().into_result()
            .expect("valid request scored after rejects");
        assert_eq!(ok.logits.len(), 3);
        assert!(ok.logits.iter().all(|x| x.is_finite()));
        assert_eq!(out.stats.rejected, 2);
        assert_eq!(out.stats.requests, 1);
        assert_eq!(out.stats.exec_failures, 0);
    }

    #[test]
    fn exec_failure_replies_error_and_keeps_worker_alive() {
        let g = clique_ring(3, 4);
        let (mut w, _) = reference_worker(&g, 4, 8, 3);
        w.backend = Backend::Broken;
        let (tx, rx) = sync_channel::<ServerMsg>(16);
        let (r1, rx1) = score(0, vec![0.1; 4]);
        let (r2, rx2) = score(1, vec![0.2; 4]);
        tx.send(ServerMsg::Score(r1)).unwrap();
        tx.send(ServerMsg::Score(r2)).unwrap();
        drop(tx);
        // max_batch 1 => two batches => two independent failures, and
        // the second proves the worker survived the first
        let out = w.batcher_loop(
            rx,
            BatchPolicy { max_batch: 1, ..BatchPolicy::default() },
            None);
        for r in [rx1.recv().unwrap(), rx2.recv().unwrap()] {
            match r {
                ScoreResponse::Err(e) => assert!(matches!(
                    e.reject, ScoreReject::ExecFailed { .. })),
                r => panic!("expected ExecFailed, got {r:?}"),
            }
        }
        assert_eq!(out.stats.exec_failures, 2);
        assert_eq!(out.stats.failed, 2);
        assert_eq!(out.stats.requests, 0);
    }

    #[test]
    fn exec_failure_writes_flight_record_with_batch_span() {
        // Serialize against other tests that redirect the global
        // flight-dump dir.
        let _guard = crate::obs::flight::test_lock();
        let dir = std::env::temp_dir().join(format!(
            "repro-serve-flight-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        crate::obs::flight::set_dir(&dir);
        crate::obs::trace::set_enabled(true);
        let g = clique_ring(3, 4);
        let (mut w, _) = reference_worker(&g, 4, 8, 3);
        w.backend = Backend::Broken;
        let (tx, rx) = sync_channel::<ServerMsg>(16);
        let (r1, rx1) = score(0, vec![0.1; 4]);
        tx.send(ServerMsg::Score(r1)).unwrap();
        drop(tx);
        let out = w.batcher_loop(rx, BatchPolicy::default(), None);
        assert!(matches!(rx1.recv().unwrap(), ScoreResponse::Err(_)));
        assert_eq!(out.stats.exec_failures, 1);
        // The dump must carry the failing batch's span and the
        // registry state at failure time.
        let mut found = false;
        for entry in std::fs::read_dir(&dir).unwrap() {
            let p = entry.unwrap().path();
            let name = p.file_name().unwrap()
                .to_string_lossy().into_owned();
            if !name.contains("batch-exec-failed")
                || !name.ends_with(".json")
            {
                continue;
            }
            let v = crate::util::json::parse(
                &std::fs::read_to_string(&p).unwrap()).unwrap();
            assert_eq!(v.req_str("reason").unwrap(),
                       "batch-exec-failed");
            let snap = v.req("snapshot").unwrap();
            assert_eq!(snap.req("derived").unwrap()
                           .req_f64("serve.exec_failures").unwrap(),
                       1.0);
            if v.req_arr("trace").unwrap().iter().any(|e| {
                e.req_str("name").unwrap() == "serve.batch"
            }) {
                found = true;
            }
        }
        assert!(found, "flight record carries the failing batch span");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stats_message_returns_live_snapshot() {
        let g = clique_ring(4, 5);
        let (mut w, _) = reference_worker(&g, 4, 8, 3);
        let (tx, rx) = sync_channel::<ServerMsg>(16);
        let handle = std::thread::spawn(move || {
            w.batcher_loop(rx, BatchPolicy::default(), None)
        });
        let (r1, rx1) = score(1, vec![0.5; 4]);
        tx.send(ServerMsg::Score(r1)).unwrap();
        // Counters increment before the reply is sent, so once the
        // score came back the next snapshot must count it.
        assert!(rx1.recv().unwrap().is_ok());
        let (stx, srx) = stats_oneshot();
        tx.send(ServerMsg::Stats(StatsRequest { reply: stx })).unwrap();
        let snap = srx.recv().expect("stats answered while serving");
        drop(tx);
        let out = handle.join().unwrap();
        assert_eq!(snap.counter("serve.requests"), 1);
        assert_eq!(snap.counter("serve.batches"), 1);
        let lat = snap.hist("serve.latency").expect("latency hist");
        assert_eq!(lat.count, 1);
        assert_eq!(out.stats.requests, 1);
    }

    #[test]
    fn reference_aggregate_matches_graph_sums() {
        let g = clique_ring(3, 5);
        let (w, _) = reference_worker(&g, 1, 4, 2);
        let p = &w.plan;
        // h[new] = old id of that row, one feature column
        let mut h = vec![0f32; p.n_pad];
        for new in 0..p.n {
            h[new] = p.perm[new] as f32;
        }
        let a = reference_aggregate(p, &h, 1);
        for (v, ns) in g.iter() {
            let want: f32 = ns.iter().map(|&u| u as f32).sum();
            let got = a[p.inv_perm[v as usize] as usize];
            assert!((got - want).abs() < 1e-4,
                    "node {v}: {got} vs {want}");
        }
    }

    #[test]
    fn reference_batches_feed_the_cost_model() {
        let g = clique_ring(4, 5);
        let (w, mut s) = reference_worker(&g, 4, 8, 3);
        let c = Counters::default();
        for _ in 0..12 {
            w.run_batch(&c).unwrap();
        }
        // one sample per batch; a fixed plan yields collinear
        // samples, so the fit lands on the shared-rate fallback
        assert_eq!(c.cost.samples(), 12);
        let (alpha, beta) = c.cost.alpha_beta();
        assert!(alpha > 0.0 && alpha == beta,
                "collinear fallback: α̂={alpha} β̂={beta}");
        let (combine, scatter) = plan_op_counts(&w.plan);
        let width = (w.f_in + w.hidden) as u64;
        assert_eq!(c.meas_aggs.get(),
                   12 * (combine + scatter) * width);
        assert_eq!(c.meas_transfers.get(),
                   12 * (2 * combine + scatter) * width);

        // attribution + calibration land in one snapshot
        let (hag, _) = s.plan();
        obs::cost::record_plan_terms(&c.registry, &hag,
                                     s.shard_terms());
        c.cost.publish(&c.registry);
        let snap = c.registry.snapshot();
        assert_eq!(snap.gauge("cost.pred_aggregations"),
                   hag.aggregations() as i64);
        assert_eq!(snap.gauge("cost.pred_transfers"),
                   hag.data_transfers() as i64);
        assert_eq!(snap.gauge("cost.samples"), 12);
        assert_eq!(snap.gauge("cost.calibrated"), 1);
        assert!(snap.gauge("cost.alpha") > 0);
        // executed rows strictly exceed the padding-free prediction
        assert!(combine + scatter >= hag.aggregations() as u64);
    }

    #[test]
    fn repermute_h0_moves_rows_and_zeroes_new_nodes() {
        let g = clique_ring(3, 4);
        let mut s = Session::from_graph(&g, LowerSpec::default());
        let (_, old) = s.plan();
        assert!(s.apply(GraphDelta::NodeAdd));
        let v = (s.n() - 1) as u32;
        assert!(s.apply(GraphDelta::EdgeInsert { src: 0, dst: v }));
        let (_, new) = s.plan();
        let f = 2usize;
        let mut h0 = vec![0f32; old.n_pad * f];
        for vv in 0..old.n {
            let row = old.inv_perm[vv] as usize;
            h0[row * f] = vv as f32 + 1.0;
        }
        let out = repermute_h0(&old, &new, &h0, f);
        assert_eq!(out.len(), new.n_pad * f);
        for vv in 0..old.n {
            let row = new.inv_perm[vv] as usize;
            assert_eq!(out[row * f], vv as f32 + 1.0);
        }
        let row = new.inv_perm[v as usize] as usize;
        assert_eq!(out[row * f], 0.0, "added node starts zeroed");
    }
}
