//! L3 coordinator: ties search -> plan -> runtime into training and
//! serving workflows, and emits bucket specs for the AOT build.

pub mod packing;
pub mod server;
pub mod trainer;

pub use packing::{pack_workload, unpermute_rows, PackedWorkload};
pub use server::{BatchPolicy, InferenceServer, ScoreRequest,
                 ScoreResponse, ServeStats, ServerMsg, UpdateRequest,
                 UpdateResponse};
pub use trainer::{EpochStats, TrainReport, Trainer};

use anyhow::Result;

use crate::datasets::{Dataset, Task};
use crate::graph::Graph;
use crate::hag::{build_plan, hag_search, AggregateKind, ExecutionPlan,
                 Hag, PlanConfig, SearchConfig};
use crate::runtime::BucketSpec;

/// Which graph representation a workload runs under (the paper's
/// central comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Repr {
    /// Standard GNN-graph (no aggregation hierarchy) — the baseline.
    GnnGraph,
    /// Optimized HAG from Algorithm 3.
    Hag,
}

impl Repr {
    pub fn tag(self) -> &'static str {
        match self {
            Repr::GnnGraph => "gnn",
            Repr::Hag => "hag",
        }
    }
}

/// A dataset lowered under one representation: the HAG (trivial for the
/// baseline), its plan, and the bucket the artifact must be built for.
pub struct Lowered {
    pub repr: Repr,
    pub hag: Hag,
    pub plan: ExecutionPlan,
    pub bucket: BucketSpec,
}

/// Hidden dim used across the paper's eval (§5.3: 16 hidden dims).
pub const HIDDEN: usize = 16;

/// Search + lower `ds` under `repr`. Deterministic in the dataset (the
/// search takes no RNG; the sharded path uses the fixed
/// [`DEFAULT_PARTITION_SEED`](crate::partition::DEFAULT_PARTITION_SEED)).
///
/// `shards: Some(k)` with `k >= 2` routes the HAG search through the
/// partitioned parallel driver
/// ([`partition::search_sharded`](crate::partition::search_sharded)):
/// per-shard searches on a worker pool, cross-shard edges falling back
/// to direct aggregation. `None` / `Some(1)` is the single-threaded
/// whole-graph search.
pub fn lower_dataset(ds: &Dataset, repr: Repr, capacity: Option<usize>,
                     shards: Option<usize>,
                     plan_cfg: &PlanConfig) -> Result<Lowered> {
    let hag = match repr {
        Repr::GnnGraph => Hag::from_graph(&ds.graph, AggregateKind::Set),
        Repr::Hag => {
            let cfg = SearchConfig::paper_default(ds.graph.n())
                .with_capacity(capacity
                    .unwrap_or(ds.graph.n() / 4));
            match shards {
                Some(k) if k >= 2 => {
                    crate::partition::search_sharded(&ds.graph, k, &cfg).0
                }
                _ => hag_search(&ds.graph, &cfg).0,
            }
        }
    };
    let plan = build_plan(&ds.graph, &hag, plan_cfg);
    let bucket = bucket_for(ds, &plan, repr);
    Ok(Lowered { repr, hag, plan, bucket })
}

/// Bucket spec for a lowered dataset (name convention:
/// `<dataset>_<repr>`; aot.py compiles `gcn_{train,infer}_<name>`).
pub fn bucket_for(ds: &Dataset, plan: &ExecutionPlan,
                  repr: Repr) -> BucketSpec {
    let g_pad = match ds.task {
        Task::NodeClassification => 0,
        Task::GraphClassification => {
            (ds.num_graphs + 1).next_multiple_of(16)
        }
    };
    BucketSpec {
        name: format!("{}_{}", ds.name.to_lowercase(), repr.tag()),
        n_pad: plan.n_pad,
        f_in: ds.f_in,
        hidden: HIDDEN,
        classes: ds.classes,
        levels: plan.levels,
        l_pad: plan.l_pad,
        bands: plan.bands.clone(),
        br: plan.br,
        lvl_block: plan.lvl_block,
        g_pad,
        // "mxu" = the Pallas block-CSR path, whose cost is proportional
        // to operand reads — the same cost model as the paper's GPU
        // backend (and a real TPU), so the Fig 2 comparison measures
        // what the paper measured. The "scatter" engine is ~5x faster
        // in absolute terms on this CPU testbed but padded-slot-bound;
        // both are measured in EXPERIMENTS.md §Perf.
        impl_: "mxu".into(),
    }
}

/// Artifact name for a lowered dataset.
pub fn artifact_name(model: &str, kind: &str, bucket: &BucketSpec)
                     -> String {
    format!("{model}_{kind}_{}", bucket.name)
}

/// Emit `artifacts/buckets.json` for a set of datasets (both
/// representations each) — phase 1 of the two-phase AOT build.
/// `shards` must match the value later passed to `lower_dataset` at
/// train/infer time, or the plan will not fit the compiled bucket.
pub fn emit_buckets(datasets: &[Dataset], shards: Option<usize>,
                    plan_cfg: &PlanConfig,
                    out: &std::path::Path) -> Result<Vec<BucketSpec>> {
    let mut buckets = Vec::new();
    for ds in datasets {
        for repr in [Repr::GnnGraph, Repr::Hag] {
            let lowered = lower_dataset(ds, repr, None, shards,
                                        plan_cfg)?;
            buckets.push(lowered.bucket);
        }
    }
    write_buckets_json(&buckets, out)?;
    Ok(buckets)
}

/// Serialize bucket specs as the `buckets.json` document aot.py reads.
pub fn write_buckets_json(buckets: &[BucketSpec],
                          out: &std::path::Path) -> Result<()> {
    use crate::util::json;
    let doc = json::obj(vec![(
        "buckets",
        json::Value::Arr(buckets.iter().map(|b| b.to_json()).collect()),
    )]);
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(out, doc.to_string_pretty())?;
    Ok(())
}

/// Baseline comparator used by ablation benches: merge random
/// co-aggregated pairs instead of max-redundancy ones (validates that
/// the greedy heap choice matters).
pub fn random_merge_hag(g: &Graph, capacity: usize, seed: u64) -> Hag {
    let mut rng = crate::util::Rng::seed_from_u64(seed);
    let mut hag = Hag::from_graph(g, AggregateKind::Set);
    let mut made = 0usize;
    'outer: while made < capacity {
        // pick a random node with >= 2 in-edges, merge a random pair of
        // its in-slots across all co-consumers
        let candidates: Vec<usize> = (0..hag.n)
            .filter(|&v| hag.in_edges[v].len() >= 2)
            .collect();
        if candidates.is_empty() {
            break;
        }
        for _ in 0..16 {
            let &v = rng.choose(&candidates).unwrap();
            let list = &hag.in_edges[v];
            let mut pair: Vec<crate::hag::Slot> = list.clone();
            rng.shuffle(&mut pair);
            let (a, b) = (pair[0], pair[1]);
            // find all consumers of both
            let users: Vec<usize> = (0..hag.n)
                .filter(|&u| hag.in_edges[u].contains(&a)
                        && hag.in_edges[u].contains(&b))
                .collect();
            if users.len() < 2 {
                continue;
            }
            let w = hag.slots() as u32;
            hag.agg_nodes.push(crate::hag::AggNode { left: a, right: b });
            for u in users {
                hag.in_edges[u].retain(|&s| s != a && s != b);
                hag.in_edges[u].push(w);
            }
            made += 1;
            continue 'outer;
        }
        break; // no merge found in 16 tries
    }
    hag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::hag::check_equivalence;

    #[test]
    fn lower_both_reprs() {
        let ds = datasets::load("BZR", 0.02, 3);
        let cfg = PlanConfig::default();
        let base = lower_dataset(&ds, Repr::GnnGraph, None, None, &cfg)
            .unwrap();
        let hag = lower_dataset(&ds, Repr::Hag, None, None, &cfg)
            .unwrap();
        assert_eq!(base.plan.levels, 0);
        check_equivalence(&ds.graph, &hag.hag).unwrap();
        assert!(hag.hag.aggregations() <= base.hag.aggregations());
        assert_eq!(base.bucket.name, "bzr_gnn");
        assert_eq!(hag.bucket.name, "bzr_hag");
        assert!(base.bucket.fits(&base.plan));
        assert!(hag.bucket.fits(&hag.plan));
    }

    #[test]
    fn lower_sharded_repr_is_equivalent() {
        let ds = datasets::load("BZR", 0.02, 3);
        let cfg = PlanConfig::default();
        let sharded =
            lower_dataset(&ds, Repr::Hag, None, Some(4), &cfg).unwrap();
        sharded.hag.validate().unwrap();
        check_equivalence(&ds.graph, &sharded.hag).unwrap();
        // sharding can only miss merges, never add aggregations
        assert!(sharded.hag.cost_core() <= ds.graph.e());
        assert!(sharded.bucket.fits(&sharded.plan));
        // Some(1) and None take the identical single-shard path
        let one = lower_dataset(&ds, Repr::Hag, None, Some(1), &cfg)
            .unwrap();
        let none = lower_dataset(&ds, Repr::Hag, None, None, &cfg)
            .unwrap();
        assert_eq!(one.hag.agg_nodes, none.hag.agg_nodes);
    }

    #[test]
    fn random_merge_is_equivalent_but_weaker() {
        let ds = datasets::load("BZR", 0.01, 5);
        let rnd = random_merge_hag(&ds.graph, 50, 7);
        check_equivalence(&ds.graph, &rnd).unwrap();
        // same merge budget for a fair comparison
        let cfg = SearchConfig::paper_default(ds.graph.n())
            .with_capacity(50);
        let (greedy, _) = hag_search(&ds.graph, &cfg);
        assert!(greedy.cost_core() <= rnd.cost_core(),
                "greedy {} vs random {}", greedy.cost_core(),
                rnd.cost_core());
    }

    #[test]
    fn emit_buckets_writes_json() {
        let dir = std::env::temp_dir().join("repro_buckets_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("buckets.json");
        let ds = datasets::load("BZR", 0.01, 3);
        let buckets =
            emit_buckets(&[ds], None, &PlanConfig::default(), &path)
                .unwrap();
        assert_eq!(buckets.len(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.req_arr("buckets").unwrap().len(), 2);
        // aot.py-side parse: every bucket must round-trip
        for b in v.req_arr("buckets").unwrap() {
            crate::runtime::BucketSpec::from_json(b).unwrap();
        }
    }
}
