//! L3 coordinator: ties search -> plan -> runtime into training and
//! serving workflows, and emits bucket specs for the AOT build.
//!
//! Lowering entry points live in [`crate::session`] (a `LowerSpec` +
//! `Session` own the search/plan/bucket pipeline and its per-shard
//! plan cache); the `lower_dataset` / `emit_buckets` functions here
//! are deprecated one-shot wrappers kept for external callers
//! mid-migration. This module keeps the runtime-facing pieces: data
//! packing, the trainer, the inference server, and the bucket/artifact
//! naming contract.

pub mod packing;
pub mod server;
pub mod trainer;

pub use packing::{pack_workload, plan_tensors, unpermute_rows,
                  PackedWorkload};
pub use server::{BatchPolicy, InferenceServer, Resident, ScoreError,
                 ScoreOk, ScoreReject, ScoreRequest, ScoreResponse,
                 ServeOutcome, ServeStats, ServerMsg, StatsRequest,
                 SwapPolicy, UpdateRequest, UpdateResponse};
pub use trainer::{EpochStats, TrainReport, Trainer};

use anyhow::Result;

use crate::datasets::{Dataset, Task};
use crate::graph::Graph;
use crate::hag::{AggregateKind, ExecutionPlan, Hag, PlanConfig};
use crate::runtime::BucketSpec;

/// Which graph representation a workload runs under (the paper's
/// central comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Repr {
    /// Standard GNN-graph (no aggregation hierarchy) — the baseline.
    GnnGraph,
    /// Optimized HAG from Algorithm 3.
    Hag,
}

impl Repr {
    pub fn tag(self) -> &'static str {
        match self {
            Repr::GnnGraph => "gnn",
            Repr::Hag => "hag",
        }
    }
}

/// A dataset lowered under one representation: the HAG (trivial for the
/// baseline), its plan, and the bucket the artifact must be built for.
pub struct Lowered {
    pub repr: Repr,
    pub hag: Hag,
    pub plan: ExecutionPlan,
    pub bucket: BucketSpec,
}

/// Hidden dim used across the paper's eval (§5.3: 16 hidden dims).
pub const HIDDEN: usize = 16;

/// Search + lower `ds` under `repr`.
///
/// Deprecated positional-knob entry point: the five knobs here are a
/// strict subset of [`LowerSpec`](crate::session::LowerSpec), and this
/// wrapper simply builds the equivalent spec and runs a one-shot
/// [`Session`](crate::session::Session). Migrate to
/// `Session::new(ds, spec).lower()` — a session also caches per-shard
/// searches across re-plans, which this wrapper throws away.
#[deprecated(since = "0.1.0",
             note = "use session::Session::new(ds, spec).lower(); \
                     this wrapper re-searches from scratch every call")]
pub fn lower_dataset(ds: &Dataset, repr: Repr, capacity: Option<usize>,
                     shards: Option<usize>,
                     plan_cfg: &PlanConfig) -> Result<Lowered> {
    let mut spec = crate::session::LowerSpec::default()
        .with_repr(repr)
        .with_shards(shards.unwrap_or(1))
        .with_plan(plan_cfg.clone());
    if let Some(c) = capacity {
        spec = spec.with_capacity(c);
    }
    crate::session::Session::new(ds, spec).lower()
}

/// Bucket spec for a lowered dataset (name convention:
/// `<dataset>_<repr>`; aot.py compiles `gcn_{train,infer}_<name>`).
pub fn bucket_for(ds: &Dataset, plan: &ExecutionPlan,
                  repr: Repr) -> BucketSpec {
    bucket_for_parts(&ds.name, ds.f_in, ds.classes, ds.task,
                     ds.num_graphs, plan, repr)
}

/// [`bucket_for`] over the dataset fields it actually reads — the
/// session subsystem keeps these (not the whole feature matrix) as its
/// dataset metadata.
pub fn bucket_for_parts(name: &str, f_in: usize, classes: usize,
                        task: Task, num_graphs: usize,
                        plan: &ExecutionPlan, repr: Repr) -> BucketSpec {
    let g_pad = match task {
        Task::NodeClassification => 0,
        Task::GraphClassification => {
            (num_graphs + 1).next_multiple_of(16)
        }
    };
    BucketSpec {
        name: format!("{}_{}", name.to_lowercase(), repr.tag()),
        n_pad: plan.n_pad,
        f_in,
        hidden: HIDDEN,
        classes,
        levels: plan.levels,
        l_pad: plan.l_pad,
        bands: plan.bands.clone(),
        br: plan.br,
        lvl_block: plan.lvl_block,
        g_pad,
        // "mxu" = the Pallas block-CSR path, whose cost is proportional
        // to operand reads — the same cost model as the paper's GPU
        // backend (and a real TPU), so the Fig 2 comparison measures
        // what the paper measured. The "scatter" engine is ~5x faster
        // in absolute terms on this CPU testbed but padded-slot-bound;
        // both are measured in EXPERIMENTS.md §Perf.
        impl_: "mxu".into(),
    }
}

/// Artifact name for a lowered dataset.
pub fn artifact_name(model: &str, kind: &str, bucket: &BucketSpec)
                     -> String {
    format!("{model}_{kind}_{}", bucket.name)
}

/// Emit `artifacts/buckets.json` for a set of datasets (both
/// representations each) — phase 1 of the two-phase AOT build.
///
/// Deprecated: this wrapper cannot express a capacity, so it pins the
/// default — the historical foot-gun where a bucket emitted here could
/// disagree with a capacity-bearing plan trained against it. Migrate
/// to [`session::emit_buckets`](crate::session::emit_buckets), whose
/// [`LowerSpec`](crate::session::LowerSpec) carries capacity (and
/// every other knob) end-to-end.
#[deprecated(since = "0.1.0",
             note = "use session::emit_buckets(datasets, &spec, out); \
                     this wrapper cannot carry a capacity")]
pub fn emit_buckets(datasets: &[Dataset], shards: Option<usize>,
                    plan_cfg: &PlanConfig,
                    out: &std::path::Path) -> Result<Vec<BucketSpec>> {
    let spec = crate::session::LowerSpec::default()
        .with_shards(shards.unwrap_or(1))
        .with_plan(plan_cfg.clone());
    crate::session::emit_buckets(datasets, &spec, out)
}

/// Serialize bucket specs as the `buckets.json` document aot.py reads.
pub fn write_buckets_json(buckets: &[BucketSpec],
                          out: &std::path::Path) -> Result<()> {
    use crate::util::json;
    let doc = json::obj(vec![(
        "buckets",
        json::Value::Arr(buckets.iter().map(|b| b.to_json()).collect()),
    )]);
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    crate::util::atomic_write(out, doc.to_string_pretty().as_bytes())?;
    Ok(())
}

/// Baseline comparator used by ablation benches: merge random
/// co-aggregated pairs instead of max-redundancy ones (validates that
/// the greedy heap choice matters).
pub fn random_merge_hag(g: &Graph, capacity: usize, seed: u64) -> Hag {
    let mut rng = crate::util::Rng::seed_from_u64(seed);
    let mut hag = Hag::from_graph(g, AggregateKind::Set);
    let mut made = 0usize;
    'outer: while made < capacity {
        // pick a random node with >= 2 in-edges, merge a random pair of
        // its in-slots across all co-consumers
        let candidates: Vec<usize> = (0..hag.n)
            .filter(|&v| hag.in_edges[v].len() >= 2)
            .collect();
        if candidates.is_empty() {
            break;
        }
        for _ in 0..16 {
            let &v = rng.choose(&candidates).unwrap();
            let list = &hag.in_edges[v];
            let mut pair: Vec<crate::hag::Slot> = list.clone();
            rng.shuffle(&mut pair);
            let (a, b) = (pair[0], pair[1]);
            // find all consumers of both
            let users: Vec<usize> = (0..hag.n)
                .filter(|&u| hag.in_edges[u].contains(&a)
                        && hag.in_edges[u].contains(&b))
                .collect();
            if users.len() < 2 {
                continue;
            }
            let w = hag.slots() as u32;
            hag.agg_nodes.push(crate::hag::AggNode { left: a, right: b });
            for u in users {
                hag.in_edges[u].retain(|&s| s != a && s != b);
                hag.in_edges[u].push(w);
            }
            made += 1;
            continue 'outer;
        }
        break; // no merge found in 16 tries
    }
    hag
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::hag::{check_equivalence, hag_search, SearchConfig};
    use crate::session::{LowerSpec, Session};

    #[test]
    fn lower_both_reprs() {
        let ds = datasets::load("BZR", 0.02, 3);
        let base = Session::new(&ds, LowerSpec::default()
            .with_repr(Repr::GnnGraph)).lower().unwrap();
        let hag = Session::new(&ds, LowerSpec::default())
            .lower().unwrap();
        assert_eq!(base.plan.levels, 0);
        check_equivalence(&ds.graph, &hag.hag).unwrap();
        assert!(hag.hag.aggregations() <= base.hag.aggregations());
        assert_eq!(base.bucket.name, "bzr_gnn");
        assert_eq!(hag.bucket.name, "bzr_hag");
        assert!(base.bucket.fits(&base.plan));
        assert!(hag.bucket.fits(&hag.plan));
    }

    /// The deprecated wrappers must delegate exactly (they exist only
    /// for external callers mid-migration; `-D deprecated` CI keeps
    /// them out of this crate).
    #[test]
    #[allow(deprecated)]
    fn deprecated_wrappers_delegate_to_sessions() {
        let ds = datasets::load("BZR", 0.02, 3);
        let cfg = PlanConfig::default();
        let old = lower_dataset(&ds, Repr::Hag, None, Some(4), &cfg)
            .unwrap();
        let new = Session::new(&ds, LowerSpec::default()
            .with_shards(4)).lower().unwrap();
        assert_eq!(old.hag, new.hag);
        assert_eq!(old.plan, new.plan);
        assert_eq!(old.bucket.name, new.bucket.name);
        assert!(old.bucket.fits(&new.plan));
    }

    #[test]
    fn lower_sharded_repr_is_equivalent() {
        let ds = datasets::load("BZR", 0.02, 3);
        let sharded = Session::new(&ds, LowerSpec::default()
            .with_shards(4)).lower().unwrap();
        sharded.hag.validate().unwrap();
        check_equivalence(&ds.graph, &sharded.hag).unwrap();
        // sharding can only miss merges, never add aggregations
        assert!(sharded.hag.cost_core() <= ds.graph.e());
        assert!(sharded.bucket.fits(&sharded.plan));
        // shards = 1 and the (clamped) 0 take the identical
        // single-shard path
        let one = Session::new(&ds, LowerSpec::default()
            .with_shards(1)).lower().unwrap();
        let zero = Session::new(&ds, LowerSpec::default()
            .with_shards(0)).lower().unwrap();
        assert_eq!(one.hag.agg_nodes, zero.hag.agg_nodes);
    }

    #[test]
    fn random_merge_is_equivalent_but_weaker() {
        let ds = datasets::load("BZR", 0.01, 5);
        let rnd = random_merge_hag(&ds.graph, 50, 7);
        check_equivalence(&ds.graph, &rnd).unwrap();
        // same merge budget for a fair comparison
        let cfg = SearchConfig::paper_default(ds.graph.n())
            .with_capacity(50);
        let (greedy, _) = hag_search(&ds.graph, &cfg);
        assert!(greedy.cost_core() <= rnd.cost_core(),
                "greedy {} vs random {}", greedy.cost_core(),
                rnd.cost_core());
    }

    #[test]
    fn emit_buckets_writes_json() {
        let dir = std::env::temp_dir().join("repro_buckets_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("buckets.json");
        let ds = datasets::load("BZR", 0.01, 3);
        let buckets = crate::session::emit_buckets(
            &[ds], &LowerSpec::default(), &path).unwrap();
        assert_eq!(buckets.len(), 2);
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.req_arr("buckets").unwrap().len(), 2);
        // aot.py-side parse: every bucket must round-trip
        for b in v.req_arr("buckets").unwrap() {
            crate::runtime::BucketSpec::from_json(b).unwrap();
        }
    }
}
