//! Training coordinator: drives AOT-compiled train-step executables.
//!
//! The entire optimization step (fwd + bwd + Adam) is one XLA program;
//! rust owns the epoch loop, parameter state, metrics and logging. Plan
//! and data tensors are uploaded to device **once**; only parameters and
//! optimizer state round-trip per step (they are the step outputs).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::util::Rng;

use crate::runtime::xla;
use crate::runtime::{Executable, HostTensor, Runtime, TensorSpec};

use super::packing::PackedWorkload;

/// Per-epoch record for the loss curve / throughput reporting.
#[derive(Debug, Clone)]
pub struct EpochStats {
    pub epoch: usize,
    pub loss: f32,
    pub accuracy: f32,
    pub wall_ms: f64,
}

/// Training run summary.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub artifact: String,
    pub epochs: Vec<EpochStats>,
    pub total_s: f64,
    pub mean_epoch_ms: f64,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.epochs.last().map(|e| e.loss).unwrap_or(f32::NAN)
    }

    pub fn final_accuracy(&self) -> f32 {
        self.epochs.last().map(|e| e.accuracy).unwrap_or(f32::NAN)
    }
}

/// How artifact inputs split into sections (by manifest naming
/// convention; see aot.py `build_entry`).
fn is_param(s: &TensorSpec) -> bool {
    !s.name.starts_with("m_")
        && !s.name.starts_with("v_")
        && s.name != "opt_step"
        && !is_data_or_plan(s)
}

fn is_data_or_plan(s: &TensorSpec) -> bool {
    matches!(s.name.as_str(),
             "h0" | "deg" | "labels" | "mask" | "graph_seg"
             | "graph_sizes" | "graph_labels" | "graph_mask")
        || s.name.starts_with("lvl_")
        || s.name.starts_with("band")
}

/// Glorot-ish param init matching `model.init_gcn_params` /
/// `init_sage_params` statistics (exact values differ; training
/// dynamics, not bit-equality, is the contract here).
pub fn init_params(specs: &[TensorSpec], seed: u64) -> Vec<HostTensor> {
    let mut rng = Rng::seed_from_u64(seed);
    specs
        .iter()
        .map(|s| {
            let n = s.elements();
            let data = if s.shape.len() == 2 {
                let scale =
                    (2.0 / (s.shape[0] + s.shape[1]) as f32).sqrt();
                (0..n).map(|_| rng.normal_f32() * scale).collect()
            } else {
                vec![0f32; n] // biases
            };
            HostTensor::f32(data, &s.shape)
        })
        .collect()
}

/// Trainer over one artifact + one packed workload.
pub struct Trainer {
    runtime: Arc<Runtime>,
    exe: Arc<Executable>,
    /// Current parameters, artifact order.
    pub params: Vec<HostTensor>,
    /// Optimizer state (m.., v.., step), artifact order.
    opt: Vec<HostTensor>,
    /// Uploaded data + plan buffers, keyed by input index.
    static_bufs: Vec<(usize, xla::PjRtBuffer)>,
    n_params: usize,
}

impl Trainer {
    /// Trainer straight from a lowered session workload: derives the
    /// artifact name from the bucket and packs the dataset against the
    /// plan. `lowered` should come from
    /// [`Session::lower`](crate::session::Session::lower) on the same
    /// dataset.
    pub fn for_lowered(runtime: Arc<Runtime>, model: &str,
                       ds: &crate::datasets::Dataset,
                       lowered: &super::Lowered,
                       seed: u64) -> Result<Self> {
        let artifact =
            super::artifact_name(model, "train", &lowered.bucket);
        let workload = super::pack_workload(ds, &lowered.plan,
                                            &lowered.bucket)?;
        Trainer::new(runtime, &artifact, &workload, seed)
    }

    pub fn new(runtime: Arc<Runtime>, artifact: &str,
               workload: &PackedWorkload, seed: u64) -> Result<Self> {
        let exe = runtime.compile(artifact)?;
        let spec = &exe.spec;
        if spec.kind != "train" {
            bail!("{artifact} is not a train artifact");
        }
        let param_specs: Vec<TensorSpec> = spec.inputs.iter()
            .filter(|s| is_param(s)).cloned().collect();
        let n_params = param_specs.len();
        let params = init_params(&param_specs, seed);
        // optimizer state: zeros of each param + step counter
        let mut opt: Vec<HostTensor> = Vec::new();
        for s in spec.inputs.iter().filter(|s| s.name.starts_with("m_")
            || s.name.starts_with("v_")) {
            opt.push(HostTensor::f32(vec![0.0; s.elements()], &s.shape));
        }
        opt.push(HostTensor::scalar_i32(0));

        // upload static (data + plan) buffers once
        let mut static_bufs = Vec::new();
        for (i, s) in spec.inputs.iter().enumerate() {
            if is_data_or_plan(s) {
                let t = workload.get(&s.name).ok_or_else(|| {
                    anyhow!("workload missing tensor {:?} needed by {}",
                          s.name, artifact)
                })?;
                if t.shape() != s.shape.as_slice() {
                    bail!("tensor {:?}: workload shape {:?} != \
                           artifact shape {:?}",
                          s.name, t.shape(), s.shape);
                }
                static_bufs.push((i, runtime.upload(t)?));
            }
        }
        Ok(Trainer { runtime, exe, params, opt, static_bufs, n_params })
    }

    /// One optimization step (one full-batch epoch for GCN training).
    pub fn step(&mut self) -> Result<(f32, f32)> {
        let spec = &self.exe.spec;
        // Assemble args in artifact order.
        let mut dyn_bufs: Vec<(usize, xla::PjRtBuffer)> = Vec::new();
        {
            let mut pi = 0usize;
            let mut oi = 0usize;
            for (i, s) in spec.inputs.iter().enumerate() {
                if is_data_or_plan(s) {
                    continue;
                }
                let t = if is_param(s) {
                    let t = &self.params[pi];
                    pi += 1;
                    t
                } else {
                    let t = &self.opt[oi];
                    oi += 1;
                    t
                };
                dyn_bufs.push((i, self.runtime.upload(t)?));
            }
        }
        let mut slots: Vec<Option<&xla::PjRtBuffer>> =
            vec![None; spec.inputs.len()];
        for (i, b) in &self.static_bufs {
            slots[*i] = Some(b);
        }
        for (i, b) in &dyn_bufs {
            slots[*i] = Some(b);
        }
        let args: Vec<&xla::PjRtBuffer> = slots
            .into_iter()
            .enumerate()
            .map(|(i, o)| o.ok_or_else(|| {
                anyhow!("input {} ({}) unbound", i, spec.inputs[i].name)
            }))
            .collect::<Result<_>>()?;

        let outs = self.runtime.execute(&self.exe, &args)?;
        // outputs: new params, new m, new v, new step, loss, acc
        let n_out = outs.len();
        let loss = outs[n_out - 2].as_f32()?[0];
        let acc = outs[n_out - 1].as_f32()?[0];
        let mut it = outs.into_iter();
        self.params = (&mut it).take(self.n_params).collect();
        self.opt = it.take(2 * self.n_params + 1).collect();
        Ok((loss, acc))
    }

    /// Run `epochs` steps, collecting per-epoch stats.
    pub fn train(&mut self, epochs: usize,
                 log_every: usize) -> Result<TrainReport> {
        let t0 = Instant::now();
        let mut stats = Vec::with_capacity(epochs);
        for e in 0..epochs {
            let ts = Instant::now();
            let (loss, acc) = self.step()?;
            let wall_ms = ts.elapsed().as_secs_f64() * 1e3;
            if log_every > 0 && (e % log_every == 0 || e + 1 == epochs) {
                crate::obs_info!(
                    "[train {}] epoch {e:4}  loss {loss:.4}  \
                     acc {acc:.3}  {wall_ms:.1} ms",
                    self.exe.spec.name);
            }
            stats.push(EpochStats { epoch: e, loss, accuracy: acc,
                                    wall_ms });
        }
        let total_s = t0.elapsed().as_secs_f64();
        // steady-state epoch time: skip warmup epoch 0
        let tail: Vec<f64> =
            stats.iter().skip(1.min(stats.len() - 1))
                .map(|s| s.wall_ms).collect();
        let mean_epoch_ms = if tail.is_empty() {
            f64::NAN
        } else {
            tail.iter().sum::<f64>() / tail.len() as f64
        };
        Ok(TrainReport {
            artifact: self.exe.spec.name.clone(),
            epochs: stats,
            total_s,
            mean_epoch_ms,
        })
    }

    pub fn artifact_name(&self) -> &str {
        &self.exe.spec.name
    }
}
